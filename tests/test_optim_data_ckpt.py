"""Optimizer, compression, checkpoint: unit + integration."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (latest_step, load_checkpoint,
                              prune_checkpoints, restore, save_checkpoint)
from repro.optim import (AdamWConfig, CompressionState, adamw_init,
                         adamw_update, compress_error_feedback, global_norm,
                         warmup_cosine)


# ------------------------------------------------------------------ adamw
def test_adamw_converges_on_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=5, total_steps=200,
                      weight_decay=0.0, clip_norm=0.0)
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = adamw_init(params)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    for _ in range(200):
        g = jax.grad(loss)({"w": state["master"]["w"]})
        new_master, state, _ = adamw_update(g, state, cfg)
    assert float(loss({"w": state["master"]["w"]})) < 1e-2


def test_adamw_clipping_bounds_update():
    cfg = AdamWConfig(lr=1.0, clip_norm=1.0, warmup_steps=0, total_steps=10,
                      weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = adamw_init(params)
    g = {"w": jnp.full(4, 1e6)}
    new_master, state, m = adamw_update(g, state, cfg)
    assert float(m["grad_norm"]) > 1e5
    assert float(jnp.max(jnp.abs(new_master["w"]))) < 2.0  # clipped step


def test_bf16_moments_track_fp32():
    cfg = AdamWConfig(lr=0.01, warmup_steps=0, total_steps=100,
                      weight_decay=0.0)
    params = {"w": jnp.ones(16)}
    s32 = adamw_init(params, jnp.float32)
    s16 = adamw_init(params, jnp.bfloat16)
    assert s16["mu"]["w"].dtype == jnp.bfloat16
    for i in range(20):
        g = {"w": jnp.sin(jnp.arange(16.0) + i)}
        m32, s32, _ = adamw_update(g, s32, cfg)
        m16, s16, _ = adamw_update(g, s16, cfg)
    np.testing.assert_allclose(np.asarray(m32["w"]), np.asarray(m16["w"]),
                               atol=5e-3)


def test_warmup_cosine_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    sched = warmup_cosine(cfg)
    assert float(sched(0)) == 0.0
    assert float(sched(10)) == pytest.approx(1.0)
    assert float(sched(100)) == pytest.approx(0.1, abs=1e-6)
    assert float(sched(55)) < float(sched(20))


# ------------------------------------------------------ int8 compression
def test_compressed_psum_close_to_exact():
    n_dev = 4
    grads = {"w": jnp.asarray(np.random.default_rng(0).normal(
        size=(n_dev, 32)), jnp.float32)}
    state = CompressionState.init({"w": grads["w"][0]})
    states = jax.tree.map(lambda e: jnp.stack([e] * n_dev), state.error)

    def f(g, e):
        out, ns = compress_error_feedback(
            {"w": g}, CompressionState({"w": e}), "dp")
        return out["w"], ns.error["w"]

    out, errs = jax.vmap(f, axis_name="dp")(grads["w"], states["w"])
    exact = jnp.mean(grads["w"], axis=0)
    rel = float(jnp.max(jnp.abs(out[0] - exact))
                / jnp.max(jnp.abs(exact)))
    assert rel < 0.02
    # all shards agree exactly (same psum + same scale)
    for i in range(1, n_dev):
        np.testing.assert_array_equal(np.asarray(out[0]),
                                      np.asarray(out[i]))


def test_error_feedback_reduces_bias_over_steps():
    """With a constant gradient, error feedback makes the *time-averaged*
    compressed estimate converge to the true value."""
    g = {"w": jnp.asarray([0.001, 1.0, -0.3], jnp.float32)}
    state = CompressionState.init(g)
    acc = jnp.zeros(3)
    n = 50

    def f(gw, ew):
        out, ns = compress_error_feedback(
            {"w": gw}, CompressionState({"w": ew}), "dp")
        return out["w"], ns.error["w"]

    err = jnp.stack([state.error["w"]])
    gs = jnp.stack([g["w"]])
    for _ in range(n):
        out, err = jax.vmap(f, axis_name="dp")(gs, err)
        acc = acc + out[0]
    np.testing.assert_allclose(np.asarray(acc / n), np.asarray(g["w"]),
                               atol=1e-4)


# -------------------------------------------------------------- checkpoint
def _state():
    return {"params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
            "opt": {"step": jnp.int32(7)}}


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 10, _state(), extra={"arch": "x"})
    assert latest_step(d) == 10
    target = jax.eval_shape(_state)
    restored, meta = restore(d, target)
    assert meta["step"] == 10 and meta["extra"]["arch"] == "x"
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(_state()["params"]["w"]))
    assert int(restored["opt"]["step"]) == 7


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 1, _state())
    save_checkpoint(d, 2, _state())
    assert not [f for f in os.listdir(d) if f.startswith(".tmp")]
    prune_checkpoints(d, keep=1)
    assert latest_step(d) == 2
    assert len(os.listdir(d)) == 1


def test_checkpoint_shape_mismatch_raises(tmp_path):
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 1, _state())
    bad = {"params": {"w": jax.ShapeDtypeStruct((3, 3), jnp.float32)},
           "opt": {"step": jax.ShapeDtypeStruct((), jnp.int32)}}
    with pytest.raises(ValueError):
        restore(d, bad)
