"""Policy-driven serving front-end: admission policies (two-tenant DRF
fairness vs FCFS starvation), SamplingParams (temp-0 bitwise-greedy across
dense/paged, top-k/top-p membership, seeded determinism — wave mode
included now that it samples host-side), ServeConfig + legacy-kwargs
shim, RequestHandle lifecycle/streaming, run() stall reporting.  Engine
construction helpers live in tests/conftest.py (shared with the
preemption / paged-KV / spec-decode suites)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import cached_engine as _reused_engine
from conftest import make_engine as _engine
from conftest import tiny_lm as _model

from repro.configs import get_config
from repro.models import LM, RuntimeKnobs
from repro.runtime.sampling import SamplingParams, matches_stop, sample_tokens
from repro.runtime.scheduler import (ADMISSION_POLICIES, Scheduler,
                                     ServeResource, get_admission_policy)
from repro.runtime.serve import (Request, RequestState, ServeConfig,
                                 ServeEngine, ServeStalled)


# ----------------------------------------------------- policy unit behavior
def _req(i, plen=2, max_new=4, **kw):
    return Request(i, np.arange(1, plen + 1, dtype=np.int32),
                   max_new_tokens=max_new, **kw)


def test_policy_registry_mirrors_core():
    assert set(ADMISSION_POLICIES) == {"fcfs", "priority", "sjf",
                                       "drf-fair"}
    for name in ADMISSION_POLICIES:
        assert get_admission_policy(name).name == name


def test_priority_policy_orders_by_priority_then_fifo():
    sched = Scheduler("priority", slots=1, max_len=32)
    for i, pr in enumerate([0, 2, 2, 1]):
        sched.submit(_req(i, priority=pr))
    order = []
    while sched.queue:
        adm = sched.decide([None]).admissions
        order.append(adm[0].req.req_id)
    assert order == [1, 2, 3, 0]


def test_sjf_policy_prefers_short_jobs():
    sched = Scheduler("sjf", slots=1, max_len=32)
    sched.submit(_req(0, plen=6, max_new=8))
    sched.submit(_req(1, plen=1, max_new=2))
    sched.submit(_req(2, plen=2, max_new=2))
    order = []
    while sched.queue:
        order.append(sched.decide([None]).admissions[0].req.req_id)
    assert order == [1, 2, 0]


def test_drf_policy_alternates_tenants_and_credits_on_finish():
    sched = Scheduler("drf-fair", slots=2, max_len=32)
    for i in range(4):
        sched.submit(_req(i, tenant="a"))
    for i in range(4, 6):
        sched.submit(_req(i, tenant="b"))
    adm = sched.decide([None, None]).admissions
    assert [a.req.tenant for a in adm] == ["a", "b"]
    shares = sched.policy.shares()
    assert shares["a"] == pytest.approx(shares["b"])
    for a in adm:
        sched.on_finish(a.req)
    assert sched.policy.shares()["a"] == 0.0


def test_serve_resource_dominant_share():
    total = ServeResource(slots=4, kv=100)
    assert ServeResource(2, 10).dominant_share(total) == 0.5
    assert ServeResource(1, 80).dominant_share(total) == 0.8


# ------------------------------------------------- two-tenant flood (engine)
@pytest.mark.parametrize("policy", ["fcfs", "drf-fair"])
def test_two_tenant_flood(policy):
    """Tenant "heavy" floods the queue before "light" submits: fcfs
    provably starves the light tenant (heavy holds every slot, light's
    first completion waits for the backlog), drf-fair keeps heavy's slot
    share bounded and completes light work almost immediately."""
    slots, n_heavy, n_light = 4, 12, 4
    eng = _engine(batch_slots=slots, max_len=32, policy=policy)
    rng = np.random.default_rng(0)
    for i in range(n_heavy):
        eng.submit(Request(i, rng.integers(1, 64, size=2).astype(np.int32),
                           max_new_tokens=3, tenant="heavy"))
    for i in range(n_heavy, n_heavy + n_light):
        eng.submit(Request(i, rng.integers(1, 64, size=2).astype(np.int32),
                           max_new_tokens=3, tenant="light"))
    max_heavy_share = 0.0
    while eng.queue or any(r is not None for r in eng.active):
        eng.step()
        if any(r.tenant == "light" for r in eng.queue):
            heavy = sum(1 for r in eng.active
                        if r is not None and r.tenant == "heavy")
            max_heavy_share = max(max_heavy_share, heavy / slots)
    done = eng._finished
    assert len(done) == n_heavy + n_light
    light_first = next(i for i, r in enumerate(done)
                       if r.tenant == "light")
    if policy == "fcfs":
        # starvation: every slot went to heavy while light queued, and
        # light's first completion waited out most of the flood
        assert max_heavy_share == 1.0
        assert light_first >= n_heavy - slots
    else:
        # DRF bound: heavy never exceeds its fair share of the slots
        # (+1 slot of slack for admission transients) while light queues
        assert max_heavy_share <= 0.5 + 1.0 / slots
        assert light_first <= 3
        # accounting drained: all shares back to zero
        assert all(v == 0.0 for v in eng.scheduler.policy.shares().values())


# ----------------------------------------------------- sampling (pure fn)
def test_sample_tokens_temp0_is_bitwise_argmax():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(5, 33)), jnp.float32)
    out = sample_tokens(logits, jnp.arange(5, dtype=jnp.int32),
                        jnp.zeros(5, jnp.float32),
                        jnp.zeros(5, jnp.int32), jnp.ones(5, jnp.float32),
                        jnp.zeros((5, 2), jnp.uint32))
    assert np.array_equal(np.asarray(out),
                          np.asarray(jnp.argmax(logits, -1)))


def test_matches_stop_reasons():
    sp = SamplingParams(stop=(7, (1, 2, 3)))
    assert matches_stop([5, 7], sp) == "stop"
    assert matches_stop([1, 2, 3], sp) == "stop"
    assert matches_stop([2, 3], sp) is None
    assert matches_stop([4], sp, eos_id=4) == "eos"
    assert matches_stop([], sp) is None


def test_sampling_params_validation():
    with pytest.raises(ValueError):
        SamplingParams(temperature=-1.0)
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        SamplingParams(top_k=-1)


try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    pass
else:
    @pytest.mark.slow
    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 10_000), batch=st.integers(1, 4),
           vocab=st.integers(4, 40))
    def test_temp0_bitwise_argmax_hypothesis(seed, batch, vocab):
        """Sampled decode with temperature=0 is bitwise the greedy argmax
        whatever the top-k/top-p/keys riding along."""
        rng = np.random.default_rng(seed)
        logits = jnp.asarray(rng.normal(size=(batch, vocab)) * 4,
                             jnp.float32)
        out = sample_tokens(
            logits, jnp.asarray(rng.integers(0, 31, batch), jnp.int32),
            jnp.zeros(batch, jnp.float32),
            jnp.asarray(rng.integers(0, vocab, batch), jnp.int32),
            jnp.asarray(rng.uniform(0.1, 1.0, batch), jnp.float32),
            jnp.asarray(rng.integers(0, 2**31, (batch, 2)), jnp.uint32))
        assert np.array_equal(np.asarray(out),
                              np.asarray(jnp.argmax(logits, -1)))

    @pytest.mark.slow
    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 10_000), k=st.integers(1, 6),
           p=st.floats(0.05, 1.0))
    def test_sampled_token_respects_topk_topp(seed, k, p):
        rng = np.random.default_rng(seed)
        b, v = 3, 24
        logits = rng.normal(size=(b, v)).astype(np.float32) * 3
        out = np.asarray(sample_tokens(
            jnp.asarray(logits), jnp.asarray(rng.integers(0, 15, b),
                                             jnp.int32),
            jnp.full(b, 0.8, jnp.float32), jnp.full(b, k, jnp.int32),
            jnp.full(b, p, jnp.float32),
            jnp.asarray(rng.integers(0, 2**31, (b, 2)), jnp.uint32)))
        for row, tok in zip(logits, out):
            order = np.argsort(-row)
            rank = int(np.where(order == tok)[0][0])
            assert rank < k  # top-k membership
            probs = np.exp(row[order] / 0.8 - np.max(row / 0.8))
            probs /= probs.sum()
            # exclusive-cumsum nucleus: mass strictly below tok < p
            assert rank == 0 or float(np.cumsum(probs)[rank - 1]) < p

    @pytest.mark.slow
    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 10_000), n=st.integers(1, 5))
    def test_temp0_engine_bitwise_hypothesis(seed, n):
        """Engine-level: random traces decode identically through the
        wave-greedy, dense-sampled and paged-sampled paths at temp 0
        (the engines are shared so the steps compile once)."""
        trace = _trace(seed, n)
        wave = _serve(_reused_engine("wave", batch_slots=2, max_len=32,
                                     mode="wave"), trace)
        dense = _serve(_reused_engine("dense", batch_slots=2, max_len=32),
                       trace)
        paged = _serve(_reused_engine("paged", batch_slots=2, max_len=32,
                                      cache="paged", page_size=8), trace)
        assert wave == dense == paged


# ------------------------------------------ engine-level sampling semantics
def _trace(seed, n, max_new=4):
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, 64, size=int(rng.integers(1, 7)))
             .astype(np.int32), max_new) for _ in range(n)]


def _serve(eng, trace, sampling=None):
    for i, (prompt, max_new) in enumerate(trace):
        eng.submit(Request(i, prompt.copy(), max_new_tokens=max_new,
                           sampling=sampling or SamplingParams()))
    return {r.req_id: r.output for r in eng.run()}


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_temp0_engine_bitwise_matches_greedy_dense_and_paged(seed):
    """The sampled decode step with temperature=0 reproduces the wave
    engine's pure-greedy tokens bit for bit, on both cache layouts."""
    trace = _trace(seed, 5)
    wave = _serve(_reused_engine("wave", batch_slots=2, max_len=32,
                                 mode="wave"), trace)
    dense = _serve(_reused_engine("dense", batch_slots=2, max_len=32),
                   trace)
    paged = _serve(_reused_engine("paged", batch_slots=2, max_len=32,
                                  cache="paged", page_size=8), trace)
    assert wave == dense == paged


def test_topk1_sampled_equals_greedy_end_to_end():
    trace = _trace(3, 4)
    greedy = _serve(_reused_engine("dense", batch_slots=2, max_len=32),
                    trace)
    forced = _serve(_reused_engine("dense", batch_slots=2, max_len=32),
                    trace, SamplingParams(temperature=3.0, top_k=1))
    assert greedy == forced


def test_seeded_sampling_is_deterministic_and_slot_independent():
    """Same (seed, prompt) reproduces tokens regardless of slot; a
    different seed decodes a different trajectory."""
    prompt = np.array([3, 5, 7], np.int32)
    eng = _reused_engine("dense", batch_slots=2, max_len=32)
    for i, seed in enumerate([11, 11, 12]):
        eng.submit(Request(i, prompt.copy(), max_new_tokens=6,
                           sampling=SamplingParams(temperature=1.5,
                                                   seed=seed)))
    outs = {r.req_id: r.output for r in eng.run()}
    assert outs[0] == outs[1]
    assert outs[0] != outs[2]
    # paged engine draws the identical trajectory (fold keyed on position)
    paged = _reused_engine("paged", batch_slots=2, max_len=32,
                           cache="paged", page_size=8)
    paged.submit(Request(0, prompt.copy(), max_new_tokens=6,
                         sampling=SamplingParams(temperature=1.5, seed=11)))
    assert eng is not paged
    assert {r.req_id: r.output for r in paged.run()}[0] == outs[0]


def test_wave_mode_serves_sampled_requests_bitwise():
    """Sampled wave mode (host-side draw from the wave logits via
    ``sample_tokens``) decodes the identical seeded trajectory as the
    continuous engine — wave slots advance from position 0 in lockstep,
    so the (key, position) fold matches and the equality tests no longer
    special-case greedy."""
    trace = _trace(9, 4)
    sp = SamplingParams(temperature=1.3, top_k=6, seed=77)
    wave = _serve(_reused_engine("wave", batch_slots=2, max_len=32,
                                 mode="wave"), trace, sp)
    dense = _serve(_reused_engine("dense", batch_slots=2, max_len=32),
                   trace, sp)
    assert wave == dense


# --------------------------------------------- request handle + lifecycle
def test_handle_lifecycle_and_streaming():
    eng = _engine(batch_slots=1, max_len=32)
    h0 = eng.submit(_req(0, max_new=4))
    h1 = eng.submit(_req(1, max_new=4))
    assert h0.state is RequestState.QUEUED
    assert h1.state is RequestState.QUEUED
    seen = []
    for tok in h1.tokens():  # streams h1, driving h0 through first
        seen.append(tok)
        assert h1.state in (RequestState.PREFILL, RequestState.DECODE,
                            RequestState.FINISHED)
    assert h1.done and h1.finish_reason == "length"
    assert seen == h1.output and len(seen) == 4
    assert h0.done  # same engine drained it on the way
    m = h1.metrics()
    assert m["ttft_s"] >= 0 and m["tpot_s"] >= 0


def test_stop_sequence_and_eos_reasons():
    eng = _engine(batch_slots=1, max_len=32)
    probe = eng.submit(_req(0, max_new=8)).result()
    assert probe.finish_reason == "length"
    stop = tuple(probe.output[1:3])
    r = eng.submit(_req(1, max_new=8,
                        sampling=SamplingParams(stop=(stop,)))).result()
    assert r.finish_reason == "stop"
    assert tuple(r.output[-len(stop):]) == stop
    assert len(r.output) < len(probe.output)
    r = eng.submit(Request(2, np.arange(1, 3, dtype=np.int32),
                           max_new_tokens=8,
                           eos_id=probe.output[0])).result()
    assert r.finish_reason == "eos" and len(r.output) == 1


def test_token_feed_path_reports_prefill_state():
    """SSM/hybrid plans feed prompts token by token: the request is
    observably PREFILL across ticks before its first output."""
    cfg = dataclasses.replace(get_config("zamba2-2.7b", smoke=True),
                              vocab_size=64)
    model = LM(cfg, RuntimeKnobs(cache_dtype=jnp.float32))
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, ServeConfig(batch_slots=1, max_len=32))
    assert not eng.chunked
    h = eng.submit(Request(0, np.arange(1, 5, dtype=np.int32),
                           max_new_tokens=2))
    eng.step()
    assert h.state is RequestState.PREFILL
    h.result()
    assert h.state is RequestState.FINISHED


# --------------------------------------------------------- run() stalls
def test_run_raises_on_undrained_ticks():
    eng = _engine(batch_slots=1, max_len=32)
    eng.submit(_req(0, max_new=8))
    eng.submit(_req(1, max_new=8))
    with pytest.raises(ServeStalled, match="2 requests undrained"):
        eng.run(max_ticks=1)
    # the engine is still usable: draining finishes both requests
    assert len(eng.run()) == 2


def test_run_warn_mode_reports_partial():
    eng = _engine(batch_slots=1, max_len=32, on_stall="warn")
    eng.submit(_req(0, max_new=8))
    eng.submit(_req(1, max_new=8))
    with pytest.warns(RuntimeWarning, match="undrained"):
        done = eng.run(max_ticks=1)
    assert len(done) < 2
    eng.run()  # drain so the shared cache state is clean


# ------------------------------------------------- ServeConfig + shim
def test_legacy_kwargs_shim_pr1_and_pr2_call_sites():
    """PR 1/2-era keyword construction still works (DeprecationWarning)
    and serves requests identically to ServeConfig construction."""
    model, params = _model()
    with pytest.warns(DeprecationWarning, match="ServeConfig"):
        legacy = ServeEngine(model, params, batch_slots=2, max_len=32,
                             mode="continuous", prefill_chunk=8)
    assert legacy.config == ServeConfig(batch_slots=2, max_len=32,
                                        prefill_chunk=8)
    with pytest.warns(DeprecationWarning):
        paged = ServeEngine(model, params, batch_slots=2, max_len=32,
                            cache="paged", page_size=8, num_pages=17,
                            page_policy="spread", prefix_cache=False)
    assert paged.kv is not None and paged.kv.prefix is None
    trace = _trace(7, 3)
    assert _serve(legacy, trace) == _serve(
        _reused_engine("dense", batch_slots=2, max_len=32), trace)


def test_config_and_kwargs_are_exclusive_and_checked():
    model, params = _model()
    with pytest.raises(TypeError, match="not both"):
        ServeEngine(model, params, ServeConfig(), batch_slots=2)
    with pytest.raises(TypeError, match="unknown"):
        ServeEngine(model, params, bogus=3)
