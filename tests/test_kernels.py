"""Per-kernel allclose vs the pure-jnp oracle: sweep shapes + dtypes.

Kernels run in interpret mode on CPU (the kernel body executes in Python);
the BlockSpec tiling/index maps are exercised for real.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (attention_ref, decode_attention,
                           decode_attention_ref, flash_attention, ssd_chunk,
                           ssd_chunk_ref)

RNG = np.random.default_rng(42)


def arr(*shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


# ------------------------------------------------------- flash attention
@pytest.mark.parametrize("b,h,kv,s,d", [
    (1, 4, 4, 64, 32),    # MHA
    (2, 4, 2, 128, 32),   # GQA
    (1, 8, 1, 128, 16),   # MQA
    (2, 2, 2, 96, 64),    # non-pow2 seq
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_causal(b, h, kv, s, d, dtype):
    q, k, v = arr(b, s, h, d, dtype=dtype), arr(b, s, kv, d, dtype=dtype), \
        arr(b, s, kv, d, dtype=dtype)
    bq = bk = 32
    out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
    ref = attention_ref(q.swapaxes(1, 2), k.swapaxes(1, 2),
                        v.swapaxes(1, 2), causal=True).swapaxes(1, 2)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("window", [16, 48, 200])
def test_flash_attention_window(window):
    b, h, kv, s, d = 2, 4, 2, 128, 32
    q, k, v = arr(b, s, h, d), arr(b, s, kv, d), arr(b, s, kv, d)
    out = flash_attention(q, k, v, causal=True, window=window, block_q=32,
                          block_k=32)
    ref = attention_ref(q.swapaxes(1, 2), k.swapaxes(1, 2),
                        v.swapaxes(1, 2), causal=True,
                        window=window).swapaxes(1, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)


def test_flash_attention_block_size_invariance():
    b, h, kv, s, d = 1, 2, 2, 128, 32
    q, k, v = arr(b, s, h, d), arr(b, s, kv, d), arr(b, s, kv, d)
    outs = [flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
            for bq, bk in [(32, 32), (64, 32), (32, 64), (128, 128)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=1e-5, rtol=1e-5)


# --------------------------------------------------------- decode attention
@pytest.mark.parametrize("pos", [0, 17, 63, 127])
@pytest.mark.parametrize("kv", [1, 2, 4])
def test_decode_attention(pos, kv):
    b, h, s, d = 2, 4, 128, 32
    q = arr(b, 1, h, d)
    kc, vc = arr(b, s, kv, d), arr(b, s, kv, d)
    out = decode_attention(q, kc, vc, jnp.int32(pos), block_k=32)
    ref = decode_attention_ref(q.swapaxes(1, 2), kc.swapaxes(1, 2),
                               vc.swapaxes(1, 2), pos).swapaxes(1, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)


def test_decode_attention_window_and_dtype():
    b, h, kv, s, d = 1, 4, 2, 256, 64
    q = arr(b, 1, h, d, dtype=jnp.bfloat16)
    kc = arr(b, s, kv, d, dtype=jnp.bfloat16)
    vc = arr(b, s, kv, d, dtype=jnp.bfloat16)
    out = decode_attention(q, kc, vc, jnp.int32(200), window=64, block_k=64)
    ref = decode_attention_ref(q.swapaxes(1, 2), kc.swapaxes(1, 2),
                               vc.swapaxes(1, 2), 200,
                               window=64).swapaxes(1, 2)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=2e-2,
                               rtol=2e-2)


# ------------------------------------------------------------- ssd chunk
@pytest.mark.parametrize("bb,nc,nh,g,q,hp,ds", [
    (1, 2, 2, 1, 16, 8, 8),
    (2, 3, 4, 2, 16, 8, 16),
    (1, 1, 8, 8, 32, 16, 8),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_chunk(bb, nc, nh, g, q, hp, ds, dtype):
    x = arr(bb, nc, nh, q, hp, dtype=dtype)
    b = arr(bb, nc, g, q, ds, dtype=dtype)
    c = arr(bb, nc, g, q, ds, dtype=dtype)
    dt = jnp.abs(arr(bb, nc, nh, q)) * 0.1
    cum = jnp.cumsum(-dt * 0.5, axis=-1)
    y, st = ssd_chunk(x, b, c, dt, cum)
    yr, sr = ssd_chunk_ref(x, b, c, dt, cum)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))
    np.testing.assert_allclose(np.asarray(st), np.asarray(sr),
                               atol=_tol(dtype), rtol=_tol(dtype))


def test_ssd_chunk_matches_model_ssm():
    """The kernel's math must agree with the model's chunked SSD path."""
    from repro.models.ssm import ssm_forward, ssm_init
    from repro.configs import SSMConfig

    cfg = SSMConfig(d_state=8, head_dim=8, expand=2, chunk_size=8)
    dm = 16
    params = ssm_init(jax.random.PRNGKey(0), dm, cfg)
    x = arr(2, 32, dm)
    y = ssm_forward(params, x, dm, cfg)
    assert jnp.isfinite(y).all()
