"""Disaggregated prefill/decode serving: roles, KV handoff, chaos.

The disagg contract under test everywhere: splitting the pool into
prefill and decode workers is invisible in the token streams — every
request's output is bitwise-identical to the unified single-engine
run (greedy and seeded-sampled, dense and paged), through backpressure,
replica retirement, and chaos kills mid-handoff.
"""
import dataclasses
import glob
import json
import os

import numpy as np
import pytest

from conftest import tiny_lm
from repro.runtime.cluster import ReplicaState
from repro.runtime.disagg import (ROLES, DisaggRouter, Handoff,
                                  transfer_chain)
from repro.runtime.fault import FaultEvent, ReplicaFaultInjector
from repro.runtime.sampling import SamplingParams
from repro.runtime.serve import Request, ServeConfig, ServeEngine
from repro.runtime.telemetry import Telemetry, validate_chrome_trace

_PAGED = dict(cache="paged", page_size=8, prefix_cache=False)


def _role_factory(roles, **kw):
    """make_engine(rid) that builds each replica with its role's
    ``ServeConfig.role`` (fresh engine per call)."""
    model, params = tiny_lm()
    base = ServeConfig(**{"batch_slots": 2, "max_len": 64, **kw})

    def make(rid):
        return ServeEngine(model, params,
                           dataclasses.replace(base, role=roles[rid]))

    return make


def _router(roles, *, engine_kw=None, **kw):
    roles = list(roles)
    return DisaggRouter(_role_factory(roles, **(engine_kw or {})),
                        len(roles), roles=roles, **kw)


def _reqs(n=4, *, max_new=8, seed=0, base_id=100):
    """Mixed greedy / seeded-sampled request set (the bitwise contract
    must hold for both sampler paths)."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        prompt = rng.integers(1, 60,
                              size=int(rng.integers(3, 9))).astype(np.int32)
        sp = SamplingParams(temperature=0.8 if i % 2 else 0.0, seed=7)
        out.append(Request(base_id + i, prompt, max_new_tokens=max_new,
                           sampling=sp,
                           tenant="gold" if i % 3 == 0 else "free"))
    return out


def _fresh(reqs):
    return [dataclasses.replace(r, prompt=np.asarray(r.prompt), output=[])
            for r in reqs]


def _reference(reqs, **kw):
    """Unified single-engine outputs for a request set."""
    model, params = tiny_lm()
    eng = ServeEngine(model, params,
                      ServeConfig(**{"batch_slots": 2, "max_len": 64, **kw}))
    for r in _fresh(reqs):
        eng.submit(r)
    return {r.req_id: list(r.output) for r in eng.run()}


def _assert_pools_balanced(router):
    for rh in router.replicas:
        if rh.engine is not None and rh.engine.kv is not None:
            pool = rh.engine.kv.pool
            assert pool.in_use == 0, f"replica {rh.rid} leaked pages"
            assert not np.any(np.asarray(pool.ref[1:]))


# ------------------------------------------------------------------- roles
def test_role_validation():
    with pytest.raises(ValueError, match="2 entries for 3"):
        DisaggRouter(_role_factory(["prefill", "decode", "decode"]), 3,
                     roles=["prefill", "decode"])
    with pytest.raises(ValueError, match="unknown roles"):
        _router(["prefill", "verify"])
    with pytest.raises(ValueError, match="prefill-capable"):
        _router(["decode", "decode"])
    with pytest.raises(ValueError, match="decode-capable"):
        _router(["prefill", "prefill"])
    # a DOWN spare does not count toward initial capability
    with pytest.raises(ValueError, match="decode-capable"):
        _router(["prefill", "decode"], start_down=(1,))


def test_serve_config_role_validation():
    model, params = tiny_lm()
    with pytest.raises(ValueError, match="role"):
        ServeEngine(model, params, ServeConfig(role="verify"))
    with pytest.raises(ValueError, match="continuous"):
        ServeEngine(model, params, ServeConfig(role="prefill",
                                               mode="wave"))


def test_decode_engine_rejects_fresh_requests():
    model, params = tiny_lm()
    eng = ServeEngine(model, params, ServeConfig(role="decode"))
    with pytest.raises(ValueError, match="handed-off"):
        eng.submit(Request(1, np.array([3, 4], np.int32),
                           max_new_tokens=2))


def test_router_places_fresh_only_on_prefill_capable():
    router = _router(["prefill", "decode"])
    for r in _reqs(4):
        router.submit(r)
    router.step()
    # anything on the decode replica arrived via handoff (placed on the
    # prefill replica first), never as a fresh placement
    assert all(rr.history[0] == 0 for rr in router.placed[1])
    assert not router._accepts_new(router.replicas[1])
    assert router._accepts_new(router.replicas[0])


# ----------------------------------------------------- cross-pool transfer
def test_transfer_chain_refcount_balanced():
    """Satellite regression: the cross-pool path moves a chain's pages
    without leaking a refcount in either pool — source frees exactly
    the chain, destination holds exactly the chain, and finishing the
    request drains the destination back to empty."""
    model, params = tiny_lm()
    cfg = ServeConfig(batch_slots=2, max_len=64, **_PAGED)
    src = ServeEngine(model, params,
                      dataclasses.replace(cfg, role="prefill"))
    dst = ServeEngine(model, params,
                      dataclasses.replace(cfg, role="decode"))
    req = _reqs(1, max_new=6)[0]
    src.submit(req)
    for _ in range(10):
        src.step()
        if req.output:
            break
    assert req.output  # prefill done, first token out
    ck = src.release(req)
    n = len(ck.pages)
    assert n > 0
    assert src.kv.pool.in_use == n  # checkpoint still holds the chain
    assert dst.kv.pool.in_use == 0
    assert transfer_chain(src, dst, req)
    assert src.kv.pool.in_use == 0  # source hold released
    assert not np.any(np.asarray(src.kv.pool.ref[1:]))
    assert dst.kv.pool.in_use == n  # destination adopted exactly n
    dst.submit(req)
    dst.run()
    assert req.done
    assert dst.kv.pool.in_use == 0  # drained after finish
    assert not np.any(np.asarray(dst.kv.pool.ref[1:]))


def test_transfer_chain_backpressure_leaves_source_intact():
    """A destination with no room refuses the chain; the source pool
    keeps its hold so the handoff can retry later."""
    model, params = tiny_lm()
    cfg = ServeConfig(batch_slots=2, max_len=64, **_PAGED)
    src = ServeEngine(model, params,
                      dataclasses.replace(cfg, role="prefill"))
    dst = ServeEngine(model, params,
                      dataclasses.replace(cfg, role="decode",
                                          num_pages=2))
    req = _reqs(1, max_new=6, seed=3)[0]
    src.submit(req)
    for _ in range(10):
        src.step()
        if req.output:
            break
    ck = src.release(req)
    n = len(ck.pages)
    held = src.kv.pool.in_use
    if n <= 1:  # need a chain the 2-page pool (1 null + 1 free) can't fit
        pytest.skip("prompt fit one page; backpressure needs > 1")
    assert not transfer_chain(src, dst, req)
    assert src.kv.pool.in_use == held  # nothing released
    assert dst.kv.pool.in_use == 0  # nothing half-adopted


def test_dense_checkpoint_transfer_is_free():
    model, params = tiny_lm()
    cfg = ServeConfig(batch_slots=2, max_len=64)
    src = ServeEngine(model, params,
                      dataclasses.replace(cfg, role="prefill"))
    dst = ServeEngine(model, params,
                      dataclasses.replace(cfg, role="decode"))
    req = _reqs(1, max_new=4)[0]
    src.submit(req)
    for _ in range(10):
        src.step()
        if req.output:
            break
    ck = src.release(req)
    assert ck.pages is None and ck.kv is not None  # host snapshot
    assert transfer_chain(src, dst, req)  # nothing to move


# ----------------------------------------------------------- bitwise runs
@pytest.mark.parametrize("engine_kw", [{}, _PAGED],
                         ids=["dense", "paged"])
def test_disagg_bitwise_identical_to_unified(engine_kw):
    """The tentpole contract: prefill/decode split with KV handoff
    emits bitwise-identical streams (mixed greedy + seeded-sampled)."""
    reqs = _reqs(6, max_new=10, seed=2)
    ref = _reference(reqs, **engine_kw)
    router = _router(["prefill", "decode", "decode"],
                     engine_kw=engine_kw)
    for r in _fresh(reqs):
        router.submit(r)
    done = router.run(max_ticks=500)
    st = router.stats()
    assert st["handoffs_done"] == 6  # every request crossed the split
    assert st["handoffs_in_transit"] == 0
    assert {r.req_id: list(r.output) for r in done} == ref
    _assert_pools_balanced(router)


def test_handoff_backpressure_queues_and_completes():
    """One single-slot decode replica: handoffs outnumber slots, queue
    under backpressure, and still all complete bitwise."""
    reqs = _reqs(5, max_new=8, seed=4)
    ref = _reference(reqs, **_PAGED)
    router = _router(["prefill", "decode"],
                     engine_kw=dict(_PAGED, batch_slots=1))

    # reference uses 2 slots; re-run it with 1 to match admission order
    model, params = tiny_lm()
    eng = ServeEngine(model, params,
                      ServeConfig(batch_slots=1, max_len=64, **_PAGED))
    for r in _fresh(reqs):
        eng.submit(r)
    ref = {r.req_id: list(r.output) for r in eng.run()}

    for r in _fresh(reqs):
        router.submit(r)
    done = router.run(max_ticks=500)
    st = router.stats()
    assert st["handoff_backpressure"] >= 1
    assert st["handoffs_done"] == 5
    assert {r.req_id: list(r.output) for r in done} == ref
    _assert_pools_balanced(router)


def test_unified_role_in_disagg_pool():
    """A unified replica both prefills and decodes alongside the split
    pool; no handoff is required for its requests."""
    reqs = _reqs(4, max_new=6, seed=6)
    ref = _reference(reqs)
    router = _router(["unified", "unified"])
    for r in _fresh(reqs):
        router.submit(r)
    done = router.run(max_ticks=300)
    assert router.stats()["handoffs_done"] == 0
    assert {r.req_id: list(r.output) for r in done} == ref


# ------------------------------------------------------------------ chaos
def _drive_until_handoff_from(router, src_rid, max_ticks=60):
    for _ in range(max_ticks):
        router.step()
        if any(h.src == src_rid for h in router.handoffs):
            return True
    return False


@pytest.mark.parametrize("engine_kw", [{}, _PAGED],
                         ids=["dense", "paged"])
def test_chaos_kill_prefill_mid_handoff_bitwise(engine_kw):
    """ISSUE acceptance: a prefill replica dies while its handoffs sit
    in transit (paged chains still in the dying pool).  The sweep feeds
    them through deterministic replay and every continuation is bitwise
    intact."""
    reqs = _reqs(6, max_new=10, seed=8)
    ref = _reference(reqs, **engine_kw)
    # single-slot decode replica keeps the handoff queue non-empty;
    # prefill work spreads over replicas 0 and 1
    router = _router(["prefill", "prefill", "decode"],
                     engine_kw=dict(engine_kw, batch_slots=1),
                     miss_threshold=1)
    for r in _fresh(reqs):
        router.submit(r)
    assert _drive_until_handoff_from(router, 1)
    in_flight = [h.rr.req.req_id for h in router.handoffs if h.src == 1]
    router.replicas[1].killed = True  # dies mid-handoff
    done = router.run(max_ticks=800)
    st = router.stats()
    assert st["replicas_lost"] == 1
    assert st["recoveries"] >= len(in_flight) >= 1
    assert st["failed"] == 0
    assert {r.req_id: list(r.output) for r in done} == ref
    _assert_pools_balanced(router)


def test_fence_flight_dump_snapshots_handoff_queue(tmp_path):
    """Satellite: the fence's flight dump carries the in-transit
    handoff queue (request id, source replica, pages in flight) as it
    stood at the instant of death — before the sweep clears it."""
    tm = Telemetry(trace=True, flight=128, flight_dir=str(tmp_path))
    reqs = _reqs(6, max_new=10, seed=8)
    router = _router(["prefill", "prefill", "decode"],
                     engine_kw=dict(_PAGED, batch_slots=1),
                     miss_threshold=1, telemetry=tm)
    for r in _fresh(reqs):
        router.submit(r)
    assert _drive_until_handoff_from(router, 1)
    in_flight = {h.rr.req.req_id: h for h in router.handoffs
                 if h.src == 1}
    router.replicas[1].killed = True
    router.run(max_ticks=800)
    dumps = sorted(glob.glob(os.path.join(str(tmp_path), "flight_*.json")))
    assert dumps
    with open(dumps[0]) as f:
        payload = json.load(f)
    snap = {e["req_id"]: e for e in payload["handoffs_in_transit"]}
    for rid, h in in_flight.items():
        assert snap[rid]["src_replica"] == 1
        assert snap[rid]["pages_in_flight"] == h.n_pages > 0
        assert snap[rid]["target_role"] == "decode"
    # spans stay balanced through the fence (HANDOFF closed by sweep)
    assert validate_chrome_trace(tm.trace.to_chrome())["unbalanced"] == {}


def test_chaos_injector_schedule_with_rejoin():
    """Seeded-style explicit schedule through the injector path: kill a
    prefill worker, rejoin it later, zero lost requests."""
    reqs = _reqs(8, max_new=8, seed=9)
    ref = _reference(reqs, **_PAGED)
    inj = ReplicaFaultInjector([FaultEvent(3, "kill", 1),
                                FaultEvent(20, "rejoin", 1)])
    router = _router(["prefill", "prefill", "decode"],
                     engine_kw=_PAGED, miss_threshold=1, injector=inj)
    for r in _fresh(reqs):
        router.submit(r)
    done = router.run(max_ticks=800)
    st = router.stats()
    assert st["failed"] == 0
    assert {r.req_id: list(r.output) for r in done} == ref
    _assert_pools_balanced(router)


# ----------------------------------------------------------- retire/drain
def test_retire_migrates_work_and_reaches_down():
    """Scale-down drain: running decodes checkpoint out of the retiree
    and hand off to a sibling; the replica reaches DOWN only once no
    in-transit handoff points at its pool, and outputs stay bitwise."""
    reqs = _reqs(6, max_new=12, seed=10)
    ref = _reference(reqs, **_PAGED)
    router = _router(["unified", "unified", "decode"],
                     engine_kw=_PAGED)
    for r in _fresh(reqs):
        router.submit(r)
    for _ in range(3):
        router.step()
    assert any(len(router.placed[rid]) for rid in (0, 1))
    victim = 0 if router.placed[0] else 1
    router.retire(victim)
    assert router.replicas[victim].state is ReplicaState.DRAINING
    done = router.run(max_ticks=800)
    assert router.replicas[victim].state is ReplicaState.DOWN
    assert router.replicas[victim].engine is None
    assert {r.req_id: list(r.output) for r in done} == ref
    _assert_pools_balanced(router)
    assert router.stats()["failed"] == 0


def test_can_retire_blocks_on_in_transit_handoff():
    router = _router(["prefill", "decode"])
    rh = router.replicas[0]
    assert router._can_retire(rh)
    rr = type("RR", (), {"req": type("R", (), {"req_id": 1})()})()
    router.handoffs.append(Handoff(rr=rr, src=0, n_pages=2, tick=0))
    assert not router._can_retire(rh)
    assert router._can_retire(router.replicas[1])
    router.handoffs.clear()


# -------------------------------------------------------------- telemetry
def test_disagg_stats_and_gauges():
    router = _router(["prefill", "decode"])
    st = router.stats()
    assert st["roles"] == {0: "prefill", 1: "decode"}
    assert st["handoffs_done"] == 0
    for r in _reqs(3, max_new=4):
        router.submit(r)
    router.run(max_ticks=300)
    st = router.stats()
    assert st["handoffs_done"] == 3
    assert st["handoffs_in_transit"] == 0
    v = router.tm.registry.value
    assert v("disagg_handoffs_done") == 3


def test_handoff_spans_balanced():
    tm = Telemetry(trace=True)
    router = _router(["prefill", "decode"], telemetry=tm)
    for r in _reqs(4, max_new=6):
        router.submit(r)
    router.run(max_ticks=300)
    summary = validate_chrome_trace(tm.trace.to_chrome())
    assert summary["unbalanced"] == {}


def test_roles_tuple_export():
    assert ROLES == ("prefill", "decode", "unified")
