"""Per-arch REQUIRED smoke tests: reduced config, one forward/train step on
CPU, output shapes + no NaNs; plus decode==forward consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs
from repro.models import LM, RuntimeKnobs
from repro.models.layers import embed as embed_fn, unembed
from repro.optim import AdamWConfig
from repro.runtime.steps import init_train_state, make_train_step

B, S = 2, 32


def _batch(cfg, key=1, seq=S):
    tokens = jax.random.randint(jax.random.PRNGKey(key), (B, seq), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.input_mode == "embeddings":
        batch["embeds"] = jax.random.normal(jax.random.PRNGKey(key + 1),
                                            (B, seq, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    model = LM(cfg, RuntimeKnobs(cache_dtype=jnp.float32))
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, AdamWConfig(warmup_steps=2,
                                                      total_steps=10)))
    batch = _batch(cfg)
    new_state, metrics = step(state, batch)
    assert jnp.isfinite(metrics["loss"]), arch
    assert jnp.isfinite(metrics["grad_norm"]), arch
    assert metrics["grad_norm"] > 0
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, l: a + float(jnp.sum(jnp.abs(l[0] - l[1]))),
        jax.tree.map(lambda a, b: (a, b), new_state["params"],
                     state["params"]), 0.0)
    assert delta > 0


@pytest.mark.parametrize("arch", list_archs())
def test_prefill_shapes_no_nan(arch):
    cfg = get_config(arch, smoke=True)
    model = LM(cfg, RuntimeKnobs(cache_dtype=jnp.float32))
    params = model.init(jax.random.PRNGKey(0))
    logits, caches = jax.jit(model.prefill)(params, _batch(cfg))
    assert logits.shape == (B, cfg.vocab_size)
    assert jnp.isfinite(logits).all()
    assert caches is not None


@pytest.mark.parametrize("arch", list_archs())
def test_decode_matches_forward(arch):
    cfg = get_config(arch, smoke=True)
    if cfg.moe is not None:
        # drop-free capacity so prefill==decode exactly (see models/moe.py)
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, eval_capacity_factor=float(cfg.moe.num_experts)))
    model = LM(cfg, RuntimeKnobs(cache_dtype=jnp.float32, q_chunk=8))
    params = model.init(jax.random.PRNGKey(0))
    seq = 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, seq), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.input_mode == "embeddings":
        batch["embeds"] = embed_fn(params["embed"], tokens)
    x, _, _ = jax.jit(lambda p, b: model.hidden(p, b, "prefill"))(params,
                                                                  batch)
    full_logits = unembed(params["embed"], x)
    caches = model.init_cache(B, seq)
    step = jax.jit(model.decode_step)
    scale = float(jnp.max(jnp.abs(full_logits)))
    for t in range(seq):
        logits, caches = step(params, caches, tokens[:, t:t + 1],
                              jnp.int32(t))
        err = float(jnp.max(jnp.abs(logits - full_logits[:, t, :])))
        assert err / scale < 2e-3, (arch, t, err)
