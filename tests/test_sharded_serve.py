"""Sharded serving: a ServeEngine split over a device mesh must produce
BITWISE-identical token streams to the single-device engine.

Each test runs in a subprocess with 8 forced host devices (the main
pytest process must keep seeing 1 device — see conftest), the same
pattern as tests/test_multidevice.py.  The equality tests mix greedy and
seeded-sampled requests: sampled trajectories only match when every
logit is bit-exact, so integer token equality is the strongest check we
can state.  The sharding layout under test is the gather-form TP of
``sharding/rules.py`` (``ServeShardFn`` / ``serve_param_shardings`` /
``serve_cache_shardings``) — reductions stay in single-device order, so
identity holds by construction, and these tests pin that construction.
"""
import subprocess
import sys
import textwrap

HEADER = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import sys
sys.path.insert(0, "src")
import dataclasses
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models import LM, RuntimeKnobs
from repro.runtime.serve import (Request, SamplingParams, ServeConfig,
                                 ServeEngine)

def tiny_model():
    cfg = dataclasses.replace(get_config("internlm2-1.8b", smoke=True),
                              num_layers=2, vocab_size=64, d_model=64,
                              num_heads=4, num_kv_heads=2, head_dim=16,
                              d_ff=128)
    return LM(cfg, RuntimeKnobs(cache_dtype=jnp.float32, q_chunk=16))

def requests(n=6, max_new=12):
    rng = np.random.default_rng(7)
    out = []
    for i in range(n):
        p = rng.integers(1, 64, size=int(rng.integers(3, 20)))
        sp = (SamplingParams() if i % 2 == 0 else
              SamplingParams(temperature=0.8, top_k=20, seed=i))
        out.append(Request(req_id=i, prompt=p.astype(np.int32),
                           max_new_tokens=max_new, sampling=sp))
    return out

def run_engine(**cfg_kw):
    m = tiny_model()
    params = m.init(jax.random.PRNGKey(0))
    eng = ServeEngine(m, params, ServeConfig(batch_slots=4, max_len=64,
                                             **cfg_kw))
    for r in requests():
        eng.submit(r)
    done = eng.run(max_ticks=500)
    return {r.req_id: (tuple(r.output), r.finish_reason)
            for r in done}, eng
"""


def run_sub(body: str, timeout=560):
    code = HEADER + textwrap.dedent(body)
    p = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout, cwd=".")
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


def test_sharded_dense_decode_bitwise_identical():
    """TP-only (1,2) and TP x data (2,2) dense engines reproduce the
    unsharded engine's greedy AND seeded-sampled streams exactly."""
    run_sub("""
        base, _ = run_engine(cache="dense")
        assert any(r.sampling.temperature > 0 for r in requests())
        for shape in ((1, 2), (2, 2)):
            got, eng = run_engine(cache="dense", mesh_shape=shape)
            assert got == base, (shape, got, base)
            assert eng.mesh is not None
        print("dense OK")
        """)


def test_sharded_paged_decode_bitwise_identical():
    run_sub("""
        base, _ = run_engine(cache="paged")
        for shape in ((1, 2), (2, 2)):
            got, _ = run_engine(cache="paged", mesh_shape=shape)
            assert got == base, (shape, got, base)
        print("paged OK")
        """)


def test_sharded_spec_decode_bitwise_identical():
    """Speculative decode (draft -> verify -> accept) over a sharded
    paged engine emits the same streams as the unsharded spec engine."""
    run_sub("""
        base, _ = run_engine(cache="paged", draft_k=3)
        got, _ = run_engine(cache="paged", draft_k=3, mesh_shape=(2, 2))
        assert got == base
        print("spec OK")
        """)


def test_sharded_offer_reports_per_host_pages():
    """Regression: a sharded paged engine's offer() advertises the
    per-host sub-pool split, it sums to the aggregate, and an admitted
    slot's page chain lands entirely on the slot's own host."""
    run_sub("""
        _, eng = run_engine(cache="paged", mesh_shape=(2, 2))
        off = eng.offer()
        assert eng.kv.num_hosts == 2
        by_host = off["free_pages_by_host"]
        assert len(by_host) == 2
        assert sum(by_host) == off["free_pages"], (by_host, off)
        # host-locality of a live chain: admit one request per slot and
        # check every mapped page sits in its slot's sub-pool
        for r in requests(4):
            eng.submit(r)
        eng.step()
        for s in range(eng.slots):
            host = eng.kv.slot_host(s)
            for pg in eng.kv._held[s]:
                assert eng.kv.pool.host_of(pg) == host, (s, pg, host)
        # unsharded engines advertise no per-host split
        _, flat = run_engine(cache="paged")
        assert "free_pages_by_host" not in flat.offer()
        print("offer OK")
        """)


def test_serve_cache_shardings_on_paged_specs():
    """serve_cache_shardings maps paged K/V pools to (page over data,
    KV-head over model) — never the in-page sequence dim — and dense
    stripes to (slot over data, KV-head over model)."""
    run_sub("""
        from repro.compat import AxisType, make_mesh as compat_make_mesh
        from repro.sharding import (ServeShardFn, serve_cache_shardings,
                                    serve_param_shardings)
        mesh = compat_make_mesh((2, 2), ("data", "model"),
                                axis_types=(AxisType.Auto,) * 2)
        m = tiny_model()
        paged = jax.eval_shape(lambda: m.init_cache_paged(8, 16))
        sh = serve_cache_shardings(mesh, paged, paged=True)
        flat = jax.tree_util.tree_flatten_with_path(sh)[0]
        assert flat, "no cache leaves"
        for path, s in flat:
            spec = tuple(s.spec)
            # trailing dims: (pages, page_size, KV, head) — page dim on
            # "data", KV heads on "model", sequence dim NEVER sharded
            assert spec[-3] is None, (path, spec)
            assert spec[-2] == "model", (path, spec)
            assert spec[-4] == "data", (path, spec)
        dense = jax.eval_shape(lambda: m.init_cache(4, 64))
        dsh = serve_cache_shardings(mesh, dense, paged=False)
        for path, s in jax.tree_util.tree_flatten_with_path(dsh)[0]:
            spec = tuple(s.spec)
            assert spec[-3] is None, (path, spec)  # seq dim replicated
            assert spec[-2] == "model", (path, spec)
        # ServeShardFn is hashable + mesh-keyed: engines over the same
        # mesh share compiled steps through the runtime.steps LRU
        assert ServeShardFn(mesh) == ServeShardFn(mesh)
        assert hash(ServeShardFn(mesh)) == hash(ServeShardFn(mesh))
        # param shardings: ff dim of the MLP up/gate is TP-sharded, the
        # combine (down) projection stays replicated — the gather form
        params = m.param_specs()
        psh = serve_param_shardings(mesh, m.cfg, params)
        blocks = psh["blocks"]
        flat = {jax.tree_util.keystr(p): s for p, s in
                jax.tree_util.tree_flatten_with_path(blocks)[0]}
        for key, s in flat.items():
            spec = tuple(s.spec)
            if "w_gate" in key or "w_up" in key:
                assert spec[-1] == "model", (key, spec)
            if "w_down" in key or "'wo'" in key:
                assert all(a is None for a in spec), (key, spec)
        print("specs OK")
        """)
