"""Unified telemetry subsystem: metrics registry, span tracing, flight
recorder, and the legacy stats()-dict schema contract.

The invariants under test:

* the registry round-trips through both exposition formats (Prometheus
  text + JSON dict) without losing series or label values,
* every request span the engine opens is closed by the time the run
  drains — including under preemption and under a replica kill, where
  the router's fence closes the dead replica's spans and opens REPLAY
  spans that close on re-placement,
* the null sink is a true no-op (``Telemetry()`` with tracing off keeps
  the hot path allocation-free),
* the flight recorder's ring bounds memory and its fence dump is a
  self-contained, valid JSON artifact,
* the legacy ``stats()/kv_stats()/spec_stats()`` dicts — now views over
  the registry — keep their exact key sets (the schema-stability
  contract the dashboards and older tests rely on).
"""
import dataclasses
import json

import numpy as np
import pytest

from conftest import make_engine, tiny_lm
from repro.runtime.cluster import ClusterRouter
from repro.runtime.fault import FaultEvent, ReplicaFaultInjector
from repro.runtime.sampling import SamplingParams
from repro.runtime.serve import Request, ServeConfig, ServeEngine
from repro.runtime.steps import step_cache_stats
from repro.runtime.telemetry import (NULL_TRACE, ROUTER_PID,
                                     MetricsRegistry, NullTrace, Telemetry,
                                     TraceRecorder, validate_chrome_trace)


def _reqs(n=4, *, max_new=6, sampled=True, base_id=0):
    rng = np.random.default_rng(0)
    out = []
    for i in range(n):
        prompt = rng.integers(1, 60,
                              size=int(rng.integers(2, 7))).astype(np.int32)
        sp = SamplingParams(temperature=0.8 if (sampled and i % 2) else 0.0,
                            seed=5)
        out.append(Request(base_id + i, prompt, max_new_tokens=max_new,
                           sampling=sp))
    return out


# ------------------------------------------------------------- registry
def test_registry_counters_gauges_histograms():
    reg = MetricsRegistry()
    c = reg.counter("req_total", "requests", ("replica",))
    c.labels(replica="0").inc()
    c.labels(replica="0").inc(2)
    c.labels(replica="1").inc()
    assert reg.value("req_total", replica="0") == 3
    assert reg.value("req_total", replica="1") == 1
    g = reg.gauge("depth", "queue depth")
    g.labels().set(7)
    g.labels().dec(2)
    assert reg.value("depth") == 5
    h = reg.histogram("lat", "latency", buckets=(0.1, 1.0))
    h.labels().observe(0.05)
    h.labels().observe(0.5)
    h.labels().observe(5.0)
    snap = h.labels().get()
    assert snap["count"] == 3 and snap["sum"] == pytest.approx(5.55)
    assert snap["buckets"] == {"0.1": 1, "1.0": 2}  # cumulative
    # re-registration is idempotent (same family), type mismatch is not
    assert reg.counter("req_total", "requests", ("replica",)) is c
    with pytest.raises(ValueError):
        reg.gauge("req_total", "requests")
    with pytest.raises(ValueError):
        c.labels(tenant="x")  # undeclared label name


def test_registry_function_backed_gauge_reads_live():
    reg = MetricsRegistry()
    state = {"v": 1}
    reg.gauge("live", "live value").labels().set_function(
        lambda: state["v"])
    assert reg.value("live") == 1
    state["v"] = 42
    assert reg.value("live") == 42
    assert reg.to_dict()["live"]["series"][0]["value"] == 42


def test_prometheus_exposition_parses(tmp_path):
    reg = MetricsRegistry()
    reg.counter("tok_total", "tokens served", ("replica",)) \
        .labels(replica="0").inc(9)
    reg.gauge("tenant_share", "escaping", ("tenant",)) \
        .labels(tenant='a"b\\c\n').set(1)
    reg.histogram("lat_s", "latency", buckets=(0.1,)).labels().observe(0.5)
    text = reg.to_prometheus()
    lines = text.splitlines()
    assert "# TYPE tok_total counter" in lines
    assert 'tok_total{replica="0"} 9' in lines
    # label values escape backslash, quote, newline per exposition 0.0.4
    assert any('tenant="a\\"b\\\\c\\n"' in ln for ln in lines)
    # histogram expands to _bucket (cumulative, +Inf last) + _sum + _count
    assert 'lat_s_bucket{le="0.1"} 0' in lines
    assert 'lat_s_bucket{le="+Inf"} 1' in lines
    assert "lat_s_count 1" in lines
    # write() routes on extension: .prom = text, else JSON
    prom = tmp_path / "m.prom"
    reg.write(str(prom))
    assert prom.read_text() == text
    js = tmp_path / "m.json"
    reg.write(str(js))
    assert json.loads(js.read_text())["tok_total"]["type"] == "counter"


# ---------------------------------------------------------------- traces
def test_trace_roundtrip_and_validation(tmp_path):
    tr = TraceRecorder()
    tr.set_process_name(0, "replica 0")
    tr.begin(0, 1, "PREFILL", slot=0)
    tr.instant(0, "hb_miss", tid=1)
    tr.counter(0, "engine", {"live_slots": 1})
    tr.end(0, 1, tokens=3)
    path = tmp_path / "t.json"
    tr.write(str(path))
    doc = json.loads(path.read_text())
    assert doc["displayTimeUnit"] == "ms"
    phases = [e["ph"] for e in doc["traceEvents"]]
    assert phases.count("B") == phases.count("E") == 1
    v = validate_chrome_trace(str(path))
    assert v["balanced"] and not v["unbalanced"] and v["pids"] == [0]
    # an unclosed span is flagged, not silently dropped
    tr.begin(0, 2, "DECODE")
    v2 = validate_chrome_trace(tr.to_chrome())
    assert not v2["balanced"] and v2["unbalanced"]
    assert tr.open_spans() == {(0, 2): ["DECODE"]}
    assert tr.end_if_open(0, 2) and not tr.end_if_open(0, 2)


def test_validator_rejects_malformed(tmp_path):
    with pytest.raises(ValueError):
        validate_chrome_trace({"no_events_here": 1})
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"traceEvents": [{"ph": "B"}]}))
    with pytest.raises(ValueError):
        validate_chrome_trace(str(bad))


def test_ring_buffer_bounds_memory():
    tr = TraceRecorder(limit=16)
    for i in range(100):
        tr.instant(0, f"e{i}")
    assert len(tr.events) == 16
    assert tr.total == 100 and tr.dropped == 84
    assert [e["name"] for e in tr.tail(2)] == ["e98", "e99"]


def test_null_sink_is_noop():
    nt = NullTrace()
    assert not nt.enabled and not NULL_TRACE.enabled
    nt.begin(0, 1, "X")
    nt.end(0, 1)
    nt.instant(0, "y")
    nt.counter(0, "c", {})
    assert nt.end_all(0) == 0 and not nt.end_if_open(0, 1)
    # default Telemetry routes to the shared null sink; metrics still work
    tm = Telemetry()
    assert tm.trace is NULL_TRACE
    tm.req_transition(0, 1, "QUEUED")
    tm.req_end(0, 1)
    assert tm.dump_flight("nothing-armed") is None
    with pytest.raises(ValueError):
        tm.write_trace("nowhere.json")


# ------------------------------------------------- engine instrumentation
def test_engine_spans_balanced_and_metrics(tmp_path):
    tm = Telemetry(trace=True)
    model, params = tiny_lm()
    eng = ServeEngine(model, params,
                      ServeConfig(batch_slots=2, max_len=64), telemetry=tm)
    for r in _reqs(4):
        eng.submit(r)
    done = eng.run()
    assert len(done) == 4
    assert tm.trace.open_spans() == {}
    names = {e["name"] for e in tm.trace.events if e["ph"] == "B"}
    assert {"QUEUED", "PREFILL", "DECODE"} <= names
    reg = tm.registry
    assert reg.value("engine_requests_submitted_total", replica="0") == 4
    fam = reg.to_dict()["engine_requests_finished_total"]
    assert sum(s["value"] for s in fam["series"]
               if s["labels"]["replica"] == "0") == 4
    assert reg.value("engine_tokens_total", replica="0") == \
        sum(len(r.output) for r in done)
    assert reg.value("engine_ticks_total", replica="0") > 0
    assert reg.value("engine_live_slots", replica="0") == 0
    path = tm.write_trace(str(tmp_path / "engine.json"))
    assert validate_chrome_trace(path)["balanced"]


def test_preemption_spans_balanced():
    tm = Telemetry(trace=True)
    model, params = tiny_lm()
    eng = ServeEngine(model, params, ServeConfig(
        batch_slots=2, max_len=64, policy="drf-fair", preempt=True,
        tenant_weights={"gold": 3, "free": 1},
        victim_policy="lowest-weight-share-first"), telemetry=tm)
    gold = [dataclasses.replace(r, tenant="gold")
            for r in _reqs(4, max_new=10, sampled=False)]
    for r in gold:
        eng.submit(r)
    eng.step()
    eng.step()
    free = [dataclasses.replace(r, tenant="free")
            for r in _reqs(2, max_new=4, sampled=False, base_id=50)]
    for r in free:
        eng.submit(r)
    done = eng.run()
    assert len(done) == 6
    assert eng.scheduler.preempted_total >= 1
    names = [e["name"] for e in tm.trace.events if e["ph"] == "B"]
    assert "PREEMPTED" in names
    assert tm.trace.open_spans() == {}
    assert tm.registry.value("serve_preempted", replica="0") >= 1


def test_cluster_chaos_spans_and_flight_dump(tmp_path):
    tm = Telemetry(trace=True, flight=128, flight_dir=str(tmp_path))
    model, params = tiny_lm()

    def make(rid):
        return ServeEngine(model, params,
                           ServeConfig(batch_slots=2, max_len=64))

    injector = ReplicaFaultInjector([FaultEvent(4, "kill", 1),
                                     FaultEvent(24, "rejoin", 1)])
    router = ClusterRouter(make, 3, policy="spread", miss_threshold=2,
                           injector=injector, telemetry=tm)
    for r in _reqs(9, max_new=8):
        router.submit(r)
    done = router.run(max_ticks=4000)
    assert len(done) == 9
    assert all(r.finish_reason != "failed" for r in done)
    assert tm.trace.open_spans() == {}
    replays = [e for e in tm.trace.events
               if e["ph"] == "B" and e["name"] == "REPLAY"]
    assert replays and all(e["pid"] == ROUTER_PID for e in replays)
    instants = {e["name"] for e in tm.trace.events if e["ph"] == "i"}
    assert {"hb_miss", "replica_lost", "place"} <= instants
    # the fence armed the flight recorder: one dump, self-contained
    assert len(tm.flight_dumps) == 1
    dump = json.loads(open(tm.flight_dumps[0]).read())
    assert dump["reason"].startswith("fence-replica1")
    assert dump["recovered"] >= 1
    # the dump is a fence-time snapshot: the victims' REPLAY spans are
    # open in it (they close later, on re-placement)
    assert any("REPLAY" in names for names in dump["open_spans"].values())
    assert dump["events"] and "cluster_recoveries" in dump["metrics"]
    assert tm.registry.value("cluster_recoveries") >= 1


# -------------------------------------------------- schema stability
def test_stats_schemas_are_registry_views():
    """The legacy dicts are now registry reads — their key sets are a
    frozen contract (dashboards + older tests parse them)."""
    eng = make_engine(batch_slots=2, max_len=64, cache="paged",
                      page_size=8, draft_k=2)
    for r in _reqs(3, sampled=False):
        eng.submit(r)
    eng.run()
    assert set(eng.kv_stats()) == {
        "cache", "kv_reserved_bytes", "page_size", "capacity_pages",
        "in_use_pages", "prefix_entries", "prefix_hits", "prefix_misses"}
    assert set(eng.spec_stats()) == {
        "draft_k", "drafter", "proposed", "accepted", "acceptance_rate",
        "spec_ticks", "tokens_per_tick"}
    assert set(eng.offer()) == {"free_slots", "free_pages", "page_size",
                                "queue_depth"}
    assert set(step_cache_stats()) == {"size", "hits", "misses", "build_s"}

    model, params = tiny_lm()
    router = ClusterRouter(
        lambda rid: ServeEngine(model, params,
                                ServeConfig(batch_slots=2, max_len=64)), 2)
    for r in _reqs(2, sampled=False):
        router.submit(r)
    router.run(max_ticks=2000)
    st = router.stats()
    assert set(st) == {"ticks", "recoveries", "replicas_lost", "failed",
                       "brownout_ticks", "queued", "replicas"}
    assert set(st["replicas"][0]) == {"state", "placements", "steps",
                                      "slow", "flags"}
