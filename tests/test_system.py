"""End-to-end behaviour tests for the whole system: scheduler placing real
(arch x shape) jobs with dry-run-derived profiles, driving actual JAX
training of a smoke model per the paper's event flow (Figure 3)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import SHAPES, cells, get_config
from repro.core import ClusterSpec, JobSpec, Simulator
from repro.core.costmodel import analytic_profile, load_dryrun_profiles
from repro.data import MarkovSynthetic
from repro.models import LM, RuntimeKnobs
from repro.optim import AdamWConfig
from repro.runtime.train import TrainConfig, Trainer


def test_every_runnable_cell_is_schedulable():
    """All 34 runnable (arch x shape) cells place + finish on a 2-pod
    cluster under the auto policy — Scylla's end-to-end promise."""
    sim = Simulator(ClusterSpec(n_pods=2, hosts_per_pod=8),
                    compile_cache=True)
    n = 0
    for arch, shape, skip in cells():
        if skip:
            continue
        sim.submit_at(float(n), JobSpec(f"{arch}/{shape}", arch, shape,
                                        chips=8, policy="auto", steps=10))
        n += 1
    res = sim.run()
    assert len(res["jobs"]) == n == 34
    assert res["pending"] == 0 and res["running"] == 0


def test_analytic_profile_covers_all_cells():
    for arch, shape, skip in cells():
        if skip:
            continue
        prof, infeed = analytic_profile(arch, shape)
        assert prof.flops > 0 and prof.hbm_bytes > 0, (arch, shape)
        assert infeed >= 0


def test_dryrun_profiles_loadable_when_present():
    profiles = load_dryrun_profiles("artifacts/roofline.json")
    if profiles:  # produced by launch/dryrun.py; present after the sweep
        assert all(p.flops > 0 for p in profiles.values())


def test_paper_event_flow_end_to_end(tmp_path):
    """Figure 3 flow: submit -> offers -> placement -> launch -> train ->
    finish, with a real (smoke) model actually training on the placed
    'gang' and checkpointing like Task-0 would."""
    sim = Simulator(ClusterSpec(n_pods=1, hosts_per_pod=4))
    sim.submit_at(0.0, JobSpec("real", "internlm2-1.8b", "train_4k",
                               chips=8, policy="minhost", steps=100))
    res = sim.run()
    job = res["jobs"]["real"]
    assert job.n_hosts == 2  # minhost packed 8 chips onto 2 hosts

    # now actually run the training the placement represents (reduced cfg)
    cfg = dataclasses.replace(get_config("internlm2-1.8b", smoke=True),
                              num_layers=2, vocab_size=64)
    model = LM(cfg, RuntimeKnobs(cache_dtype=jnp.float32))
    data = MarkovSynthetic(vocab_size=64, seq_len=32, global_batch=4,
                           seed=0)
    tr = Trainer(model, data, TrainConfig(
        steps=12, checkpoint_every=6, log_every=4,
        checkpoint_dir=str(tmp_path / "ck"),
        opt=AdamWConfig(warmup_steps=2, total_steps=12)))
    out = tr.run()
    assert out["step"] == 12
    # a fresh trainer resumes from the checkpoint (restart path)
    tr2 = Trainer(model, data, tr.tcfg)
    assert tr2.maybe_restore() and tr2.step == 12
