"""Pipeline parallelism: pipelined forward == sequential forward (subprocess
with 4 host devices as 4 stages)."""
import subprocess
import sys
import textwrap

PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.compat import AxisType, make_mesh
    from repro.sharding.pipeline import make_pipelined_forward

    S, LPS, M, MB, D = 4, 2, 6, 3, 8   # 4 stages x 2 layers, 6 microbatches
    rng = np.random.default_rng(0)
    # per-layer MLP params stacked (stages, layers_per_stage, ...)
    w = jnp.asarray(rng.normal(size=(S, LPS, D, D)) * 0.3, jnp.float32)
    b = jnp.asarray(rng.normal(size=(S, LPS, D)) * 0.1, jnp.float32)
    params = {"w": w, "b": b}
    x = jnp.asarray(rng.normal(size=(M, MB, D)), jnp.float32)

    def stage_fn(p, h):
        def layer(h, wb):
            wi, bi = wb
            return jnp.tanh(h @ wi + bi), None
        h, _ = jax.lax.scan(layer, h, (p["w"], p["b"]))
        return h

    # sequential reference: all S*LPS layers in order
    def reference(x):
        h = x
        for s in range(S):
            h = stage_fn({"w": w[s], "b": b[s]}, h)
        return h

    mesh = make_mesh((S,), ("stage",),
                     axis_types=(AxisType.Explicit,))
    # leading dim S is sharded over the stage axis; shard_map's local view
    # keeps it as a singleton that pipeline_apply's p[0] strips
    fwd = make_pipelined_forward(stage_fn, mesh, axis_name="stage")
    out = jax.jit(fwd)(params, x)
    ref = jax.vmap(reference)(x)
    err = float(jnp.max(jnp.abs(out - ref)))
    assert err < 1e-5, err
    # the pipelined HLO must contain collective-permute (the PP schedule)
    txt = jax.jit(fwd).lower(params, x).compile().as_text()
    assert "collective-permute" in txt
    print("OK pipeline", err)
""")


def test_pipeline_matches_sequential():
    p = subprocess.run([sys.executable, "-c", PROG], capture_output=True,
                       text=True, timeout=560, cwd=".")
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    assert "OK pipeline" in p.stdout
