"""Hypothesis property tests on the system's invariants."""
import math

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

pytestmark = pytest.mark.slow  # hypothesis-heavy: full-suite lane only

from repro.core import (Cluster, ClusterSpec, DRFAllocator, JobSpec,
                        MinHostPolicy, ResourceSpec, SpreadPolicy)
from repro.data import MarkovSynthetic, SyntheticDataset, host_shard
from repro.launch.roofline import _shape_bytes
from repro.optim import dequantize_int8, quantize_int8

policies = st.sampled_from([SpreadPolicy(), MinHostPolicy()])
cluster_specs = st.builds(ClusterSpec,
                          n_pods=st.integers(1, 3),
                          hosts_per_pod=st.integers(1, 8))


@settings(max_examples=50, deadline=None)
@given(spec=cluster_specs, chips=st.integers(1, 40), policy=policies)
def test_placement_gang_exact_or_none(spec, chips, policy):
    """A placement either satisfies the gang exactly within offer limits,
    or is None when demand exceeds capacity."""
    c = Cluster(spec)
    offers = c.advertise()
    job = JobSpec("j", "internlm2-1.8b", "train_4k", chips=chips)
    pl = policy.place(job, offers, c)
    if chips > spec.n_chips:
        assert pl is None
        return
    assert pl is not None
    assert sum(pl.assignment.values()) == chips
    free = {o.agent.agent_id: o.available.chips for o in offers}
    for aid, n in pl.assignment.items():
        assert 0 < n <= free[aid]
    c.allocate("j", pl.assignment)  # must not raise


@settings(max_examples=30, deadline=None)
@given(spec=cluster_specs,
       demands=st.lists(st.integers(1, 12), min_size=1, max_size=6))
def test_allocate_release_conserves_capacity(spec, demands):
    c = Cluster(spec)
    placed = []
    for i, d in enumerate(demands):
        pl = MinHostPolicy().place(
            JobSpec(f"j{i}", "internlm2-1.8b", "train_4k", chips=d),
            c.advertise(), c)
        if pl is not None:
            c.allocate(f"j{i}", pl.assignment)
            placed.append(f"j{i}")
    used = c.used().chips
    assert used <= spec.n_chips
    for jid in placed:
        c.release(jid)
    assert c.used().chips == 0


@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 2), st.integers(1, 8)),
                min_size=1, max_size=20))
def test_drf_shares_bounded_and_conserved(events):
    total = ResourceSpec(64, 64 * 16e9)
    drf = DRFAllocator(total)
    held = {f"f{i}": [] for i in range(3)}
    for fw, chips in events:
        name = f"f{fw}"
        drf.register(name)
        res = ResourceSpec(chips, chips * 16e9)
        if sum(r.chips for rs in held.values() for r in rs) + chips <= 64:
            drf.charge(name, res)
            held[name].append(res)
        assert 0.0 <= drf.dominant_share(name) <= 1.0
    for name, rss in held.items():
        if name not in drf.accounts:
            continue
        for r in rss:
            drf.credit(name, r)
        assert drf.dominant_share(name) == pytest.approx(0.0, abs=1e-9)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(-1e4, 1e4, allow_nan=False), min_size=1,
                max_size=64))
def test_int8_quantization_error_bound(xs):
    x = np.asarray(xs, np.float32)
    q, scale = quantize_int8(x)
    err = np.abs(dequantize_int8(q, scale) - x)
    assert float(err.max()) <= float(scale) * 0.5 + 1e-6


@settings(max_examples=20, deadline=None)
@given(step=st.integers(0, 1000), seed=st.integers(0, 10),
       hosts=st.sampled_from([1, 2, 4, 8]))
def test_data_determinism_and_shard_partition(step, seed, hosts):
    ds = SyntheticDataset(vocab_size=97, seq_len=16, global_batch=16,
                          seed=seed)
    a, b = ds.batch(step)["tokens"], ds.batch(step)["tokens"]
    assert (a == b).all()  # same (seed, step) -> same batch, any host
    shards = [host_shard({"tokens": a}, i, hosts)["tokens"] for i in
              range(hosts)]
    assert np.concatenate(shards).shape == a.shape
    assert (np.concatenate(shards) == a).all()


def test_markov_dataset_is_learnable_structure():
    ds = MarkovSynthetic(vocab_size=64, seq_len=128, global_batch=8,
                         seed=3, noise=0.1)
    t = ds.batch(0)["tokens"]
    hits = (t[:, 1:] == (5 * t[:, :-1] + 17) % 64).mean()
    assert 0.8 < hits < 0.98  # ~1 - noise


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(1, 64), min_size=0, max_size=4),
       st.sampled_from(["f32", "bf16", "s32", "pred", "f16"]))
def test_hlo_shape_bytes_parser(dims, dtype):
    nbytes = {"f32": 4, "bf16": 2, "s32": 4, "pred": 1, "f16": 2}[dtype]
    s = f"{dtype}[{','.join(map(str, dims))}]{{{','.join('0' * len(dims))}}}"
    expected = nbytes * int(np.prod(dims)) if dims else nbytes
    assert _shape_bytes(s) == expected
