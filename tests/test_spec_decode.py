"""Speculative multi-token decode: drafter registry + n-gram proposer
units, multi-token verify kernel/oracle parity, model-level verify ==
sequential decode (bitwise), engine-level greedy speculative == PR 1
baseline decode (bitwise, dense + paged, across draft lengths and slot
placements), seeded sampled replay determinism, and paged rollback
refcount balance including rollback-then-preempt round trips.  Engine
construction helpers live in tests/conftest.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import cached_engine, make_engine, tiny_lm

from repro.kernels.decode_attention import decode_attention_tpu
from repro.kernels.paged_attention import paged_decode_attention_tpu
from repro.kernels.ref import decode_attention_ref, paged_decode_attention_ref
from repro.runtime.draft import DRAFTERS, NgramDrafter, get_drafter
from repro.runtime.sampling import (SamplingParams, sample_tokens,
                                    sample_tokens_multi, speculative_accept)
from repro.runtime.serve import Request, RequestState


# ------------------------------------------------------------ drafter units
def test_drafter_registry_mirrors_policies():
    assert set(DRAFTERS) == {"ngram"}
    for name in DRAFTERS:
        assert get_drafter(name).name == name
    with pytest.raises(KeyError):
        get_drafter("small-model")  # future registry entry, not yet


def test_ngram_drafter_proposes_continuation_of_tail_match():
    d = NgramDrafter(max_n=3, min_n=1)
    ctx = np.array([5, 6, 7, 8, 5, 6, 7], np.int32)
    # tail [5,6,7] matched at j=0; continuation is what followed: [8, 5]
    assert d.propose(ctx, 2).tolist() == [8, 5]
    # proposals never invent tokens: no tail match -> empty
    assert d.propose(np.array([1, 2, 3, 4, 5], np.int32), 2).size == 0
    assert d.propose(np.array([1], np.int32), 4).size == 0
    assert d.propose(ctx, 0).size == 0


def test_ngram_drafter_prefers_full_continuation_and_is_pure():
    d = NgramDrafter(max_n=3, min_n=1)
    ctx = np.array([1, 2] * 5, np.int32)  # period-2 decode loop
    # most recent tail match truncates at the context end; the drafter
    # must fall back to the latest occurrence with a FULL k continuation
    assert d.propose(ctx, 3).tolist() == [1, 2, 1]
    assert d.propose(ctx, 3).tolist() == d.propose(ctx, 3).tolist()


def test_speculative_accept_longest_confirmed_prefix():
    assert speculative_accept([], [4]) == 0
    assert speculative_accept([4], [4, 9]) == 1
    assert speculative_accept([4, 5, 6], [4, 5, 6, 7]) == 3
    assert speculative_accept([4, 5, 6], [4, 9, 6, 7]) == 1
    assert speculative_accept([3], [4, 3]) == 0  # position 0 mismatch


# ----------------------------------------------------- sampling (pure fn)
def test_sample_tokens_multi_matches_per_row_sample_tokens():
    """Row t of the multi sampler is bitwise the single-token sampler at
    fold position pos + t — the property that makes accepted speculative
    draws identical to the baseline's draws."""
    rng = np.random.default_rng(0)
    b, t, v = 3, 4, 32
    logits = jnp.asarray(rng.normal(size=(b, t, v)) * 3, jnp.float32)
    pos = jnp.asarray(rng.integers(0, 20, b), jnp.int32)
    temp = jnp.asarray([0.0, 0.9, 1.7], jnp.float32)  # greedy row included
    top_k = jnp.asarray([0, 5, 0], jnp.int32)
    top_p = jnp.asarray([1.0, 1.0, 0.8], jnp.float32)
    keys = jnp.asarray(rng.integers(0, 2 ** 31, (b, 2)), jnp.uint32)
    multi = np.asarray(sample_tokens_multi(logits, pos, temp, top_k, top_p,
                                           keys))
    for i in range(t):
        row = np.asarray(sample_tokens(logits[:, i], pos + i, temp, top_k,
                                       top_p, keys))
        assert np.array_equal(multi[:, i], row)
    # greedy row is the raw argmax of every verify column
    assert np.array_equal(multi[0], np.asarray(jnp.argmax(logits[0], -1)))


# -------------------------------------------------------- kernel parity
RNG = np.random.default_rng(7)


def arr(*s):
    return jnp.asarray(RNG.normal(size=s), jnp.float32)


@pytest.mark.parametrize("window", [0, 8])
@pytest.mark.parametrize("tq", [2, 4])
def test_multi_token_kernel_matches_ref(window, tq):
    """The dense ragged kernel with a T-row query block equals the jnp
    oracle — including windowed cases where a short draft row is fully
    masked inside a block another row needs."""
    b, kv, g, d, s = 3, 2, 2, 16, 64
    h = kv * g
    q = arr(b, h, tq, d)
    k, v = arr(b, kv, s, d), arr(b, kv, s, d)
    pos = np.array([0, 13, 59 - tq], np.int32)
    ref = decode_attention_ref(q, k, v, pos, window=window)
    out = decode_attention_tpu(q, k, v, pos, window=window, block_k=16,
                               interpret=True)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-3
    # parked slot still returns zeros with a multi-row block
    out2 = decode_attention_tpu(q, k, v, np.array([-1, 5, 20], np.int32),
                                window=window, block_k=16, interpret=True)
    assert float(jnp.max(jnp.abs(out2[0]))) == 0.0


@pytest.mark.parametrize("window", [0, 8])
def test_multi_token_paged_kernel_matches_ref(window):
    b, kv, g, d, ps, mp, tq = 3, 2, 2, 16, 8, 8, 3
    h = kv * g
    n_pages = 1 + b * mp
    kp, vp = arr(n_pages, kv, ps, d), arr(n_pages, kv, ps, d)
    pt = RNG.permutation(np.arange(1, n_pages))[:b * mp] \
        .reshape(b, mp).astype(np.int32)
    q = arr(b, h, tq, d)
    pos = np.array([-1, 7, 50], np.int32)
    ref = paged_decode_attention_ref(q, kp, vp, pt, pos, window=window)
    out = paged_decode_attention_tpu(q, kp, vp, jnp.asarray(pt), pos,
                                     window=window, interpret=True)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-3
    assert float(jnp.max(jnp.abs(out[0]))) == 0.0


# ------------------------------------------- model-level verify (bitwise)
def test_verify_step_logits_bitwise_equal_sequential_decode():
    """One multi-token verify pass produces, row by row, the exact fp32
    logits sequential decode emits at the same positions — dense and
    paged.  This is the kernel-level half of the bitwise guarantee."""
    model, params = tiny_lm()
    B, S, T, ps = 2, 32, 3, 8
    dec = jax.jit(model.decode_step)
    spec = jax.jit(model.decode_step_spec)
    rng = np.random.default_rng(1)
    toks = rng.integers(0, 64, size=(B, T)).astype(np.int32)
    pos0 = np.array([2, 9], np.int32)

    caches = model.init_cache(B, S)
    seq = []
    for t in range(T):
        lg, caches = dec(params, caches, jnp.asarray(toks[:, t:t + 1]),
                         jnp.asarray(pos0 + t))
        seq.append(np.asarray(lg))
    seq = np.stack(seq, axis=1)
    got, _ = spec(params, model.init_cache(B, S), jnp.asarray(toks),
                  jnp.asarray(pos0))
    assert np.array_equal(np.asarray(got), seq)

    mp = S // ps
    pt = np.arange(1, 1 + B * mp, dtype=np.int32).reshape(B, mp)
    n_pages = 1 + B * mp
    decp = jax.jit(lambda p, c, t_, po, pi: model.decode_step_paged(
        p, c, t_, po, pi, page_size=ps))
    specp = jax.jit(lambda p, c, t_, po, pi: model.decode_step_spec_paged(
        p, c, t_, po, pi, page_size=ps))
    caches = model.init_cache_paged(n_pages, ps)
    seqp = []
    for t in range(T):
        lg, caches = decp(params, caches, jnp.asarray(toks[:, t:t + 1]),
                          jnp.asarray(pos0 + t), jnp.asarray(pt))
        seqp.append(np.asarray(lg))
    seqp = np.stack(seqp, axis=1)
    gotp, _ = specp(params, model.init_cache_paged(n_pages, ps),
                    jnp.asarray(toks), jnp.asarray(pos0), jnp.asarray(pt))
    assert np.array_equal(np.asarray(gotp), seqp)
    assert np.array_equal(seqp, seq)  # layout-invariant too


def test_spec_decode_rejected_for_ssm_plans():
    import dataclasses

    from repro.configs import get_config
    from repro.models import LM, RuntimeKnobs
    from repro.runtime.serve import ServeConfig, ServeEngine

    cfg = dataclasses.replace(get_config("mamba2-1.3b", smoke=True),
                              vocab_size=64)
    ssm = LM(cfg, RuntimeKnobs(cache_dtype=jnp.float32))
    with pytest.raises(ValueError, match="speculative"):
        ServeEngine(ssm, ssm.init(jax.random.PRNGKey(0)),
                    ServeConfig(batch_slots=1, max_len=32, draft_k=2))
    with pytest.raises(ValueError, match="continuous"):
        make_engine(batch_slots=1, max_len=32, mode="wave", draft_k=2)
    with pytest.raises(ValueError):
        make_engine(batch_slots=1, max_len=32, draft_k=-1)
    with pytest.raises(ValueError, match="too deep"):
        make_engine(batch_slots=1, max_len=8, draft_k=8)


# --------------------------------------------------- engine level (greedy)
def _trace(seed, n, max_new=10, vocab=64):
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, vocab, size=int(rng.integers(1, 7)))
             .astype(np.int32), max_new) for _ in range(n)]


def _serve(eng, trace, sampling=None):
    for i, (prompt, max_new) in enumerate(trace):
        eng.submit(Request(i, prompt.copy(), max_new_tokens=max_new,
                           sampling=sampling or SamplingParams()))
    return {r.req_id: r.output for r in eng.run()}


def _baseline(trace):
    return _serve(cached_engine("spec-base", batch_slots=2, max_len=64),
                  trace)


@pytest.mark.parametrize("cache_kw", [
    {}, {"cache": "paged", "page_size": 8},
], ids=["dense", "paged"])
@pytest.mark.parametrize("k", [1, 3])
def test_greedy_spec_engine_bitwise_matches_baseline(cache_kw, k):
    """The acceptance gate: greedy speculative output streams are
    bitwise-identical to the non-speculative engine's, dense and paged,
    across draft depths — and the spec path actually speculated."""
    trace = _trace(0, 5)
    base = _baseline(trace)
    eng = cached_engine(f"spec-{k}-{tuple(sorted(cache_kw))}",
                        batch_slots=2, max_len=64, draft_k=k, **cache_kw)
    assert _serve(eng, trace) == base
    st = eng.spec_stats()
    assert st["proposed"] > 0  # the drafter did real work on this trace
    assert 0.0 <= st["acceptance_rate"] <= 1.0
    assert st["tokens_per_tick"] >= 1.0  # never worse than plain decode


def test_spec_engine_bitwise_across_slot_placements():
    """The same requests decode identically whatever slot mix serves
    them: 1-slot (serial), 3-slot (all concurrent), and arrival-order
    permutations over a 2-slot engine."""
    trace = _trace(4, 3, max_new=8)
    base = _baseline(trace)
    for slots in (1, 3):
        eng = cached_engine(f"spec-slots-{slots}", batch_slots=slots,
                            max_len=64, draft_k=2)
        assert _serve(eng, trace) == base
    eng = cached_engine("spec-slots-2", batch_slots=2, max_len=64,
                        draft_k=2)
    for i, (prompt, max_new) in reversed(list(enumerate(trace))):
        eng.submit(Request(i, prompt.copy(), max_new_tokens=max_new))
    assert {r.req_id: r.output for r in eng.run()} == base


def test_draft_cap_respects_budget_window_and_page_span():
    """_draft_cap never lets a draft overshoot the token budget, the
    max_len window, or (paged) the slot's reserved page span."""
    eng = make_engine(batch_slots=1, max_len=16, draft_k=4, cache="paged",
                      page_size=8, num_pages=5)
    req = Request(0, np.arange(1, 4, dtype=np.int32), max_new_tokens=20)
    eng.submit(req)
    eng.step()  # prefill + first verify tick
    s = next(i for i, r in enumerate(eng.active) if r is req)
    cap = eng._draft_cap(s, req)
    assert cap <= req.max_new_tokens - len(req.output) - 1
    assert int(eng.pos[s]) + 1 + cap <= eng.max_len - 1
    assert int(eng.pos[s]) + cap <= eng.kv.slot_span(s) - 1
    out = eng.run()  # drains without tripping any page/window assert
    assert out[0].finish_reason == "length"
    assert eng.kv.pool.in_use == 0


def test_stop_sequences_truncate_accepted_drafts():
    """A stop hit inside an accepted draft block ends the request at the
    stop token — accepted-but-past-stop tokens must be discarded, like
    the sequential engine which never produces them."""
    trace = _trace(11, 1, max_new=10)
    base = _baseline(trace)[0]
    assert len(base) > 3
    stop = (tuple(base[1:3]),)
    ref = _serve(cached_engine("spec-base", batch_slots=2, max_len=64),
                 trace, SamplingParams(stop=stop))
    got = _serve(cached_engine("spec-3-()", batch_slots=2, max_len=64,
                               draft_k=3), trace, SamplingParams(stop=stop))
    assert got == ref  # bitwise incl. the truncation point
    assert len(got[0]) < len(base)  # the stop actually fired early
    assert tuple(got[0][-2:]) == stop[0]


# ------------------------------------------------- engine level (sampled)
def test_seeded_sampled_spec_replays_deterministically():
    """Seeded sampled speculative runs are replay-deterministic AND equal
    to the non-speculative engine's sampled trajectory — each verify row
    folds its absolute position into the request key, so acceptance only
    ever confirms the token the baseline would have drawn."""
    trace = _trace(8, 4)
    sp = SamplingParams(temperature=1.4, top_k=8, seed=123)
    base = _serve(cached_engine("spec-base", batch_slots=2, max_len=64),
                  trace, sp)
    eng = cached_engine("spec-3-()", batch_slots=2, max_len=64, draft_k=3)
    first = _serve(eng, trace, sp)
    again = _serve(eng, trace, sp)
    assert first == again == base
    paged = _serve(
        cached_engine("spec-3-('cache', 'page_size')", batch_slots=2,
                      max_len=64, draft_k=3, cache="paged", page_size=8),
        trace, sp)
    assert paged == base


# ------------------------------------------ rollback + preemption (paged)
_WEIGHTED = dict(policy="drf-fair", tenant_weights={"gold": 3, "free": 1},
                 preempt=True, victim_policy="lowest-weight-share-first")


def _prompts(n, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 64, size=int(rng.integers(2, 6)))
            .astype(np.int32) for _ in range(n)]


def _spec_flood(eng, prompts, *, n_gold, max_new=8):
    for i in range(n_gold):
        eng.submit(Request(i, prompts[i].copy(), max_new_tokens=max_new,
                           tenant="gold"))
    eng.step()
    eng.step()
    for i in range(n_gold, len(prompts)):
        eng.submit(Request(i, prompts[i].copy(), max_new_tokens=max_new,
                           tenant="free"))
    return {r.req_id: r for r in eng.run()}


def test_paged_rollback_then_preempt_refcount_balanced_and_bitwise():
    """The hard composition: speculative rejections (position rollback)
    interleaved with preemption checkpoints (page-chain detach/attach)
    must leak no page, double-free no page, and still replay every
    request bitwise-identical to its uninterrupted solo run."""
    prompts = _prompts(9, seed=3)
    solo = cached_engine("spec-solo", batch_slots=1, max_len=64, draft_k=3)
    ref = [solo.submit(Request(i, p.copy(), max_new_tokens=8)).result()
           .output for i, p in enumerate(prompts)]
    eng = make_engine(batch_slots=4, max_len=64, cache="paged", page_size=8,
                      prefix_cache=False, draft_k=3, **_WEIGHTED)
    done = _spec_flood(eng, prompts, n_gold=7)
    assert eng.scheduler.preempted_total >= 1
    assert sum(r.preempt_count for r in done.values()) >= 1
    for i in range(len(prompts)):
        assert done[i].output == ref[i], \
            f"request {i} (preempted {done[i].preempt_count}x) diverged"
    # refcount balance: every non-null page back on the free list
    assert eng.kv.pool.in_use == 0
    assert not np.any(np.asarray(eng.kv.pool.ref[1:]))
    assert not np.any(eng.kv.page_table)
    assert all(v == 0.0 for v in eng.scheduler.shares().values())


def test_dense_spec_preemption_round_trip_bitwise():
    """Dense checkpoint (host stripe snapshot) under speculation: stale
    rejected-draft K/V rides along in the snapshot and must never leak
    into the resumed stream."""
    prompts = _prompts(8, seed=6)
    solo = cached_engine("spec-solo", batch_slots=1, max_len=64, draft_k=3)
    ref = [solo.submit(Request(i, p.copy(), max_new_tokens=8)).result()
           .output for i, p in enumerate(prompts)]
    eng = make_engine(batch_slots=4, max_len=64, draft_k=3, **_WEIGHTED)
    done = _spec_flood(eng, prompts, n_gold=6)
    assert eng.scheduler.preempted_total >= 1
    for i in range(len(prompts)):
        assert done[i].output == ref[i]


# ----------------------------------------------------- hypothesis (slow)
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    pass
else:
    @pytest.mark.slow
    @settings(max_examples=12, deadline=None)
    @given(seed=st.integers(0, 10_000), k=st.sampled_from([1, 3]),
           paged=st.booleans(), n=st.integers(1, 4))
    def test_greedy_spec_bitwise_hypothesis(seed, k, paged, n):
        """Random traces decode bitwise-identically through the
        speculative engines across draft lengths and cache layouts
        (engines are shared so each (k, layout) compiles once)."""
        trace = _trace(seed, n, max_new=8)
        base = _baseline(trace)
        kw = {"cache": "paged", "page_size": 8} if paged else {}
        eng = cached_engine(f"spec-{k}-{tuple(sorted(kw))}", batch_slots=2,
                            max_len=64, draft_k=k, **kw)
        assert _serve(eng, trace) == base

    @pytest.mark.slow
    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 10_000), sample_seed=st.integers(0, 2 ** 20))
    def test_sampled_spec_replay_hypothesis(seed, sample_seed):
        """Seeded sampled speculative runs replay bitwise and match the
        non-speculative sampled trajectory for arbitrary seeds."""
        trace = _trace(seed, 2, max_new=6)
        sp = SamplingParams(temperature=1.1, top_k=6, seed=sample_seed)
        base = _serve(cached_engine("spec-base", batch_slots=2, max_len=64),
                      trace, sp)
        eng = cached_engine("spec-3-()", batch_slots=2, max_len=64,
                            draft_k=3)
        assert _serve(eng, trace, sp) == base

    @pytest.mark.slow
    @settings(max_examples=4, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_paged_rollback_refcount_hypothesis(seed):
        """Random spec + preemption floods always drain the pool back to
        refcount balance (no leak, no double-free)."""
        prompts = _prompts(8, seed=seed)
        eng = make_engine(batch_slots=3, max_len=64, cache="paged",
                          page_size=8, prefix_cache=False, draft_k=2,
                          **_WEIGHTED)
        _spec_flood(eng, prompts, n_gold=6)
        assert eng.kv.pool.in_use == 0
        assert not np.any(np.asarray(eng.kv.pool.ref[1:]))
        assert not np.any(eng.kv.page_table)
