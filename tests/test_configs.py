"""Config registry: exact assigned dims, param counts vs public sizes."""
import pytest

from repro.configs import SHAPES, cells, get_config, list_archs, smoke

EXPECTED_BILLIONS = {  # public sizes (±20% tolerance on our counting)
    "zamba2-2.7b": 2.7, "llava-next-mistral-7b": 7.2, "gemma3-27b": 27.0,
    "qwen2.5-32b": 32.8, "granite-20b": 20.0, "internlm2-1.8b": 1.8,
    "mixtral-8x7b": 46.7, "qwen3-moe-235b-a22b": 235.0, "mamba2-1.3b": 1.3,
    "musicgen-large": 3.3,
}


def test_ten_archs():
    assert len(list_archs()) == 10


@pytest.mark.parametrize("arch", list_archs())
def test_param_count_matches_public(arch):
    n = get_config(arch).param_count() / 1e9
    exp = EXPECTED_BILLIONS[arch]
    assert abs(n - exp) / exp < 0.20, f"{arch}: {n:.2f}B vs public {exp}B"


def test_active_params_moe():
    cfg = get_config("qwen3-moe-235b-a22b")
    a = cfg.param_count(active_only=True) / 1e9
    assert 18 < a < 26  # a22b
    cfg = get_config("mixtral-8x7b")
    a = cfg.param_count(active_only=True) / 1e9
    assert 11 < a < 15  # ~12.9b active


def test_assigned_dims_exact():
    c = get_config("gemma3-27b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (62, 5376, 32, 16, 21504, 262144)
    c = get_config("qwen3-moe-235b-a22b")
    assert (c.num_layers, c.moe.num_experts, c.moe.experts_per_token,
            c.moe.d_ff) == (94, 128, 8, 1536)
    c = get_config("mamba2-1.3b")
    assert c.is_attention_free and c.ssm.d_state == 128
    c = get_config("granite-20b")
    assert c.num_kv_heads == 1 and not c.gated_mlp


def test_cells_40_with_skips():
    all_cells = cells()
    assert len(all_cells) == 40
    skipped = [c for c in all_cells if c[2]]
    assert len(skipped) == 6  # pure full-attention archs skip long_500k
    for arch, shape, _ in skipped:
        assert shape == "long_500k"
        assert not get_config(arch).supports_long_context


def test_shapes_assigned():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].kind == "decode"
    assert SHAPES["long_500k"].seq_len == 524_288


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_reduction_preserves_family(arch):
    full, small = get_config(arch), get_config(arch, smoke=True)
    assert small.family == full.family
    assert (small.moe is None) == (full.moe is None)
    assert (small.ssm is None) == (full.ssm is None)
    assert small.d_model <= 64 and small.vocab_size <= 256
