"""Model-level Pallas integration: use_pallas=True (kernels, interpret mode
on CPU) must reproduce the XLA path end-to-end — forward, prefill, decode —
for an attention arch, a windowed (SWA/MoE) arch, and the SSM arch."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import LM, RuntimeKnobs


def _models(arch, seq):
    cfg = get_config(arch, smoke=True)
    if cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, eval_capacity_factor=float(cfg.moe.num_experts)))
    base = RuntimeKnobs(cache_dtype=jnp.float32, q_chunk=min(16, seq))
    xla = LM(cfg, base)
    pal = LM(cfg, base.with_(use_pallas=True))
    return cfg, xla, pal


@pytest.mark.parametrize("arch,seq", [
    ("internlm2-1.8b", 32),   # plain GQA attention
    ("mixtral-8x7b", 32),     # SWA window + MoE
    ("mamba2-1.3b", 32),      # SSD kernel
    ("zamba2-2.7b", 32),      # hybrid: SSD + shared attention
])
def test_pallas_model_forward_matches_xla(arch, seq):
    cfg, xla, pal = _models(arch, seq)
    params = xla.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, seq),
                                          0, cfg.vocab_size)}
    lx, _ = jax.jit(xla.loss)(params, batch)
    lp, _ = jax.jit(pal.loss)(params, batch)
    assert abs(float(lx) - float(lp)) < 2e-4, (arch, float(lx), float(lp))


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "zamba2-2.7b"])
def test_pallas_decode_matches_xla(arch):
    seq = 16
    cfg, xla, pal = _models(arch, seq)
    params = xla.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, seq), 0,
                                cfg.vocab_size)
    cx = xla.init_cache(2, seq)
    cp = pal.init_cache(2, seq)
    sx = jax.jit(xla.decode_step)
    sp = jax.jit(pal.decode_step)
    for t in range(6):
        lx, cx = sx(params, cx, tokens[:, t:t + 1], jnp.int32(t))
        lp, cp = sp(params, cp, tokens[:, t:t + 1], jnp.int32(t))
        err = float(jnp.max(jnp.abs(lx - lp)))
        assert err < 2e-3, (arch, t, err)
