import os
import sys

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device; multi-device tests spawn subprocesses.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest

# --------------------------------------------------------------------------
# Shared serving fixtures.  The engine/config construction helpers below
# were copy-pasted across test_serving_api.py / test_preemption.py /
# test_paged_kv.py (and now test_spec_decode.py); they live here once so
# every suite shares ONE tiny model (params built once per session) and
# the module-level compiled-step LRU actually deduplicates jit work
# across test files.  They are plain functions (not only fixtures) so
# hypothesis-driven tests can call them without function-scoped-fixture
# health errors.
# --------------------------------------------------------------------------
_SHARED = {}


def tiny_lm(arch="internlm2-1.8b", **overrides):
    """(model, params) for the canonical serving test model: 2 layers,
    64-token vocab, fp32 KV cache (bitwise-equality tests need exact
    cache round trips).  Cached per (arch, overrides) for the session."""
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import LM, RuntimeKnobs

    over = dict({"num_layers": 2, "vocab_size": 64}, **overrides)
    key = (arch, tuple(sorted(over.items())))
    if key not in _SHARED:
        cfg = dataclasses.replace(get_config(arch, smoke=True), **over)
        model = LM(cfg, RuntimeKnobs(cache_dtype=jnp.float32))
        _SHARED[key] = (model, model.init(jax.random.PRNGKey(0)))
    return _SHARED[key]


def make_engine(**kw):
    """Fresh ServeEngine over the shared tiny model (compiled steps still
    dedupe through the runtime.steps module LRU)."""
    from repro.runtime.serve import ServeConfig, ServeEngine

    model, params = tiny_lm()
    return ServeEngine(model, params, ServeConfig(**kw))


def cached_engine(name, **kw):
    """Engines are reusable after run(); suites share them by name so the
    jitted steps compile once per test session.  The kwargs are part of
    the cache key — the cache is global across test modules now, so two
    files reusing a generic name ("dense", "wave") with different
    configs must get different engines, not silently share one."""
    key = ("engine", name,
           tuple(sorted((k, repr(v)) for k, v in kw.items())))
    if key not in _SHARED:
        _SHARED[key] = make_engine(**kw)
    return _SHARED[key]


@pytest.fixture(scope="session")
def tiny_serving_lm():
    """(model, params) fixture view of ``tiny_lm()``."""
    return tiny_lm()


@pytest.fixture
def engine_factory():
    """Fixture view of ``make_engine`` (fresh engine per call)."""
    return make_engine
