"""Sharding rules (divisibility across all archs) + roofline HLO analyzer."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest
from repro.compat import AxisType, abstract_mesh

from repro.configs import get_config, list_archs
from repro.launch.roofline import analyze_hlo, roofline
from repro.models import LM, RuntimeKnobs
from repro.sharding import opt_state_shardings, param_shardings


def _mesh(shape, axes):
    return abstract_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("mesh_shape,axes", [
    ((16, 16), ("data", "model")),
    ((2, 16, 16), ("pod", "data", "model")),
])
def test_param_shardings_divisible_all_archs(arch, mesh_shape, axes):
    """Every sharded dim must divide its mesh axes — for the FULL configs."""
    mesh = _mesh(mesh_shape, axes)
    cfg = get_config(arch)
    model = LM(cfg, RuntimeKnobs(param_dtype=jnp.bfloat16))
    specs = model.param_specs()
    for shardings in (param_shardings(mesh, cfg, specs, fsdp=True),
                      param_shardings(mesh, cfg, specs, fsdp=False),
                      opt_state_shardings(mesh, cfg, specs, fsdp=True)):
        flat_sh = jax.tree_util.tree_flatten_with_path(shardings)[0]
        flat_sp = jax.tree.leaves(specs)
        sizes = dict(zip(axes, mesh_shape))
        for (path, sh), spec in zip(flat_sh, flat_sp):
            for dim, ax in zip(spec.shape, sh.spec):
                if ax is None:
                    continue
                n = (sizes[ax] if isinstance(ax, str)
                     else int(jnp.prod(jnp.asarray([sizes[a] for a in ax]))))
                assert dim % n == 0, (arch, path, spec.shape, sh.spec)


def test_big_params_get_meaningfully_sharded():
    """No parameter >100M elements may end up fully replicated (small
    per-layer tensors like MoE routers stay replicated by design)."""
    mesh = _mesh((16, 16), ("data", "model"))
    for arch in list_archs():
        cfg = get_config(arch)
        model = LM(cfg, RuntimeKnobs(param_dtype=jnp.bfloat16))
        specs = model.param_specs()
        sh = param_shardings(mesh, cfg, specs, fsdp=True)
        flat = zip(jax.tree_util.tree_flatten_with_path(specs)[0],
                   jax.tree.leaves(sh))
        for (path, spec), s in flat:
            n = 1
            for d in spec.shape:
                n *= d
            if n > 100_000_000:
                assert any(a is not None for a in s.spec), (arch, path)


# ------------------------------------------------------------ HLO analyzer
_PROBE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.compat import AxisType, make_mesh

    mesh = make_mesh((2, 4), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)

    L, M, K, N = 7, 64, 32, 16

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=L)
        return jax.lax.with_sharding_constraint(
            out, NamedSharding(mesh, P(None, None)))

    x = jax.ShapeDtypeStruct((M, K), jnp.float32)
    w = jax.ShapeDtypeStruct((K, K), jnp.float32)
    low = jax.jit(f, in_shardings=(NamedSharding(mesh, P("data", None)),
                                   NamedSharding(mesh, P(None, None)))).lower(x, w)
    print(low.compile().as_text())
""")


def test_analyze_hlo_trip_count_flops():
    hlo = subprocess.run([sys.executable, "-c", _PROBE],
                         capture_output=True, text=True, timeout=300).stdout
    assert "HloModule" in hlo
    res = analyze_hlo(hlo)
    # 7 scan iterations of (M/2 x K) @ (K x K): 2*32*32*32 per device step
    expected = 7 * 2 * 32 * 32 * 32
    assert res["flops"] == pytest.approx(expected, rel=0.01)


def test_roofline_terms_and_bottleneck():
    coll = {"ici_bytes": 50e9, "dcn_bytes": 0.0}
    t = roofline(197e12, 819e9, coll, n_devices=256)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(1.0)
    assert t["ici_s"] == pytest.approx(1.0)
    t2 = roofline(1e12, 819e9 * 3, coll, n_devices=256)
    assert t2["bottleneck"] == "memory"


def test_roofline_dcn_term_per_host():
    coll = {"ici_bytes": 0.0, "dcn_bytes": 12.5e9 / 4}  # per device
    t = roofline(0.0, 0.0, coll, n_devices=512, n_pods=2)
    # per host: 4 chips x (12.5e9/4) bytes = 12.5 GB over 12.5 GB/s = 1 s
    assert t["dcn_s"] == pytest.approx(1.0)
    assert t["bottleneck"] == "collective"
