"""Scylla core: offers, DRF, policies, gang scheduling, faults."""
import dataclasses

import pytest

from repro.core import (Cluster, ClusterSpec, DRFAllocator, JobSpec,
                        MinHostPolicy, ResourceSpec, ScyllaScheduler,
                        SpreadPolicy, get_policy)
from repro.core.jobs import JobPhase

SMALL = ClusterSpec(n_pods=2, hosts_per_pod=4)  # 32 chips


def _job(jid="j1", chips=8, policy="spread", **kw):
    return JobSpec(jid, "internlm2-1.8b", "train_4k", chips=chips,
                   policy=policy, **kw)


# ----------------------------------------------------------------- cluster
def test_advertise_matches_free_capacity():
    c = Cluster(SMALL)
    offers = c.advertise()
    assert len(offers) == 8
    assert all(o.available.chips == 4 for o in offers)
    c.allocate("x", {offers[0].agent.agent_id: 3})
    offers = c.advertise()
    assert sum(o.available.chips for o in offers) == 29


def test_over_allocation_rejected():
    c = Cluster(SMALL)
    aid = next(iter(c.hosts))
    with pytest.raises(ValueError):
        c.allocate("x", {aid: 5})
    c.allocate("a", {aid: 3})
    with pytest.raises(ValueError):
        c.allocate("b", {aid: 2})


def test_fail_host_returns_victims_and_frees():
    c = Cluster(SMALL)
    aid = next(iter(c.hosts))
    c.allocate("a", {aid: 2})
    victims = c.fail_host(aid)
    assert victims == ["a"]
    assert c.hosts[aid].free_chips == 0  # dead hosts offer nothing
    c.heal_host(aid)
    assert c.hosts[aid].free_chips == 4


# --------------------------------------------------------------------- DRF
def test_drf_prefers_lowest_dominant_share():
    drf = DRFAllocator(ResourceSpec(32, 32 * 16e9))
    drf.register("a")
    drf.register("b")
    drf.charge("a", ResourceSpec(8, 8 * 16e9))
    assert drf.next_framework() == "b"
    drf.charge("b", ResourceSpec(16, 16 * 16e9))
    assert drf.next_framework() == "a"
    drf.credit("b", ResourceSpec(16, 16 * 16e9))
    assert drf.next_framework() == "b"


# ---------------------------------------------------------------- policies
def test_spread_uses_many_hosts_minhost_few():
    c = Cluster(SMALL)
    offers = c.advertise()
    sp = SpreadPolicy().place(_job(chips=8), offers, c)
    mh = MinHostPolicy().place(_job(chips=8), offers, c)
    assert sp.n_hosts == 8  # one chip per host across the cluster
    assert mh.n_hosts == 2  # 2 full hosts
    # minhost stays in one pod
    pods = {o.agent.agent_id: o.agent.pod_id for o in offers}
    assert len({pods[a] for a in mh.assignment}) == 1
    assert len({pods[a] for a in sp.assignment}) == 2


def test_gang_all_or_nothing():
    c = Cluster(SMALL)
    offers = c.advertise()
    assert SpreadPolicy().place(_job(chips=33), offers, c) is None
    assert MinHostPolicy().place(_job(chips=33), offers, c) is None
    pl = MinHostPolicy().place(_job(chips=32), offers, c)
    assert sum(pl.assignment.values()) == 32


def test_placement_respects_offer_capacity():
    c = Cluster(SMALL)
    first = next(iter(c.hosts))
    c.allocate("other", {first: 3})
    offers = c.advertise()
    for pol in (SpreadPolicy(), MinHostPolicy(), get_policy("auto")):
        pl = pol.place(_job(chips=16), offers, c)
        free = {o.agent.agent_id: o.available.chips for o in offers}
        assert sum(pl.assignment.values()) == 16
        for aid, n in pl.assignment.items():
            assert 0 < n <= free[aid]


# --------------------------------------------------------------- scheduler
def test_co_scheduling_places_multiple_gangs():
    sched = ScyllaScheduler(Cluster(SMALL), co_schedule=True)
    for i in range(3):
        sched.submit(_job(f"j{i}", chips=8), now=0.0)
    started = sched.try_schedule(0.0)
    assert len(started) == 3
    assert sched.cluster.utilization() == 0.75


def test_exclusive_mode_one_gang_at_a_time():
    sched = ScyllaScheduler(Cluster(SMALL), co_schedule=False)
    for i in range(3):
        sched.submit(_job(f"j{i}", chips=8), now=0.0)
    assert len(sched.try_schedule(0.0)) == 1
    assert len(sched.try_schedule(1.0)) == 0  # blocked while one runs
    sched.finish("j0", 2.0)
    assert len(sched.try_schedule(2.0)) == 1


def test_drf_order_across_frameworks():
    sched = ScyllaScheduler(Cluster(SMALL), co_schedule=True)
    sched.submit(_job("a1", chips=16, framework="alice"), 0.0)
    sched.submit(_job("b1", chips=8, framework="bob"), 0.0)
    sched.submit(_job("b2", chips=8, framework="bob"), 0.0)
    started = sched.try_schedule(0.0)
    assert {j.spec.job_id for j in started} == {"a1", "b1", "b2"}
    # alice's share 0.5, bob's 0.5 — both served


def test_host_failure_evicts_to_checkpoint_and_requeues():
    sched = ScyllaScheduler(Cluster(SMALL), co_schedule=True)
    js = sched.submit(_job("j0", chips=32, checkpoint_every=10), 0.0)
    sched.try_schedule(0.0)
    js.steps_done = 57
    js.last_checkpoint_step = 50
    victims = sched.on_host_failure(next(iter(sched.cluster.hosts)), 1.0)
    assert victims[0].spec.job_id == "j0"
    assert js.phase == JobPhase.PENDING
    assert js.steps_done == 50  # rolled back to checkpoint
    assert js.restarts == 1
    assert sched.cluster.used().chips == 0
    assert sched.drf.dominant_share("default") == 0.0


def test_straggler_detection():
    sched = ScyllaScheduler(Cluster(SMALL), straggler_threshold=2.0)
    sched.submit(_job("j0", chips=32), 0.0)
    sched.try_schedule(0.0)
    t_fast = sched.step_time_s(sched.running["j0"])
    aid = next(iter(sched.cluster.hosts))
    sched.cluster.set_straggler(aid, 3.0)
    t_slow = sched.step_time_s(sched.running["j0"])
    assert t_slow == pytest.approx(3.0 * t_fast, rel=1e-6)
    assert sched.stragglers_to_migrate() == ["j0"]


def test_compile_cache_warm_launch():
    sched = ScyllaScheduler(Cluster(SMALL), compile_cache=True)
    spec = _job("j0", chips=8)
    cold = sched.launch_overhead_s(spec)
    warm = sched.launch_overhead_s(dataclasses.replace(spec, job_id="j1"))
    assert warm < cold / 5


# ------------------------------------------------- failure conservation
def _chips_conserved(sched):
    """No allocation leaks anywhere in the accounting stack: cluster
    used == sum over running gangs == DRF charges, per-host books
    balance, and dead hosts hold nothing."""
    running_chips = sum(sum(js.assignment.values())
                        for js in sched.running.values())
    assert sched.cluster.used().chips == running_chips
    for host in sched.cluster.hosts.values():
        assert host.used_chips == sum(host.jobs.values())
        assert 0 <= host.used_chips <= host.agent.capacity.chips
        if not host.alive:
            assert not host.jobs
    drf_chips = sum(acct.allocated.chips
                    for acct in sched.drf.accounts.values())
    assert drf_chips == running_chips
    for js in sched.running.values():
        # gangs stay whole: a surviving job holds its full allocation
        assert sum(js.assignment.values()) == js.spec.chips


def _kill_sequence(sched, ops, now=0.0):
    """Drive submit/schedule/kill/heal/finish ops, checking conservation
    after every transition (not just at the end)."""
    hosts = sorted(sched.cluster.hosts)
    for i, (kind, arg) in enumerate(ops):
        now += 1.0
        if kind == "submit":
            sched.submit(_job(f"j{i}", chips=arg,
                              framework=f"fw{arg % 3}"), now)
            sched.try_schedule(now)
        elif kind == "kill":
            sched.on_host_failure(hosts[arg % len(hosts)], now)
        elif kind == "heal":
            sched.cluster.heal_host(hosts[arg % len(hosts)])
            sched.try_schedule(now)
        elif kind == "finish":
            if sched.running:
                jid = sorted(sched.running)[arg % len(sched.running)]
                sched.finish(jid, now)
        _chips_conserved(sched)


def test_host_failure_requeue_conserves_chips_seeded():
    """Deterministic always-on twin of the hypothesis sweep below."""
    import numpy as np

    rng = np.random.default_rng(1234)
    for _ in range(5):
        sched = ScyllaScheduler(Cluster(SMALL), co_schedule=True)
        ops = [("submit", int(rng.integers(1, 17))) for _ in range(4)]
        for _ in range(12):
            kind = ("kill", "heal", "finish",
                    "submit")[int(rng.integers(0, 4))]
            arg = int(rng.integers(0, 16))
            ops.append((kind, arg if kind != "submit"
                        else max(1, arg % 12)))
        _kill_sequence(sched, ops)


try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    pass
else:
    _OPS = st.lists(
        st.one_of(
            st.tuples(st.just("submit"), st.integers(1, 16)),
            st.tuples(st.just("kill"), st.integers(0, 7)),
            st.tuples(st.just("heal"), st.integers(0, 7)),
            st.tuples(st.just("finish"), st.integers(0, 7))),
        min_size=1, max_size=24)

    @pytest.mark.slow
    @settings(max_examples=60, deadline=None)
    @given(ops=_OPS)
    def test_host_failure_requeue_conserves_chips_hypothesis(ops):
        """`on_host_failure` + evict/requeue never leaks an allocation:
        for ANY interleaving of submits, host kills, heals, and
        finishes, every accounting layer (cluster, hosts, DRF, gangs)
        stays exactly balanced."""
        _kill_sequence(ScyllaScheduler(Cluster(SMALL), co_schedule=True),
                       ops)


def test_scheduler_recommends_layout_from_profile():
    """§Perf H3 integrated: small models get the pure-DP layout, big
    models keep TP — the paper's profile-follows-placement idea applied
    to mesh-axis assignment."""
    from repro.core.costmodel import recommended_layout

    assert recommended_layout("internlm2-1.8b") == "dp"
    assert recommended_layout("mamba2-1.3b") == "dp"
    assert recommended_layout("qwen3-moe-235b-a22b") == "tp"
    assert recommended_layout("qwen2.5-32b") == "tp"
    sched = ScyllaScheduler(Cluster(SMALL), co_schedule=True)
    sched.submit(_job("small", chips=8), 0.0)
    sched.submit(JobSpec("big", "gemma3-27b", "train_4k", chips=8), 0.0)
    sched.try_schedule(0.0)
    assert sched.running["small"].layout == "dp"
    assert sched.running["big"].layout == "tp"
