"""Cluster router: offers, placement policies, health/recovery, chaos.

The recovery contract under test everywhere: a replica death is
invisible in the token streams — every request completes and every
output is bitwise-identical to a fault-free run, because recovery
replays ``prompt + already-emitted`` under PR 3's position-folded
sampling.
"""
import dataclasses
import os

import numpy as np
import pytest

from conftest import make_engine, tiny_lm
from repro.runtime.cluster import (ROUTER_POLICIES, ClusterRouter,
                                   ReplicaOffer, ReplicaState,
                                   get_router_policy, reset_for_replay)
from repro.runtime.fault import (FaultEvent, ReplicaFaultInjector,
                                 StepWatchdog)
from repro.runtime.sampling import SamplingParams
from repro.runtime.serve import Request, ServeConfig, ServeEngine

WEIGHTS = {"gold": 3.0, "free": 1.0}


def _factory(**kw):
    """make_engine(rid) closure over a fixed config (fresh engine per
    call — routers must never share engine state across replicas)."""
    model, params = tiny_lm()
    cfg = ServeConfig(**{"batch_slots": 2, "max_len": 64, **kw})

    def make(rid):
        return ServeEngine(model, params, cfg)

    return make


_PAGED = dict(cache="paged", page_size=8, prefix_cache=False)


def _reqs(n=4, *, max_new=8, seed=0, sampled=True, base_id=100):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        prompt = rng.integers(1, 60,
                              size=int(rng.integers(3, 9))).astype(np.int32)
        sp = SamplingParams(temperature=0.8 if (sampled and i % 2) else 0.0,
                            seed=7)
        out.append(Request(base_id + i, prompt, max_new_tokens=max_new,
                           sampling=sp,
                           tenant="gold" if i % 3 == 0 else "free"))
    return out


def _fresh(reqs):
    return [dataclasses.replace(r, prompt=np.asarray(r.prompt), output=[])
            for r in reqs]


def _reference(reqs, **kw):
    """Fault-free single-engine outputs for a request set."""
    eng = _factory(**kw)(0)
    for r in _fresh(reqs):
        eng.submit(r)
    return {r.req_id: list(r.output) for r in eng.run()}


# ----------------------------------------------------------------- offers
def test_engine_offer_and_free_slots():
    eng = make_engine(batch_slots=2, max_len=64)
    off = eng.offer()
    assert off == {"free_slots": 2, "free_pages": None, "page_size": None,
                   "queue_depth": 0}
    eng.submit(Request(1, np.array([3, 4], np.int32), max_new_tokens=2))
    # queued-not-yet-admitted work consumes advertised slots
    assert eng.offer()["free_slots"] == 1
    assert eng.offer()["queue_depth"] == 1
    eng.run()
    assert eng.offer() == off


def test_paged_offer_advertises_pool():
    eng = make_engine(batch_slots=2, max_len=64, **_PAGED)
    off = eng.offer()
    assert off["page_size"] == 8
    assert off["free_pages"] == eng.kv.pool.available > 0


# --------------------------------------------------------------- policies
def _offers(slots):
    return [ReplicaOffer(replica=i, free_slots=s, free_pages=None,
                         page_size=None, queue_depth=0)
            for i, s in enumerate(slots)]


def test_pack_picks_busiest_spread_picks_emptiest():
    offers = _offers([3, 1, 2])
    assert get_router_policy("pack").select(offers).replica == 1
    assert get_router_policy("spread").select(offers).replica == 0
    # deterministic tie-break: lowest replica id
    tie = _offers([2, 2])
    assert get_router_policy("pack").select(tie).replica == 0
    assert get_router_policy("spread").select(tie).replica == 0


def test_router_policy_registry():
    assert set(ROUTER_POLICIES) == {"pack", "spread"}
    with pytest.raises(KeyError):
        get_router_policy("bogus")
    # instances pass through (the core get_policy convention)
    pol = get_router_policy("pack")
    assert get_router_policy(pol) is pol


# --------------------------------------------------------------- injector
def test_injector_parse_explicit():
    inj = ReplicaFaultInjector.parse("8:kill:1, 20:rejoin:1,"
                                     "5:stall:0:0.02:10")
    assert [(e.tick, e.action, e.replica) for e in inj.events] == \
        [(5, "stall", 0), (8, "kill", 1), (20, "rejoin", 1)]
    assert inj.events[0].arg == 0.02 and inj.events[0].ticks == 10
    assert inj.pop(4) == []
    assert [e.action for e in inj.pop(8)] == ["stall", "kill"]
    assert inj.pop(8) == []  # each event fires once
    inj.reset()
    assert len(inj.pop(100)) == 3


def test_injector_rejects_junk():
    with pytest.raises(ValueError):
        ReplicaFaultInjector.parse("8:explode:1")
    with pytest.raises(ValueError):
        ReplicaFaultInjector.parse("8:kill")
    with pytest.raises(ValueError):
        FaultEvent(-1, "kill", 0)
    with pytest.raises(ValueError):
        FaultEvent(1, "kill", 0, ticks=0)


def test_injector_seeded_reproducible():
    a = ReplicaFaultInjector.seeded(5, n_replicas=3)
    b = ReplicaFaultInjector.parse("seed=5:3")
    assert a.events == b.events
    assert a.events != ReplicaFaultInjector.seeded(6, n_replicas=3).events
    # replica 0 is never killed: a survivor always exists
    for seed in range(40):
        inj = ReplicaFaultInjector.seeded(seed, n_replicas=3, n_faults=4)
        assert all(e.replica != 0 for e in inj.events
                   if e.action == "kill")


# --------------------------------------------------------------- watchdog
def test_watchdog_flag_threshold(monkeypatch):
    """Satellite: the straggler flag fires exactly at threshold x median
    of the trailing window (and needs >= 5 samples of history)."""
    from repro.runtime import fault

    clock = {"t": 0.0}
    monkeypatch.setattr(fault.time, "monotonic", lambda: clock["t"])

    def tick(wd, step, dt):
        wd.start()
        clock["t"] += dt
        wd(step, None)

    wd = StepWatchdog(threshold=3.0)
    tick(wd, 0, 10.0)  # huge first step (compile) — too little history
    for s in range(1, 8):
        tick(wd, s, 0.1)
    assert wd.flagged == []
    tick(wd, 8, 0.29)  # 2.9x median: below threshold
    assert wd.flagged == []
    tick(wd, 9, 0.31)  # 3.1x median: flagged
    assert [f[0] for f in wd.flagged] == [9]
    assert wd.flagged[0][2] == pytest.approx(0.1)  # the median it beat


def test_router_flags_and_routes_around_straggler():
    inj = ReplicaFaultInjector([FaultEvent(8, "stall", 1, arg=0.25,
                                           ticks=2)])
    router = ClusterRouter(_factory(), 2, policy="spread", injector=inj)
    reqs = _reqs(6, max_new=12)
    ref = _reference(reqs)
    for r in _fresh(reqs):
        router.submit(r)
    done = router.run(max_ticks=500)
    st = router.stats()
    assert st["replicas"][1]["flags"] >= 1
    assert st["brownout_ticks"] >= 1  # a slow replica degrades the pool
    assert {r.req_id: list(r.output) for r in done} == ref


# ------------------------------------------------------- health/recovery
def test_kill_detected_at_miss_threshold():
    inj = ReplicaFaultInjector([FaultEvent(3, "kill", 1)])
    router = ClusterRouter(_factory(), 2, miss_threshold=3, injector=inj)
    for r in _reqs(4, max_new=16):
        router.submit(r)
    for _ in range(4):
        router.step()
    rh = router.replicas[1]
    assert rh.state is ReplicaState.UP  # 2 misses: still tolerated
    assert rh.misses == 2
    router.step()
    assert rh.state is ReplicaState.LOST
    assert rh.engine is None  # fenced: a zombie can never double-emit
    assert router.placed[1] == []  # victims re-queued
    done = router.run(max_ticks=500)
    assert len(done) == 4
    assert all(r.finish_reason != "failed" for r in done)


def test_hbdrop_below_threshold_is_tolerated():
    inj = ReplicaFaultInjector([FaultEvent(3, "hbdrop", 1, ticks=2)])
    router = ClusterRouter(_factory(), 2, miss_threshold=3, injector=inj)
    reqs = _reqs(4)
    ref = _reference(reqs)
    for r in _fresh(reqs):
        router.submit(r)
    done = router.run(max_ticks=500)
    st = router.stats()
    assert st["replicas_lost"] == 0 and st["recoveries"] == 0
    assert {r.req_id: list(r.output) for r in done} == ref


def test_hbdrop_past_threshold_fences_live_replica():
    """A partitioned-but-alive replica is fenced exactly like a dead
    one: the router re-owns its requests, and because the engine is
    discarded the zombie cannot emit a duplicate token."""
    inj = ReplicaFaultInjector([FaultEvent(2, "hbdrop", 1, ticks=4)])
    router = ClusterRouter(_factory(), 2, miss_threshold=2, injector=inj)
    reqs = _reqs(4, max_new=10)
    ref = _reference(reqs)
    for r in _fresh(reqs):
        router.submit(r)
    done = router.run(max_ticks=500)
    st = router.stats()
    assert st["replicas_lost"] == 1 and st["recoveries"] >= 1
    assert {r.req_id: list(r.output) for r in done} == ref


def test_retry_budget_exhaustion_fails_request():
    sched = []
    for i in range(4):
        sched += [FaultEvent(2 + 8 * i, "kill", 0),
                  FaultEvent(8 + 8 * i, "rejoin", 0)]
    router = ClusterRouter(_factory(), 1, retry_budget=2,
                           miss_threshold=1, backoff_ticks=1,
                           injector=ReplicaFaultInjector(sched))
    h = router.submit(_reqs(1, max_new=32)[0])
    done = router.run(max_ticks=300)
    assert done[0].finish_reason == "failed"
    assert h.retries == 3  # budget 2 + the exhausting attempt
    assert router.stats()["failed"] == 1


def test_exponential_backoff_defers_replacement():
    router = ClusterRouter(_factory(), 2, miss_threshold=1,
                           backoff_ticks=4,
                           injector=ReplicaFaultInjector(
                               [FaultEvent(2, "kill", 1)]))
    reqs = _reqs(4, max_new=16)
    for r in reqs:
        router.submit(r)
    for _ in range(2):
        router.step()
    recovered = [rr for rr in router.queue if rr.retries == 1]
    assert recovered
    # first retry waits backoff_ticks * 2**0 ticks
    assert all(rr.not_before == 2 + 4 for rr in recovered)
    done = router.run(max_ticks=500)
    assert all(r.finish_reason != "failed" for r in done)


def test_drain_and_rejoin():
    router = ClusterRouter(_factory(), 2, policy="pack")
    for r in _reqs(3, max_new=6):
        router.submit(r)
    router.step()
    router.drain(1)
    done = router.run(max_ticks=500)
    assert len(done) == 3
    assert router.replicas[1].state is ReplicaState.DOWN
    assert router.replicas[1].engine is None
    # a drained replica can come back and serve again
    router.rejoin(1)
    assert router.replicas[1].state is ReplicaState.UP
    router.drain(1)  # drain with nothing in flight -> DOWN on next tick
    for r in _reqs(2, base_id=300):
        router.submit(r)
    done = router.run(max_ticks=500)
    assert len(done) == 2


# --------------------------------------------------------------- brownout
def test_brownout_orders_gold_before_free():
    router = ClusterRouter(_factory(), 2, tenant_weights=WEIGHTS)
    free = _reqs(2, base_id=10)
    gold = _reqs(1, base_id=20)
    for r in free:
        r.tenant = "free"
        router.submit(r)
    for r in gold:
        r.tenant = "gold"
        router.submit(r)
    # full capacity: FIFO (arrival order)
    assert [rr.req.req_id for rr in router._placement_order()] == \
        [10, 11, 20]
    router.replicas[1].killed = True  # degraded pool
    assert router.degraded()
    assert [rr.req.req_id for rr in router._placement_order()] == \
        [20, 10, 11]


def test_brownout_sheds_free_but_completes_everything():
    """During the kill window gold places first; once capacity returns
    nothing was dropped and every output is bitwise-correct."""
    reqs = _reqs(8, max_new=10, seed=3)
    ref = _reference(reqs)
    inj = ReplicaFaultInjector([FaultEvent(2, "kill", 1),
                                FaultEvent(14, "rejoin", 1)])
    router = ClusterRouter(_factory(), 2, miss_threshold=1,
                           tenant_weights=WEIGHTS, injector=inj)
    handles = [router.submit(r) for r in _fresh(reqs)]
    done = router.run(max_ticks=500)
    assert router.stats()["brownout_ticks"] >= 1
    assert len(done) == 8
    assert {r.req_id: list(r.output) for r in done} == ref
    assert all(h.finish_reason != "failed" for h in handles)


# ------------------------------------------------------------------ chaos
def _chaos_run(reqs, *, kill_tick, n_replicas=3, engine_kw=None,
               rejoin_tick=None, **router_kw):
    engine_kw = dict(_PAGED, **(engine_kw or {}))
    events = [FaultEvent(kill_tick, "kill", 1)]
    if rejoin_tick:
        events.append(FaultEvent(rejoin_tick, "rejoin", 1))
    router = ClusterRouter(_factory(**engine_kw), n_replicas,
                           miss_threshold=1,
                           injector=ReplicaFaultInjector(events),
                           **router_kw)
    for r in _fresh(reqs):
        router.submit(r)
    done = router.run(max_ticks=800)
    return router, {r.req_id: list(r.output) for r in done}


def _assert_survivors_balanced(router):
    for rh in router.replicas:
        if rh.engine is not None and rh.engine.kv is not None:
            pool = rh.engine.kv.pool
            assert pool.in_use == 0
            assert not np.any(np.asarray(pool.ref[1:]))


def test_chaos_kill_mid_prefill_bitwise():
    """Victims die before emitting a token (multi-tick chunked prefill);
    replay re-runs the whole prompt on a survivor."""
    rng = np.random.default_rng(11)
    reqs = [Request(500 + i,
                    rng.integers(1, 60, size=24).astype(np.int32),
                    max_new_tokens=6,
                    sampling=SamplingParams(temperature=0.8 if i % 2
                                            else 0.0, seed=7))
            for i in range(4)]
    ref = _reference(reqs, **_PAGED, prefill_chunk=8)
    router, out = _chaos_run(reqs, kill_tick=2,
                             engine_kw={"prefill_chunk": 8})
    st = router.stats()
    assert st["recoveries"] >= 1
    assert all(len(v) == 6 for v in out.values())
    assert out == ref
    _assert_survivors_balanced(router)


def test_chaos_kill_mid_decode_bitwise():
    """Victims die with part of their stream already delivered; replay
    re-prefills prompt + emitted and the continuation is bitwise."""
    reqs = _reqs(6, max_new=16, seed=5)
    ref = _reference(reqs, **_PAGED)
    router, out = _chaos_run(reqs, kill_tick=6, rejoin_tick=20)
    st = router.stats()
    assert st["recoveries"] >= 1
    # the kill landed mid-decode: some victim had tokens already out
    assert any(len(np.asarray(rr.req.prompt)) > 9  # original prompts < 9
               for rr in router.finished) or out == ref
    assert out == ref
    _assert_survivors_balanced(router)


def test_chaos_kill_during_preemption_checkpoint_bitwise():
    """The nastiest replay: the victim replica dies while one of its
    requests sits preempted (checkpointed pages detached from the dying
    pool).  Recovery must discard the dead checkpoint AND the stale DRF
    charge, then replay cleanly on the survivor."""
    kw = dict(_PAGED, policy="drf-fair", preempt=True,
              tenant_weights=WEIGHTS)
    free = _reqs(2, max_new=24, seed=8, base_id=700)
    gold = _reqs(1, max_new=24, seed=9, base_id=800)
    for r in free:
        r.tenant = "free"
    gold[0].tenant = "gold"
    ref = _reference(free + gold, **kw)

    router = ClusterRouter(_factory(**kw), 2, policy="pack",
                           miss_threshold=1, tenant_weights=WEIGHTS)
    for r in _fresh(free):
        router.submit(r)
    for _ in range(3):  # both free requests decoding on replica 0 (pack)
        router.step()
    assert [rr.replica for rr in router.placed[0]] != []
    # place gold INTO replica 0's engine queue (router placement never
    # overcommits, but a direct client or a rebalance could) so the
    # weighted-DRF decide phase preempts a free request
    hg = router.submit(_fresh(gold)[0])
    rr = next(rr for rr in router.queue if rr.req.req_id == 800)
    router.queue.remove(rr)
    router.replicas[0].engine.submit(rr.req)
    rr.replica = 0
    router.placed[0].append(rr)
    eng0 = router.replicas[0].engine
    for _ in range(60):
        router.step()
        if eng0.scheduler.preempted_total >= 1:
            break
    assert eng0.scheduler.preempted_total >= 1
    victim = next((rr.req for rr in router.placed[0]
                   if getattr(rr.req, "_preempted", False)), None)
    assert victim is not None and victim._ckpt_pages is not None
    # now the replica (and the pool holding the checkpoint pages) dies
    router.replicas[0].killed = True
    done = router.run(max_ticks=800)
    assert len(done) == 3
    assert all(r.finish_reason != "failed" for r in done)
    assert not getattr(victim, "_preempted", False)
    assert {r.req_id: list(r.output) for r in done} == ref
    assert hg.done
    _assert_survivors_balanced(router)


def test_reset_for_replay_clears_engine_state():
    req = Request(1, np.array([5, 6, 7], np.int32), max_new_tokens=8,
                  tenant="gold")
    req.output = [10, 11]
    req._preempted = True
    req._ckpt_pages = [3, 4]
    req._drf_charged = object()
    req._feed = object()
    req.done = True
    req.finish_reason = "length"
    out = reset_for_replay(req)
    assert out is req
    assert list(req.prompt) == [5, 6, 7, 10, 11]
    assert req.output == [10, 11]  # client-visible stream is preserved
    assert not req.done and req.finish_reason is None
    assert req._preempted is False
    assert req._ckpt_pages is None and req._drf_charged is None


# ------------------------------------------------------------ check_bench
def _load_check_bench():
    import importlib.util

    path = os.path.join(os.path.dirname(__file__), "..", "scripts",
                        "check_bench.py")
    spec = importlib.util.spec_from_file_location("_check_bench", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_check_bench_gates_cluster_serve():
    cb = _load_check_bench()
    assert "cluster_serve" in cb.DEFAULT_NAMES
    assert ("chaos_bitwise_identical",) in \
        [p for p, _, _ in cb.BOUNDS["cluster_serve"]]


def test_check_bench_update_missing_fresh_is_clear(tmp_path, monkeypatch):
    """Satellite: --update on a never-run benchmark explains itself
    instead of stack-tracing in shutil."""
    cb = _load_check_bench()
    monkeypatch.setattr(cb, "ROOT", str(tmp_path))
    monkeypatch.setattr(cb, "BASELINE_DIR", str(tmp_path / "baselines"))
    with pytest.raises(SystemExit) as ei:
        cb.update(["cluster_serve"])
    assert "no fresh run" in str(ei.value)
    assert not (tmp_path / "baselines"
                / "BENCH_cluster_serve_dry.json").exists()


def test_check_bench_update_creates_missing_baseline(tmp_path, monkeypatch,
                                                     capsys):
    cb = _load_check_bench()
    monkeypatch.setattr(cb, "ROOT", str(tmp_path))
    monkeypatch.setattr(cb, "BASELINE_DIR", str(tmp_path / "baselines"))
    (tmp_path / "BENCH_cluster_serve_dry.json").write_text("{\"x\": 1}")
    cb.update(["cluster_serve"])
    assert "created baseline" in capsys.readouterr().out
    base = tmp_path / "baselines" / "BENCH_cluster_serve_dry.json"
    assert base.read_text() == "{\"x\": 1}"
    cb.update(["cluster_serve"])  # second run is a re-baseline
    assert "re-baselined" in capsys.readouterr().out


def test_check_bench_missing_baseline_message(tmp_path, monkeypatch):
    cb = _load_check_bench()
    monkeypatch.setattr(cb, "ROOT", str(tmp_path))
    monkeypatch.setattr(cb, "BASELINE_DIR", str(tmp_path / "baselines"))
    (tmp_path / "BENCH_cluster_serve_dry.json").write_text("{}")
    fails = cb.check("cluster_serve", 0.25, 1.0)
    assert len(fails) == 1
    assert "no baseline" in fails[0] and "--update" in fails[0]


def test_check_bench_run_dry_missing_script_is_clear(tmp_path, monkeypatch):
    cb = _load_check_bench()
    monkeypatch.setattr(cb, "ROOT", str(tmp_path))
    with pytest.raises(SystemExit) as ei:
        cb.run_dry("cluster_serve")
    assert "does not exist" in str(ei.value)
