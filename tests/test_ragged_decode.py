"""Ragged continuous-batching decode: per-slot kernel + split-K parity
(interpret mode) against the ragged XLA/jnp references, chunked prefill
parity, and continuous-vs-wave engine equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.decode_attention import (decode_attention_splitk_tpu,
                                            decode_attention_tpu)
from repro.kernels.ref import decode_attention_ref
from repro.models import LM, RuntimeKnobs
from repro.models.attention import decode_attention_xla
from repro.runtime.serve import Request, ServeConfig, ServeEngine

RNG = np.random.default_rng(7)


def arr(*s):
    return jnp.asarray(RNG.normal(size=s), jnp.float32)


def _qkv(b, h, kv, s, d):
    return arr(b, h, 1, d), arr(b, kv, s, d), arr(b, kv, s, d)


# adversarial per-slot positions: zero, block boundaries (+-1), max_len-1,
# and an inactive slot parked at -1
POS_CASES = [
    np.array([0, 15, 16, 63], np.int32),
    np.array([17, 31, 32, 62], np.int32),
    np.array([-1, 0, 47, 63], np.int32),
]


@pytest.mark.parametrize("g", [1, 2, 4])
@pytest.mark.parametrize("window", [0, 8])
@pytest.mark.parametrize("pos", POS_CASES)
def test_ragged_kernel_matches_ref(g, window, pos):
    b, kv, d, s = 4, 2, 16, 64
    h = kv * g
    q, k, v = _qkv(b, h, kv, s, d)
    ref = decode_attention_ref(q, k, v, pos, window=window)
    out = decode_attention_tpu(q, k, v, pos, window=window, block_k=16,
                               interpret=True)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-3


@pytest.mark.parametrize("g", [1, 4])
@pytest.mark.parametrize("window", [0, 8])
@pytest.mark.parametrize("num_splits", [2, 4])
@pytest.mark.parametrize("pos", POS_CASES)
def test_splitk_kernel_matches_ref(g, window, num_splits, pos):
    b, kv, d, s = 4, 2, 16, 64
    h = kv * g
    q, k, v = _qkv(b, h, kv, s, d)
    ref = decode_attention_ref(q, k, v, pos, window=window)
    out = decode_attention_splitk_tpu(q, k, v, pos, window=window, block_k=16,
                                      num_splits=num_splits, interpret=True)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-3


def test_scalar_pos_still_supported():
    b, kv, g, d, s = 2, 2, 2, 16, 64
    q, k, v = _qkv(b, kv * g, kv, s, d)
    ref = decode_attention_ref(q, k, v, 30)
    out = decode_attention_tpu(q, k, v, jnp.int32(30), block_k=16,
                               interpret=True)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-3


def test_xla_reference_is_ragged_and_masks_inactive():
    """The XLA mirror (model layout) matches the jnp oracle per slot and
    zeroes inactive slots."""
    b, kv, g, d, s = 4, 2, 2, 16, 64
    h = kv * g
    q, k, v = _qkv(b, h, kv, s, d)
    pos = np.array([-1, 0, 31, 63], np.int32)
    ref = decode_attention_ref(q, k, v, pos, window=4)
    out = decode_attention_xla(q.swapaxes(1, 2), k.swapaxes(1, 2),
                               v.swapaxes(1, 2), pos, window=4)
    assert float(jnp.max(jnp.abs(out.swapaxes(1, 2) - ref))) < 1e-5
    assert float(jnp.max(jnp.abs(out[0]))) == 0.0


def _tiny_model(arch="internlm2-1.8b", **extra):
    cfg = dataclasses.replace(get_config(arch, smoke=True),
                              **(extra or dict(num_layers=2, vocab_size=64)))
    return LM(cfg, RuntimeKnobs(cache_dtype=jnp.float32))


def test_ragged_decode_step_matches_per_slot_scalar_decode():
    model = _tiny_model()
    params = model.init(jax.random.PRNGKey(0))
    b, s = 3, 32
    toks = jnp.asarray(RNG.integers(0, 64, size=(b, 1)), jnp.int32)
    pos = jnp.asarray([0, 3, 31 - 1], jnp.int32)
    ragged, _ = jax.jit(model.decode_step)(params, model.init_cache(b, s),
                                           toks, pos)
    for i in range(b):
        one, _ = jax.jit(model.decode_step)(params, model.init_cache(1, s),
                                            toks[i:i + 1],
                                            jnp.int32(int(pos[i])))
        assert float(jnp.max(jnp.abs(one[0] - ragged[i]))) < 1e-4


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "gemma3-27b"])
def test_chunked_prefill_matches_full_prefill(arch):
    model = _tiny_model(arch, vocab_size=64) if arch == "gemma3-27b" \
        else _tiny_model(arch)
    params = model.init(jax.random.PRNGKey(1))
    assert model.supports_chunked_prefill()
    s, c, p = 32, 4, 7
    prompt = jnp.asarray(RNG.integers(0, 64, size=(1, p)), jnp.int32)
    full_logits, _ = jax.jit(model.prefill)(params, {"tokens": prompt})
    caches = model.init_cache(2, s)
    padded = np.zeros(((p + c - 1) // c) * c, np.int32)
    padded[:p] = np.asarray(prompt[0])
    step = jax.jit(model.prefill_chunk_step)
    for ci in range(len(padded) // c):
        lg, caches = step(params, caches, jnp.asarray(
            padded[None, ci * c:(ci + 1) * c]), jnp.int32(1),
            jnp.int32(ci * c))
    last = (p - 1) - (len(padded) - c)
    assert float(jnp.max(jnp.abs(lg[last] - full_logits[0]))) < 1e-4


def test_chunked_prefill_rejected_for_ssm_hybrid():
    model = _tiny_model("zamba2-2.7b", vocab_size=64)
    assert not model.supports_chunked_prefill()


@pytest.mark.slow  # multi-arch engine-equality suite: full-suite lane
@pytest.mark.parametrize("arch,extra", [
    ("internlm2-1.8b", dict(num_layers=2, vocab_size=64)),
    ("zamba2-2.7b", dict(vocab_size=64)),
])
def test_continuous_engine_matches_wave_outputs(arch, extra):
    """Greedy outputs are admission-order invariant: per-slot continuous
    batching (chunked prefill or token feed) reproduces the wave engine."""
    model = _tiny_model(arch, **extra)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    outs = {}
    for mode in ("wave", "continuous"):
        eng = ServeEngine(model, params,
                          ServeConfig(batch_slots=2, max_len=32,
                                      mode=mode))
        for i in range(5):
            eng.submit(Request(i, rng.integers(0, 64, size=int(
                rng.integers(1, 6))).astype(np.int32), max_new_tokens=4))
        rng = np.random.default_rng(3)  # same trace for both modes
        done = eng.run()
        assert len(done) == 5
        outs[mode] = {r.req_id: r.output for r in done}
    assert outs["wave"] == outs["continuous"]


def test_continuous_engine_admits_into_freed_slot_without_wave_barrier():
    """A short request finishing must not wait for the long one: with 2
    slots and 3 requests, the third starts while the long request is still
    decoding (ticks to finish < wave engine's)."""
    model = _tiny_model()
    params = model.init(jax.random.PRNGKey(0))

    def load(eng):
        eng.submit(Request(0, np.array([1], np.int32), max_new_tokens=20))
        eng.submit(Request(1, np.array([2], np.int32), max_new_tokens=2))
        eng.submit(Request(2, np.array([3], np.int32), max_new_tokens=2))

    ticks = {}
    for mode in ("continuous", "wave"):
        eng = ServeEngine(model, params,
                          ServeConfig(batch_slots=2, max_len=32,
                                      mode=mode))
        load(eng)
        n = 0
        while eng.queue or any(r is not None for r in eng.active):
            eng.step()
            n += 1
        ticks[mode] = n
    assert ticks["continuous"] < ticks["wave"], ticks


def test_max_new_tokens_one_completes_at_prefill():
    """Chunked prefill emits the first token; a 1-token request completes
    without a decode tick, the slot admits the next request, and step()
    counts the prefill-emitted tokens."""
    model = _tiny_model()
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, ServeConfig(batch_slots=1, max_len=32))
    assert eng.chunked
    for i in range(3):
        eng.submit(Request(i, np.array([i + 1], np.int32), max_new_tokens=1))
    emitted = 0
    while eng.queue or any(r is not None for r in eng.active):
        emitted += eng.step()
    done, eng._finished = eng._finished, []
    assert len(done) == 3
    assert all(len(r.output) == 1 for r in done)
    assert emitted == sum(len(r.output) for r in done)


def test_submit_rejects_bad_prompt_lengths():
    model = _tiny_model()
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, ServeConfig(batch_slots=1, max_len=16))
    with pytest.raises(ValueError):
        eng.submit(Request(0, np.zeros(0, np.int32)))
    with pytest.raises(ValueError):
        eng.submit(Request(1, np.zeros(16, np.int32)))
    eng.submit(Request(2, np.zeros(15, np.int32), max_new_tokens=1))
    assert len(eng.run()) == 1
