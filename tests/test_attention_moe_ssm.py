"""Unit tests for the model substrate layers (pure XLA paths)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig, SSMConfig
from repro.kernels import attention_ref
from repro.models.attention import (decode_attention_xla,
                                    flash_attention_xla)
from repro.models.moe import moe_ffn, moe_ffn_ref, moe_init
from repro.models.ssm import (ssm_decode_step, ssm_forward, ssm_init,
                              ssm_init_cache)

RNG = np.random.default_rng(7)


def arr(*shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype)


# ------------------------------------------------------- XLA attention path
@pytest.mark.parametrize("q_chunk", [16, 32, 128])
def test_flash_xla_matches_naive(q_chunk):
    b, h, kv, s, d = 2, 4, 2, 128, 16
    q, k, v = arr(b, s, h, d), arr(b, s, kv, d), arr(b, s, kv, d)
    out = flash_attention_xla(q, k, v, causal=True, q_chunk=q_chunk)
    ref = attention_ref(q.swapaxes(1, 2), k.swapaxes(1, 2),
                        v.swapaxes(1, 2), causal=True).swapaxes(1, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5,
                               rtol=1e-5)


@pytest.mark.parametrize("window", [16, 32, 100])
def test_flash_xla_window_sliced_kv(window):
    """The windowed path dynamically slices KV — verify against full mask."""
    b, h, kv, s, d = 1, 2, 2, 128, 16
    q, k, v = arr(b, s, h, d), arr(b, s, kv, d), arr(b, s, kv, d)
    out = flash_attention_xla(q, k, v, causal=True, window=window,
                              q_chunk=32)
    ref = attention_ref(q.swapaxes(1, 2), k.swapaxes(1, 2),
                        v.swapaxes(1, 2), causal=True,
                        window=window).swapaxes(1, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5,
                               rtol=1e-5)


def test_decode_xla_window():
    b, h, kv, s, d = 2, 4, 1, 64, 16
    q = arr(b, 1, h, d)
    kc, vc = arr(b, s, kv, d), arr(b, s, kv, d)
    from repro.kernels import decode_attention_ref
    for pos, win in [(5, 0), (40, 16), (63, 8)]:
        out = decode_attention_xla(q, kc, vc, pos, window=win)
        ref = decode_attention_ref(q.swapaxes(1, 2), kc.swapaxes(1, 2),
                                   vc.swapaxes(1, 2), pos,
                                   window=win).swapaxes(1, 2)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------- MoE
def _moe(e=4, k=2, dff=16, chunk=8, cf=1.25, ecf=2.0):
    return MoEConfig(num_experts=e, experts_per_token=k, d_ff=dff,
                     capacity_factor=cf, eval_capacity_factor=ecf,
                     dispatch_chunk=chunk)


def test_moe_matches_dense_oracle_when_dropfree():
    cfg = _moe(cf=4.0)  # cap = chunk*k*cf/E = 8*2*4/4 = 16 >= chunk*k: no drop
    dm = 12
    params = moe_init(jax.random.PRNGKey(0), dm, cfg)
    x = arr(2, 32, dm)
    out, aux = moe_ffn(params, x, cfg, train=True)
    ref = moe_ffn_ref(params, x, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4,
                               rtol=1e-4)
    assert float(aux["moe_drop_frac"]) == 0.0


def test_moe_capacity_drops_bounded():
    cfg = _moe(cf=0.5)
    dm = 12
    params = moe_init(jax.random.PRNGKey(0), dm, cfg)
    x = arr(4, 64, dm)
    out, aux = moe_ffn(params, x, cfg, train=True)
    assert jnp.isfinite(out).all()
    assert 0.0 < float(aux["moe_drop_frac"]) < 1.0
    # lb loss counts only kept slots, so drops pull it below 1.0
    assert float(aux["moe_lb_loss"]) > 0.3


def test_moe_decode_never_drops():
    cfg = _moe(e=8, k=8, chunk=8)
    dm = 12
    params = moe_init(jax.random.PRNGKey(0), dm, cfg)
    x = arr(3, 1, dm)  # single-token decode
    out, aux = moe_ffn(params, x, cfg, train=False)
    assert float(aux["moe_drop_frac"]) == 0.0


def test_moe_chunk_size_changes_capacity_not_semantics():
    cfg_a, cfg_b = _moe(chunk=8, cf=4.0), _moe(chunk=16, cf=4.0)
    dm = 12
    params = moe_init(jax.random.PRNGKey(0), dm, cfg_a)
    x = arr(2, 32, dm)
    out_a, _ = moe_ffn(params, x, cfg_a, train=True)
    out_b, _ = moe_ffn(params, x, cfg_b, train=True)
    np.testing.assert_allclose(np.asarray(out_a), np.asarray(out_b),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------- SSD
def _naive_ssm_scan(params, x, dm, cfg):
    """Token-by-token linear recurrence — the ground truth for chunking."""
    b, s, _ = x.shape
    cache = ssm_init_cache(b, dm, cfg, jnp.float32)
    ys = []
    for t in range(s):
        y, cache = ssm_decode_step(params, cache, x[:, t:t + 1, :], dm, cfg)
        ys.append(y)
    return jnp.concatenate(ys, axis=1), cache


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_ssd_chunked_matches_recurrence(chunk):
    cfg = SSMConfig(d_state=8, head_dim=8, expand=2, chunk_size=chunk)
    dm = 16
    params = ssm_init(jax.random.PRNGKey(0), dm, cfg)
    x = arr(2, 16, dm, scale=0.5)
    y_chunked, state = ssm_forward(params, x, dm, cfg, return_state=True)
    y_naive, cache_naive = _naive_ssm_scan(params, x, dm, cfg)
    np.testing.assert_allclose(np.asarray(y_chunked), np.asarray(y_naive),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(state["state"]),
                               np.asarray(cache_naive["state"]), atol=1e-4,
                               rtol=1e-3)


def test_ssd_state_handoff_prefill_to_decode():
    cfg = SSMConfig(d_state=8, head_dim=8, expand=2, chunk_size=8)
    dm = 16
    params = ssm_init(jax.random.PRNGKey(0), dm, cfg)
    x = arr(1, 24, dm, scale=0.5)
    # full forward over 24 tokens
    y_full = ssm_forward(params, x, dm, cfg)
    # prefill 16, then decode 8 one-by-one
    y_pre, cache = ssm_forward(params, x[:, :16], dm, cfg, return_state=True)
    ys = [y_pre]
    for t in range(16, 24):
        y, cache = ssm_decode_step(params, cache, x[:, t:t + 1], dm, cfg)
        ys.append(y)
    y_split = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_split),
                               atol=1e-4, rtol=1e-3)
