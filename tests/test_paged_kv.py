"""Paged KV-cache subsystem: paged-kernel vs dense-ragged parity across
(pos, active, page_size) grids, allocator invariants (no double-free,
refcount balance, CoW isolation, full alloc/free round-trip), prefix-cache
semantics, and engine pool-exhaustion + drain.  Engine construction
helpers live in tests/conftest.py."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import make_engine, tiny_lm

from repro.configs import get_config
from repro.kernels.paged_attention import paged_decode_attention_tpu
from repro.kernels.ref import decode_attention_ref, paged_decode_attention_ref
from repro.models import LM, RuntimeKnobs
from repro.models.attention import (paged_cache_update,
                                    paged_decode_attention_xla)
from repro.runtime.kv_pool import (KV_PAGE_POLICIES, KVCacheManager,
                                   PagePool, PoolExhausted, PrefixCache,
                                   get_page_policy)
from repro.runtime.serve import Request, ServeConfig, ServeEngine
from repro.runtime.steps import pick_decode_splits

RNG = np.random.default_rng(11)


def arr(*s):
    return jnp.asarray(RNG.normal(size=s), jnp.float32)


# ----------------------------------------------------------- kernel parity
def _paged_case(b, kv, h, d, page_size, max_pages, *, extra_pages=3):
    """Random pools + a random page table with distinct live pages per
    slot (page 0 reserved as the null page)."""
    n_pages = 1 + b * max_pages + extra_pages
    kp = arr(n_pages, kv, page_size, d)
    vp = arr(n_pages, kv, page_size, d)
    perm = RNG.permutation(np.arange(1, n_pages))[:b * max_pages]
    pt = perm.reshape(b, max_pages).astype(np.int32)
    return kp, vp, pt


POS_CASES = [  # zero, page boundaries +-1, max-1, inactive slot at -1
    np.array([0, 15, 16, 63], np.int32),
    np.array([17, 31, 32, 62], np.int32),
    np.array([-1, 0, 47, 63], np.int32),
]


@pytest.mark.slow  # 108-case kernel-parity sweep: full-suite lane
@pytest.mark.parametrize("g", [1, 2, 4])
@pytest.mark.parametrize("window", [0, 8])
@pytest.mark.parametrize("page_size", [8, 16, 32])
@pytest.mark.parametrize("pos", POS_CASES)
def test_paged_kernel_matches_dense_ragged_ref(g, window, page_size, pos):
    """The paged kernel equals the DENSE ragged oracle on the gathered
    view — physical indirection must not change logical attention."""
    b, kv, d, s = 4, 2, 16, 64
    h = kv * g
    max_pages = s // page_size
    q = arr(b, h, 1, d)
    kp, vp, pt = _paged_case(b, kv, h, d, page_size, max_pages)
    # dense gather: slot b's logical cache is its pages back to back
    kd = jnp.asarray(kp)[pt].transpose(0, 2, 1, 3, 4).reshape(b, kv, s, d)
    vd = jnp.asarray(vp)[pt].transpose(0, 2, 1, 3, 4).reshape(b, kv, s, d)
    ref = decode_attention_ref(q, kd, vd, pos, window=window)
    out = paged_decode_attention_tpu(q, kp, vp, jnp.asarray(pt), pos,
                                     window=window, interpret=True)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-3


@pytest.mark.parametrize("page_size", [8, 32])
@pytest.mark.parametrize("pos", POS_CASES)
def test_paged_ref_and_kernel_agree(page_size, pos):
    b, kv, g, d, s = 4, 2, 2, 16, 64
    h = kv * g
    max_pages = s // page_size
    q = arr(b, h, 1, d)
    kp, vp, pt = _paged_case(b, kv, h, d, page_size, max_pages)
    ref = paged_decode_attention_ref(q, kp, vp, pt, pos)
    out = paged_decode_attention_tpu(q, kp, vp, jnp.asarray(pt), pos,
                                     interpret=True)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-3


def test_paged_kernel_scalar_pos_and_shared_pages():
    """Scalar pos broadcasts; two slots mapping the SAME physical page
    (prefix sharing) read identical K/V."""
    b, kv, g, d, ps, mp = 2, 2, 2, 16, 16, 2
    h = kv * g
    kp = arr(1 + 2 * mp, kv, ps, d)
    vp = arr(1 + 2 * mp, kv, ps, d)
    pt = np.array([[1, 2], [1, 3]], np.int32)  # page 1 shared
    q1 = arr(1, h, 1, d)
    q = jnp.concatenate([q1, q1], axis=0)
    out = paged_decode_attention_tpu(q, kp, vp, jnp.asarray(pt),
                                     jnp.int32(ps - 1), interpret=True)
    # positions < ps only touch the shared page: slots must agree exactly
    assert float(jnp.max(jnp.abs(out[0] - out[1]))) == 0.0


def test_paged_xla_matches_ref():
    b, kv, g, d, ps, s = 4, 2, 2, 16, 16, 64
    h = kv * g
    mp = s // ps
    q = arr(b, h, 1, d)
    kp, vp, pt = _paged_case(b, kv, h, d, ps, mp)
    pos = np.array([-1, 0, 31, 63], np.int32)
    ref = paged_decode_attention_ref(q, kp, vp, pt, pos, window=4)
    out = paged_decode_attention_xla(
        q.swapaxes(1, 2), kp.swapaxes(1, 2), vp.swapaxes(1, 2), pt, pos,
        window=4)
    assert float(jnp.max(jnp.abs(out.swapaxes(1, 2) - ref))) < 1e-5
    assert float(jnp.max(jnp.abs(out[0]))) == 0.0  # inactive slot zeroed


def test_paged_cache_update_writes_mapped_page_and_null_for_inactive():
    kv, d, ps, n_pages = 2, 4, 8, 6
    kp = jnp.zeros((n_pages, ps, kv, d))
    vp = jnp.zeros((n_pages, ps, kv, d))
    k_new = arr(3, 1, kv, d)
    v_new = arr(3, 1, kv, d)
    pt = np.array([[1, 2], [3, 4], [0, 0]], np.int32)
    pos = np.array([3, 11, -1], np.int32)  # slot 2 inactive
    kp2, vp2 = paged_cache_update(kp, vp, k_new, v_new, pos, pt, ps)
    assert float(jnp.max(jnp.abs(kp2[1, 3] - k_new[0, 0]))) == 0.0
    assert float(jnp.max(jnp.abs(kp2[4, 3] - k_new[1, 0]))) == 0.0
    assert float(jnp.max(jnp.abs(vp2[4, 3] - v_new[1, 0]))) == 0.0
    # inactive write landed in the null page only; pages 1-5 untouched
    # elsewhere
    assert float(jnp.sum(jnp.abs(kp2[5]))) == 0.0
    assert float(jnp.sum(jnp.abs(kp2[2]))) == 0.0


# ----------------------------------------------------- allocator invariants
def test_pool_alloc_free_round_trip():
    pool = PagePool(17, 8, policy="pack", num_banks=4)
    cap = pool.capacity
    pages = pool.alloc(cap)  # drain completely
    assert sorted(pages) == list(range(1, 17))
    assert pool.available == 0
    with pytest.raises(PoolExhausted):
        pool.alloc(1)
    for p in pages:
        pool.decref(p)
    assert pool.available == cap
    # round-trip again: the free list regenerated cleanly
    again = pool.alloc(cap)
    assert sorted(again) == sorted(pages)


def test_pool_no_double_free_and_no_incref_of_free():
    pool = PagePool(9, 8)
    (p,) = pool.alloc(1)
    pool.incref(p)
    pool.decref(p)
    pool.decref(p)  # now free
    with pytest.raises(AssertionError):
        pool.decref(p)
    with pytest.raises(AssertionError):
        pool.incref(p)


def test_pool_null_page_is_never_allocated():
    pool = PagePool(5, 4)
    pages = pool.alloc(pool.capacity)
    assert 0 not in pages


def test_policy_pack_vs_spread_bank_placement():
    for name in ("pack", "spread"):
        assert KV_PAGE_POLICIES[name]().name == name
    pack = PagePool(33, 8, policy="pack", num_banks=4)
    spread = PagePool(33, 8, policy="spread", num_banks=4)
    n = 4
    assert pack.banks_touched(pack.alloc(n)) == 1
    assert spread.banks_touched(spread.alloc(n)) == 4
    with pytest.raises(KeyError):
        get_page_policy("nope")


def test_policy_pack_prefers_partially_used_banks():
    pool = PagePool(33, 8, policy="pack", num_banks=4)
    first = pool.alloc(3)
    second = pool.alloc(2)  # should stay in the same bank (still has room)
    assert pool.banks_touched(first + second) == 1


def _random_pool_workload(policy, seed):
    """Randomized alloc/incref/decref storm; refcounts must balance and
    the free list must exactly complement live pages at every step."""
    rng = np.random.default_rng(seed)
    pool = PagePool(41, 8, policy=policy, num_banks=5)
    live = {}  # page -> refcount we believe it has
    for _ in range(300):
        op = rng.integers(0, 3)
        if op == 0 and pool.available:
            n = int(rng.integers(1, pool.available + 1))
            for p in pool.alloc(n):
                assert p not in live
                live[p] = 1
        elif op == 1 and live:
            p = int(rng.choice(list(live)))
            pool.incref(p)
            live[p] += 1
        elif live:
            p = int(rng.choice(list(live)))
            pool.decref(p)
            live[p] -= 1
            if not live[p]:
                del live[p]
        assert pool.in_use == len(live)
        for p, r in live.items():
            assert pool.ref[p] == r
    for p in sorted(live):
        for _ in range(live[p]):
            pool.decref(p)
    assert pool.available == pool.capacity


@pytest.mark.parametrize("policy", ["pack", "spread"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_pool_refcounts_balance_random_workload(policy, seed):
    _random_pool_workload(policy, seed)


# ------------------------------------------------- prefix cache + manager
def test_prefix_cache_lookup_insert_evict():
    pool = PagePool(9, 4)
    cache = PrefixCache(pool)
    prompt = np.arange(10, dtype=np.int32)  # 2 full pages + 2 tokens
    pages = pool.alloc(3)
    cache.insert(prompt, pages)  # only the 2 full pages are registered
    assert len(cache) == 2
    hit, matched = cache.lookup(prompt)
    assert hit == pages[:2] and matched == 8
    for p in hit:
        pool.decref(p)
    # different second page -> only the first page hits
    other = prompt.copy()
    other[5] += 1
    hit2, matched2 = cache.lookup(other)
    assert hit2 == pages[:1] and matched2 == 4
    pool.decref(hit2[0])
    # release the owner's refs: pages become cache-only and evictable
    for p in pages:
        pool.decref(p)
    freed = cache.evict(2)
    assert freed == 2 and len(cache) == 0


def test_cow_isolation():
    """CoW: writes through one slot's table must not reach the sharing
    slot's page — the allocator gives the writer a private copy."""
    m = KVCacheManager(slots=2, max_len=32, page_size=8, num_pages=12)
    prompt = np.arange(16, dtype=np.int32)  # exactly 2 pages -> full hit
    r0 = m.admit(0, prompt, max_new=4)
    assert r0.matched == 0 and r0.start == 0 and not r0.cow
    m.register_prefix(0, prompt)
    r1 = m.admit(1, prompt, max_new=4)
    assert r1.matched == 16  # full-prompt hit
    assert r1.start == 8  # re-runs the last page to recover logits
    assert len(r1.cow) == 1
    src, dst = r1.cow[0]
    # the shared page stays mapped in slot 0, the copy in slot 1
    assert m.page_table[0, 1] == src
    assert m.page_table[1, 1] == dst
    assert src != dst
    # slot 0's first page is genuinely shared (owner + slot1 + cache)
    shared = m.page_table[0, 0]
    assert m.page_table[1, 0] == shared
    assert m.pool.ref[shared] == 3
    m.free_slot(1)
    assert m.pool.ref[shared] == 2  # slot 0 + prefix cache


def test_manager_backpressure_and_rollback():
    m = KVCacheManager(slots=2, max_len=32, page_size=8, num_pages=5,
                       prefix_cache=False)
    r0 = m.admit(0, np.arange(9, dtype=np.int32), max_new=8)  # 3 pages
    assert r0 is not None
    assert m.admit(1, np.arange(9, dtype=np.int32), max_new=8) is None
    assert m.pool.in_use == 3  # failed admission rolled back cleanly
    m.free_slot(0)
    assert m.pool.in_use == 0
    assert m.admit(1, np.arange(9, dtype=np.int32), max_new=8) is not None


def test_manager_eviction_under_pressure():
    """Cache-only pages are evicted to satisfy a new admission."""
    m = KVCacheManager(slots=2, max_len=32, page_size=8, num_pages=6)
    prompt = np.arange(16, dtype=np.int32)
    m.admit(0, prompt, max_new=1)  # 3 pages (17 positions)
    m.register_prefix(0, prompt)
    m.free_slot(0)  # 2 pages survive, held by the prefix cache only
    assert m.pool.in_use == 2
    other = 100 + np.arange(17, dtype=np.int32)
    res = m.admit(1, other, max_new=16)  # needs 5 pages -> must evict
    assert res is not None
    assert m.pool.in_use == 5


def _manager_admit_free_round_trip(seed, page_size, n_reqs):
    """Admissions and frees in random order: refcounts balance, the table
    maps exactly the held pages, and a drained manager leaves only
    prefix-cache refs behind."""
    rng = np.random.default_rng(seed)
    m = KVCacheManager(slots=4, max_len=32, page_size=page_size,
                       num_pages=4 * (32 // page_size) + 1)
    live = []
    for _ in range(n_reqs):
        free = [s for s in range(4) if s not in live]
        if free and (not live or rng.integers(0, 2)):
            s = int(rng.choice(free))
            plen = int(rng.integers(1, 16))
            res = m.admit(s, rng.integers(0, 8, size=plen).astype(np.int32),
                          max_new=int(rng.integers(1, 8)))
            if res is not None:
                live.append(s)
                assert all(m.page_table[s, i] > 0
                           for i in range(len(res.blocks)))
        elif live:
            m.free_slot(live.pop(int(rng.integers(0, len(live)))))
    for s in list(live):
        m.free_slot(s)
    # only prefix-cache refs remain
    assert m.pool.in_use == sum(1 for p in range(1, m.pool.num_pages)
                                if m.pool.ref[p] == 1)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_manager_admit_free_round_trip(seed):
    _manager_admit_free_round_trip(seed, page_size=8, n_reqs=8)


# Hypothesis variants of the allocator properties (skipped when the
# dependency is absent — the numpy-RNG versions above still run).
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    pass
else:
    @pytest.mark.slow
    @settings(max_examples=30, deadline=None)
    @given(policy=st.sampled_from(["pack", "spread"]),
           seed=st.integers(0, 10_000))
    def test_pool_invariants_hypothesis(policy, seed):
        _random_pool_workload(policy, seed)

    @pytest.mark.slow
    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 10_000), page_size=st.sampled_from([4, 8]),
           n_reqs=st.integers(1, 8))
    def test_manager_admit_free_round_trip_hypothesis(seed, page_size,
                                                      n_reqs):
        _manager_admit_free_round_trip(seed, page_size, n_reqs)


# ------------------------------------------------------------ engine level
def _shared_prefix_trace(n, shared_len, seed=5):
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, 64, size=shared_len).astype(np.int32)
    reqs = []
    for i in range(n):
        tail = rng.integers(0, 64, size=int(rng.integers(1, 5))) \
            .astype(np.int32)
        prompt = np.concatenate([shared, tail]) if i % 2 else tail
        reqs.append(Request(i, prompt, max_new_tokens=4))
    return reqs


@pytest.mark.slow  # engine-equality suite: full-suite lane
def test_paged_engine_matches_dense_outputs():
    """Greedy outputs are layout-invariant: the paged engine (prefix
    cache on) reproduces the dense continuous engine token for token."""
    model, params = tiny_lm()
    outs = {}
    for cache in ("dense", "paged"):
        eng = ServeEngine(model, params,
                          ServeConfig(batch_slots=2, max_len=32,
                                      cache=cache, page_size=8))
        for r in _shared_prefix_trace(7, shared_len=9):
            eng.submit(Request(r.req_id, r.prompt.copy(),
                               max_new_tokens=r.max_new_tokens))
        done = eng.run()
        assert len(done) == 7
        outs[cache] = {r.req_id: r.output for r in done}
    assert outs["dense"] == outs["paged"]


def test_paged_engine_pool_exhaustion_backpressure_and_drain():
    """Regression: a pool far smaller than slots * max_len serves the
    whole queue — admission backpressures instead of step() raising, and
    freed pages admit the stragglers."""
    model, params = tiny_lm()
    # 8 usable pages of 8 = 64 positions, vs 2 slots * max_len 32 = 64
    # dense positions, but requests need 3 pages each -> at most 2 live;
    # queue depth forces multiple backpressure/drain cycles
    eng = ServeEngine(model, params,
                      ServeConfig(batch_slots=2, max_len=32, cache="paged",
                                  page_size=8, num_pages=9,
                                  prefix_cache=False))
    rng = np.random.default_rng(0)
    for i in range(6):
        eng.submit(Request(i, rng.integers(0, 64, size=12)
                           .astype(np.int32), max_new_tokens=6))
    done = eng.run()
    assert len(done) == 6
    assert all(len(r.output) == 6 for r in done)
    assert eng.kv.pool.in_use == 0  # every page returned on drain


def test_paged_engine_rejects_impossible_request_at_submit():
    model, params = tiny_lm()
    eng = ServeEngine(model, params,
                      ServeConfig(batch_slots=1, max_len=32, cache="paged",
                                  page_size=8, num_pages=3))
    with pytest.raises(ValueError):
        eng.submit(Request(0, np.zeros(20, np.int32), max_new_tokens=8))


def test_paged_engine_requires_continuous_attention():
    model, params = tiny_lm()
    with pytest.raises(ValueError):
        ServeEngine(model, params,
                    ServeConfig(batch_slots=1, max_len=32, mode="wave",
                                cache="paged"))
    ssm_cfg = dataclasses.replace(get_config("mamba2-1.3b", smoke=True),
                                  vocab_size=64)
    ssm = LM(ssm_cfg, RuntimeKnobs(cache_dtype=jnp.float32))
    with pytest.raises(ValueError):
        ServeEngine(ssm, ssm.init(jax.random.PRNGKey(0)),
                    ServeConfig(batch_slots=1, max_len=32, cache="paged"))


def test_prefix_cache_skips_prefill_work():
    """Requests repeating a cached prompt admit at the last chunk: the
    engine's prefix stats show hits and the matched length."""
    model, params = tiny_lm()
    eng = ServeEngine(model, params,
                      ServeConfig(batch_slots=1, max_len=32, cache="paged",
                                  page_size=8, prefill_chunk=8))
    prompt = np.arange(16, dtype=np.int32)
    eng.submit(Request(0, prompt, max_new_tokens=2))
    eng.run()
    assert eng.kv.stats()["prefix_entries"] == 2
    res = eng.kv.admit(0, prompt, max_new=2)
    assert res is not None and res.matched == 16 and res.start == 8
    eng.kv.free_slot(0)


def test_copy_cache_pages_duplicates_page_in_every_layer_pool():
    """LM.copy_cache_pages (the device half of CoW for callers without
    the full-rewrite invariant) copies src -> dst in each stacked pool."""
    model, _ = tiny_lm()
    caches = model.init_cache_paged(num_pages=5, page_size=8)
    leaf = caches["stack"]["k"]
    caches["stack"]["k"] = leaf.at[:, 2].set(7.0)
    out = jax.jit(model.copy_cache_pages)(caches, jnp.int32(2), jnp.int32(4))
    got = out["stack"]["k"]
    assert float(jnp.min(got[:, 4])) == 7.0  # every layer's page copied
    assert float(jnp.max(jnp.abs(got[:, 3]))) == 0.0  # others untouched


# ------------------------------------------------------- split-K autotune
def test_pick_decode_splits_heuristic():
    # short contexts stay single-stream
    assert pick_decode_splits(100, 1, max_len=1 << 15) == 1
    assert pick_decode_splits(2047, 1, max_len=1 << 15) == 1
    # long context, single slot: fan out
    assert pick_decode_splits(32_000, 1, max_len=1 << 15) == 8
    # wide batch already saturates the memory streams
    assert pick_decode_splits(32_000, 32, max_len=1 << 15) == 1
    assert pick_decode_splits(32_000, 8, max_len=1 << 15) == 4
    # splits must divide max_len
    assert (1 << 15) % pick_decode_splits(32_000, 1, max_len=1 << 15) == 0
    assert pick_decode_splits(32_000, 1, max_len=12_000) in (1, 2, 4, 8)
    # static knob overrides
    assert pick_decode_splits(32_000, 1, max_len=1 << 15, override=2) == 2
    assert pick_decode_splits(10, 64, max_len=1 << 15, override=4) == 4


def test_autotune_enabled_only_for_dense_pallas_auto():
    eng = make_engine(batch_slots=1, max_len=32)  # use_pallas=False
    assert not eng._autotune  # XLA path: nothing to tune
    # fan-out 1 resolves to the engine's base steps (no split-K rebuild)
    assert eng._step_for_splits(1, False) is eng._step
    assert eng._step_for_splits(1, True) is eng._step_sampled


@pytest.mark.parametrize("max_len,page_size", [
    (64, 8), (64, 16), (96, 16), (96, 32), (128, 16), (1 << 15, 32),
    (12_288, 16), (2048, 2048)])
def test_pick_decode_splits_divides_page_count(max_len, page_size):
    """Bugfix regression: the paged kernel tiles by whole pages, so the
    chosen fan-out must divide max_pages = max_len // page_size —
    dividing max_len alone is not enough (96/16 = 6 pages: 4 divides 96
    but not 6)."""
    max_pages = max_len // page_size
    for max_pos, batch in ((100, 1), (3000, 1), (32_000, 1), (32_000, 8),
                           (1 << 20, 2)):
        s = pick_decode_splits(max_pos, batch, max_len=max_len,
                               page_size=page_size)
        assert max_pages % s == 0, (max_pos, batch, s)
    for override in (2, 3, 4, 5, 8):
        s = pick_decode_splits(32_000, 1, max_len=max_len,
                               page_size=page_size, override=override)
        assert max_pages % s == 0 and 1 <= s <= override


def test_pick_decode_splits_paged_vs_dense_divisor():
    # the motivating misalignment: old logic picked 4 here (4 | 96)
    assert pick_decode_splits(32_000, 1, max_len=96, page_size=16) == 2
    # dense behaviour unchanged by the new keyword's default
    assert pick_decode_splits(32_000, 1, max_len=96) == \
        pick_decode_splits(32_000, 1, max_len=96, page_size=0)
    # a misaligned static override is clamped down to a divisor
    assert pick_decode_splits(10, 1, max_len=96, page_size=16,
                              override=4) == 3


# ----------------------------------------------- host-aligned pool sizing
def test_pool_rounds_up_num_pages_to_host_multiple():
    """Satellite regression: an unaligned num_pages is rounded UP (with
    a warning) instead of raising — capacity never silently shrinks and
    the host sub-pools stay equal."""
    with pytest.warns(RuntimeWarning, match="rounding up"):
        pool = PagePool(10, 8, num_hosts=4)
    assert pool.num_pages == 12
    assert pool.capacity == 11
    assert sum(pool.free_by_host()) == pool.available
    assert [pool.host_of(p) for p in (0, 2, 3, 11)] == [0, 0, 1, 3]
    # aligned pools stay warning-free
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error")
        assert PagePool(12, 8, num_hosts=4).num_pages == 12
    with pytest.warns(RuntimeWarning):
        m = KVCacheManager(slots=2, max_len=32, page_size=8, num_pages=9,
                           num_hosts=2)
    assert m.pool.num_pages == 10
    # the manager still admits/frees cleanly over the rounded pool
    assert m.admit(0, np.arange(9, dtype=np.int32), max_new=4) is not None
    m.free_slot(0)
    assert m.pool.in_use == 0


# -------------------------------------------- buffered prefill / split-K
def _chunked_prefill(step, model, prompt, pt, c, *, buffered):
    """Drive a compiled paged chunked-prefill step over one slot's
    prompt; returns the per-chunk next-token arrays and final caches."""
    caches = model.init_cache_paged(num_pages=1 + pt.shape[1], page_size=8)
    buf = model.init_cache(1, 32)
    outs = []
    for ci in range(len(prompt) // c):
        chunk = jnp.asarray(prompt[None, ci * c:(ci + 1) * c])
        args = (model.init(jax.random.PRNGKey(0)), caches, chunk,
                jnp.int32(0), jnp.int32(ci * c), jnp.asarray(pt))
        if buffered:
            nxt, caches, buf = step(*args, buf)
        else:
            nxt, caches = step(*args)
        outs.append(np.asarray(nxt))
    return outs, caches


def test_buffered_prefill_matches_legacy_gather_step():
    """The buffered XLA chunked-prefill step (reusing the dense slot
    view across chunks) is bitwise-identical to the legacy per-chunk
    full-gather step — the retained parity oracle."""
    from repro.runtime.steps import compiled_step

    model, params = tiny_lm()
    rng = np.random.default_rng(4)
    prompt = rng.integers(1, 60, size=24).astype(np.int32)
    pt = np.array([[1, 2, 3, 4]], np.int32)
    legacy = compiled_step(model, "paged_prefill_chunk", page_size=8)
    buf_step = compiled_step(model, "paged_prefill_chunk_buf", page_size=8)
    ref_outs, ref_caches = _chunked_prefill(legacy, model, prompt, pt, 8,
                                            buffered=False)
    got_outs, got_caches = _chunked_prefill(buf_step, model, prompt, pt, 8,
                                            buffered=True)
    assert all((a == b).all() for a, b in zip(got_outs, ref_outs))
    for a, b in zip(jax.tree.leaves(got_caches),
                    jax.tree.leaves(ref_caches)):
        assert (np.asarray(a) == np.asarray(b)).all()


@pytest.mark.slow  # engine-equality suite: full-suite lane
def test_paged_pallas_engine_matches_xla_bitwise():
    """Fused Pallas paged prefill + decode vs the XLA buffered path:
    identical token streams (greedy and seeded-sampled), including
    prefix-cache hits (the gather-variant first chunk)."""
    from repro.runtime.sampling import SamplingParams

    model, params = tiny_lm()
    pallas = LM(model.cfg, model.knobs.with_(use_pallas=True))
    for sampled in (False, True):
        outs = {}
        for name, m in (("xla", model), ("pallas", pallas)):
            eng = ServeEngine(m, params,
                              ServeConfig(batch_slots=2, max_len=64,
                                          cache="paged", page_size=8,
                                          prefill_chunk=16))
            for r in _shared_prefix_trace(7, shared_len=17):
                sp = (SamplingParams(temperature=0.7, top_k=16, seed=3)
                      if sampled and r.req_id % 2 else SamplingParams())
                eng.submit(Request(r.req_id, r.prompt.copy(),
                                   max_new_tokens=6, sampling=sp))
            outs[name] = {r.req_id: r.output for r in eng.run()}
        assert outs["pallas"] == outs["xla"], f"sampled={sampled}"


@pytest.mark.slow
def test_paged_splitk_engine_matches_single_split():
    """Acceptance gate: the paged split-K decode variant emits the same
    tokens as the single-split kernel (max_len 64 / page 16 -> 4 pages,
    fan-out 4 = one page per split)."""
    model, params = tiny_lm()
    one = LM(model.cfg, model.knobs.with_(use_pallas=True))
    split = LM(model.cfg, model.knobs.with_(use_pallas=True,
                                            decode_splits=4))
    outs = {}
    for name, m in (("one", one), ("split", split)):
        eng = ServeEngine(m, params,
                          ServeConfig(batch_slots=2, max_len=64,
                                      cache="paged", page_size=16,
                                      prefill_chunk=16))
        for r in _shared_prefix_trace(5, shared_len=21, seed=8):
            eng.submit(Request(r.req_id, r.prompt.copy(),
                               max_new_tokens=8))
        outs[name] = {r.req_id: r.output for r in eng.run()}
    assert outs["split"] == outs["one"]
