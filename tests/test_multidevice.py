"""Multi-device behaviors, each in a subprocess with 8 host devices (the
main pytest process must keep seeing 1 device — see conftest).

Covers: sharded train-step lowering+compile on a 2x4 mesh (a miniature of
the production dry-run), elastic checkpoint restore onto a different mesh
shape, and the roofline analyzer on a genuinely partitioned module.
"""
import subprocess
import sys
import textwrap

import pytest

HEADER = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import sys
sys.path.insert(0, "src")
import dataclasses
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.compat import AxisType, make_mesh as compat_make_mesh
from repro.configs import get_config
from repro.models import LM, RuntimeKnobs
from repro.optim import AdamWConfig
from repro.runtime.steps import init_train_state, make_train_step, train_state_specs
from repro.sharding import batch_shardings, cache_shardings, make_shard_fn, opt_state_shardings, param_shardings

def tiny_model(mesh=None):
    cfg = dataclasses.replace(get_config("internlm2-1.8b", smoke=True),
                              num_layers=2, vocab_size=64, d_model=64,
                              num_heads=4, num_kv_heads=2, head_dim=16,
                              d_ff=128)
    knobs = RuntimeKnobs(cache_dtype=jnp.float32, q_chunk=16)
    if mesh is not None:
        knobs = knobs.with_(shard_fn=make_shard_fn(mesh, cfg))
    return LM(cfg, knobs)
"""


def run_sub(body: str, timeout=560):
    code = HEADER + textwrap.dedent(body)
    p = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout, cwd=".")
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


def test_sharded_train_step_runs_and_matches_single_device():
    out = run_sub("""
        mesh = compat_make_mesh((2, 4), ("data", "model"),
                               axis_types=(AxisType.Auto,) * 2)
        model = tiny_model(mesh)
        cfg = model.cfg
        state = init_train_state(model, jax.random.PRNGKey(0))
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                              (4, 32), 0, 64)}
        # single-device reference
        ref_model = tiny_model()
        step0 = jax.jit(make_train_step(ref_model, AdamWConfig()))
        ref_state, ref_metrics = step0(init_train_state(
            ref_model, jax.random.PRNGKey(0)), batch)

        specs = train_state_specs(model)
        p_sh = param_shardings(mesh, cfg, specs["params"], fsdp=False)
        o_sh = opt_state_shardings(mesh, cfg, specs["params"], fsdp=False)
        state_sh = {"params": p_sh, "opt": {"master": o_sh, "mu": o_sh,
                    "nu": o_sh, "step": NamedSharding(mesh, P())}}
        b_sh = batch_shardings(mesh, jax.eval_shape(lambda: batch))
        step = jax.jit(make_train_step(model, AdamWConfig()),
                       in_shardings=(state_sh, b_sh),
                       out_shardings=(state_sh, None))
        with mesh:
            state = jax.device_put(state, state_sh)
            new_state, metrics = step(state, batch)
        assert np.isfinite(float(metrics["loss"]))
        assert abs(float(metrics["loss"]) - float(ref_metrics["loss"])) < 1e-3, \\
            (float(metrics["loss"]), float(ref_metrics["loss"]))
        print("OK", float(metrics["loss"]))
    """)
    assert "OK" in out


def test_elastic_checkpoint_restore_across_mesh_shapes():
    out = run_sub("""
        from repro.checkpoint import restore, save_checkpoint
        mesh_a = compat_make_mesh((2, 4), ("data", "model"),
                                 axis_types=(AxisType.Auto,) * 2)
        mesh_b = compat_make_mesh((4, 2), ("data", "model"),
                                 axis_types=(AxisType.Auto,) * 2)
        model = tiny_model(mesh_a)
        cfg = model.cfg
        specs = train_state_specs(model)
        sh_a = param_shardings(mesh_a, cfg, specs["params"], fsdp=True)
        sh_b = param_shardings(mesh_b, cfg, specs["params"], fsdp=True)
        state = init_train_state(model, jax.random.PRNGKey(0))
        params_a = jax.device_put(state["params"], sh_a)
        save_checkpoint("/tmp/elastic_ck", 3, params_a)
        restored, meta = restore("/tmp/elastic_ck", specs["params"], sh_b)
        assert meta["step"] == 3
        flat0 = jax.tree.leaves(state["params"])
        flat1 = jax.tree.leaves(restored)
        for a, b in zip(flat0, flat1):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # restored leaves live on the NEW mesh's sharding
        for leaf, sh in zip(flat1, jax.tree.leaves(sh_b)):
            assert leaf.sharding == sh
        print("OK elastic")
    """)
    assert "OK elastic" in out


def test_mini_dryrun_with_serve_step_and_roofline():
    out = run_sub("""
        from repro.launch.roofline import analyze_hlo, roofline
        from repro.runtime.steps import make_serve_step
        mesh = compat_make_mesh((2, 4), ("data", "model"),
                               axis_types=(AxisType.Auto,) * 2)
        model = tiny_model(mesh)
        cfg = model.cfg
        pspecs = model.param_specs()
        p_sh = param_shardings(mesh, cfg, pspecs, fsdp=False)
        c_specs = model.cache_specs(8, 64)
        c_sh = cache_shardings(mesh, c_specs)
        b = {"tokens": jax.ShapeDtypeStruct((8, 1), jnp.int32),
             "pos": jax.ShapeDtypeStruct((), jnp.int32)}
        b_sh = batch_shardings(mesh, b)
        step = make_serve_step(model)
        with mesh:
            low = jax.jit(step, in_shardings=(p_sh, c_sh, b_sh["tokens"],
                                              b_sh["pos"]),
                          out_shardings=(None, c_sh)).lower(
                pspecs, c_specs, b["tokens"], b["pos"])
            comp = low.compile()
        res = analyze_hlo(comp.as_text())
        assert res["flops"] > 0
        terms = roofline(res["flops"], res["hbm_bytes"], res, n_devices=8)
        assert terms["step_s"] > 0
        ma = comp.memory_analysis()
        assert ma.temp_size_in_bytes >= 0
        print("OK dryrun", res["flops"], terms["bottleneck"])
    """)
    assert "OK dryrun" in out
