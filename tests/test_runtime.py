"""Runtime integration: training convergence, fault restart, serving."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import MarkovSynthetic
from repro.models import LM, RuntimeKnobs
from repro.optim import AdamWConfig
from repro.runtime.fault import (FailureInjector, SimulatedHostFailure,
                                 StepWatchdog, run_with_failures)
from repro.runtime.serve import Request, ServeConfig, ServeEngine
from repro.runtime.train import TrainConfig, Trainer


def _tiny_model():
    cfg = get_config("internlm2-1.8b", smoke=True)
    cfg = dataclasses.replace(cfg, num_layers=2, vocab_size=64)
    return LM(cfg, RuntimeKnobs(cache_dtype=jnp.float32))


def _dataset(model, batch=8, seq=32):
    return MarkovSynthetic(vocab_size=model.cfg.vocab_size, seq_len=seq,
                           global_batch=batch, seed=1, noise=0.05)


def test_training_reduces_loss():
    model = _tiny_model()
    tcfg = TrainConfig(steps=40, log_every=1, checkpoint_every=0,
                       opt=AdamWConfig(lr=3e-3, warmup_steps=5,
                                       total_steps=40))
    tr = Trainer(model, _dataset(model), tcfg)
    out = tr.run()
    first = out["history"][0]["loss"]
    last = out["history"][-1]["loss"]
    assert last < 0.8 * first, (first, last)


def test_grad_accum_equivalent_loss_scale():
    """grad_accum=2 over the same data gives a similar first-step loss and
    finite metrics (semantic check of the microbatch scan)."""
    model = _tiny_model()
    for accum in (1, 2):
        tcfg = TrainConfig(steps=2, grad_accum=accum, log_every=1,
                           checkpoint_every=0)
        tr = Trainer(model, _dataset(model), tcfg)
        out = tr.run()
        assert np.isfinite(out["history"][-1]["loss"])


def test_fault_restart_resumes_from_checkpoint(tmp_path):
    model = _tiny_model()
    ckpt = str(tmp_path / "ck")
    inj = FailureInjector(fail_at_steps=(12,))

    def make_trainer(attempt):
        tcfg = TrainConfig(steps=25, checkpoint_every=5, log_every=1,
                           checkpoint_dir=ckpt)
        return Trainer(model, _dataset(model), tcfg)

    out = run_with_failures(make_trainer, injector=inj)
    assert out["restarts"] == 1
    assert out["step"] == 25
    # restart resumed from step 10 (last checkpoint before 12)
    steps = [h["step"] for h in out["history"]]
    assert 11 in steps and 12 in steps


def test_failure_without_checkpoint_restarts_from_zero(tmp_path):
    model = _tiny_model()
    inj = FailureInjector(fail_at_steps=(3,))
    calls = []

    def make_trainer(attempt):
        calls.append(attempt)
        return Trainer(model, _dataset(model),
                       TrainConfig(steps=6, checkpoint_every=0,
                                   log_every=1))

    out = run_with_failures(make_trainer, injector=inj)
    assert out["step"] == 6 and len(calls) == 2


def test_watchdog_flags_injected_straggle(monkeypatch):
    wd = StepWatchdog(threshold=3.0)
    wd.start()
    t = [0.0]

    def fake_monotonic():
        return t[0]

    monkeypatch.setattr("time.monotonic", fake_monotonic)
    wd._last = 0.0
    for step in range(1, 20):
        t[0] += 10.0 if step == 15 else 1.0
        wd(step, {})
    assert [f[0] for f in wd.flagged] == [15]


def test_serve_engine_greedy_matches_manual_decode():
    model = _tiny_model()
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, ServeConfig(batch_slots=2, max_len=32))
    prompts = [np.array([3, 5, 7], np.int32), np.array([11, 2], np.int32)]
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, max_new_tokens=5))
    done = eng.run()
    assert len(done) == 2
    # manual single-request decode for request 0
    caches = model.init_cache(1, 32)
    tok = jnp.asarray([[3]], jnp.int32)
    outs = []
    pos = 0
    for t in prompts[0][1:]:
        _, caches = model.decode_step(params, caches, tok, jnp.int32(pos))
        tok = jnp.asarray([[int(t)]], jnp.int32)
        pos += 1
    for _ in range(5):
        logits, caches = model.decode_step(params, caches, tok,
                                           jnp.int32(pos))
        nxt = int(jnp.argmax(logits[0]))
        outs.append(nxt)
        tok = jnp.asarray([[nxt]], jnp.int32)
        pos += 1
    req0 = next(r for r in done if r.req_id == 0)
    assert req0.output == outs


def test_serve_engine_recycles_slots_in_waves():
    model = _tiny_model()
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, ServeConfig(batch_slots=2, max_len=16))
    for i in range(5):
        eng.submit(Request(i, np.array([i + 1], np.int32),
                           max_new_tokens=3))
    done = eng.run()
    assert len(done) == 5
    assert all(len(r.output) == 3 for r in done)
