"""Preemptive serving: slot checkpoint/restore (dense host snapshot via
copy_cache_out/in, paged zero-copy page-chain detach), weighted-DRF SLO
tiers, victim policies, preempt/resume/finish page-refcount balance, and
the module-level compiled-step cache.  Engine construction helpers live
in tests/conftest.py."""
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import cached_engine, make_engine as _engine, tiny_lm as _model

from repro.models import LM, RuntimeKnobs
from repro.runtime import steps
from repro.runtime.kv_pool import KVCacheManager
from repro.runtime.scheduler import (VICTIM_POLICIES, Scheduler,
                                     ServeResource, get_victim_policy)
from repro.runtime.serve import Request, RequestState


def _solo_outputs(prompts, max_new=8):
    """Uninterrupted greedy reference for each prompt (single-slot
    engine, shared across the module via the compiled-step cache)."""
    eng = cached_engine("preemption-solo", batch_slots=1, max_len=64)
    out = []
    for i, p in enumerate(prompts):
        out.append(eng.submit(Request(i, p.copy(),
                                      max_new_tokens=max_new)).result()
                   .output)
    return out


def _prompts(n, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 64, size=int(rng.integers(2, 6)))
            .astype(np.int32) for _ in range(n)]


def _flood(eng, prompts, *, n_gold, max_new=8):
    """Gold floods, then free trickles in after two ticks; returns the
    drained requests by id."""
    for i in range(n_gold):
        eng.submit(Request(i, prompts[i].copy(), max_new_tokens=max_new,
                           tenant="gold"))
    eng.step()
    eng.step()
    for i in range(n_gold, len(prompts)):
        eng.submit(Request(i, prompts[i].copy(), max_new_tokens=max_new,
                           tenant="free"))
    return {r.req_id: r for r in eng.run()}


_WEIGHTED = dict(policy="drf-fair", tenant_weights={"gold": 3, "free": 1},
                 preempt=True, victim_policy="lowest-weight-share-first")


# ------------------------------------------------- bitwise round trip
@pytest.mark.parametrize("cache_kw", [
    {},  # dense: host-side stripe snapshot
    {"cache": "paged", "page_size": 8},  # paged: zero-copy page detach
], ids=["dense", "paged"])
def test_preempted_request_resumes_bitwise_identical(cache_kw):
    """A preempted-then-resumed request's final token stream equals its
    uninterrupted greedy run — the checkpoint restores pos, last token,
    and KV exactly (sampling keys fold position, never slot)."""
    prompts = _prompts(8)
    ref = _solo_outputs(prompts)
    eng = _engine(batch_slots=4, max_len=64, **_WEIGHTED, **cache_kw)
    done = _flood(eng, prompts, n_gold=6)
    assert eng.scheduler.preempted_total >= 1
    assert sum(r.preempt_count for r in done.values()) >= 1
    for i in range(len(prompts)):
        assert done[i].output == ref[i], \
            f"request {i} (preempted {done[i].preempt_count}x) diverged"
    assert all(v == 0.0 for v in eng.scheduler.shares().values())


def test_no_page_leak_after_preempt_resume_finish():
    """Refcount balance: after a flood with preemptions fully drains,
    every non-null page is free again (prefix cache off so cache-held
    pages don't mask a leak)."""
    prompts = _prompts(9, seed=3)
    eng = _engine(batch_slots=4, max_len=64, cache="paged", page_size=8,
                  prefix_cache=False, **_WEIGHTED)
    _flood(eng, prompts, n_gold=7)
    assert eng.scheduler.preempted_total >= 1
    assert eng.kv.pool.in_use == 0
    assert not np.any(np.asarray(eng.kv.pool.ref[1:]))
    assert not np.any(eng.kv.page_table)


def test_weighted_drf_share_converges_under_flood():
    """With weights {gold: 3, free: 1} over 4 slots, preemption clamps
    gold to exactly its 3/(3+1) entitlement while free has queued work,
    and the PREEMPTED lifecycle state is observable."""
    prompts = _prompts(12, seed=5)
    eng = _engine(batch_slots=4, max_len=64, **_WEIGHTED)
    for i in range(9):
        eng.submit(Request(i, prompts[i].copy(), max_new_tokens=8,
                           tenant="gold"))
    eng.step()
    handles = [eng.submit(Request(i, prompts[i].copy(), max_new_tokens=4,
                                  tenant="free"))
               for i in range(9, 12)]
    seen_preempted = False
    gold_shares = []
    while eng.queue or any(r is not None for r in eng.active):
        eng.step()
        seen_preempted |= any(r.state is RequestState.PREEMPTED
                              for r in eng.queue)
        if any(r.tenant == "free" for r in eng.queue):
            gold = sum(1 for r in eng.active
                       if r is not None and r.tenant == "gold")
            gold_shares.append(gold / 4)
    assert seen_preempted
    assert max(gold_shares) == pytest.approx(0.75)
    assert all(h.done for h in handles)


def test_preempt_requires_continuous_mode():
    with pytest.raises(ValueError, match="continuous"):
        _engine(batch_slots=2, max_len=32, mode="wave", preempt=True)


# ------------------------------------------------ scheduler host logic
def _decoding(i, tenant, seq):
    r = Request(i, np.arange(1, 3, dtype=np.int32), max_new_tokens=8,
                tenant=tenant)
    r.state = RequestState.DECODE
    r.output = [1]
    r._feed = None
    r._admit_seq = seq
    r._drf_charged = ServeResource(slots=1, kv=10)
    return r


def test_two_phase_decide_swaps_only_on_strict_improvement():
    """Phase 2 preempts exactly while the queued tenant's weighted share
    after admission stays strictly below a victim tenant's before it —
    gold (weight 3) reclaims 3 of 4 slots from free, then stops."""
    sched = Scheduler("drf-fair", slots=4, max_len=32,
                      weights={"gold": 3, "free": 1}, preempt=True,
                      victim="lowest-weight-share-first")
    free = [_decoding(i, "free", i) for i in range(4)]
    for r in free:
        sched.allocator.charge("free", r._drf_charged)
    for i in range(4, 8):
        sched.submit(Request(i, np.arange(1, 3, dtype=np.int32),
                             max_new_tokens=8, tenant="gold"))
    plan = sched.decide(free)
    assert len(plan.preemptions) == 3
    assert len(plan.admissions) == 3
    assert all(p.req.tenant == "free" for p in plan.preemptions)
    assert all(a.req.tenant == "gold" for a in plan.admissions)
    # the victims re-entered the queue at the front, marked for resume
    assert [r._preempted for r in list(sched.queue)[:3]] == [True] * 3
    # weighted shares equalized: 3/4 / 3 == 1/4 / 1
    ws = sched.allocator.weighted_shares()
    assert ws["gold"] == pytest.approx(ws["free"])


def test_same_tenant_flood_never_self_preempts():
    sched = Scheduler("drf-fair", slots=2, max_len=32, preempt=True)
    running = [_decoding(i, "a", i) for i in range(2)]
    for r in running:
        sched.allocator.charge("a", r._drf_charged)
    sched.submit(Request(9, np.arange(1, 3, dtype=np.int32), tenant="a"))
    plan = sched.decide(running)
    assert not plan.preemptions and not plan.admissions


def test_victim_policy_registry_and_selection():
    assert set(VICTIM_POLICIES) == {"youngest-first",
                                    "lowest-weight-share-first"}
    for name in VICTIM_POLICIES:
        assert get_victim_policy(name).name == name
    sched = Scheduler("drf-fair", slots=3, max_len=32,
                      weights={"a": 1, "b": 1, "c": 8}, preempt=True,
                      victim="youngest-first")
    running = [_decoding(0, "a", 7), _decoding(1, "b", 3),
               _decoding(2, "b", 11)]
    for r in running:
        sched.allocator.charge(r.tenant, r._drf_charged)
    sched.submit(Request(9, np.arange(1, 3, dtype=np.int32), tenant="c"))
    plan = sched.decide(running)
    # youngest overall (seq 11, tenant b) regardless of tenant shares
    assert [p.slot for p in plan.preemptions] == [2]
    sched2 = Scheduler("drf-fair", slots=3, max_len=32,
                       weights={"a": 1, "b": 3, "c": 8}, preempt=True,
                       victim="lowest-weight-share-first")
    running = [_decoding(0, "a", 7), _decoding(1, "b", 3),
               _decoding(2, "b", 11)]
    for r in running:
        sched2.allocator.charge(r.tenant, r._drf_charged)
    sched2.submit(Request(9, np.arange(1, 3, dtype=np.int32), tenant="c"))
    plan = sched2.decide(running)
    # tenant a's weighted share (1/3 per unit weight) tops b's (2/3 over
    # weight 3): a is furthest over entitlement, so its slot is evicted
    assert [p.slot for p in plan.preemptions] == [0]


def test_mid_prefill_requests_are_not_preemptible():
    sched = Scheduler("drf-fair", slots=1, max_len=32, preempt=True)
    r = _decoding(0, "a", 0)
    r.state = RequestState.PREFILL
    sched.allocator.charge("a", r._drf_charged)
    sched.submit(Request(9, np.arange(1, 3, dtype=np.int32), tenant="b"))
    assert not sched.decide([r]).preemptions


def test_backpressure_falls_back_to_resuming_detained_chain():
    """Livelock guard: when the policy's fresh choice cannot reserve
    pages, a queued PREEMPTED request resumes instead (zero new pages) —
    its detained chain only drains back to the pool by completing, so a
    non-FIFO policy must not park it behind an unadmittable request."""
    kv = KVCacheManager(slots=2, max_len=32, page_size=8, num_pages=6,
                        prefix_cache=False)
    sched = Scheduler("sjf", slots=2, max_len=32, kv=kv, preempt=True)
    held = Request(0, np.arange(1, 10, dtype=np.int32), max_new_tokens=8,
                   tenant="a")  # 17 tokens -> 3 of the 5 pool pages
    res = kv.admit(0, held.prompt, held.max_new_tokens)
    held._drf_charged = ServeResource(slots=1, kv=3)
    sched.allocator.charge("a", held._drf_charged)
    held._ckpt_pages = kv.detach_slot(0)
    held._preempted = True
    sched.allocator.credit("a", ServeResource(slots=1, kv=0))
    held._drf_charged = held._drf_charged - ServeResource(slots=1, kv=0)
    fresh = Request(1, np.arange(1, 10, dtype=np.int32),
                    max_new_tokens=8, tenant="b")  # needs 3, only 2 free
    sched.submit(fresh)  # sjf ties -> FIFO: fresh first
    sched.submit(held)
    plan = sched.decide([None, None])
    assert [a.req.req_id for a in plan.admissions] == [0]
    assert plan.admissions[0].resume
    assert list(sched.queue) == [fresh]  # retried once pages free up
    assert res.blocks == kv._held[plan.admissions[0].slot]


def test_pages_needed_now_matches_admit_consumption():
    """The scheduler's preemption pre-check sizes fresh admissions with
    ``pages_needed_now`` — it must equal what ``admit`` actually takes,
    including prefix-cache sharing and CoW headroom."""
    kv = KVCacheManager(slots=2, max_len=64, page_size=8, num_pages=20,
                        chunk=8)
    prompt = np.arange(1, 25, dtype=np.int32)  # 3 full pages
    est = kv.pages_needed_now(prompt, 8)
    before = kv.pool.available
    kv.admit(0, prompt, 8)
    assert before - kv.pool.available == est
    kv.register_prefix(0, prompt)
    est_shared = kv.pages_needed_now(prompt, 8)
    assert est_shared < est  # prefix hit: shares pages, pays only CoW
    before = kv.pool.available
    kv.admit(1, prompt, 8)
    assert before - kv.pool.available == est_shared
    assert kv.fits_now(prompt, 8)


def test_fits_now_excludes_own_prefix_from_evictable():
    """A request's own cached prefix pages are increfed by admit's
    lookup before eviction runs, so fits_now must not count them as
    reclaimable headroom (miscounting caused an unsatisfiable swap)."""
    kv = KVCacheManager(slots=2, max_len=32, page_size=8, num_pages=5,
                        chunk=8)
    prompt = np.arange(1, 17, dtype=np.int32)  # 2 full pages
    kv.admit(0, prompt, 8)  # 3 pages: 2 prompt + 1 budget
    kv.register_prefix(0, prompt)
    kv.free_slot(0)  # only the prefix cache holds the 2 prompt pages now
    kv.admit(1, np.arange(50, 59, dtype=np.int32), 8)  # eats the rest
    assert kv.pool.available == 0
    # full-prompt hit: needs 1 CoW + 1 budget page; the only ref-1 pages
    # are its OWN prefix -> admit cannot evict them -> must report unfit
    assert not kv.fits_now(prompt, 8)
    assert kv.admit(0, prompt, 8) is None  # fits_now agreed with admit


def test_failed_swap_rolls_back_preemption(monkeypatch):
    """If the admission paired with a preemption fails, the host-side
    preemption is undone: the victim keeps its slot and pages, no Plan
    entry leaks, and the DRF book returns to its pre-swap state."""
    kv = KVCacheManager(slots=2, max_len=32, page_size=8, num_pages=9,
                        prefix_cache=False)
    sched = Scheduler("drf-fair", slots=2, max_len=32, kv=kv,
                      preempt=True, weights={"a": 1, "b": 8})
    victims = []
    for s, i in enumerate(range(2)):
        r = _decoding(i, "a", i)
        res = kv.admit(s, r.prompt, r.max_new_tokens)
        r._drf_charged = ServeResource(slots=1, kv=len(res.blocks))
        sched.allocator.charge("a", r._drf_charged)
        victims.append(r)
    monkeypatch.setattr(kv, "admit", lambda *a, **k: None)
    shares_before = sched.allocator.shares()
    held_before = [list(h) for h in kv._held]
    sched.submit(Request(9, np.arange(1, 3, dtype=np.int32), tenant="b"))
    plan = sched.decide(victims)
    assert not plan.preemptions and not plan.admissions
    assert sched.preempted_total == 0
    assert not any(getattr(r, "_preempted", False) for r in victims)
    assert [list(h) for h in kv._held] == held_before
    # book restored: a's share untouched, b registered but holds nothing
    assert sched.allocator.shares()["a"] == shares_before["a"]
    assert sched.allocator.shares().get("b", 0.0) == 0.0
    assert len(sched.queue) == 1  # the unadmittable request stays queued


def test_paged_detach_attach_round_trip():
    kv = KVCacheManager(slots=2, max_len=32, page_size=8, num_pages=9,
                        prefix_cache=False)
    res = kv.admit(0, np.arange(1, 12, dtype=np.int32), max_new=4)
    pages = list(res.blocks)
    refs_before = kv.pool.ref.copy()
    detached = kv.detach_slot(0)
    assert detached == pages
    assert not np.any(kv.page_table[0])
    assert np.array_equal(kv.pool.ref, refs_before)  # zero-copy: no churn
    kv.attach_slot(1, detached)
    assert list(kv.page_table[1, :len(pages)]) == pages
    assert np.array_equal(kv.pool.ref, refs_before)
    kv.free_slot(1)
    assert kv.pool.in_use == 0


# ------------------------------------------------- compiled-step cache
def test_compiled_step_cache_shared_across_engines():
    """The per-fanout/per-variant compiled steps are a module-level LRU
    keyed on (cfg, knobs, kind, sampled, page_size): a second engine over
    the same model reuses the first's jitted callables (no recompile)."""
    model, params = _model()
    e1 = _engine(batch_slots=2, max_len=32)
    before = steps.step_cache_stats()
    e2 = _engine(batch_slots=2, max_len=32)
    after = steps.step_cache_stats()
    assert e2._step is e1._step
    assert e2._step_sampled is e1._step_sampled
    assert e2._decode_one is e1._decode_one
    assert after["hits"] >= before["hits"] + 3
    assert after["misses"] == before["misses"]
    # distinct configs miss (different max_len is fine — shapes are not
    # part of the key; a different knob set is a different key)
    other = LM(model.cfg, RuntimeKnobs(cache_dtype=jnp.bfloat16))
    assert steps.compiled_step(other, "serve") is not e1._step
