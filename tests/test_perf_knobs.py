"""Regression tests for the §Perf hillclimb knobs (EXPERIMENTS.md)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.compat import AxisType, abstract_mesh

from repro.configs import get_config
from repro.kernels import attention_ref
from repro.models import LM, RuntimeKnobs
from repro.models.attention import flash_attention_xla
from repro.sharding import (batch_shardings, grad_shardings, make_shard_fn,
                            param_shardings)

RNG = np.random.default_rng(11)


def arr(*s):
    return jnp.asarray(RNG.normal(size=s), jnp.float32)


# ------------------------------------------------- H2: causal block skip
@pytest.mark.parametrize("s,q_chunk", [(128, 16), (256, 32), (96, 32)])
def test_causal_skip_matches_ref(s, q_chunk):
    b, h, kv, d = 2, 4, 2, 16
    q, k, v = arr(b, s, h, d), arr(b, s, kv, d), arr(b, s, kv, d)
    out = flash_attention_xla(q, k, v, causal=True, q_chunk=q_chunk,
                              causal_skip=True)
    ref = attention_ref(q.swapaxes(1, 2), k.swapaxes(1, 2),
                        v.swapaxes(1, 2), causal=True).swapaxes(1, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5,
                               rtol=1e-5)


def test_causal_skip_grads_match():
    b, s, h, kv, d = 1, 64, 2, 2, 8
    q, k, v = arr(b, s, h, d), arr(b, s, kv, d), arr(b, s, kv, d)

    def loss(fn_skip):
        def f(q, k, v):
            return jnp.sum(flash_attention_xla(
                q, k, v, causal=True, q_chunk=16,
                causal_skip=fn_skip) ** 2)
        return jax.grad(f, argnums=(0, 1, 2))(q, k, v)

    g0, g1 = loss(False), loss(True)
    for a, b_ in zip(g0, g1):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   atol=1e-4, rtol=1e-4)


def test_model_with_causal_skip_trains():
    cfg = dataclasses.replace(get_config("internlm2-1.8b", smoke=True),
                              num_layers=2, vocab_size=64)
    model = LM(cfg, RuntimeKnobs(cache_dtype=jnp.float32, q_chunk=8,
                                 causal_skip=True))
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32),
                                          0, 64)}
    loss, _ = jax.jit(model.loss)(params, batch)
    assert jnp.isfinite(loss)


# -------------------------------------------------- H3: pure-DP layout
def _mesh():
    return abstract_mesh((16, 16), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)


def test_dp_layout_replicates_params_keeps_opt_sharded():
    mesh = _mesh()
    cfg = get_config("internlm2-1.8b")
    model = LM(cfg, RuntimeKnobs(param_dtype=jnp.bfloat16))
    specs = model.param_specs()
    psh = param_shardings(mesh, cfg, specs, fsdp=False, layout="dp")
    for s in jax.tree.leaves(psh):
        assert all(a is None for a in s.spec)
    from repro.sharding import opt_state_shardings

    osh = opt_state_shardings(mesh, cfg, specs, fsdp=False, layout="dp")
    sharded = sum(1 for s in jax.tree.leaves(osh)
                  if any(a is not None for a in s.spec))
    assert sharded > 0  # ZeRO-1 still shards optimizer state


def test_dp_layout_batch_uses_all_axes():
    mesh = _mesh()
    specs = {"tokens": jax.ShapeDtypeStruct((256, 128), jnp.int32)}
    sh = batch_shardings(mesh, specs, layout="dp")["tokens"]
    axes = sh.spec[0]
    assert axes == ("data", "model")


# ------------------------------------- H1: data-only ZeRO-2 grad shardings
def test_grad_shardings_never_use_pod_axis():
    mesh = abstract_mesh((2, 16, 16), ("pod", "data", "model"),
                         axis_types=(AxisType.Auto,) * 3)
    cfg = get_config("qwen3-moe-235b-a22b")
    model = LM(cfg, RuntimeKnobs(param_dtype=jnp.bfloat16))
    specs = model.param_specs()
    gsh = grad_shardings(mesh, cfg, specs)
    for s in jax.tree.leaves(gsh):
        flat = []
        for a in s.spec:
            if isinstance(a, (tuple, list)):
                flat.extend(a)
            elif a is not None:
                flat.append(a)
        assert "pod" not in flat, s.spec


def test_embed_table_never_fsdp_dm_sharded():
    """The H1 fix: FSDP dm-sharding of the embedding triggers per-micro
    replicate-repartition (see EXPERIMENTS.md §Perf H1)."""
    mesh = _mesh()
    for arch in ("qwen3-moe-235b-a22b", "gemma3-27b", "qwen2.5-32b"):
        cfg = get_config(arch)
        model = LM(cfg, RuntimeKnobs(param_dtype=jnp.bfloat16))
        specs = model.param_specs()
        psh = param_shardings(mesh, cfg, specs, fsdp=True)
        spec = psh["embed"]["table"].spec
        assert spec[0] == "model" and spec[1] is None, (arch, spec)
