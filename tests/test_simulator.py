"""Discrete-event simulator: the paper's experimental claims, in test form."""
import dataclasses

import pytest

from repro.core import ClusterSpec, JobSpec, RooflineProfile, Simulator

SMALL = ClusterSpec(n_pods=2, hosts_per_pod=8)  # 64 chips


def _jobs(n, chips=16, policy="spread", steps=200, arch="internlm2-1.8b"):
    return [JobSpec(f"j{i}", arch, "train_4k", chips=chips, policy=policy,
                    steps=steps) for i in range(n)]


def test_co_scheduling_beats_exclusive():
    """Paper Figs 8-11: co-scheduling roughly halves makespan and lifts
    utilization (paper: ~2x, +60% CPU / +44% mem util)."""
    results = {}
    for co in (False, True):
        sim = Simulator(SMALL, co_schedule=co)
        for j in _jobs(6):
            sim.submit_at(0.0, j)
        results[co] = sim.run()
    assert results[True]["makespan"] < 0.6 * results[False]["makespan"]
    assert results[True]["avg_utilization"] > 1.4 * results[False]["avg_utilization"]
    assert results[True]["mean_wait_s"] < results[False]["mean_wait_s"]


def test_comm_bound_prefers_minhost():
    """Paper Fig 13: MinHost wins for communication-intensive jobs."""
    prof = RooflineProfile(flops=1e15, hbm_bytes=1e12, ici_bytes=5e12)
    times = {}
    for pol in ("spread", "minhost"):
        sim = Simulator(SMALL)
        sim.submit_at(0.0, JobSpec("c", "qwen3-moe-235b-a22b", "train_4k",
                                   chips=32, policy=pol, steps=50,
                                   profile=prof))
        r = sim.run()
        j = r["jobs"]["c"]
        times[pol] = j.finish_time - j.start_time
    assert times["minhost"] < times["spread"]


def test_contended_compute_job_prefers_spread():
    """Paper Fig 12: on a fragmented cluster, Spread avoids host-level
    contention (input pipeline / NIC) for host-resource-intensive jobs."""
    prof = RooflineProfile(flops=1e15, hbm_bytes=1e12, ici_bytes=1e10)
    times = {}
    for pol in ("spread", "minhost"):
        sim = Simulator(SMALL)
        # fragment 12 of 16 hosts with 3-chip tenants: packing must share
        for i in range(12):
            sim.submit_at(0.0, JobSpec(f"bg{i}", "internlm2-1.8b",
                                       "train_4k", chips=3,
                                       policy="minhost", steps=100_000))
        sim.submit_at(1.0, JobSpec("main", "llava-next-mistral-7b",
                                   "train_4k", chips=22, policy=pol,
                                   steps=100, profile=prof))
        r = sim.run(until=5e6)
        j = r["jobs"]["main"]
        times[pol] = j.finish_time - j.start_time
    assert times["spread"] < times["minhost"]


def test_auto_policy_never_worse_than_both():
    prof = RooflineProfile(flops=1e15, hbm_bytes=1e12, ici_bytes=5e12)
    times = {}
    for pol in ("spread", "minhost", "auto"):
        sim = Simulator(SMALL)
        sim.submit_at(0.0, JobSpec("c", "mixtral-8x7b", "train_4k", chips=32,
                                   policy=pol, steps=50, profile=prof))
        r = sim.run()
        j = r["jobs"]["c"]
        times[pol] = j.finish_time - j.start_time
    assert times["auto"] <= min(times["spread"], times["minhost"]) * 1.001


def test_failure_restart_completes_with_rollback():
    sim = Simulator(SMALL)
    sim.submit_at(0.0, JobSpec("f", "internlm2-1.8b", "train_4k", chips=32,
                               steps=500, checkpoint_every=50))
    sim.fail_host_at(200.0, "pod0/host000")
    r = sim.run()
    j = r["jobs"]["f"]
    assert j.restarts == 1
    assert j.steps_done == 500
    # a no-failure run finishes strictly earlier
    sim2 = Simulator(SMALL)
    sim2.submit_at(0.0, JobSpec("f", "internlm2-1.8b", "train_4k", chips=32,
                                steps=500, checkpoint_every=50))
    r2 = sim2.run()
    assert r2["jobs"]["f"].finish_time < j.finish_time


def test_straggler_migration_beats_waiting():
    def run(migrate):
        sim = Simulator(SMALL, migrate_stragglers=migrate)
        sim.submit_at(0.0, JobSpec("s", "internlm2-1.8b", "train_4k",
                                   chips=16, policy="minhost", steps=2000,
                                   checkpoint_every=100))
        sim.straggle_at(100.0, "pod0/host000", 10.0)
        return sim.run()

    slow = run(False)
    fast = run(True)
    if "s" in fast["jobs"] and "s" in slow["jobs"]:
        assert fast["jobs"]["s"].finish_time < slow["jobs"]["s"].finish_time


def test_elastic_restart_on_smaller_cluster():
    """After a failure the gang re-places on the surviving hosts."""
    sim = Simulator(ClusterSpec(n_pods=1, hosts_per_pod=5))
    sim.submit_at(0.0, JobSpec("e", "internlm2-1.8b", "train_4k", chips=16,
                               steps=300, checkpoint_every=50))
    sim.fail_host_at(50.0, "pod0/host000")
    r = sim.run()
    j = r["jobs"]["e"]
    assert j.steps_done == 300 and j.restarts == 1
    assert "pod0/host000" not in j.assignment


def test_util_trace_one_sample_per_event_and_monotone():
    """Regression: run() records exactly one utilization sample per
    processed event (the handlers used to also record, duplicating samples
    and skewing the time-weighted average), and the trace is time-ordered."""
    sim = Simulator(SMALL)
    for j in _jobs(4):
        sim.submit_at(0.0, j)
    sim.straggle_at(5.0, "pod0/host000", 2.0)
    sim.fail_host_at(10.0, "pod0/host001")
    sim.heal_host_at(20.0, "pod0/host001")
    sim.run()
    assert len(sim.util_trace) == sim.events_processed
    times = [t for t, _ in sim.util_trace]
    assert times == sorted(times)
