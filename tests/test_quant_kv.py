"""Quantized paged KV cache (int8 / fp8): round-trip error bounds,
kernel parity against the dequantized jnp oracle (decode, fused prefill,
split-K), scale pools traveling with pages through CoW and the
disaggregated handoff, and engine-level identity + memory gates.
Engine construction helpers live in tests/conftest.py."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import tiny_lm

from repro.kernels.paged_attention import (paged_decode_attention_splitk_tpu,
                                           paged_decode_attention_tpu,
                                           paged_prefill_attention_tpu)
from repro.kernels.ref import (dequantize_ref, paged_decode_attention_ref,
                               paged_decode_attention_quant_ref,
                               paged_prefill_attention_ref)
from repro.models import LM, RuntimeKnobs
from repro.models.attention import (KV_QUANT_DTYPES, dequantize_kv,
                                    gather_slot_pages, kv_quant_dtype,
                                    paged_cache_update_quant,
                                    paged_decode_attention_xla, quantize_kv)
from repro.runtime.disagg import transfer_chain
from repro.runtime.sampling import SamplingParams
from repro.runtime.serve import Request, ServeConfig, ServeEngine

RNG = np.random.default_rng(23)

_QMAX = {"int8": 127.0, "fp8": 448.0}


def arr(*s):
    return jnp.asarray(RNG.normal(size=s), jnp.float32)


def _quant_pools(kp, vp, name):
    kq, ks = quantize_kv(kp, KV_QUANT_DTYPES[name])
    vq, vs = quantize_kv(vp, KV_QUANT_DTYPES[name])
    return kq, ks, vq, vs


# ------------------------------------------------------------- round trip
def _roundtrip_bound(x, name):
    """Symmetric per-row quantization error bound: int8 rounds to the
    nearest of 255 levels (half a step = amax/254); fp8 e4m3 keeps a
    3-bit mantissa (relative error <= 2^-4 of the row max after the
    power-of-two exponent)."""
    q, s = quantize_kv(x, KV_QUANT_DTYPES[name])
    err = jnp.abs(dequantize_kv(q, s) - x.astype(jnp.float32))
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    bound = amax / (2 * _QMAX[name]) if name == "int8" else amax * 0.0625
    assert bool(jnp.all(err <= bound + 1e-6)), float(jnp.max(err - bound))


@pytest.mark.parametrize("name", sorted(KV_QUANT_DTYPES))
def test_quantize_roundtrip_bound(name):
    _roundtrip_bound(10.0 * arr(16, 4, 2, 32), name)


@pytest.mark.parametrize("name", sorted(KV_QUANT_DTYPES))
def test_quantize_zero_rows_are_exact(name):
    """All-zero rows must dequantize to exactly zero (scale 0, not a
    0/0): freshly initialized pool rows and null-page writes stay 0."""
    q, s = quantize_kv(jnp.zeros((3, 5, 2, 16)), KV_QUANT_DTYPES[name])
    assert float(jnp.max(jnp.abs(dequantize_kv(q, s)))) == 0.0
    # row max exactly representable -> round trips exactly too
    x = jnp.full((1, 1, 1, 4), 2.0)
    q, s = quantize_kv(x, KV_QUANT_DTYPES[name])
    assert float(jnp.max(jnp.abs(dequantize_kv(q, s) - x))) == 0.0


def test_kv_quant_dtype_lookup():
    assert kv_quant_dtype("") is None
    assert kv_quant_dtype("int8") == jnp.int8
    assert kv_quant_dtype("fp8") == jnp.float8_e4m3fn
    with pytest.raises(KeyError):
        kv_quant_dtype("int4")


try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    pass
else:
    @pytest.mark.slow
    @settings(max_examples=50, deadline=None)
    @given(name=st.sampled_from(sorted(KV_QUANT_DTYPES)),
           seed=st.integers(0, 10_000),
           scale=st.floats(1e-3, 1e3),
           d=st.sampled_from([1, 4, 64]))
    def test_quantize_roundtrip_bound_hypothesis(name, seed, scale, d):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(scale * rng.normal(size=(4, 3, 2, d)), jnp.float32)
        _roundtrip_bound(x, name)


# ----------------------------------------------------------- kernel parity
def _paged_case(b, kv, d, page_size, max_pages):
    n_pages = 1 + b * max_pages + 3
    kp = arr(n_pages, kv, page_size, d)
    vp = arr(n_pages, kv, page_size, d)
    perm = RNG.permutation(np.arange(1, n_pages))[:b * max_pages]
    return kp, vp, perm.reshape(b, max_pages).astype(np.int32)


@pytest.mark.parametrize("name", sorted(KV_QUANT_DTYPES))
@pytest.mark.parametrize("window", [0, 8])
def test_quant_decode_kernel_matches_dequant_oracle(name, window):
    """In-kernel dequantization equals dequantize-then-attend: the fused
    read must not change logical attention."""
    b, kv, g, d, ps, mp = 4, 2, 2, 16, 16, 4
    kp, vp, pt = _paged_case(b, kv, d, ps, mp)
    kq, ks, vq, vs = _quant_pools(kp, vp, name)
    q = arr(b, kv * g, 1, d)
    pos = np.array([-1, 0, 31, 63], np.int32)
    ref = paged_decode_attention_quant_ref(q, kq, vq, ks, vs, pt, pos,
                                           window=window)
    out = paged_decode_attention_tpu(q, kq, vq, jnp.asarray(pt), pos,
                                     window=window, k_scale=ks, v_scale=vs,
                                     interpret=True)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-3


@pytest.mark.parametrize("name", sorted(KV_QUANT_DTYPES))
@pytest.mark.parametrize("offset", [0, 16])
def test_quant_prefill_kernel_matches_dequant_oracle(name, offset):
    b, kv, g, d, ps, mp, c = 1, 2, 2, 16, 16, 4, 16
    kp, vp, pt = _paged_case(b, kv, d, ps, mp)
    kq, ks, vq, vs = _quant_pools(kp, vp, name)
    q = arr(1, kv * g, c, d)
    row = jnp.asarray(pt[0])
    ref = paged_prefill_attention_ref(q, dequantize_ref(kq, ks),
                                      dequantize_ref(vq, vs), row, offset)
    out = paged_prefill_attention_tpu(q, kq, vq, row, offset, k_scale=ks,
                                      v_scale=vs, interpret=True)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-3


@pytest.mark.parametrize("name", sorted(KV_QUANT_DTYPES))
@pytest.mark.parametrize("num_splits", [2, 4])
def test_quant_splitk_kernel_matches_dequant_oracle(name, num_splits):
    b, kv, g, d, ps, mp = 2, 2, 2, 16, 16, 4
    kp, vp, pt = _paged_case(b, kv, d, ps, mp)
    kq, ks, vq, vs = _quant_pools(kp, vp, name)
    q = arr(b, kv * g, 1, d)
    pos = np.array([29, -1], np.int32)
    ref = paged_decode_attention_quant_ref(q, kq, vq, ks, vs, pt, pos)
    out = paged_decode_attention_splitk_tpu(
        q, kq, vq, jnp.asarray(pt), pos, num_splits=num_splits,
        k_scale=ks, v_scale=vs, interpret=True)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-3


def test_quant_xla_matches_dequant_oracle():
    b, kv, g, d, ps, mp = 4, 2, 2, 16, 16, 4
    kp, vp, pt = _paged_case(b, kv, d, ps, mp)
    kq, ks, vq, vs = _quant_pools(kp, vp, "int8")
    q = arr(b, kv * g, 1, d)
    pos = np.array([-1, 0, 31, 63], np.int32)
    ref = paged_decode_attention_quant_ref(q, kq, vq, ks, vs, pt, pos)
    out = paged_decode_attention_xla(
        q.swapaxes(1, 2), kq.swapaxes(1, 2), vq.swapaxes(1, 2), pt, pos,
        k_scale=ks.swapaxes(1, 2), v_scale=vs.swapaxes(1, 2))
    assert float(jnp.max(jnp.abs(out.swapaxes(1, 2) - ref))) < 1e-5


def test_int8_decode_accuracy_vs_fp32():
    """Quantization is lossy but bounded: int8 attention outputs stay
    within 5e-2 of the unquantized fp32 outputs (normalized softmax
    averages of O(1) values; observed ~1e-2)."""
    b, kv, g, d, ps, mp = 4, 2, 2, 16, 16, 4
    kp, vp, pt = _paged_case(b, kv, d, ps, mp)
    kq, ks, vq, vs = _quant_pools(kp, vp, "int8")
    q = arr(b, kv * g, 1, d)
    pos = np.array([5, 17, 31, 63], np.int32)
    exact = paged_decode_attention_ref(q, kp, vp, pt, pos)
    out = paged_decode_attention_tpu(q, kq, vq, jnp.asarray(pt), pos,
                                     k_scale=ks, v_scale=vs, interpret=True)
    assert float(jnp.max(jnp.abs(out - exact))) < 5e-2


# --------------------------------------------------- cache update / layout
def test_quant_cache_update_writes_pages_and_scales():
    """The quantized scatter puts the row in the mapped page and its
    scale in the matching scale-pool position; inactive slots land in
    the null page; the dequantized row round-trips within bound."""
    kv, d, ps, n_pages = 2, 16, 8, 6
    kp = jnp.zeros((n_pages, ps, kv, d), jnp.int8)
    vp = jnp.zeros((n_pages, ps, kv, d), jnp.int8)
    ks = jnp.zeros((n_pages, ps, kv, 1))
    vs = jnp.zeros((n_pages, ps, kv, 1))
    k_new, v_new = arr(3, 1, kv, d), arr(3, 1, kv, d)
    pt = np.array([[1, 2], [3, 4], [0, 0]], np.int32)
    pos = np.array([3, 11, -1], np.int32)  # slot 2 inactive
    kp2, vp2, ks2, vs2 = paged_cache_update_quant(
        kp, vp, ks, vs, k_new, v_new, pos, pt, ps)
    got = dequantize_kv(kp2[4, 3], ks2[4, 3])
    assert float(jnp.max(jnp.abs(got - k_new[1, 0]))) < \
        float(jnp.max(jnp.abs(k_new))) / 127
    assert float(jnp.max(jnp.abs(vs2[1, 3]))) > 0.0  # slot 0 scale landed
    # untouched pages keep zero scales (and so dequantize to zero)
    assert float(jnp.sum(jnp.abs(ks2[5]))) == 0.0
    assert float(jnp.sum(jnp.abs(ks2[2]))) == 0.0


def test_quant_model_cache_layout_and_copy_pages():
    """A kv_quant model allocates int8 pools plus f32 scale pools with
    the page axis at ndim-4 — the invariant every page-copy/transfer
    helper keys on — and LM.copy_cache_pages moves page AND scale."""
    model, _ = tiny_lm()
    qm = LM(model.cfg, model.knobs.with_(kv_quant="int8"))
    caches = qm.init_cache_paged(num_pages=5, page_size=8)
    leafd = caches["stack"]
    assert leafd["k"].dtype == jnp.int8
    assert leafd["k_scale"].dtype == jnp.float32
    assert leafd["k_scale"].shape == leafd["k"].shape[:-1] + (1,)
    leafd["k"] = leafd["k"].at[:, 2].set(7)
    leafd["k_scale"] = leafd["k_scale"].at[:, 2].set(0.5)
    out = jax.jit(qm.copy_cache_pages)(caches, jnp.int32(2), jnp.int32(4))
    assert int(jnp.min(out["stack"]["k"][:, 4])) == 7
    assert float(jnp.min(out["stack"]["k_scale"][:, 4])) == 0.5
    assert float(jnp.max(jnp.abs(out["stack"]["k_scale"][:, 3]))) == 0.0


def test_gather_slot_pages_dequantizes_with_scales():
    kv, d, ps, mp = 2, 16, 8, 2
    kp, vp, pt = _paged_case(1, kv, d, ps, mp)
    kpm, vpm = kp.swapaxes(1, 2), vp.swapaxes(1, 2)  # model layout
    kq, ks = quantize_kv(kpm, jnp.int8)
    vq, vs = quantize_kv(vpm, jnp.int8)
    kd, vd = gather_slot_pages(kq, vq, jnp.asarray(pt), jnp.int32(0),
                               k_scale=ks, v_scale=vs)
    want = dequantize_kv(kq, ks)[pt[0]].reshape(1, mp * ps, kv, d)
    assert kd.dtype == jnp.float32
    assert float(jnp.max(jnp.abs(kd - want))) == 0.0


# ------------------------------------------------------------ engine level
def _reqs(n, max_new=6, seed=3, sampled=False):
    rng = np.random.default_rng(seed)
    shared = rng.integers(1, 60, size=18).astype(np.int32)
    out = []
    for i in range(n):
        tail = rng.integers(1, 60, size=int(rng.integers(2, 6))) \
            .astype(np.int32)
        prompt = np.concatenate([shared, tail]) if i % 2 else tail
        sp = (SamplingParams(temperature=0.8, top_k=20, seed=7)
              if sampled and i % 2 else SamplingParams())
        out.append(Request(i, prompt, max_new_tokens=max_new, sampling=sp))
    return out


def _run(model, params, cfg, reqs):
    eng = ServeEngine(model, params, cfg)
    hs = [eng.submit(dataclasses.replace(
        r, prompt=np.asarray(r.prompt), output=[])) for r in reqs]
    eng.run()
    return eng, [h.output for h in hs]


_PAGED = dict(batch_slots=2, max_len=64, cache="paged", page_size=8,
              prefill_chunk=16)


@pytest.mark.parametrize("name", sorted(KV_QUANT_DTYPES))
def test_quant_engine_serves_shared_prefix_trace(name):
    """int8/fp8 engines drain a shared-prefix trace with prefix hits,
    balanced pools, and (int8) about half the reserved KV bytes of the
    f32 baseline — the scale pools cost D=1 of overhead per row."""
    model, params = tiny_lm()
    cfg = ServeConfig(**_PAGED, kv_dtype=name)
    eng, outs = _run(model, params, cfg, _reqs(6))
    assert all(len(o) == 6 for o in outs)
    # drained: only prefix-cache refs (== 1) may remain
    assert not np.any(np.asarray(eng.kv.pool.ref[1:]) > 1)
    assert eng.kv.stats()["prefix_hits"] > 0
    base, _ = _run(model, params, ServeConfig(**_PAGED), _reqs(6))
    ratio = eng.kv_reserved_bytes() / base.kv_reserved_bytes()
    if name == "int8":  # 4B -> 1B + 4/D scale overhead (D=64: ~0.31)
        assert ratio < 0.5
    assert eng.kv_reserved_bytes() < base.kv_reserved_bytes()


@pytest.mark.slow  # engine-equality suite: full-suite lane
def test_quant_engine_pallas_matches_xla_bitwise():
    """Acceptance gate: in-kernel dequantization (Pallas fused decode +
    prefill) and the XLA gather path emit identical token streams over
    the same quantized pools — greedy and seeded-sampled."""
    model, params = tiny_lm()
    pallas = LM(model.cfg, model.knobs.with_(use_pallas=True))
    for sampled in (False, True):
        reqs = _reqs(6, sampled=sampled)
        cfg = ServeConfig(**_PAGED, kv_dtype="int8")
        _, ref = _run(model, params, cfg, reqs)
        _, out = _run(pallas, params, cfg, reqs)
        assert out == ref, f"sampled={sampled}"


@pytest.mark.slow
def test_quant_spec_decode_identical_to_plain():
    """Speculative decode's bitwise contract survives quantization: the
    multi-token verify writes the same quantized rows + scales the
    one-token path would."""
    model, params = tiny_lm()
    reqs = _reqs(5, max_new=8)
    _, ref = _run(model, params, ServeConfig(**_PAGED, kv_dtype="int8"),
                  reqs)
    _, out = _run(model, params,
                  ServeConfig(**_PAGED, kv_dtype="int8", draft_k=3), reqs)
    assert out == ref


def test_quant_cow_isolation_with_scales():
    """Two requests sharing a cached prefix stay isolated after the CoW
    split: the writer's appended tokens never perturb the sharer's
    output (scales travel with their pages through the copy)."""
    model, params = tiny_lm()
    eng = ServeEngine(model, params, ServeConfig(**_PAGED, kv_dtype="int8"))
    rng = np.random.default_rng(9)
    prompt = rng.integers(1, 60, size=17).astype(np.int32)
    h0 = eng.submit(Request(0, prompt.copy(), max_new_tokens=6))
    eng.run()
    # resubmits hit the prefix cache (matched > 0 -> CoW on last page)
    h1 = eng.submit(Request(1, prompt.copy(), max_new_tokens=6))
    h2 = eng.submit(Request(2, np.concatenate(
        [prompt, rng.integers(1, 60, size=3).astype(np.int32)]),
        max_new_tokens=6))
    eng.run()
    assert eng.kv.stats()["prefix_hits"] >= 2
    assert h1.output == h0.output  # sharer unperturbed by writer slot
    # only prefix-cache refs (== 1) may remain after the drain
    assert not np.any(np.asarray(eng.kv.pool.ref[1:]) > 1)


def test_quant_disagg_transfer_refcounts_balance():
    """Satellite regression: the cross-pool handoff moves a quantized
    chain — values AND scale pools — without leaking a refcount, and
    the decode engine finishes from the transferred pages."""
    model, params = tiny_lm()
    cfg = ServeConfig(**{**_PAGED, "prefix_cache": False},
                      kv_dtype="int8")
    src = ServeEngine(model, params,
                      dataclasses.replace(cfg, role="prefill"))
    dst = ServeEngine(model, params,
                      dataclasses.replace(cfg, role="decode"))
    req = _reqs(2)[1]  # long (shared+tail) prompt -> multi-page chain
    src.submit(req)
    for _ in range(10):
        src.step()
        if req.output:
            break
    assert req.output
    ck = src.release(req)
    n = len(ck.pages)
    assert n > 1
    assert src.kv.pool.in_use == n
    assert transfer_chain(src, dst, req)
    assert src.kv.pool.in_use == 0
    assert not np.any(np.asarray(src.kv.pool.ref[1:]))
    assert dst.kv.pool.in_use == n
    dst.submit(req)
    dst.run()
    assert req.done and len(req.output) == req.max_new_tokens
    assert dst.kv.pool.in_use == 0
    assert not np.any(np.asarray(dst.kv.pool.ref[1:]))


def test_kv_dtype_validation():
    model, params = tiny_lm()
    with pytest.raises(ValueError, match="cache='paged'"):
        ServeEngine(model, params,
                    ServeConfig(batch_slots=1, max_len=32,
                                kv_dtype="int8"))
    with pytest.raises(ValueError, match="int8/fp8"):
        ServeEngine(model, params,
                    ServeConfig(batch_slots=1, max_len=32, cache="paged",
                                kv_dtype="int4"))
