"""Elastic autoscaler: policy units, anti-flap damping, graceful drain,
and the churn simulator + real-router integration.

The scaling contract: a role grows only under *sustained* backlog,
never flaps inside the cooldown window, and a retiring replica drains
through the checkpoint path (pools refcount-balanced) before it leaves.
"""
import dataclasses

import numpy as np
import pytest

from conftest import tiny_lm
from repro.core.simulator import ServeChurnSim
from repro.runtime.autoscale import (AUTOSCALE_POLICIES, Autoscaler,
                                     RoleObservation, get_autoscale_policy)
from repro.runtime.disagg import DisaggRouter
from repro.runtime.sampling import SamplingParams
from repro.runtime.serve import Request, ServeConfig, ServeEngine
from repro.runtime.telemetry import Telemetry, validate_chrome_trace


def _obs(role="decode", live=1, backlog=0, weighted=None, free=0,
         slots=2):
    return RoleObservation(role=role, live=live, backlog=backlog,
                           weighted_backlog=(float(backlog)
                                             if weighted is None
                                             else weighted),
                           free_slots=free, slots_per_replica=slots)


# --------------------------------------------------------------- policies
def test_policy_registry():
    assert set(AUTOSCALE_POLICIES) == {"queue-depth", "slo-backlog"}
    with pytest.raises(KeyError):
        get_autoscale_policy("bogus")
    pol = get_autoscale_policy("queue-depth")
    assert get_autoscale_policy(pol) is pol  # instance passthrough


def test_queue_depth_hysteresis_band():
    pol = get_autoscale_policy("queue-depth")
    # up: backlog exceeds one replica's slots
    assert pol.desire(_obs(backlog=3, slots=2)) == 1
    assert pol.desire(_obs(backlog=2, slots=2)) == 0  # at threshold: hold
    # down: empty backlog AND two replicas' worth of slack
    assert pol.desire(_obs(backlog=0, free=4, slots=2)) == -1
    assert pol.desire(_obs(backlog=0, free=3, slots=2)) == 0
    # in the band (busy but not backed up): hold
    assert pol.desire(_obs(backlog=1, free=0, slots=2)) == 0
    # a nonzero backlog blocks shrink even with slack
    assert pol.desire(_obs(backlog=1, free=8, slots=2)) == 0


def test_slo_backlog_weights_gold_pressure():
    pol = get_autoscale_policy("slo-backlog")
    # one gold (weight 3) request outweighs the 2-slot threshold
    assert pol.desire(_obs(backlog=1, weighted=3.0, slots=2)) == 1
    # the same depth unweighted holds
    assert pol.desire(_obs(backlog=1, weighted=1.0, slots=2)) == 0
    # shrink side stays unweighted: needs an EMPTY backlog
    assert pol.desire(_obs(backlog=1, weighted=0.5, free=8, slots=2)) == 0
    assert pol.desire(_obs(backlog=0, weighted=0.0, free=4, slots=2)) == -1


# ------------------------------------------------------------ fake adapter
class FakeCluster:
    """Minimal adapter: one role, integer replica populations, a drain
    latch the test controls."""

    def __init__(self, role="decode", live=1, spares=2, slots=2):
        self.role = role
        self.backlog = 0
        self.weighted = None
        self.free_slots = 0
        self.slots = slots
        self.up = list(range(live))
        self.spare = [live + i for i in range(spares)]
        self.draining = []

    def scale_roles(self):
        return [self.role]

    def replica_state(self, rid):
        if rid in self.up:
            return "up"
        if rid in self.draining:
            return "draining"
        return "down"

    def observe(self, role):
        return _obs(role, live=len(self.up), backlog=self.backlog,
                    weighted=self.weighted, free=self.free_slots,
                    slots=self.slots)

    def scale_up(self, role):
        if not self.spare:
            return None
        rid = self.spare.pop(0)
        self.up.append(rid)
        return rid

    def begin_scale_down(self, role):
        rid = self.up.pop()
        self.draining.append(rid)
        return rid

    def finish_drain(self):
        while self.draining:
            self.spare.append(self.draining.pop())


def test_ctor_validation():
    with pytest.raises(ValueError, match="cooldown"):
        Autoscaler(FakeCluster(), cooldown=-1)
    with pytest.raises(ValueError, match="sustain"):
        Autoscaler(FakeCluster(), sustain=0)


def test_bounds_int_and_dict():
    sc = Autoscaler(FakeCluster(), min_replicas={"decode": 2},
                    max_replicas=3)
    assert sc.bounds("decode", population=1) == (2, 3)
    assert sc.bounds("prefill", population=1) == (1, 3)  # dict default
    sc2 = Autoscaler(FakeCluster())  # max defaults to population
    assert sc2.bounds("decode", population=4) == (1, 4)


def test_scale_up_needs_sustained_backlog():
    """Satellite: growth fires on the sustain-th consecutive pressure
    tick, not the first."""
    fc = FakeCluster()
    sc = Autoscaler(fc, sustain=3, cooldown=5, max_replicas=3)
    fc.backlog = 10
    sc.tick(0)
    sc.tick(1)
    assert sc.scale_ups == 0  # two ticks of pressure: not yet
    sc.tick(2)
    assert sc.scale_ups == 1 and len(fc.up) == 2
    assert [e.action for e in sc.events] == ["up"]
    assert sc.events[0].tick == 2 and sc.events[0].role == "decode"


def test_blip_resets_the_streak():
    fc = FakeCluster()
    sc = Autoscaler(fc, sustain=3, max_replicas=3)
    fc.backlog = 10
    sc.tick(0)
    sc.tick(1)
    fc.backlog = 0  # one quiet tick wipes the streak
    sc.tick(2)
    fc.backlog = 10
    sc.tick(3)
    sc.tick(4)
    assert sc.scale_ups == 0
    sc.tick(5)
    assert sc.scale_ups == 1


def test_no_flap_inside_cooldown():
    """Satellite: after an event the role is frozen for ``cooldown``
    ticks even under continuous pressure."""
    fc = FakeCluster(spares=3)
    sc = Autoscaler(fc, sustain=2, cooldown=6, max_replicas=4)
    fc.backlog = 50
    for t in range(2):
        sc.tick(t)
    assert sc.scale_ups == 1 and sc.events[0].tick == 1
    for t in range(2, 7):  # ticks 2..6 sit inside the freeze
        sc.tick(t)
    assert sc.scale_ups == 1
    sc.tick(7)  # 7 - 1 >= cooldown AND the streak re-sustained
    assert sc.scale_ups == 2
    assert [e.tick for e in sc.events] == [1, 7]


def test_scale_up_respects_max():
    fc = FakeCluster(live=2, spares=2)
    sc = Autoscaler(fc, sustain=1, cooldown=0, max_replicas=2)
    fc.backlog = 50
    for t in range(5):
        sc.tick(t)
    assert sc.scale_ups == 0 and len(fc.up) == 2


def test_scale_up_without_spares_is_a_noop():
    fc = FakeCluster(live=1, spares=0)
    sc = Autoscaler(fc, sustain=1, cooldown=0, max_replicas=4)
    fc.backlog = 50
    sc.tick(0)
    assert sc.scale_ups == 0 and sc.events == []


def test_scale_down_drains_before_retiring():
    """Satellite: scale-down begins a drain, the SCALE_DOWN span stays
    open while the retiree empties, and closes only when the adapter
    reports it DOWN."""
    tm = Telemetry(trace=True)
    fc = FakeCluster(live=3, spares=0)
    # min=2: exactly one drain can ever fire, so the retiring count
    # below tracks THAT drain rather than a follow-up
    sc = Autoscaler(fc, sustain=2, cooldown=0, min_replicas=2,
                    telemetry=tm)
    fc.free_slots = 12  # idle pool
    sc.tick(0)
    sc.tick(1)
    assert sc.scale_downs == 1
    assert fc.draining and sc.stats()["retiring"] == 1
    # span still open: drain in progress
    assert validate_chrome_trace(tm.trace.to_chrome())["unbalanced"]
    sc.tick(2)  # still draining
    assert sc.stats()["retiring"] == 1
    fc.finish_drain()
    sc.tick(3)
    assert sc.stats()["retiring"] == 0
    assert validate_chrome_trace(tm.trace.to_chrome())["unbalanced"] == {}


def test_scale_down_respects_min_floor():
    fc = FakeCluster(live=1, spares=0)
    sc = Autoscaler(fc, sustain=1, cooldown=0, min_replicas=1)
    fc.free_slots = 20
    for t in range(5):
        sc.tick(t)
    assert sc.scale_downs == 0 and len(fc.up) == 1


def test_retiring_replicas_count_toward_the_floor():
    """With one replica already draining, live=2 min=1 must NOT start a
    second drain (live - retiring would hit zero)."""
    fc = FakeCluster(live=2, spares=0)
    sc = Autoscaler(fc, sustain=1, cooldown=0, min_replicas=1)
    fc.free_slots = 20
    sc.tick(0)
    assert sc.scale_downs == 1
    sc.tick(1)  # still draining; live=1, retiring=1
    assert sc.scale_downs == 1


def test_stats_and_events_roundtrip():
    fc = FakeCluster()
    sc = Autoscaler(fc, sustain=1, cooldown=0, max_replicas=2)
    fc.backlog = 9
    sc.tick(4)
    st = sc.stats()
    assert st["policy"] == "queue-depth"
    assert st["scale_ups"] == 1 and st["scale_downs"] == 0
    assert st["events"] == [{"tick": 4, "role": "decode", "action": "up",
                             "replica": 1, "backlog": 9, "live": 1}]


# --------------------------------------------------------- churn simulator
def test_churn_sim_scales_and_loses_nothing():
    """ISSUE acceptance at scale: hundreds of requests churn through
    the fake cluster driving the REAL Autoscaler — zero lost, bounds
    respected, and both directions of scaling observed."""
    sim = ServeChurnSim(seed=1, max_replicas=4, cooldown=8, sustain=2)
    res = sim.run()
    assert res["lost"] == 0 and res["pending"] == 0
    assert res["completed"] == res["arrived"] > 100
    assert res["bounds_respected"]
    assert res["scale_ups"] >= 1 and res["scale_downs"] >= 1
    assert res["peak_replicas"]["prefill"] >= 2 or \
        res["peak_replicas"]["decode"] >= 2


def test_churn_sim_slo_policy_and_reproducible():
    a = ServeChurnSim(seed=7, policy="slo-backlog").run()
    b = ServeChurnSim(seed=7, policy="slo-backlog").run()
    assert a["lost"] == 0 and a["bounds_respected"]
    assert a == b  # same seed, same trajectory


@pytest.mark.slow  # thousands-of-requests churn: full-suite lane
def test_churn_sim_large_scale():
    sim = ServeChurnSim(seed=3, trace=[5] * 300 + [0] * 100 + [4] * 200,
                        max_replicas=6, cooldown=6, sustain=2)
    res = sim.run(max_ticks=50_000)
    assert res["arrived"] >= 2000
    assert res["lost"] == 0 and res["pending"] == 0
    assert res["bounds_respected"]
    assert res["scale_ups"] >= 2 and res["scale_downs"] >= 1


# ------------------------------------------------------------- real router
def _reqs(n, *, max_new=8, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        prompt = rng.integers(1, 60,
                              size=int(rng.integers(3, 9))).astype(np.int32)
        sp = SamplingParams(temperature=0.8 if i % 2 else 0.0, seed=7)
        out.append(Request(100 + i, prompt, max_new_tokens=max_new,
                           sampling=sp))
    return out


def test_autoscaler_on_real_disagg_router():
    """Small-scale integration: cold DOWN spares rejoin under backlog,
    outputs stay bitwise vs the unified engine, pools drain balanced."""
    model, params = tiny_lm()
    paged = dict(cache="paged", page_size=8, prefix_cache=False)
    base = ServeConfig(batch_slots=2, max_len=64, **paged)
    roles = ["prefill", "prefill", "decode", "decode"]

    def make(rid):
        return ServeEngine(model, params,
                           dataclasses.replace(base, role=roles[rid]))

    reqs = _reqs(10, max_new=8, seed=5)
    ref_eng = ServeEngine(model, params, base)
    for r in reqs:
        ref_eng.submit(dataclasses.replace(
            r, prompt=np.asarray(r.prompt), output=[]))
    ref = {r.req_id: list(r.output) for r in ref_eng.run()}

    tm = Telemetry(trace=True)
    router = DisaggRouter(make, 4, roles=roles, start_down=(1, 3),
                          telemetry=tm)
    router.autoscaler = Autoscaler(router, "queue-depth", cooldown=2,
                                   sustain=2, max_replicas=2,
                                   telemetry=tm)
    for r in reqs:
        router.submit(r)
    done = router.run(max_ticks=800)
    assert router.autoscaler.scale_ups >= 1  # a spare rejoined
    assert {r.req_id: list(r.output) for r in done} == ref
    assert router.stats()["failed"] == 0
    for rh in router.replicas:
        if rh.engine is not None and rh.engine.kv is not None:
            assert rh.engine.kv.pool.in_use == 0
    # SCALE_* spans land in the trace and balance out
    names = {e.get("name") for e in tm.trace.to_chrome()["traceEvents"]}
    assert "SCALE_UP" in names
    assert validate_chrome_trace(tm.trace.to_chrome())["unbalanced"] == {}
