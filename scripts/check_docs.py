"""Docs checker: broken intra-repo links and phantom CLI flags.

    python scripts/check_docs.py

Two failure classes, both cheap to detect and historically the two ways
these docs have rotted:

* **Broken intra-repo links** — every markdown link target that is not
  an external URL or a bare anchor must resolve to a real file (relative
  to the doc, or repo-root-relative).  Renaming a doc or module without
  chasing its references fails here.

* **Phantom flags** — every ``--flag`` token mentioned in the docs must
  exist in some repo CLI: the serving/training launchers, the scripts,
  or the benchmarks (collected by scanning their ``add_argument`` calls,
  so the check needs no jax import), plus a small allowlist for
  third-party tools the docs quote (pytest/coverage).  Docs advertising
  a flag ``python -m repro.launch.serve --help`` does not know fail
  here — the bug PR 7/8 reviews kept catching by hand.

Exit status is nonzero on any finding; run it via ``scripts/ci.sh
tier1`` (or ``all``).
"""
from __future__ import annotations

import os
import re
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

# docs under check: everything in docs/ plus the top-level entry points
DOC_GLOBS = ("docs", "README.md", "ROADMAP.md", "CHANGES.md")

# where repo CLIs define their flags (scanned for add_argument("--..."))
CLI_SOURCE_DIRS = ("src/repro/launch", "scripts", "benchmarks")

# flags the docs quote that belong to third-party tools, not repo CLIs
THIRD_PARTY_FLAGS = {
    "--cov", "--cov-report", "--cov-fail-under",  # pytest-cov
    "--help",  # argparse built-in (never in add_argument calls)
}

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FLAG_RE = re.compile(r"(?<![\w-])(--[a-z][a-z0-9-]*)")
ADD_ARG_RE = re.compile(r"add_argument\(\s*[\"'](--[a-z][a-z0-9-]*)[\"']")


def doc_files() -> list:
    out = []
    for entry in DOC_GLOBS:
        path = os.path.join(ROOT, entry)
        if os.path.isdir(path):
            out += [os.path.join(path, f) for f in sorted(os.listdir(path))
                    if f.endswith(".md")]
        elif os.path.exists(path):
            out.append(path)
    return out


def known_flags() -> set:
    flags = set(THIRD_PARTY_FLAGS)
    for d in CLI_SOURCE_DIRS:
        base = os.path.join(ROOT, d)
        for root, _dirs, files in os.walk(base):
            for f in files:
                if not f.endswith(".py"):
                    continue
                with open(os.path.join(root, f)) as fh:
                    flags.update(ADD_ARG_RE.findall(fh.read()))
    return flags


def check_links(path: str, text: str) -> list:
    errors = []
    for n, line in enumerate(text.splitlines(), 1):
        for target in LINK_RE.findall(line):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            target = target.split("#", 1)[0]  # strip in-file anchors
            if not target:
                continue
            rel = os.path.normpath(
                os.path.join(os.path.dirname(path), target))
            root_rel = os.path.normpath(os.path.join(ROOT, target))
            if not (os.path.exists(rel) or os.path.exists(root_rel)):
                errors.append(f"{os.path.relpath(path, ROOT)}:{n}: "
                              f"broken link -> {target}")
    return errors


def check_flags(path: str, text: str, flags: set) -> list:
    errors = []
    for n, line in enumerate(text.splitlines(), 1):
        for flag in FLAG_RE.findall(line):
            if flag not in flags:
                errors.append(f"{os.path.relpath(path, ROOT)}:{n}: "
                              f"flag {flag} not defined by any repo CLI "
                              f"(launchers/scripts/benchmarks)")
    return errors


def main() -> int:
    flags = known_flags()
    errors = []
    docs = doc_files()
    for path in docs:
        with open(path) as fh:
            text = fh.read()
        errors += check_links(path, text)
        errors += check_flags(path, text, flags)
    if errors:
        print(f"check_docs: {len(errors)} finding(s) in {len(docs)} docs:")
        for e in errors:
            print(f"  {e}")
        return 1
    print(f"check_docs OK: {len(docs)} docs, {len(flags)} known flags, "
          f"links + flags clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
