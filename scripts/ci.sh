#!/usr/bin/env bash
# CI entry point: tier-1 tests, per-arch smoke (fails loudly on any arch
# error), then the serving benchmark in fast dry mode.  Run from repo root:
#
#   bash scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "== tier-1 pytest =="
python -m pytest -x -q

echo "== smoke_all (every arch: fwd/loss/prefill/decode) =="
python scripts/smoke_all.py

echo "== serve throughput (dry) =="
python benchmarks/serve_throughput.py --dry

echo "CI OK"
