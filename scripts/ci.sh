#!/usr/bin/env bash
# Tiered CI entry point — the local mirror of .github/workflows/ci.yml.
# Run from anywhere:
#
#   bash scripts/ci.sh [lint|tier1|smoke|chaos|bench|all]
#
#   lint   ruff check (skipped with a warning if ruff is not installed)
#   tier1  fast pytest lane:  -m "not slow"  (the per-push CI lane);
#          with pytest-cov installed it also enforces a line-coverage
#          floor over src/repro/runtime/ (skipped with a warning
#          otherwise — containers without the plugin still gate tests);
#          then the forced-8-device sharded-decode equality tests
#          (tests/test_sharded_serve.py) and the doc link/flag checker
#          (scripts/check_docs.py)
#   smoke  per-arch smoke_all + serving launcher smokes (paged, every
#          admission policy, preemption + weighted SLO tiers,
#          speculative decode)
#   chaos  cluster-serving chaos smoke: one of three replicas is killed
#          mid-run via --fault-schedule and must rejoin; the launcher
#          asserts zero lost requests (recovery by deterministic replay);
#          plus disagg (prefill/decode split) and autoscaled-disagg
#          smokes through the same launcher flags
#   bench  dry benchmarks + the regression gate (scripts/check_bench.py)
#   all    full pytest (the pre-merge lane) + smoke + chaos + bench
#          [default]
#
# Re-baselining the bench gate after an intentional perf change:
#   python scripts/check_bench.py --update   # then commit the baselines
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

tier="${1:-all}"

lint() {
    echo "== lint (ruff) =="
    if command -v ruff >/dev/null 2>&1; then
        ruff check src tests benchmarks scripts
    else
        echo "ruff not installed — skipping lint (CI runs it)"
    fi
}

tier1() {
    echo "== tier-1 pytest (-m 'not slow') =="
    if python -c "import pytest_cov" >/dev/null 2>&1; then
        # line-coverage floor for the serving runtime: the layer every
        # PR touches and the one whose regressions are silent (a dead
        # branch in the scheduler/engine still "passes" smoke runs)
        python -m pytest -x -q -m "not slow" \
            --cov=repro.runtime --cov-report=term --cov-fail-under=80
    else
        echo "pytest-cov not installed — running tier1 without the" \
             "coverage floor (CI enforces it)"
        python -m pytest -x -q -m "not slow"
    fi

    echo "== tier-1 sharded decode equality (forced 8-device host) =="
    # the equality tests spawn their own 8-device subprocesses, but the
    # env var on the runner pins the invariant this lane exists for:
    # sharded == unsharded bitwise on a genuinely multi-device mesh
    XLA_FLAGS="--xla_force_host_platform_device_count=8" \
        python -m pytest -x -q tests/test_sharded_serve.py

    echo "== doc link + flag checker =="
    python scripts/check_docs.py
}

full_tests() {
    echo "== full pytest (pre-merge lane) =="
    python -m pytest -x -q
}

smoke() {
    echo "== smoke_all (every arch: fwd/loss/prefill/decode) =="
    python scripts/smoke_all.py

    echo "== paged serve smoke (launcher) =="
    python -m repro.launch.serve --arch internlm2-1.8b --smoke --requests 6 \
        --slots 2 --max-len 64 --max-new 6 --cache paged --page-size 8

    echo "== quantized paged KV smoke (launcher --kv-dtype int8) =="
    python -m repro.launch.serve --arch internlm2-1.8b --smoke --requests 6 \
        --slots 2 --max-len 64 --max-new 6 --cache paged --page-size 8 \
        --kv-dtype int8

    echo "== admission policy smokes (launcher, sampled, 2 tenants) =="
    for policy in fcfs priority sjf drf-fair; do
        python -m repro.launch.serve --arch internlm2-1.8b --smoke \
            --requests 6 --slots 2 --max-len 64 --max-new 6 \
            --policy "$policy" --tenants 2 --temperature 0.7 --top-k 8
    done

    echo "== preemption + weighted SLO smoke (launcher) =="
    python -m repro.launch.serve --arch internlm2-1.8b --smoke \
        --requests 8 --slots 2 --max-len 64 --max-new 6 \
        --policy drf-fair --tenants 2 \
        --tenant-weights "tenant-0=3,tenant-1=1" --preempt \
        --victim-policy lowest-weight-share-first

    echo "== telemetry smoke (launcher --trace-out, then validate) =="
    python -m repro.launch.serve --arch internlm2-1.8b --smoke \
        --requests 6 --slots 2 --max-len 64 --max-new 6 \
        --trace-out artifacts/smoke_trace.json \
        --metrics-out artifacts/smoke_metrics.prom
    python -m repro.runtime.telemetry artifacts/smoke_trace.json

    echo "== sharded decode smoke (launcher --tp / --mesh-shape) =="
    XLA_FLAGS="--xla_force_host_platform_device_count=8" \
        python -m repro.launch.serve --arch internlm2-1.8b --smoke \
        --requests 6 --slots 2 --max-len 64 --max-new 6 --tp 2
    XLA_FLAGS="--xla_force_host_platform_device_count=8" \
        python -m repro.launch.serve --arch internlm2-1.8b --smoke \
        --requests 6 --slots 4 --max-len 64 --max-new 6 \
        --mesh-shape 2,2 --cache paged

    echo "== speculative decode smoke (launcher, dense + paged) =="
    python -m repro.launch.serve --arch internlm2-1.8b --smoke \
        --requests 6 --slots 2 --max-len 64 --max-new 8 \
        --speculate --draft-k 3
    python -m repro.launch.serve --arch internlm2-1.8b --smoke \
        --requests 6 --slots 2 --max-len 64 --max-new 8 \
        --speculate --draft-k 3 --cache paged --page-size 8
}

chaos() {
    echo "== cluster chaos smoke (kill 1 of 3 replicas mid-run) =="
    # the launcher exits nonzero if any request fails its retry budget,
    # so "zero lost requests" is asserted in-process
    # fully telemetered: Chrome trace + metrics land in artifacts/ (CI
    # uploads them on failure), the armed flight recorder dumps its ring
    # on the fence, and the trace must validate with balanced spans
    python -m repro.launch.serve --arch internlm2-1.8b --smoke \
        --requests 9 --slots 2 --max-len 64 --max-new 8 \
        --replicas 3 --router-policy spread \
        --tenants 2 --tenant-weights "tenant-0=3,tenant-1=1" \
        --fault-schedule "4:kill:1,24:rejoin:1" --miss-threshold 2 \
        --trace-out artifacts/chaos_smoke_trace.json \
        --metrics-out artifacts/chaos_smoke_metrics.json \
        --flight-recorder 512
    python -m repro.runtime.telemetry artifacts/chaos_smoke_trace.json

    echo "== cluster chaos smoke (seeded schedule, paged KV) =="
    python -m repro.launch.serve --arch internlm2-1.8b --smoke \
        --requests 9 --slots 2 --max-len 64 --max-new 8 \
        --replicas 3 --cache paged --page-size 8 --no-prefix-cache \
        --fault-schedule "seed=3:3:30"

    echo "== disagg smoke (prefill/decode split, paged handoff) =="
    python -m repro.launch.serve --arch internlm2-1.8b --smoke \
        --requests 8 --slots 2 --max-len 64 --max-new 8 \
        --replicas 3 --roles "prefill=1,decode=2" \
        --cache paged --page-size 8 --no-prefix-cache

    echo "== disagg + autoscale smoke (cold spares, chaos kill) =="
    # prefill replica 1 is killed mid-run and rejoins; the autoscaler
    # wakes cold spares under the backlog — the launcher asserts zero
    # lost requests in-process
    python -m repro.launch.serve --arch internlm2-1.8b --smoke \
        --requests 12 --slots 2 --max-len 64 --max-new 8 \
        --replicas 3 --roles "prefill=2,decode=1" \
        --autoscale-policy queue-depth --max-replicas 3 \
        --scale-cooldown 4 \
        --fault-schedule "4:kill:1,24:rejoin:1" --miss-threshold 2 \
        --trace-out artifacts/disagg_smoke_trace.json
    python -m repro.runtime.telemetry artifacts/disagg_smoke_trace.json
}

bench() {
    echo "== dry benchmarks + regression gate =="
    # headroom over the strict defaults: local dev boxes and shared
    # containers carry neighbor load a dedicated runner would not (the
    # structural DRF/preemption/replay bounds are exact regardless)
    python scripts/check_bench.py --tolerance 0.4 --retries 3
}

case "$tier" in
    lint)  lint ;;
    tier1) tier1 ;;
    smoke) smoke ;;
    chaos) chaos ;;
    bench) bench ;;
    all)   lint; full_tests; smoke; chaos; bench ;;
    *) echo "usage: $0 [lint|tier1|smoke|chaos|bench|all]" >&2; exit 2 ;;
esac

echo "CI OK ($tier)"
