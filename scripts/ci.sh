#!/usr/bin/env bash
# CI entry point: tier-1 tests, per-arch smoke (fails loudly on any arch
# error), then the serving benchmark in fast dry mode.  Run from repo root:
#
#   bash scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "== tier-1 pytest =="
python -m pytest -x -q

echo "== smoke_all (every arch: fwd/loss/prefill/decode) =="
python scripts/smoke_all.py

echo "== serve throughput (dry) =="
python benchmarks/serve_throughput.py --dry

echo "== paged serve (dry): paged+prefix-cache vs dense =="
python benchmarks/paged_serve.py --dry

echo "== paged serve smoke (launcher) =="
python -m repro.launch.serve --arch internlm2-1.8b --smoke --requests 6 \
    --slots 2 --max-len 64 --max-new 6 --cache paged --page-size 8

echo "== admission policy smokes (launcher, sampled, 2 tenants) =="
for policy in fcfs priority sjf drf-fair; do
    python -m repro.launch.serve --arch internlm2-1.8b --smoke \
        --requests 6 --slots 2 --max-len 64 --max-new 6 \
        --policy "$policy" --tenants 2 --temperature 0.7 --top-k 8
done

echo "CI OK"
