"""Benchmark regression gate: compare a fresh dry run against tracked
baselines.

    PYTHONPATH=src python scripts/check_bench.py [names...]
        [--tolerance 0.25] [--latency-tolerance 1.0]
        [--no-run] [--update]

For each benchmark name (default: ``serve_throughput`` and
``paged_serve``) this (1) runs ``benchmarks/<name>.py --dry`` — which
writes ``BENCH_<name>_dry.json`` at the repo root — unless ``--no-run``,
then (2) compares the fresh JSON against the tracked baseline
``benchmarks/baselines/BENCH_<name>_dry.json``:

* **rate metrics** (``tok_per_s``, ``continuous_speedup``) must not fall
  more than ``--tolerance`` (default ±25%) below baseline — faster
  always passes;
* **latency metrics** (p99 TTFT / p99 TPOT) must not rise more than
  ``--latency-tolerance`` above baseline (default ±100%: wall-clock
  percentiles on shared CI runners are far noisier than throughput);
* **DRF share bounds** are structural, machine-independent, and checked
  absolutely: the flooding tenant's share stays at its entitlement
  (unweighted: ≤ 0.75 over 4 slots; weighted SLO flood: 0.75 ± 0.1),
  preemption fired, and the checkpoint/resume replay was bitwise
  identical.

Dry traces are single wall-clock samples, so the gate is best-of-N: a
benchmark passes if ANY of ``--retries`` fresh runs clears every bound
(a genuine regression fails all of them; one-off scheduler noise does
not).  Exit status is nonzero on any regression.  To re-baseline after
an intentional perf change, run with ``--update`` (copies the fresh dry
JSONs over the baselines) and commit the result — see docs/ci.md.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import subprocess
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
BASELINE_DIR = os.path.join(ROOT, "benchmarks", "baselines")
DEFAULT_NAMES = ("serve_throughput", "paged_serve", "spec_decode",
                 "cluster_serve", "disagg_serve", "kernel_roofline",
                 "sharded_decode", "quant_kv")

# (json path into the payload, kind): kind "rate" = higher is better,
# "latency" = lower is better, gated by the respective tolerance
METRICS = {
    # NOTE: the flood/SLO tail latencies are deliberately NOT gated
    # cross-run here — their claims (drf light tenant faster than fcfs,
    # preemption beating the no-preempt baseline) are asserted
    # *relatively within one process* by the benchmark itself, which is
    # robust; their absolute ~15 ms values are pure scheduler jitter.
    "serve_throughput": [
        (("continuous", "tok_per_s"), "rate"),
        (("continuous_speedup",), "rate"),
        (("continuous", "p99_ttft_s"), "latency"),
        (("continuous", "p99_tpot_s"), "latency"),
    ],
    "paged_serve": [
        (("paged", "tok_per_s"), "rate"),
        (("paged", "p99_ttft_s"), "latency"),
    ],
    "spec_decode": [
        (("spec", "tok_per_s"), "rate"),
        (("spec_paged", "tok_per_s"), "rate"),
    ],
    "cluster_serve": [
        (("tok_per_s_1",), "rate"),
        (("tok_per_s_2",), "rate"),
        (("tok_per_s_4",), "rate"),
        (("chaos", "tok_per_s"), "rate"),
    ],
    "disagg_serve": [
        (("unified", "tok_per_s"), "rate"),
        (("disagg", "tok_per_s"), "rate"),
        (("chaos", "tok_per_s"), "rate"),
        (("disagg", "p99_ttft_s"), "latency"),
    ],
    # achieved roofline fractions: numerator is a pure function of the
    # HLO, so the ratio regresses exactly when the kernel's real speed
    # does (ROADMAP "roofline-gated" item)
    # sharded decode: tok/s trends only — the identity/offer claims are
    # BOUNDS (bitwise flags), machine-independent by construction
    "sharded_decode": [
        (("unsharded", "tok_per_s"), "rate"),
        (("tp2", "tok_per_s"), "rate"),
    ],
    "kernel_roofline": [
        (("dense_decode", "achieved_fraction"), "rate"),
        (("paged_decode", "achieved_fraction"), "rate"),
        (("quant_decode", "achieved_fraction"), "rate"),
        (("paged_prefill", "achieved_fraction"), "rate"),
        (("paged_splitk", "achieved_fraction"), "rate"),
        (("spec_verify", "achieved_fraction"), "rate"),
    ],
    # quantized KV: throughput trends for both engines; the density and
    # completion claims are BOUNDS (pure functions of shapes / flags)
    "quant_kv": [
        (("bf16", "tok_per_s"), "rate"),
        (("int8", "tok_per_s"), "rate"),
    ],
}

# (json path, predicate, description): machine-independent share/shape
# bounds — these never need re-baselining
BOUNDS = {
    "serve_throughput": [
        (("flood", "drf-fair", "max_heavy_slot_share"),
         lambda v: v <= 0.75 + 1e-9,
         "unweighted DRF flood share bounded by fair share"),
        (("slo_flood", "weighted-preempt",
          "max_gold_share_while_free_waits"),
         lambda v: abs(v - 0.75) <= 0.1,
         "weighted SLO flood: gold at its 3:1 entitlement (0.75 +- 0.1)"),
        (("slo_flood", "weighted-preempt", "preemptions"),
         lambda v: v >= 1, "preemption fired under the SLO flood"),
        (("slo_flood", "weighted-preempt", "replay_bitwise_identical"),
         lambda v: bool(v), "preempted request replayed bitwise-identical"),
        (("telemetry", "overhead_frac"), lambda v: v <= 0.02,
         "full tracing costs <= 2% tokens/s (same-process pairwise)"),
        (("telemetry", "spans_balanced"), lambda v: bool(v),
         "traced run left no orphan spans"),
    ],
    "paged_serve": [],
    "spec_decode": [
        (("spec", "acceptance_rate"), lambda v: v >= 0.3,
         "n-gram drafter acceptance holds on the repetition trace"),
        (("spec", "tokens_per_tick"), lambda v: v >= 1.5,
         "speculation amortizes ticks (>= 1.5 verified tokens/tick)"),
        (("spec_speedup",), lambda v: v >= 1.3,
         "speculative decode >= 1.3x baseline tokens/s (same process, "
         "machine-independent ratio)"),
        (("replay_bitwise_identical",), lambda v: bool(v),
         "speculative output bitwise-identical to baseline decode"),
        (("spec_paged", "pool_drained"), lambda v: bool(v),
         "paged spec run returned every page (no rollback leak)"),
    ],
    "cluster_serve": [
        (("all_completed_1",), lambda v: bool(v),
         "fault-free pool N=1 served every request"),
        (("all_completed_2",), lambda v: bool(v),
         "fault-free pool N=2 served every request"),
        (("all_completed_4",), lambda v: bool(v),
         "fault-free pool N=4 served every request"),
        (("chaos", "all_completed"), lambda v: bool(v),
         "zero requests lost to the injected replica kill"),
        (("chaos", "recoveries"), lambda v: v >= 1,
         "the kill schedule actually exercised recovery"),
        (("chaos_bitwise_identical",), lambda v: bool(v),
         "recovered outputs bitwise-identical to the fault-free run"),
        (("chaos", "pool_drained"), lambda v: bool(v),
         "surviving replicas returned every KV page after recovery"),
        (("gold_p99_ttft_bounded",), lambda v: bool(v),
         "brown-out shedding kept gold p99 TTFT <= free p99 TTFT"),
        (("chaos", "replay_spans"), lambda v: v >= 1,
         "the chaos trace shows recovery as REPLAY spans"),
        (("chaos", "spans_balanced"), lambda v: bool(v),
         "chaos trace left no orphan spans (kill/replay close cleanly)"),
        (("chaos", "trace_valid"), lambda v: bool(v),
         "chaos Chrome-trace export validates (Perfetto-loadable)"),
    ],
    "disagg_serve": [
        (("disagg_bitwise_identical",), lambda v: bool(v),
         "disagg outputs bitwise-identical to the unified pool"),
        (("disagg", "pool_drained"), lambda v: bool(v),
         "both halves of the split returned every KV page"),
        (("chaos", "all_completed"), lambda v: bool(v),
         "zero requests lost to the mid-handoff prefill kill"),
        (("chaos", "recoveries"), lambda v: v >= 1,
         "the mid-handoff kill actually exercised replay recovery"),
        (("chaos_bitwise_identical",), lambda v: bool(v),
         "post-kill continuations bitwise-identical to the clean twin"),
        (("chaos", "pool_drained"), lambda v: bool(v),
         "surviving pools drained after the mid-handoff kill"),
        (("chaos", "handoff_spans"), lambda v: v >= 1,
         "the chaos trace shows the handoff pipeline as HANDOFF spans"),
        (("chaos", "spans_balanced"), lambda v: bool(v),
         "chaos trace left no orphan spans"),
        (("chaos", "trace_valid"), lambda v: bool(v),
         "chaos Chrome-trace export validates (Perfetto-loadable)"),
        (("chaos", "flight_has_handoff_snapshot"), lambda v: bool(v),
         "the fence's flight dump carried the in-transit handoff queue"),
        (("churn", "lost"), lambda v: v == 0,
         "autoscaled churn lost zero requests"),
        (("churn", "pool_drained"), lambda v: bool(v),
         "autoscaled churn drained every pool"),
        (("churn", "scale_ups"), lambda v: v >= 1,
         "churn backlog woke at least one cold spare"),
        (("churn", "scale_spans"), lambda v: v >= 1,
         "scale events are visible as SCALE_* telemetry spans"),
        (("sim", "completed_all"), lambda v: bool(v),
         "simulator churn completed every arrival (zero lost/pending)"),
        (("sim", "bounds_respected"), lambda v: bool(v),
         "simulator kept every role inside its min/max bounds"),
        (("sim", "scale_downs"), lambda v: v >= 1,
         "simulator churn exercised scale-down (drain-before-retire)"),
    ],
    "sharded_decode": [
        (("tp2_bitwise_identical",), lambda v: bool(v),
         "TP-2 sharded decode bitwise-identical to single-device"),
        (("dp2tp2_bitwise_identical",), lambda v: bool(v),
         "2-host TP-2 sharded decode bitwise-identical to single-device"),
        (("offer_by_host_sums",), lambda v: bool(v),
         "sharded offer's per-host page split sums to the aggregate"),
    ],
    "kernel_roofline": [
        (("dense_decode", "flops"), lambda v: v > 0,
         "HLO analyzer counted compute for the dense decode kernel"),
        (("dense_decode", "hbm_bytes"), lambda v: v > 0,
         "HLO analyzer counted HBM traffic for the dense decode kernel"),
        (("paged_decode", "flops"), lambda v: v > 0,
         "HLO analyzer counted compute for the paged decode kernel"),
        (("paged_decode", "hbm_bytes"), lambda v: v > 0,
         "HLO analyzer counted HBM traffic for the paged decode kernel"),
        (("dense_decode", "achieved_fraction"), lambda v: v > 0,
         "dense decode achieved fraction is positive"),
        (("paged_decode", "achieved_fraction"), lambda v: v > 0,
         "paged decode achieved fraction is positive"),
        (("spec_verify", "achieved_fraction"), lambda v: v > 0,
         "speculative verify achieved fraction is positive"),
        (("quant_decode", "flops"), lambda v: v > 0,
         "HLO analyzer counted compute for the quantized decode kernel"),
        (("quant_decode", "hbm_bytes"), lambda v: v > 0,
         "HLO analyzer counted HBM traffic for the quantized decode kernel"),
        (("quant_decode", "achieved_fraction"), lambda v: v > 0,
         "quantized decode achieved fraction is positive"),
        (("paged_prefill", "flops"), lambda v: v > 0,
         "HLO analyzer counted compute for the paged prefill kernel"),
        (("paged_prefill", "hbm_bytes"), lambda v: v > 0,
         "HLO analyzer counted HBM traffic for the paged prefill kernel"),
        (("paged_prefill", "achieved_fraction"), lambda v: v > 0,
         "paged prefill achieved fraction is positive"),
        (("paged_splitk", "flops"), lambda v: v > 0,
         "HLO analyzer counted compute for the paged split-K kernel"),
        (("paged_splitk", "hbm_bytes"), lambda v: v > 0,
         "HLO analyzer counted HBM traffic for the paged split-K kernel"),
        (("paged_splitk", "achieved_fraction"), lambda v: v > 0,
         "paged split-K achieved fraction is positive"),
    ],
    "quant_kv": [
        # the reservation is a pure function of shapes, so the density
        # ratio is machine-independent; the benchmark additionally
        # asserts it against the exact analytic 2D/(D+4) in-process
        (("kv_bytes_ratio",), lambda v: v >= 1.5,
         "int8 pools hold >= 1.5x the pages per reserved HBM byte"),
        (("speed_ratio",), lambda v: v >= 0.5,
         "int8 engine holds >= 0.5x bf16 tokens/s (dry CPU floor; full "
         "runs gate parity in-process)"),
        (("int8", "completed_all"), lambda v: bool(v),
         "int8 engine served the full shared-prefix trace"),
        (("bf16", "completed_all"), lambda v: bool(v),
         "bf16 baseline served the full shared-prefix trace"),
        (("int8", "prefix_hits"), lambda v: v >= 1,
         "prefix cache (CoW pages + scales) hits under quantization"),
    ],
}


def dig(payload: dict, path: tuple):
    for key in path:
        payload = payload[key]
    return payload


def run_dry(name: str) -> None:
    script = os.path.join(ROOT, "benchmarks", f"{name}.py")
    if not os.path.exists(script):
        sys.exit(f"check_bench: benchmark script "
                 f"{os.path.relpath(script, ROOT)} does not exist (gated "
                 f"name without a benchmark? known: {sorted(METRICS)})")
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(ROOT, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run([sys.executable, script, "--dry"],
                          cwd=ROOT, env=env)
    if proc.returncode != 0:
        sys.exit(f"check_bench: benchmarks/{name}.py --dry exited with "
                 f"status {proc.returncode} — fix the benchmark (its "
                 f"in-process asserts gate correctness) before gating "
                 f"its numbers")


def check(name: str, tol: float, lat_tol: float,
          structural_only: bool = False) -> list[str]:
    fresh_path = os.path.join(ROOT, f"BENCH_{name}_dry.json")
    base_path = os.path.join(BASELINE_DIR, f"BENCH_{name}_dry.json")
    if not structural_only and not os.path.exists(base_path):
        return [f"{name}: no baseline at {os.path.relpath(base_path, ROOT)}"
                f" — run scripts/check_bench.py --update and commit it"]
    if not os.path.exists(fresh_path):
        return [f"{name}: no fresh run at "
                f"{os.path.relpath(fresh_path, ROOT)} — drop --no-run or "
                f"run benchmarks/{name}.py --dry first"]
    with open(fresh_path) as f:
        fresh = json.load(f)
    base = {}
    if not structural_only:
        with open(base_path) as f:
            base = json.load(f)
    failures = []
    for path, kind in ([] if structural_only else METRICS[name]):
        label = f"{name}:{'.'.join(path)}"
        try:
            fv, bv = float(dig(fresh, path)), float(dig(base, path))
        except KeyError:
            failures.append(f"{label}: missing (baseline stale? re-run "
                            f"--update)")
            continue
        if kind == "rate":
            floor = bv * (1 - tol)
            ok = fv >= floor
            verdict = f"{fv:.4g} vs baseline {bv:.4g} (floor {floor:.4g})"
        else:
            ceil = bv * (1 + lat_tol)
            ok = fv <= ceil
            verdict = f"{fv:.4g} vs baseline {bv:.4g} (ceil {ceil:.4g})"
        print(f"  {'ok  ' if ok else 'FAIL'} {label}: {verdict}")
        if not ok:
            failures.append(f"{label}: {verdict}")
    for path, pred, desc in BOUNDS[name]:
        label = f"{name}:{'.'.join(path)}"
        try:
            v = dig(fresh, path)
        except KeyError:
            failures.append(f"{label}: missing ({desc})")
            continue
        ok = pred(v)
        print(f"  {'ok  ' if ok else 'FAIL'} {label} = {v!r} ({desc})")
        if not ok:
            failures.append(f"{label} = {v!r} violates: {desc}")
    return failures


def update(names) -> None:
    os.makedirs(BASELINE_DIR, exist_ok=True)
    for name in names:
        src = os.path.join(ROOT, f"BENCH_{name}_dry.json")
        dst = os.path.join(BASELINE_DIR, f"BENCH_{name}_dry.json")
        if not os.path.exists(src):
            sys.exit(f"check_bench: cannot re-baseline {name} — no fresh "
                     f"run at {os.path.relpath(src, ROOT)} (run "
                     f"benchmarks/{name}.py --dry first, or drop --no-run)")
        first = not os.path.exists(dst)
        shutil.copyfile(src, dst)
        print(f"{'created baseline' if first else 're-baselined'} "
              f"{os.path.relpath(dst, ROOT)}")


def main():
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("names", nargs="*",
                    help=f"benchmarks to gate (default: all of "
                         f"{', '.join(DEFAULT_NAMES)})")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="allowed relative drop in rate metrics "
                         "(default 0.25 = -25%%)")
    ap.add_argument("--latency-tolerance", type=float, default=1.0,
                    help="allowed relative rise in p99 latency metrics "
                         "(default 1.0 = +100%%; wall-clock percentiles "
                         "are noisy on shared runners)")
    ap.add_argument("--structural-only", action="store_true",
                    help="gate only the machine-independent bounds (DRF "
                         "shares, preemption, bitwise replay) — for CI "
                         "runners whose hardware does not match the "
                         "recorded baselines")
    ap.add_argument("--retries", type=int, default=2,
                    help="best-of-N gating: pass if any of N fresh runs "
                         "clears every bound (default 2)")
    ap.add_argument("--no-run", action="store_true",
                    help="compare existing BENCH_*_dry.json without "
                         "re-running the benchmarks (implies 1 attempt)")
    ap.add_argument("--update", action="store_true",
                    help="copy the fresh dry JSONs over the tracked "
                         "baselines (re-baseline) instead of gating")
    args = ap.parse_args()
    names = args.names or list(DEFAULT_NAMES)
    unknown = set(names) - set(METRICS)
    if unknown:
        ap.error(f"unknown benchmark(s) {sorted(unknown)}; "
                 f"known: {sorted(METRICS)}")

    if args.update:
        for name in names:
            if not args.no_run:
                print(f"== fresh dry run: {name} ==")
                run_dry(name)
        update(names)
        return
    failures = []
    attempts = 1 if args.no_run else max(1, args.retries)
    for name in names:
        for attempt in range(attempts):
            if not args.no_run:
                print(f"== fresh dry run: {name} "
                      f"(attempt {attempt + 1}/{attempts}) ==")
                run_dry(name)
            print(f"== gate: {name} ==")
            fails = check(name, args.tolerance, args.latency_tolerance,
                          structural_only=args.structural_only)
            if not fails:
                break
            if attempt + 1 < attempts:
                print(f"  retrying {name}: {len(fails)} metric(s) out of "
                      f"bounds (could be scheduler noise)")
        failures += fails
    if failures:
        print(f"\nBENCH GATE FAILED ({len(failures)}):", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        sys.exit(1)
    print("\nbench gate OK")


if __name__ == "__main__":
    main()
