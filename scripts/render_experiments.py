"""Render EXPERIMENTS.md §Dry-run/§Roofline tables from artifacts/roofline.json."""
import json
import sys

HW_NOTE = "197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s ICI per chip; 12.5 GB/s DCN per host"


def fmt(rows, mesh):
    out = []
    out.append(f"\n#### Mesh: {mesh} "
               f"({'2x16x16 = 512 chips' if mesh == 'multipod' else '16x16 = 256 chips'})\n")
    out.append("| arch | shape | fits 16GB | HBM GB/dev | compute s | "
               "memory s | collective s (ici/dcn) | bottleneck | "
               "MODEL/HLO flops | roofline frac |")
    out.append("|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r["mesh"] != mesh or r.get("tag", "baseline") != "baseline":
            continue
        if r.get("skipped"):
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | "
                       f"skipped: {r['skipped'][:40]} | — | — |")
            continue
        if r.get("error"):
            out.append(f"| {r['arch']} | {r['shape']} | ERROR | — | — | — |"
                       f" — | {r['error'][:40]} | — | — |")
            continue
        frac = max(r["compute_s"], r["memory_s"]) / max(r["step_s"], 1e-12)
        comp_frac = r["compute_s"] / max(r["step_s"], 1e-12)
        out.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{'yes' if r['fits_hbm'] else 'NO'} | {r['hbm_per_dev_gb']:.1f} "
            f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} "
            f"| {r['collective_s']:.3f} ({r['ici_s']:.2f}/{r['dcn_s']:.2f}) "
            f"| {r['bottleneck']} | {r['useful_flops_ratio']:.3f} "
            f"| {comp_frac * 100:.1f}% |")
    return "\n".join(out)


def main(path="artifacts/roofline.json"):
    with open(path) as f:
        rows = json.load(f)
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    n_ok = sum(1 for r in rows if not r.get("error") and not r.get("skipped")
               and r.get("tag", "baseline") == "baseline")
    n_skip = sum(1 for r in rows if r.get("skipped")
                 and r.get("tag", "baseline") == "baseline")
    print(f"Baseline cells compiled OK: {n_ok}; skipped by design: {n_skip}; "
          f"hardware: {HW_NOTE}.")
    print(fmt(rows, "single"))
    print(fmt(rows, "multipod"))
    # non-baseline tags (perf iterations)
    tagged = [r for r in rows if r.get("tag", "baseline") != "baseline"]
    if tagged:
        print("\n#### Perf-iteration cells (see §Perf)\n")
        print("| tag | arch | shape | mesh | compute s | memory s | "
              "collective s | step s | HBM GB |")
        print("|---|---|---|---|---|---|---|---|---|")
        for r in tagged:
            if r.get("error"):
                continue
            print(f"| {r['tag']} | {r['arch']} | {r['shape']} | {r['mesh']} "
                  f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} "
                  f"| {r['collective_s']:.3f} | {r['step_s']:.3f} "
                  f"| {r['hbm_per_dev_gb']:.1f} |")


if __name__ == "__main__":
    main(*sys.argv[1:])
