"""Decode-vs-forward consistency: token-by-token decode must reproduce the
teacher-forced forward logits for every arch (fp32, reduced configs)."""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import list_archs, get_config
from repro.models import LM, RuntimeKnobs
from repro.models.layers import unembed

B, S = 2, 16


def run(arch):
    cfg = get_config(arch, smoke=True)
    if cfg.moe is not None:
        import dataclasses
        # capacity = chunk*k -> provably drop-free, so prefill==decode exactly
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, eval_capacity_factor=float(cfg.moe.num_experts)))
    model = LM(cfg, RuntimeKnobs(cache_dtype=jnp.float32, q_chunk=8))
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.input_mode == "embeddings":
        from repro.models.layers import embed as _embed
        batch["embeds"] = _embed(params["embed"], tokens)

    x, _, _ = jax.jit(lambda p, b: model.hidden(p, b, "prefill"))(params, batch)
    full_logits = unembed(params["embed"], x)  # (B,S,V)

    caches = model.init_cache(B, S)
    step = jax.jit(model.decode_step)
    worst = 0.0
    for t in range(S):
        logits, caches = step(params, caches, tokens[:, t:t + 1], jnp.int32(t))
        err = float(jnp.max(jnp.abs(logits - full_logits[:, t, :])))
        worst = max(worst, err)
    rel = worst / float(jnp.max(jnp.abs(full_logits)))
    status = "OK " if rel < 2e-3 else "FAIL"
    print(f"{arch:28s} {status} max_abs={worst:.2e} rel={rel:.2e}")
    return rel < 2e-3


if __name__ == "__main__":
    archs = sys.argv[1:] or list_archs()
    ok = all([run(a) for a in archs])
    sys.exit(0 if ok else 1)
