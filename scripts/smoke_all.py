"""Quick dev loop: reduced-config fwd/loss/prefill/decode for every arch."""
import sys

import jax
import jax.numpy as jnp

from repro.configs import list_archs, get_config
from repro.models import LM, RuntimeKnobs

B, S = 2, 32


def run(arch):
    cfg = get_config(arch, smoke=True)
    model = LM(cfg, RuntimeKnobs(cache_dtype=jnp.float32))
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                          cfg.vocab_size)}
    if cfg.input_mode == "embeddings":
        batch["embeds"] = jax.random.normal(jax.random.PRNGKey(2),
                                            (B, S, cfg.d_model))
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert jnp.isfinite(loss), (arch, "loss NaN")
    logits, caches = jax.jit(model.prefill)(params, batch)
    assert logits.shape == (B, cfg.vocab_size)
    assert jnp.isfinite(logits).all(), (arch, "prefill NaN")
    cache0 = model.init_cache(B, S)
    tok = batch["tokens"][:, :1]
    logits2, cache1 = jax.jit(model.decode_step)(params, cache0, tok,
                                                 jnp.int32(0))
    assert logits2.shape == (B, cfg.vocab_size)
    assert jnp.isfinite(logits2).all(), (arch, "decode NaN")
    print(f"{arch:28s} OK loss={float(loss):.3f}")


if __name__ == "__main__":
    archs = sys.argv[1:] or list_archs()
    failures = []
    for a in archs:
        try:
            run(a)
        except Exception as e:  # keep going, fail loudly at the end
            failures.append((a, e))
            print(f"{a:28s} FAIL {type(e).__name__}: {e}")
    if failures:
        print(f"{len(failures)}/{len(archs)} archs failed:",
              ", ".join(a for a, _ in failures))
        sys.exit(1)
