"""Quick dev loop: reduced-config fwd/loss/prefill/decode for every arch.

Per-arch wall time is recorded into the shared telemetry registry
(``smoke_arch_seconds{arch=...}``) and reported at the end — the same
registry-as-stopwatch idiom the benchmarks use (benchmarks/common.py)."""
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import list_archs, get_config
from repro.models import LM, RuntimeKnobs
from repro.runtime.telemetry import MetricsRegistry

B, S = 2, 32

REGISTRY = MetricsRegistry()


def run(arch):
    cfg = get_config(arch, smoke=True)
    model = LM(cfg, RuntimeKnobs(cache_dtype=jnp.float32))
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                          cfg.vocab_size)}
    if cfg.input_mode == "embeddings":
        batch["embeds"] = jax.random.normal(jax.random.PRNGKey(2),
                                            (B, S, cfg.d_model))
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert jnp.isfinite(loss), (arch, "loss NaN")
    logits, caches = jax.jit(model.prefill)(params, batch)
    assert logits.shape == (B, cfg.vocab_size)
    assert jnp.isfinite(logits).all(), (arch, "prefill NaN")
    cache0 = model.init_cache(B, S)
    tok = batch["tokens"][:, :1]
    logits2, cache1 = jax.jit(model.decode_step)(params, cache0, tok,
                                                 jnp.int32(0))
    assert logits2.shape == (B, cfg.vocab_size)
    assert jnp.isfinite(logits2).all(), (arch, "decode NaN")
    print(f"{arch:28s} OK loss={float(loss):.3f}")


if __name__ == "__main__":
    archs = sys.argv[1:] or list_archs()
    failures = []
    gauge = REGISTRY.gauge("smoke_arch_seconds",
                           "wall seconds per arch smoke", ("arch",))
    for a in archs:
        t0 = time.perf_counter()
        try:
            run(a)
        except Exception as e:  # keep going, fail loudly at the end
            failures.append((a, e))
            print(f"{a:28s} FAIL {type(e).__name__}: {e}")
        gauge.labels(arch=a).set(time.perf_counter() - t0)
    times = {s["labels"]["arch"]: s["value"]
             for s in REGISTRY.to_dict()["smoke_arch_seconds"]["series"]}
    for a, dt in sorted(times.items(), key=lambda kv: -kv[1]):
        print(f"  {a:28s} {dt:6.1f}s")
    print(f"total {sum(times.values()):.1f}s over {len(times)} archs")
    if failures:
        print(f"{len(failures)}/{len(archs)} archs failed:",
              ", ".join(a for a, _ in failures))
        sys.exit(1)
