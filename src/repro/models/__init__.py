from .model import LM, RuntimeKnobs, build_model

__all__ = ["LM", "RuntimeKnobs", "build_model"]
