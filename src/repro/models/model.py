"""LM: the composable model wrapper used by training, serving, and dry-run.

``LM`` is a plain object holding the arch config + runtime knobs; all methods
are pure functions of explicit params/caches and safe to ``jax.jit``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from .layers import chunked_ce_loss, embed, embedding_init, rmsnorm, rmsnorm_init, unembed
from .transformer import (apply_blocks, apply_blocks_decode,
                          apply_blocks_prefill_chunk, cache_batch_axes,
                          copy_cache_in, copy_cache_out, copy_cache_pages,
                          copy_cache_pages_across, init_blocks, init_cache,
                          init_cache_paged, supports_chunked_prefill,
                          supports_paged_cache, supports_speculative,
                          unzip_prefill_buf, zip_prefill_buf)

MOE_LB_COEF = 0.01
MOE_Z_COEF = 1e-3


def _identity_shard(name: str, x):
    return x


@dataclasses.dataclass(frozen=True)
class RuntimeKnobs:
    """Perf / execution knobs — the hillclimbing surface."""

    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32
    cache_dtype: Any = jnp.bfloat16
    q_chunk: int = 512  # flash-attention query block
    ce_chunk: int = 1024  # chunked cross-entropy block
    remat: bool = True
    use_pallas: bool = False  # Pallas kernels (TPU); XLA path otherwise
    causal_skip: bool = False  # unrolled causal block-skip attention (H2)
    # 0 = auto (the serving engine picks per step from (max(pos), batch) via
    # runtime.steps.pick_decode_splits); >= 1 is a static override.  Both 0
    # and 1 lower to the single-pass kernel outside the engine.
    decode_splits: int = 0
    # "" = full-precision paged KV; "int8"/"fp8" store quantized page pools
    # with per-token/per-head scale leaves, dequantized inside the paged
    # kernels (~2x/4x pages per HBM byte).  Paged caches only.
    kv_quant: str = ""
    shard_fn: Callable = _identity_shard  # sharding-constraint hook

    def with_(self, **kw) -> "RuntimeKnobs":
        return dataclasses.replace(self, **kw)


class LM:
    def __init__(self, cfg, knobs: Optional[RuntimeKnobs] = None):
        self.cfg = cfg
        self.knobs = knobs or RuntimeKnobs()

    # ------------------------------------------------------------- params
    def init(self, key) -> dict:
        cfg, dt = self.cfg, self.knobs.param_dtype
        k1, k2 = jax.random.split(key)
        return {
            "embed": embedding_init(k1, cfg.vocab_size, cfg.d_model,
                                    cfg.tie_embeddings, dt),
            "blocks": init_blocks(k2, cfg, dt),
            "final_norm": rmsnorm_init(cfg.d_model, dt),
        }

    def param_specs(self):
        """Abstract params (no allocation) for the dry-run."""
        return jax.eval_shape(self.init, jax.random.PRNGKey(0))

    # ------------------------------------------------------------ forward
    def _embed_inputs(self, params, batch):
        cfg = self.cfg
        if cfg.input_mode == "embeddings":
            x = batch["embeds"].astype(self.knobs.compute_dtype)
        else:
            x = embed(params["embed"], batch["tokens"])
        return x.astype(self.knobs.compute_dtype)

    def hidden(self, params, batch, mode: str):
        x = self._embed_inputs(params, batch)
        x = self.knobs.shard_fn("hidden", x)
        b, s = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        x, aux, caches = apply_blocks(params["blocks"], x, positions,
                                      cfg=self.cfg, knobs=self.knobs, mode=mode)
        x = rmsnorm(params["final_norm"], x)
        return x, aux, caches

    # --------------------------------------------------------------- loss
    def loss(self, params, batch):
        """Next-token CE (+ MoE aux).  batch: tokens (B,S) [+ embeds]."""
        x, aux, _ = self.hidden(params, batch, mode="train")
        tokens = batch["tokens"]
        targets = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))
        mask = jnp.pad(jnp.ones_like(tokens[:, 1:], jnp.float32),
                       ((0, 0), (0, 1)))
        ce = chunked_ce_loss(params["embed"], x, targets, mask,
                             chunk=self.knobs.ce_chunk)
        loss = ce
        metrics = {"ce_loss": ce}
        if aux:
            n_moe = max(1, sum(1 for k in build_kinds(self.cfg) if k == "moe"))
            lb = aux["moe_lb_loss"] / n_moe
            zl = aux["moe_z_loss"] / n_moe
            loss = loss + MOE_LB_COEF * lb + MOE_Z_COEF * zl
            metrics.update(moe_lb_loss=lb, moe_z_loss=zl,
                           moe_drop_frac=aux["moe_drop_frac"] / n_moe)
        metrics["loss"] = loss
        return loss, metrics

    # ------------------------------------------------------------ prefill
    def prefill(self, params, batch):
        """Returns (last-position logits (B,V), caches)."""
        x, _, caches = self.hidden(params, batch, mode="prefill")
        logits = unembed(params["embed"], x[:, -1:, :])[:, 0, :]
        return logits.astype(jnp.float32), caches

    # ------------------------------------------------------------- decode
    def decode_step(self, params, caches, tokens, pos):
        """tokens (B,1) int32 -> (logits (B,V), new caches).

        ``pos`` is a scalar (all slots in lockstep) or a (B,) vector of
        per-slot positions (ragged continuous batching); slots parked at
        pos = -1 are inactive and produce don't-care logits.
        """
        x = embed(params["embed"], tokens).astype(self.knobs.compute_dtype)
        x, new_caches = apply_blocks_decode(params["blocks"], x, caches, pos,
                                            cfg=self.cfg, knobs=self.knobs)
        x = rmsnorm(params["final_norm"], x)
        logits = unembed(params["embed"], x)[:, 0, :]
        return logits.astype(jnp.float32), new_caches

    def prefill_chunk_step(self, params, caches, tokens, slot, offset):
        """Chunked prefill: one slot's prompt chunk.

        tokens (1,C) int32 at absolute positions offset..offset+C-1; writes
        the chunk's K/V into ``caches`` at (slot, offset) and returns
        (chunk logits (C,V) fp32, new caches).  The engine reads the logits
        row of the last real prompt token to seed decode.
        """
        x = embed(params["embed"], tokens).astype(self.knobs.compute_dtype)
        x, new_caches = apply_blocks_prefill_chunk(
            params["blocks"], x, caches, slot, offset, cfg=self.cfg,
            knobs=self.knobs)
        x = rmsnorm(params["final_norm"], x)
        logits = unembed(params["embed"], x)[0]
        return logits.astype(jnp.float32), new_caches

    def supports_chunked_prefill(self) -> bool:
        return supports_chunked_prefill(self.cfg)

    # -------------------------------------------- speculative (multi-token)
    def supports_speculative(self) -> bool:
        return supports_speculative(self.cfg)

    def decode_step_spec(self, params, caches, tokens, pos):
        """Multi-token verify step.  tokens (B,T) int32 — the current
        feed token plus up to T-1 drafted continuations at absolute
        positions ``pos[b] .. pos[b] + T-1`` — -> (logits (B,T,V) fp32,
        new caches).

        All T K/V pairs are written to the cache before attention runs,
        and the mask is causal within the draft block, so logits row
        ``t`` is the target model's next-token distribution *given* the
        draft prefix tokens[:, :t+1] — exactly what sequential decode
        would have produced at that position.  Rejected drafts roll back
        by position truncation: the engine simply resumes at the last
        accepted position and later writes overwrite the stale K/V,
        which the position mask keeps unattended until then.
        """
        x = embed(params["embed"], tokens).astype(self.knobs.compute_dtype)
        x, new_caches = apply_blocks_decode(params["blocks"], x, caches, pos,
                                            cfg=self.cfg, knobs=self.knobs)
        x = rmsnorm(params["final_norm"], x)
        logits = unembed(params["embed"], x)
        return logits.astype(jnp.float32), new_caches

    def decode_step_spec_paged(self, params, caches, tokens, pos, page_idx,
                               *, page_size: int):
        """Paged ``decode_step_spec``: draft K/V land in the physical
        pages the slot's page-table row maps (positions past the mapped
        span write the null page — see
        ``attention.paged_cache_update_multi``)."""
        x = embed(params["embed"], tokens).astype(self.knobs.compute_dtype)
        x, new_caches = apply_blocks_decode(params["blocks"], x, caches, pos,
                                            cfg=self.cfg, knobs=self.knobs,
                                            paged=(page_idx, page_size))
        x = rmsnorm(params["final_norm"], x)
        logits = unembed(params["embed"], x)
        return logits.astype(jnp.float32), new_caches

    # -------------------------------------------------------- paged cache
    def supports_paged_cache(self) -> bool:
        return supports_paged_cache(self.cfg)

    def decode_step_paged(self, params, caches, tokens, pos, page_idx, *,
                          page_size: int):
        """Paged ``decode_step``: caches are global page pools and slot
        ``b``'s KV prefix lives in pages ``page_idx[b]`` (0 = null page).
        ``page_size`` is static per engine."""
        x = embed(params["embed"], tokens).astype(self.knobs.compute_dtype)
        x, new_caches = apply_blocks_decode(params["blocks"], x, caches, pos,
                                            cfg=self.cfg, knobs=self.knobs,
                                            paged=(page_idx, page_size))
        x = rmsnorm(params["final_norm"], x)
        logits = unembed(params["embed"], x)[:, 0, :]
        return logits.astype(jnp.float32), new_caches

    def prefill_chunk_step_paged(self, params, caches, tokens, slot, offset,
                                 page_idx, *, page_size: int):
        """Paged ``prefill_chunk_step``: the chunk (C a multiple of
        ``page_size``, ``offset`` page-aligned) writes the physical pages
        the slot's page-table row maps."""
        x = embed(params["embed"], tokens).astype(self.knobs.compute_dtype)
        x, new_caches = apply_blocks_prefill_chunk(
            params["blocks"], x, caches, slot, offset, cfg=self.cfg,
            knobs=self.knobs, paged=(page_idx, page_size))
        x = rmsnorm(params["final_norm"], x)
        logits = unembed(params["embed"], x)[0]
        return logits.astype(jnp.float32), new_caches

    def prefill_chunk_step_paged_buf(self, params, caches, tokens, slot,
                                     offset, page_idx, buf, *,
                                     page_size: int, gather: bool = False):
        """Buffered paged ``prefill_chunk_step`` (XLA path): ``buf`` is a
        dense ``init_cache(1, max_len)`` tree carried across the chunk
        loop — each layer reuses its (1, S, KV, D) slot view instead of
        re-gathering the full page chain every chunk.  ``gather=True``
        (first chunk of a prefix-cache hit) rebuilds the view from the
        page table once.  Returns (logits, new caches, new buf)."""
        merged = zip_prefill_buf(caches, buf)
        x = embed(params["embed"], tokens).astype(self.knobs.compute_dtype)
        x, new_merged = apply_blocks_prefill_chunk(
            params["blocks"], x, merged, slot, offset, cfg=self.cfg,
            knobs=self.knobs, paged=(page_idx, page_size), gather=gather)
        new_caches, new_buf = unzip_prefill_buf(new_merged)
        x = rmsnorm(params["final_norm"], x)
        logits = unembed(params["embed"], x)[0]
        return logits.astype(jnp.float32), new_caches, new_buf

    def copy_cache_pages(self, caches, src, dst):
        """Device half of CoW: duplicate physical page src -> dst in every
        layer pool."""
        return copy_cache_pages(caches, src, dst)

    def copy_cache_pages_across(self, src_caches, dst_caches, src_idx, dst_idx):
        """Cross-engine page transfer: gather ``src_idx`` pages from one
        pool, scatter them at ``dst_idx`` in another (disagg handoff)."""
        return copy_cache_pages_across(src_caches, dst_caches, src_idx, dst_idx)

    # ------------------------------------------------- checkpoint/restore
    def cache_batch_axes(self, max_len: int):
        """Per-leaf batch-axis tree of the dense cache (host-side)."""
        return cache_batch_axes(self.cfg, self.knobs, max_len)

    def copy_cache_out(self, caches, slot, axes):
        """Slice slot ``slot``'s stripe from every dense cache leaf — the
        device half of a preemption checkpoint (KV and, for SSM/hybrid
        plans, recurrent state alike)."""
        return copy_cache_out(caches, slot, axes)

    def copy_cache_in(self, caches, snapshot, slot, axes):
        """Restore a ``copy_cache_out`` snapshot into slot ``slot``."""
        return copy_cache_in(caches, snapshot, slot, axes)

    # -------------------------------------------------------------- cache
    def init_cache(self, batch: int, max_len: int):
        return init_cache(self.cfg, self.knobs, batch, max_len)

    def init_cache_paged(self, num_pages: int, page_size: int):
        return init_cache_paged(self.cfg, self.knobs, num_pages, page_size)

    def cache_specs(self, batch: int, max_len: int):
        return jax.eval_shape(lambda: self.init_cache(batch, max_len))


def build_kinds(cfg):
    return cfg.layer_kinds()


def build_model(arch: str, smoke: bool = False,
                knobs: Optional[RuntimeKnobs] = None) -> LM:
    from repro.configs import get_config

    return LM(get_config(arch, smoke=smoke), knobs)
