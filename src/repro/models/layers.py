"""Shared layers: RMSNorm, RoPE, gated MLP, embeddings, chunked CE loss.

All functions are pure; parameters are plain dicts of jnp arrays.  Weight
init returns fp32 or the requested param dtype; compute happens in the dtype
of the activations (bf16 in production, fp32 in small CPU tests).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _init(key, shape, scale=0.02, dtype=jnp.float32):
    return (scale * jax.random.normal(key, shape, dtype=jnp.float32)).astype(dtype)


# ---------------------------------------------------------------- rmsnorm
def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32)).astype(dt)


# ------------------------------------------------------------------- rope
def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(hd, theta), dtype=jnp.float32)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(ang)[..., None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------------------------------- mlp
def mlp_init(key, d_model: int, d_ff: int, gated: bool, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {"w_down": _init(ks[2], (d_ff, d_model), dtype=dtype)}
    if gated:
        p["w_gate"] = _init(ks[0], (d_model, d_ff), dtype=dtype)
        p["w_up"] = _init(ks[1], (d_model, d_ff), dtype=dtype)
    else:
        p["w_up"] = _init(ks[1], (d_model, d_ff), dtype=dtype)
    return p


def mlp(params, x, gated: bool, shard_fn=lambda name, v: v):
    """``shard_fn("mlp_up", ...)`` is the TP seam of the gather-form
    serving layout (sharding/rules.py ``ServeShardFn``): it all-gathers
    the ff-sharded up/gate projections so the activation and the down
    projection run replicated, in the single-device order — the
    constraint that keeps sharded decode bitwise-identical.  The seam
    sits on the dot outputs, BEFORE the activation: gathering after it
    lets the partitioner compute the activation on the local shard,
    whose fused lowering differs from the full-width one by ~1 ulp
    (measured on CPU; see tests/test_sharded_serve.py)."""
    if gated:
        g = shard_fn("mlp_up", x @ params["w_gate"])
        u = shard_fn("mlp_up", x @ params["w_up"])
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(shard_fn("mlp_up", x @ params["w_up"]))
    return h @ params["w_down"]


# ------------------------------------------------------------- embeddings
def embedding_init(key, vocab: int, d_model: int, tied: bool, dtype=jnp.float32):
    ks = jax.random.split(key, 2)
    p = {"table": _init(ks[0], (vocab, d_model), dtype=dtype)}
    if not tied:
        p["head"] = _init(ks[1], (vocab, d_model), dtype=dtype)
    return p


def embed(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params, x):
    head = params.get("head", params["table"])
    return x @ head.T


# --------------------------------------------------- chunked CE next-token
def chunked_ce_loss(emb_params, x, targets, mask, chunk: int = 1024):
    """Next-token cross-entropy without materializing (B, S, V) logits.

    x: (B, S, d) final hidden states;  targets/mask: (B, S).
    Scans over sequence chunks; inside each chunk the (B, chunk, V) logits
    exist only transiently (and vocab-sharded under pjit).
    """
    b, s, d = x.shape
    chunk = min(chunk, s)
    n = s // chunk
    assert s % chunk == 0, f"seq {s} not divisible by CE chunk {chunk}"
    head = emb_params.get("head", emb_params["table"])

    xs = x.reshape(b, n, chunk, d).swapaxes(0, 1)  # (n, B, chunk, d)
    ts = targets.reshape(b, n, chunk).swapaxes(0, 1)
    ms = mask.reshape(b, n, chunk).swapaxes(0, 1)

    def body(carry, inp):
        tot, cnt = carry
        xc, tc, mc = inp
        logits = (xc @ head.T).astype(jnp.float32)  # (B, chunk, V)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mc
        return (tot + nll.sum(), cnt + mc.sum()), None

    # nested remat: the scan VJP would otherwise store (B, chunk, V) fp32
    # logits for every chunk — i.e. the full logits tensor
    body = jax.checkpoint(body)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)), (xs, ts, ms))
    return tot / jnp.maximum(cnt, 1.0)
