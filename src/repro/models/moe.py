"""Mixture-of-Experts FFN with chunked capacity-based dispatch.

Design (see DESIGN.md):

* Naive GShard one-hot dispatch costs O(S^2 * k * cf * d_model) per batch row
  because expert capacity grows with the token count being dispatched.  We
  therefore dispatch in *sequence chunks* of ``dispatch_chunk`` tokens: the
  one-hot einsum cost becomes linear in S (~10-20% of the expert matmul
  FLOPs at chunk=512) while staying fully shardable by the XLA SPMD
  partitioner (expert axis -> "model", batch axis -> "data"; the dispatch
  einsum lowers to the expected all-to-all).
* Capacity per chunk C = ceil(chunk * k / E * capacity_factor); overflow
  tokens are dropped (their residual passes through) — standard GShard
  semantics.
* Router computed in fp32; aux losses: Switch-style load-balance + z-loss.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .layers import _init


def moe_init(key, d_model: int, moe_cfg, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    e, dff = moe_cfg.num_experts, moe_cfg.d_ff
    return {
        "router": _init(ks[0], (d_model, e), dtype=jnp.float32),
        "w_gate": _init(ks[1], (e, d_model, dff), dtype=dtype),
        "w_up": _init(ks[2], (e, d_model, dff), dtype=dtype),
        "w_down": _init(ks[3], (e, dff, d_model), dtype=dtype),
    }


def _capacity(chunk: int, moe_cfg, train: bool) -> int:
    cf = moe_cfg.capacity_factor if train else moe_cfg.eval_capacity_factor
    c = int(chunk * moe_cfg.experts_per_token * cf / moe_cfg.num_experts)
    # never allow fewer slots than one token's k choices (decode must not drop)
    return max(moe_cfg.experts_per_token, c)


def moe_ffn(params, x, moe_cfg, *, train=True, shard_fn=lambda name, v: v):
    """x: (B, S, d) -> (out (B, S, d), aux_losses dict)."""
    b, s, d = x.shape
    e, k = moe_cfg.num_experts, moe_cfg.experts_per_token
    chunk = min(moe_cfg.dispatch_chunk, s)
    assert s % chunk == 0, (s, chunk)
    n = s // chunk
    cap = _capacity(chunk, moe_cfg, train)
    xc = x.reshape(b, n, chunk, d)

    # ---- router (fp32) -------------------------------------------------
    logits = jnp.einsum("bncd,de->bnce", xc.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(logits, k)  # (b,n,c,k)
    top_w = jax.nn.softmax(top_vals, axis=-1)  # normalize over chosen experts

    # ---- position-in-expert via cumsum over (k-major, then token) ------
    # slot order: all slot-0 choices first (priority to the top choice).
    idx_flat = top_idx.swapaxes(2, 3).reshape(b, n, k * chunk)  # (b,n,k*c)
    oh = jax.nn.one_hot(idx_flat, e, dtype=jnp.int32)  # (b,n,k*c,E)
    pos_flat = jnp.cumsum(oh, axis=2) * oh - 1  # position within expert
    pos_flat = jnp.max(pos_flat, axis=-1)  # (b,n,k*c) ; -1 where impossible
    pos = pos_flat.reshape(b, n, k, chunk).swapaxes(2, 3)  # (b,n,c,k)
    keep = (pos >= 0) & (pos < cap)

    # ---- one-hot dispatch / combine tensors (b,n,c,E,C) ----------------
    oh_e = jax.nn.one_hot(top_idx, e, dtype=x.dtype) * keep[..., None]
    oh_c = jax.nn.one_hot(jnp.clip(pos, 0, cap - 1), cap, dtype=x.dtype)
    dispatch = jnp.einsum("bnckE,bnckC->bncEC", oh_e, oh_c)
    combine = jnp.einsum("bnck,bnckE,bnckC->bncEC",
                         top_w.astype(x.dtype), oh_e, oh_c)

    # ---- expert compute -------------------------------------------------
    expert_in = jnp.einsum("bncEC,bncd->bnECd", dispatch, x.reshape(b, n, chunk, d))
    expert_in = shard_fn("moe_expert_in", expert_in)
    h = jax.nn.silu(jnp.einsum("bnECd,Edf->bnECf", expert_in, params["w_gate"]))
    h = h * jnp.einsum("bnECd,Edf->bnECf", expert_in, params["w_up"])
    expert_out = jnp.einsum("bnECf,Efd->bnECd", h, params["w_down"])
    expert_out = shard_fn("moe_expert_out", expert_out)
    out = jnp.einsum("bncEC,bnECd->bncd", combine, expert_out)

    # ---- aux losses ------------------------------------------------------
    # load-balance: fraction of (kept) slots routed to each expert vs mean prob
    frac = jnp.mean(oh_e.astype(jnp.float32).sum(axis=3), axis=(0, 1, 2)) / k
    mean_prob = jnp.mean(probs, axis=(0, 1, 2))
    lb_loss = e * jnp.sum(frac * mean_prob)
    z_loss = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    drop_frac = 1.0 - jnp.mean(keep.astype(jnp.float32))
    aux = {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss, "moe_drop_frac": drop_frac}
    return out.reshape(b, s, d), aux


def moe_ffn_ref(params, x, moe_cfg):
    """Dense oracle: every expert computes every token (for tests only)."""
    b, s, d = x.shape
    e, k = moe_cfg.num_experts, moe_cfg.experts_per_token
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), params["router"])
    top_vals, top_idx = jax.lax.top_k(logits, k)
    top_w = jax.nn.softmax(top_vals, axis=-1)
    gates = jnp.zeros((b, s, e), x.dtype)
    gates = jnp.take_along_axis(
        gates, top_idx, axis=-1
    )  # placeholder to keep shapes clear
    # scatter weights into a dense (b,s,E) gate matrix
    gates = jnp.sum(
        jax.nn.one_hot(top_idx, e, dtype=x.dtype) * top_w[..., None].astype(x.dtype),
        axis=2,
    )
    h_gate = jnp.einsum("bsd,edf->bsef", x, params["w_gate"])
    h_up = jnp.einsum("bsd,edf->bsef", x, params["w_up"])
    h = jax.nn.silu(h_gate) * h_up
    y = jnp.einsum("bsef,efd->bsed", h, params["w_down"])
    return jnp.einsum("bse,bsed->bsd", gates, y)
