"""Mamba2 (SSD — state-space duality) block.

Chunked SSD algorithm (arXiv:2405.21060): within a chunk of Q tokens the
token-mixing is a masked, decay-weighted "attention" matmul (MXU-friendly);
across chunks a small (heads, head_dim, d_state) state is carried by a
sequential scan.  Per-token decode is the O(1) linear recurrence.

    S_t = exp(dt_t * a) * S_{t-1} + dt_t * B_t (x) x_t
    y_t = C_t . S_t + D * x_t

The intra-chunk matmuls are the perf-critical hot spot mirrored by the
Pallas kernel in ``repro.kernels.ssd_scan``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import _init, rmsnorm


def _dims(d_model: int, cfg):
    di = cfg.d_inner(d_model)
    nh = cfg.n_heads(d_model)
    conv_dim = di + 2 * cfg.n_groups * cfg.d_state
    return di, nh, conv_dim


def ssm_init(key, d_model: int, cfg, dtype=jnp.float32):
    di, nh, conv_dim = _dims(d_model, cfg)
    g, ds = cfg.n_groups, cfg.d_state
    ks = jax.random.split(key, 6)
    d_in = 2 * di + 2 * g * ds + nh  # z, x, B, C, dt
    # dt bias such that softplus(dt_bias) ~ U[1e-3, 1e-1]
    u = jax.random.uniform(ks[3], (nh,), minval=np.log(1e-3), maxval=np.log(1e-1))
    dt0 = jnp.exp(u)
    return {
        "in_proj": _init(ks[0], (d_model, d_in), dtype=dtype),
        "conv_w": _init(ks[1], (cfg.conv_width, conv_dim), scale=0.2, dtype=dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype=dtype),
        "A_log": jnp.log(jax.random.uniform(ks[2], (nh,), minval=1.0, maxval=16.0)),
        "dt_bias": dt0 + jnp.log(-jnp.expm1(-dt0)),  # inverse softplus
        "D": jnp.ones((nh,), dtype=jnp.float32),
        "norm_scale": jnp.ones((di,), dtype=dtype),
        "out_proj": _init(ks[4], (di, d_model), dtype=dtype),
    }


def _split_proj(zxbcdt, d_model, cfg):
    di, nh, _ = _dims(d_model, cfg)
    g, ds = cfg.n_groups, cfg.d_state
    z, xs, bs, cs, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + g * ds, 2 * di + 2 * g * ds], axis=-1
    )
    return z, xs, bs, cs, dt


def _causal_conv(xbc, conv_w, conv_b):
    """Depthwise causal conv1d.  xbc (B,S,ch); conv_w (w,ch)."""
    w, ch = conv_w.shape
    rhs = conv_w[:, None, :].astype(xbc.dtype)  # (w, 1, ch) 'WIO' depthwise
    out = jax.lax.conv_general_dilated(
        xbc, rhs, window_strides=(1,), padding=[(w - 1, 0)],
        dimension_numbers=("NWC", "WIO", "NWC"), feature_group_count=ch,
    )
    return out + conv_b.astype(xbc.dtype)


def ssm_forward(params, x, d_model: int, cfg, *, initial_state=None,
                return_state=False, use_pallas=False):
    """Full-sequence chunked SSD.  x (B,S,dm) -> y (B,S,dm) [+ cache].

    use_pallas=True swaps the intra-chunk matmuls for the Pallas TPU
    kernel (kernels/ssd_scan.py); interpret mode on CPU."""
    b, s, _ = x.shape
    di, nh, conv_dim = _dims(d_model, cfg)
    g, ds, hp = cfg.n_groups, cfg.d_state, cfg.head_dim
    q = min(cfg.chunk_size, s)
    assert s % q == 0, (s, q)
    nc = s // q

    zxbcdt = x @ params["in_proj"]
    z, xs, bs, cs, dt_raw = _split_proj(zxbcdt, d_model, cfg)
    xbc = jnp.concatenate([xs, bs, cs], axis=-1)
    conv_tail = xbc[:, max(s - (cfg.conv_width - 1), 0):, :]  # decode conv cache
    xbc = jax.nn.silu(_causal_conv(xbc, params["conv_w"], params["conv_b"]))
    xs, bs, cs = jnp.split(xbc, [di, di + g * ds], axis=-1)

    xh = xs.reshape(b, nc, q, nh, hp)
    bh = bs.reshape(b, nc, q, g, ds)
    ch_ = cs.reshape(b, nc, q, g, ds)
    rep = nh // g
    bh = jnp.repeat(bh, rep, axis=3)  # (b,nc,q,nh,ds)
    chh = jnp.repeat(ch_, rep, axis=3)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (b,s,nh)
    dt = dt.reshape(b, nc, q, nh)
    a = -jnp.exp(params["A_log"].astype(jnp.float32))  # (nh,) negative
    da = dt * a  # (b,nc,q,nh)
    cum = jnp.cumsum(da, axis=2)  # inclusive cumsum within chunk

    # ---- intra-chunk (quadratic within q) -------------------------------
    # att[t,j] = exp(cum_t - cum_j) * dt_j * (C_t . B_j),  j <= t
    if use_pallas:
        from repro.kernels import ssd_chunk

        bg = bs.reshape(b, nc, q, g, ds).transpose(0, 1, 3, 2, 4)
        cg = cs.reshape(b, nc, q, g, ds).transpose(0, 1, 3, 2, 4)
        yk, st = ssd_chunk(xh.transpose(0, 1, 3, 2, 4), bg, cg,
                           dt.transpose(0, 1, 3, 2),
                           cum.transpose(0, 1, 3, 2))
        y_intra = yk.transpose(0, 1, 3, 2, 4)  # (b,nc,q,nh,hp)
        s_chunk = st.transpose(0, 1, 2, 4, 3)  # -> (b,nc,nh,hp,ds)
    else:
        cb = jnp.einsum("bnqhs,bnkhs->bnhqk", chh.astype(jnp.float32),
                        bh.astype(jnp.float32))
        decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])
        att = (cb * decay.transpose(0, 1, 4, 2, 3)
               * dt[:, :, None, :, :].transpose(0, 1, 4, 2, 3))
        mask = jnp.tril(jnp.ones((q, q), dtype=bool))
        att = jnp.where(mask[None, None, None], att, 0.0)
        y_intra = jnp.einsum("bnhqk,bnkhp->bnqhp", att.astype(x.dtype), xh)
        last_ = cum[:, :, -1:, :]
        w_state = jnp.exp(last_ - cum) * dt  # (b,nc,q,nh)
        s_chunk = jnp.einsum("bnkhs,bnkhp->bnhps",
                             (bh.astype(jnp.float32) * w_state[..., None]),
                             xh.astype(jnp.float32))  # (b,nc,nh,hp,ds)

    # ---- chunk states and inter-chunk scan ------------------------------
    last = cum[:, :, -1:, :]  # (b,nc,1,nh)
    chunk_decay = jnp.exp(last[:, :, 0, :])  # (b,nc,nh)

    s0 = (jnp.zeros((b, nh, hp, ds), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def scan_body(state, inp):
        sc, dec = inp
        before = state
        state = state * dec[:, :, None, None] + sc
        return state, before

    s_chunk_t = s_chunk.swapaxes(0, 1)  # (nc,b,...)
    dec_t = chunk_decay.swapaxes(0, 1)
    final_state, states_before = jax.lax.scan(scan_body, s0, (s_chunk_t, dec_t))
    states_before = states_before.swapaxes(0, 1)  # (b,nc,nh,hp,ds)

    y_inter = jnp.einsum("bnqhs,bnhps->bnqhp",
                         chh.astype(jnp.float32) * jnp.exp(cum)[..., None],
                         states_before).astype(x.dtype)

    y = y_intra + y_inter + (params["D"].astype(x.dtype)[None, None, None, :, None]
                             * xh)
    y = y.reshape(b, s, di)
    y = rmsnorm({"scale": params["norm_scale"]}, y * jax.nn.silu(z))
    out = y @ params["out_proj"]
    if return_state:
        cw = cfg.conv_width - 1
        pad = cw - conv_tail.shape[1]
        if pad > 0:
            conv_tail = jnp.pad(conv_tail, ((0, 0), (pad, 0), (0, 0)))
        return out, {"conv": conv_tail.astype(x.dtype), "state": final_state}
    return out


def ssm_init_cache(batch: int, d_model: int, cfg, dtype=jnp.bfloat16):
    di, nh, conv_dim = _dims(d_model, cfg)
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_dim), dtype),
        "state": jnp.zeros((batch, nh, cfg.head_dim, cfg.d_state), jnp.float32),
    }


def ssm_decode_step(params, cache, x_tok, d_model: int, cfg):
    """x_tok (B,1,dm) -> (y (B,1,dm), new cache).  O(1) per token."""
    b = x_tok.shape[0]
    di, nh, conv_dim = _dims(d_model, cfg)
    g, ds, hp = cfg.n_groups, cfg.d_state, cfg.head_dim

    zxbcdt = x_tok[:, 0, :] @ params["in_proj"]  # (B, d_in)
    z, xs, bs, cs, dt_raw = _split_proj(zxbcdt, d_model, cfg)
    xbc = jnp.concatenate([xs, bs, cs], axis=-1)  # (B, conv_dim)

    window = jnp.concatenate([cache["conv"], xbc[:, None, :]], axis=1)  # (B,w,ch)
    conv_out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                          params["conv_w"].astype(jnp.float32))
    conv_out = jax.nn.silu(conv_out + params["conv_b"].astype(jnp.float32))
    conv_out = conv_out.astype(x_tok.dtype)
    xs, bs, cs = jnp.split(conv_out, [di, di + g * ds], axis=-1)

    xh = xs.reshape(b, nh, hp).astype(jnp.float32)
    bh = jnp.repeat(bs.reshape(b, g, ds), nh // g, axis=1).astype(jnp.float32)
    chh = jnp.repeat(cs.reshape(b, g, ds), nh // g, axis=1).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (B,nh)
    a = -jnp.exp(params["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * a)  # (B,nh)

    state = cache["state"] * decay[:, :, None, None] + jnp.einsum(
        "bhp,bhs->bhps", xh * dt[..., None], bh)
    y = jnp.einsum("bhs,bhps->bhp", chh, state)  # (B,nh,hp)
    y = y + params["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(b, di).astype(x_tok.dtype)
    y = rmsnorm({"scale": params["norm_scale"]}, y * jax.nn.silu(z))
    out = (y @ params["out_proj"])[:, None, :]
    new_cache = {"conv": window[:, 1:, :].astype(cache["conv"].dtype),
                 "state": state}
    return out, new_cache
