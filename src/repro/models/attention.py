"""Attention: GQA/MQA projections + blocked (flash-style) XLA attention.

Three execution paths:

* ``flash_attention_xla`` — training/prefill.  Scans over query chunks with a
  transient (B, heads, q_chunk, kv_len) score tile, so the full (S, S) score
  matrix is never materialized (the XLA analogue of flash attention; the
  Pallas TPU kernel in ``repro.kernels`` implements the same contract).
  For windowed layers (SWA / gemma3-local) the KV is *dynamically sliced* to
  the window, making the HLO FLOPs genuinely sub-quadratic.
* ``decode_attention_xla`` — one query token against a KV cache (O(S)).
* ``repro.kernels.ops`` — Pallas kernels selected with ``use_pallas`` on TPU.

Weights layout: wq (dm, H, hd), wk/wv (dm, KV, hd), wo (H, hd, dm) so that the
head axes are explicit for sharding rules.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import _init, apply_rope


# ------------------------------------------------------------------ params
def attention_init(key, *, d_model, num_heads, num_kv_heads, head_dim, qkv_bias,
                   dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    p = {
        "wq": _init(ks[0], (d_model, num_heads, head_dim), dtype=dtype),
        "wk": _init(ks[1], (d_model, num_kv_heads, head_dim), dtype=dtype),
        "wv": _init(ks[2], (d_model, num_kv_heads, head_dim), dtype=dtype),
        "wo": _init(ks[3], (num_heads, head_dim, d_model), dtype=dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((num_heads, head_dim), dtype=dtype)
        p["bk"] = jnp.zeros((num_kv_heads, head_dim), dtype=dtype)
        p["bv"] = jnp.zeros((num_kv_heads, head_dim), dtype=dtype)
    return p


def qkv_project(params, x, positions, rope_theta):
    """x (B,S,dm) -> q (B,S,H,hd), k,v (B,S,KV,hd) with RoPE applied."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    return q, k, v


def attn_output(params, ctx):
    return jnp.einsum("bshk,hkd->bsd", ctx, params["wo"])


# ------------------------------------------------------- grouped attention
def _grouped_scores(q, k):
    """q (B,bq,KV,G,D), k (B,Sk,KV,D) -> scores (B,KV,G,bq,Sk) fp32."""
    scale = q.shape[-1] ** -0.5
    return jnp.einsum("bqhgd,bshd->bhgqs", q, k).astype(jnp.float32) * scale


def _grouped_context(probs, v):
    """probs (B,KV,G,bq,Sk) fp32, v (B,Sk,KV,D) -> (B,bq,KV,G,D)."""
    return jnp.einsum("bhgqs,bshd->bqhgd", probs.astype(v.dtype), v)


def flash_attention_xla(q, k, v, *, causal=True, window=0, q_chunk=512,
                        q_offset=0, causal_skip=False):
    """Blocked attention.  q (B,Sq,H,D); k,v (B,Sk,KV,D); GQA-aware.

    window > 0 -> sliding-window attention: each query chunk only reads the
    (window + q_chunk)-long KV slice it can see, so compiled FLOPs scale with
    S * window rather than S^2.

    causal_skip -> recursive triangle decomposition: the upper query half
    attends the full prefix, the lower half recurses on the shorter prefix.
    All slice lengths are static; compiled FLOPs drop to ~0.67x of the
    full-rectangle baseline (ideal causal = 0.5x) with only ~depth extra
    HLO bodies (EXPERIMENTS.md §Perf H2).
    """
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    q_chunk = min(q_chunk, sq)
    assert sq % q_chunk == 0, (sq, q_chunk)
    nq = sq // q_chunk

    if causal_skip and causal and not window and q_offset + sq == sk:
        return _flash_causal_recursive(q, k, v, q_chunk=q_chunk,
                                       q_offset=q_offset)

    qg = q.reshape(b, nq, q_chunk, kv, g, d).swapaxes(0, 1)  # (nq,B,bq,KV,G,D)
    kv_span = min(sk, window + q_chunk) if window else sk

    def body(_, inp):
        qc, idx = inp
        qs = idx * q_chunk + q_offset  # absolute position of first query
        qpos = qs + jnp.arange(q_chunk)
        if window and kv_span < sk:
            start = jnp.clip(qs + q_chunk - kv_span, 0, sk - kv_span)
            kc = jax.lax.dynamic_slice_in_dim(k, start, kv_span, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, start, kv_span, axis=1)
            kpos = start + jnp.arange(kv_span)
        else:
            kc, vc, kpos = k, v, jnp.arange(sk)
        scores = _grouped_scores(qc, kc)  # (B,KV,G,bq,span)
        mask = jnp.ones((q_chunk, kpos.shape[0]), dtype=bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window:
            mask &= qpos[:, None] - kpos[None, :] < window
        scores = jnp.where(mask, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        out = _grouped_context(probs, vc)  # (B,bq,KV,G,D)
        return None, out

    # nested remat: without it the scan VJP stores fp32 probs for every
    # chunk — the full (S, S) attention matrix (flash backward instead
    # recomputes scores per chunk; measured 12 GB -> ~3 GB on qwen2.5 train)
    body = jax.checkpoint(body)
    _, chunks = jax.lax.scan(body, None, (qg, jnp.arange(nq)))
    out = chunks.swapaxes(0, 1).reshape(b, sq, h, d)
    return out


def _flash_causal_recursive(q, k, v, *, q_chunk, q_offset, depth=4):
    """Static triangle decomposition of causal attention.

    q (B, Sq, H, D) attends k[:, :q_offset+Sq] causally.  The upper half of
    the queries runs one rectangular blocked flash over the full prefix;
    the lower half recurses with a prefix half as long.  Cost ratio vs the
    full rectangle: r_d = 0.5 * (1 + 1/4 + ... ) -> ~0.67 at depth 4.
    """
    sq = q.shape[1]
    end = q_offset + sq
    half = (sq // 2 // q_chunk) * q_chunk
    if depth == 0 or half < q_chunk or sq <= 2 * q_chunk:
        return flash_attention_xla(q, k[:, :end], v[:, :end], causal=True,
                                   q_chunk=q_chunk, q_offset=q_offset)
    lower = _flash_causal_recursive(q[:, :half], k, v, q_chunk=q_chunk,
                                    q_offset=q_offset, depth=depth - 1)
    upper = flash_attention_xla(q[:, half:], k[:, :end], v[:, :end],
                                causal=True, q_chunk=q_chunk,
                                q_offset=q_offset + half)
    return jnp.concatenate([lower, upper], axis=1)


def decode_attention_xla(q, k_cache, v_cache, pos, *, window=0):
    """Decode-time attention.  q (B,T,H,D); caches (B,S,KV,D).

    Reads the whole cache (O(S)); positions beyond ``pos`` and outside the
    window are masked.  Ragged: ``pos`` may be a scalar (lockstep) or a
    (B,) vector of per-slot prefix lengths — the XLA mirror of the Pallas
    per-slot kernel contract.  Slots with pos < 0 are inactive and return
    zeros.

    T > 1 is the speculative multi-token verify block: query row ``t``
    of slot ``b`` sits at absolute position ``pos[b] + t`` and attends
    keys ``kpos <= pos[b] + t`` — causal against the prefix AND within
    the draft (row t sees draft rows 0..t, freshly written to the cache
    before this call).  T = 1 is the classic one-token decode step.
    """
    b, t, h, d = q.shape
    s, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (b,))
    qpos = pos[:, None] + jnp.arange(t)[None, :]  # (B, T)
    qg = q.reshape(b, t, kv, g, d)
    scores = _grouped_scores(qg, k_cache)  # (B,KV,G,T,S)
    kpos = jnp.arange(s)
    mask = kpos[None, None, :] <= qpos[:, :, None]  # (B, T, S)
    if window:
        mask &= qpos[:, :, None] - kpos[None, None, :] < window
    scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _grouped_context(probs, v_cache)  # (B,T,KV,G,D)
    out = jnp.where((pos >= 0)[:, None, None, None, None], out, 0.0)
    return out.reshape(b, t, h, d).astype(q.dtype)


def paged_decode_attention_xla(q, k_pages, v_pages, page_idx, pos, *,
                               window=0, k_scale=None, v_scale=None):
    """Paged decode attention, XLA reference path.

    q (B,T,H,D); pools (P, page_size, KV, D); page_idx (B, max_pages)
    int32 (0 = null page for unmapped blocks).  Gathers each slot's pages
    into a dense (B, S, KV, D) view and defers to
    ``decode_attention_xla`` (T > 1 = the speculative verify block) — the
    Pallas kernel resolves the same indirection inside its
    scalar-prefetched index_map instead of materializing the gather.

    ``k_scale``/``v_scale`` (P, page_size, KV, 1) f32 select the
    quantized-pool path: the gathered int8/fp8 values are dequantized
    with their per-token scales (the XLA mirror of the kernel's in-VMEM
    dequant).
    """
    b = q.shape[0]
    _, page_size, kv, d = k_pages.shape
    max_pages = page_idx.shape[1]
    s = max_pages * page_size
    idx = jnp.asarray(page_idx, jnp.int32)
    k = jnp.take(k_pages, idx, axis=0).reshape(b, s, kv, d)
    v = jnp.take(v_pages, idx, axis=0).reshape(b, s, kv, d)
    if k_scale is not None:
        k = k.astype(jnp.float32) * jnp.take(k_scale, idx,
                                             axis=0).reshape(b, s, kv, 1)
        v = v.astype(jnp.float32) * jnp.take(v_scale, idx,
                                             axis=0).reshape(b, s, kv, 1)
    return decode_attention_xla(q, k, v, pos, window=window)


# ------------------------------------------------------------ quantized KV
KV_QUANT_DTYPES = {"int8": jnp.int8, "fp8": jnp.float8_e4m3fn}
_KV_QUANT_QMAX = {"int8": 127.0, "fp8": 448.0}  # e4m3fn finite max


def kv_quant_dtype(kv_quant: str):
    """Pool dtype for a ``RuntimeKnobs.kv_quant`` mode string ("" — the
    unquantized default — maps to None: store at cache_dtype)."""
    return KV_QUANT_DTYPES[kv_quant] if kv_quant else None


def quantize_kv(x, qdtype):
    """Per-token/per-head symmetric quantization of fresh K/V rows.

    x (..., D) fp -> (q (..., D) ``qdtype``, scale (..., 1) f32) with
    scale = absmax / qmax over the head dim.  All-zero rows get scale 0
    (dequant is exactly zero); dequant is ``q.astype(f32) * scale``.
    """
    qmax = {jnp.dtype(d): m for d, m in
            ((KV_QUANT_DTYPES[k], _KV_QUANT_QMAX[k]) for k in
             KV_QUANT_DTYPES)}[jnp.dtype(qdtype)]
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = amax / qmax
    inv = jnp.where(amax > 0, 1.0 / jnp.maximum(scale, 1e-30), 0.0)
    if jnp.dtype(qdtype) == jnp.dtype(jnp.int8):
        q = jnp.clip(jnp.round(xf * inv), -qmax, qmax).astype(jnp.int8)
    else:
        q = (xf * inv).astype(qdtype)
    return q, scale


def dequantize_kv(q, scale):
    """Inverse of ``quantize_kv``: (..., D) quantized + (..., 1) f32."""
    return q.astype(jnp.float32) * scale


def paged_cache_update_quant(k_pages, v_pages, k_scale, v_scale, k_new,
                             v_new, pos, page_idx, page_size):
    """Quantized ``paged_cache_update``: quantize the fresh (B,1,KV,D)
    rows per-token/per-head, scatter values into the int8/fp8 pools and
    scales into the (P, page_size, KV, 1) f32 scale pools through the
    same page-table indirection.  Every write is incremental — no
    read-modify-requantize of existing pages, so quant error never
    accumulates."""
    kq, ks = quantize_kv(k_new, k_pages.dtype)
    vq, vs = quantize_kv(v_new, v_pages.dtype)
    k_pages, v_pages = paged_cache_update(k_pages, v_pages, kq, vq, pos,
                                          page_idx, page_size)
    k_scale, v_scale = paged_cache_update(k_scale, v_scale, ks, vs, pos,
                                          page_idx, page_size)
    return k_pages, v_pages, k_scale, v_scale


def paged_prefill_chunk_update_quant(k_pages, v_pages, k_scale, v_scale,
                                     k_new, v_new, slot, offset, page_idx,
                                     page_size):
    """Quantized ``paged_prefill_chunk_update`` (same delegation shape as
    ``paged_cache_update_quant``)."""
    kq, ks = quantize_kv(k_new, k_pages.dtype)
    vq, vs = quantize_kv(v_new, v_pages.dtype)
    k_pages, v_pages = paged_prefill_chunk_update(
        k_pages, v_pages, kq, vq, slot, offset, page_idx, page_size)
    k_scale, v_scale = paged_prefill_chunk_update(
        k_scale, v_scale, ks, vs, slot, offset, page_idx, page_size)
    return k_pages, v_pages, k_scale, v_scale


def paged_cache_update_multi_quant(k_pages, v_pages, k_scale, v_scale,
                                   k_new, v_new, pos, page_idx, page_size):
    """Quantized ``paged_cache_update_multi`` (speculative verify
    blocks)."""
    kq, ks = quantize_kv(k_new, k_pages.dtype)
    vq, vs = quantize_kv(v_new, v_pages.dtype)
    k_pages, v_pages = paged_cache_update_multi(
        k_pages, v_pages, kq, vq, pos, page_idx, page_size)
    k_scale, v_scale = paged_cache_update_multi(
        k_scale, v_scale, ks, vs, pos, page_idx, page_size)
    return k_pages, v_pages, k_scale, v_scale


def paged_cache_update(k_pages, v_pages, k_new, v_new, pos, page_idx,
                       page_size):
    """Insert (B,1,KV,D) at logical position ``pos`` through the page
    table: slot ``b`` writes physical page ``page_idx[b, pos[b] //
    page_size]`` at offset ``pos[b] % page_size``.

    Inactive slots (pos < 0) write the null page (physical page 0, never
    mapped), so the scatter needs no branch; its contents are don't-care.
    """
    b = k_new.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (b,))
    idx = jnp.asarray(page_idx, jnp.int32)
    posc = jnp.maximum(pos, 0)
    blk = posc // page_size
    off = posc % page_size
    page = jnp.take_along_axis(idx, blk[:, None], axis=1)[:, 0]
    page = jnp.where(pos >= 0, page, 0)
    k_pages = k_pages.at[page, off].set(k_new[:, 0].astype(k_pages.dtype))
    v_pages = v_pages.at[page, off].set(v_new[:, 0].astype(v_pages.dtype))
    return k_pages, v_pages


def paged_prefill_chunk_update(k_pages, v_pages, k_new, v_new, slot, offset,
                               page_idx, page_size):
    """Write one slot's prompt chunk (1, C, KV, D), C a multiple of
    ``page_size`` and ``offset`` page-aligned, into the C // page_size
    physical pages its page-table row maps at block ``offset //
    page_size``."""
    c = k_new.shape[1]
    assert c % page_size == 0, (c, page_size)
    m = c // page_size
    kv, d = k_new.shape[2], k_new.shape[3]
    idx = jnp.asarray(page_idx, jnp.int32)
    pages = jax.lax.dynamic_slice(idx, (slot, offset // page_size),
                                  (1, m))[0]
    k_pages = k_pages.at[pages].set(
        k_new.reshape(m, page_size, kv, d).astype(k_pages.dtype))
    v_pages = v_pages.at[pages].set(
        v_new.reshape(m, page_size, kv, d).astype(v_pages.dtype))
    return k_pages, v_pages


def gather_slot_pages(k_pages, v_pages, page_idx, slot, k_scale=None,
                      v_scale=None):
    """Dense (1, S, KV, D) view of one slot's mapped prefix (chunked
    prefill reads through this; unmapped blocks gather the null page and
    are causally masked).  With ``k_scale``/``v_scale`` the quantized
    pools are gathered *and dequantized* — the view is fp32."""
    _, page_size, kv, d = k_pages.shape
    max_pages = page_idx.shape[1]
    s = max_pages * page_size
    idx = jnp.asarray(page_idx, jnp.int32)
    row = jax.lax.dynamic_slice(idx, (slot, 0), (1, max_pages))[0]
    k = jnp.take(k_pages, row, axis=0).reshape(1, s, kv, d)
    v = jnp.take(v_pages, row, axis=0).reshape(1, s, kv, d)
    if k_scale is not None:
        k = k.astype(jnp.float32) * jnp.take(k_scale, row,
                                             axis=0).reshape(1, s, kv, 1)
        v = v.astype(jnp.float32) * jnp.take(v_scale, row,
                                             axis=0).reshape(1, s, kv, 1)
    return k, v


def paged_cache_update_multi(k_pages, v_pages, k_new, v_new, pos, page_idx,
                             page_size):
    """Insert a (B,T,KV,D) draft block at logical positions ``pos[b] + t``
    through the page table — the multi-token (speculative verify)
    ``paged_cache_update``.

    Page-aware write contract: token ``t`` of slot ``b`` lands in page
    ``page_idx[b, (pos[b]+t) // page_size]``.  Inactive slots (pos < 0)
    and positions past the table's logical span write the null page
    (entry 0), so draft padding beyond a slot's reservation can never
    clobber live data or touch an unheld page — rollback of rejected
    tokens is pure position truncation, no page ever changes hands.

    One scatter per pool (indices (B, T)) rather than T single-token
    scatters: XLA CPU pays ~100us per scatter op, which at draft depths
    of 4+ would eat the ticks speculation saves.
    """
    b, t = k_new.shape[0], k_new.shape[1]
    idx = jnp.asarray(page_idx, jnp.int32)
    max_len = idx.shape[1] * page_size
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (b,))
    pos_t = pos[:, None] + jnp.arange(t)[None, :]  # (B, T) logical
    valid = (pos[:, None] >= 0) & (pos_t < max_len)
    posc = jnp.clip(pos_t, 0, max_len - 1)
    blk = posc // page_size
    off = posc % page_size
    page = jnp.take_along_axis(idx, blk, axis=1)  # (B, T) physical
    page = jnp.where(valid, page, 0)  # null page for don't-care rows
    k_pages = k_pages.at[page, off].set(k_new.astype(k_pages.dtype))
    v_pages = v_pages.at[page, off].set(v_new.astype(v_pages.dtype))
    return k_pages, v_pages


def cache_update(k_cache, v_cache, k_new, v_new, pos):
    """Insert (B,1,KV,D) at position ``pos`` of (B,S,KV,D) caches.

    ``pos`` scalar writes all slots at one position (lockstep decode); a
    (B,) vector writes each slot at its own position (ragged decode).
    Negative positions clamp to 0 — an inactive slot's garbage write lands
    at index 0 and is overwritten when the slot is next admitted.
    """
    pos = jnp.asarray(pos, jnp.int32)
    k_new = k_new.astype(k_cache.dtype)
    v_new = v_new.astype(v_cache.dtype)
    if pos.ndim == 0:
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new, pos,
                                                      axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new, pos,
                                                      axis=1)
        return k_cache, v_cache
    upd = jax.vmap(
        lambda c, n, p: jax.lax.dynamic_update_slice_in_dim(c, n, p, axis=0))
    return upd(k_cache, k_new, pos), upd(v_cache, v_new, pos)


def cache_update_multi(k_cache, v_cache, k_new, v_new, pos):
    """Insert a (B,T,KV,D) draft block at positions ``pos[b] + t`` of
    (B,S,KV,D) caches — the multi-token ``cache_update``.

    One scatter per cache with explicit (B, T) row indices rather than a
    length-T ``dynamic_update_slice`` block (which clamps the block so it
    *fits*, silently shifting a draft straddling the cache end onto
    earlier live positions) or T single-token scatters (XLA CPU pays
    ~100us per scatter op).  Each overflowing position clamps to S-1
    individually — the engine never lets an *accepted* token land there,
    so the clamped writes are draft padding whose garbage is never
    attended (rollback = position truncation); inactive slots (pos < 0)
    clamp to the don't-care low positions exactly like the single-token
    path.
    """
    t = k_new.shape[1]
    b, s = k_cache.shape[0], k_cache.shape[1]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (b,))
    rows = jnp.clip(pos[:, None] + jnp.arange(t)[None, :], 0, s - 1)
    bidx = jnp.arange(b)[:, None]
    k_cache = k_cache.at[bidx, rows].set(k_new.astype(k_cache.dtype))
    v_cache = v_cache.at[bidx, rows].set(v_new.astype(v_cache.dtype))
    return k_cache, v_cache
