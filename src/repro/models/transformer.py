"""Transformer assembly: stacked layers consumed by ``jax.lax.scan``.

Layer parameters are stored *stacked* over a leading layer axis so the whole
depth lowers to a single scanned HLO body (compile time and HLO size stay
O(1) in depth — essential for the 94-layer dry-runs).

Three structural plans (see DESIGN.md):

* uniform   — L identical blocks (dense / moe / ssm / swa archs).
* grouped   — repeating groups of (period-1) inner blocks + 1 outer block
              (gemma3: 5 local-window layers + 1 global layer), plus a
              remainder stack.  Window sizes stay *static* per call site so
              the sliding-window KV slicing lowers to static shapes.
* grouped+shared — zamba2: groups of 6 mamba2 blocks followed by ONE shared
              transformer block (weights reused across groups; per-group KV
              caches at decode).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from . import attention as attn
from .layers import mlp, mlp_init, rmsnorm, rmsnorm_init
from .moe import moe_ffn, moe_init
from .ssm import ssm_decode_step, ssm_forward, ssm_init, ssm_init_cache

MOE_AUX_KEYS = ("moe_lb_loss", "moe_z_loss", "moe_drop_frac")


@dataclasses.dataclass(frozen=True)
class Plan:
    kind: str  # "uniform" | "grouped"
    n_layers: int
    inner_kind: str  # "attn" | "ssm"
    inner_window: int = 0
    # grouped only:
    period: int = 0  # group size incl. outer block (gemma3: 6)
    n_groups: int = 0
    inner_per_group: int = 0
    remainder: int = 0
    outer_kind: Optional[str] = None  # "attn"
    outer_window: int = 0
    outer_shared: bool = False  # zamba2


def build_plan(cfg) -> Plan:
    if cfg.family == "hybrid":
        p = cfg.shared_attn_period
        return Plan(
            kind="grouped", n_layers=cfg.num_layers, inner_kind="ssm",
            period=p, n_groups=cfg.num_layers // p, inner_per_group=p,
            remainder=cfg.num_layers % p, outer_kind="attn", outer_window=0,
            outer_shared=True,
        )
    if cfg.local_global_period:
        p = cfg.local_global_period
        return Plan(
            kind="grouped", n_layers=cfg.num_layers, inner_kind="attn",
            inner_window=cfg.local_window, period=p,
            n_groups=cfg.num_layers // p, inner_per_group=p - 1,
            remainder=cfg.num_layers % p, outer_kind="attn", outer_window=0,
        )
    if cfg.family == "ssm":
        return Plan(kind="uniform", n_layers=cfg.num_layers, inner_kind="ssm")
    return Plan(kind="uniform", n_layers=cfg.num_layers, inner_kind="attn",
                inner_window=cfg.window)


# ===================================================================== init
def _init_attn_block(key, cfg, dtype, ffn: str):
    ks = jax.random.split(key, 4)
    p = {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "attn": attn.attention_init(
            ks[0], d_model=cfg.d_model, num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads, head_dim=cfg.head_dim,
            qkv_bias=cfg.qkv_bias, dtype=dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
    }
    if ffn == "moe":
        p["moe"] = moe_init(ks[1], cfg.d_model, cfg.moe, dtype)
    elif ffn == "mlp":
        p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.gated_mlp, dtype)
    return p


def _init_ssm_block(key, cfg, dtype):
    return {"ln": rmsnorm_init(cfg.d_model, dtype),
            "ssm": ssm_init(key, cfg.d_model, cfg.ssm, dtype)}


def _ffn_kind(cfg) -> str:
    return "moe" if cfg.moe is not None else ("mlp" if cfg.d_ff else "none")


def _stack(key, n, init_fn):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def init_blocks(key, cfg, dtype):
    plan = build_plan(cfg)
    ffn = _ffn_kind(cfg)
    if plan.inner_kind == "attn":
        inner_init = lambda k: _init_attn_block(k, cfg, dtype, ffn)
    else:
        inner_init = lambda k: _init_ssm_block(k, cfg, dtype)
    if plan.kind == "uniform":
        return {"stack": _stack(key, plan.n_layers, inner_init)}
    ks = jax.random.split(key, 3)
    blocks = {
        "inner": jax.vmap(lambda kk: _stack(kk, plan.inner_per_group, inner_init))(
            jax.random.split(ks[0], plan.n_groups)),
    }
    if plan.remainder:
        blocks["rem"] = _stack(ks[1], plan.remainder, inner_init)
    if plan.outer_shared:
        blocks["outer"] = _init_attn_block(ks[2], cfg, dtype, "mlp")
    else:
        blocks["outer"] = _stack(ks[2], plan.n_groups,
                                 lambda k: _init_attn_block(k, cfg, dtype, ffn))
    return blocks


# ============================================================ block bodies
def _zero_aux(cfg):
    if cfg.moe is not None:
        return {k: jnp.float32(0.0) for k in MOE_AUX_KEYS}
    return {}


def _acc_aux(aux, new):
    if not aux:
        return aux
    return {k: aux[k] + new.get(k, 0.0) for k in aux}


def _apply_attn_block(p, x, positions, *, cfg, window, knobs, collect_cache,
                      ffn, shard_fn):
    h = rmsnorm(p["ln1"], x)
    q, k, v = attn.qkv_project(p["attn"], h, positions, cfg.rope_theta)
    q = shard_fn("attn_q", q)
    k = shard_fn("attn_kv", k)
    v = shard_fn("attn_kv", v)
    if knobs.use_pallas:
        from repro.kernels import flash_attention as _pallas_flash

        blk = min(knobs.q_chunk, q.shape[1])
        ctx = _pallas_flash(q, k, v, causal=True, window=window,
                            block_q=blk, block_k=blk)
    else:
        ctx = attn.flash_attention_xla(q, k, v, causal=True, window=window,
                                       q_chunk=knobs.q_chunk,
                                       causal_skip=knobs.causal_skip)
    ctx = shard_fn("attn_out", ctx)
    x = x + attn.attn_output(p["attn"], ctx)
    h2 = rmsnorm(p["ln2"], x)
    aux = {}
    if ffn == "moe":
        out, aux = moe_ffn(p["moe"], h2, cfg.moe, train=not collect_cache,
                           shard_fn=shard_fn)
    elif ffn == "mlp":
        out = mlp(p["mlp"], h2, cfg.gated_mlp, shard_fn=shard_fn)
    else:
        out = jnp.zeros_like(h2)
    x = x + out
    x = shard_fn("hidden", x)
    cache = ({"k": k.astype(knobs.cache_dtype), "v": v.astype(knobs.cache_dtype)}
             if collect_cache else None)
    return x, aux, cache


def _ffn_out(p, h2, ffn, *, cfg, shard_fn):
    """Inference-time FFN tail shared by the cached block bodies."""
    if ffn == "moe":
        out, _ = moe_ffn(p["moe"], h2, cfg.moe, train=False, shard_fn=shard_fn)
        return out
    if ffn == "mlp":
        return mlp(p["mlp"], h2, cfg.gated_mlp, shard_fn=shard_fn)
    return jnp.zeros_like(h2)


def _apply_attn_block_decode(p, x, cache, pos, *, cfg, window, knobs, ffn,
                             shard_fn, paged=None):
    """``paged = (page_idx, page_size)`` switches the cache from a dense
    per-slot stripe to a shared page pool addressed through the slot's
    page-table row; attention masking is identical either way.

    x may carry T > 1 tokens per slot (the speculative verify block):
    token ``t`` sits at absolute position ``pos[b] + t``, all T K/V pairs
    are written to the cache first, and the attention mask is causal
    within the block as well as against the prefix."""
    b, t = x.shape[0], x.shape[1]
    h = rmsnorm(p["ln1"], x)
    pos = jnp.asarray(pos, jnp.int32)  # scalar (lockstep) or (B,) (ragged)
    positions = jnp.broadcast_to(
        (pos.reshape(-1, 1) if pos.ndim else pos) + jnp.arange(t)[None, :]
        if t > 1 else (pos.reshape(-1, 1) if pos.ndim else pos), (b, t))
    q, k_new, v_new = attn.qkv_project(p["attn"], h, positions, cfg.rope_theta)
    if paged is not None:
        page_idx, page_size = paged
        quant = "k_scale" in cache  # quantized pools carry scale leaves
        if quant:
            upd = attn.paged_cache_update_multi_quant if t > 1 \
                else attn.paged_cache_update_quant
            kc, vc, ksc, vsc = upd(
                cache["k"], cache["v"], cache["k_scale"], cache["v_scale"],
                k_new, v_new, pos, page_idx, page_size)
            new_cache = {"k": kc, "v": vc, "k_scale": ksc, "v_scale": vsc}
        else:
            upd = attn.paged_cache_update_multi if t > 1 \
                else attn.paged_cache_update
            kc, vc = upd(cache["k"], cache["v"], k_new, v_new, pos, page_idx,
                         page_size)
            ksc = vsc = None
            new_cache = {"k": kc, "v": vc}
        if knobs.use_pallas:
            from repro.kernels import paged_decode_attention as _pallas_paged

            ctx = _pallas_paged(q, kc, vc, page_idx, pos, window=window,
                                k_scale=ksc, v_scale=vsc,
                                num_splits=knobs.decode_splits if t == 1
                                else 1)
        else:
            ctx = attn.paged_decode_attention_xla(q, kc, vc, page_idx, pos,
                                                  window=window, k_scale=ksc,
                                                  v_scale=vsc)
    else:
        upd = attn.cache_update_multi if t > 1 else attn.cache_update
        kc, vc = upd(cache["k"], cache["v"], k_new, v_new, pos)
        new_cache = {"k": kc, "v": vc}
        if knobs.use_pallas:
            from repro.kernels import decode_attention as _pallas_decode

            blk = min(512, kc.shape[1])
            ctx = _pallas_decode(q, kc, vc, pos, window=window, block_k=blk,
                                 num_splits=knobs.decode_splits)
        else:
            ctx = attn.decode_attention_xla(q, kc, vc, pos, window=window)
    ctx = shard_fn("attn_out", ctx)
    x = x + attn.attn_output(p["attn"], ctx)
    h2 = rmsnorm(p["ln2"], x)
    return x + _ffn_out(p, h2, ffn, cfg=cfg, shard_fn=shard_fn), new_cache


def _apply_attn_block_prefill_chunk(p, x, cache, slot, offset, *, cfg, window,
                                    knobs, ffn, shard_fn, paged=None,
                                    gather=False):
    """One slot's prompt chunk: x (1,C,dm) at absolute positions
    offset..offset+C-1.  Writes the chunk's K/V into cache[slot] in place,
    then runs blocked flash attention of the chunk against the slot's full
    prefix (stale cache beyond offset+C is causally masked).

    ``paged = (page_idx, page_size)``: the chunk (C a page multiple,
    offset page-aligned) lands in the physical pages the slot's table
    maps, and the prefix is read back through the same indirection:

    * ``knobs.use_pallas`` — the fused paged prefill kernel reads K/V
      through the page table directly; no dense per-slot copy exists.
    * XLA with ``gk``/``gv`` leaves in ``cache`` — a dense (1, S, KV, D)
      per-slot gather *buffer* carried across chunks (zipped in by
      ``zip_prefill_buf``): chunk 0 of a prefix-cache hit re-gathers it
      once (``gather=True``); every other chunk just inserts its own
      fresh K/V, so the old per-chunk full-length gather is gone.
    * XLA without a buffer — the legacy full gather per chunk, kept as
      the parity oracle for both fast paths.
    """
    c = x.shape[1]
    h = rmsnorm(p["ln1"], x)
    positions = offset + jnp.arange(c)[None, :]
    q, k_new, v_new = attn.qkv_project(p["attn"], h, positions, cfg.rope_theta)
    if paged is not None:
        page_idx, page_size = paged
        quant = "k_scale" in cache
        if quant:
            kc, vc, ksc, vsc = attn.paged_prefill_chunk_update_quant(
                cache["k"], cache["v"], cache["k_scale"], cache["v_scale"],
                k_new, v_new, slot, offset, page_idx, page_size)
            new_cache = {"k": kc, "v": vc, "k_scale": ksc, "v_scale": vsc}
        else:
            kc, vc = attn.paged_prefill_chunk_update(
                cache["k"], cache["v"], k_new, v_new, slot, offset, page_idx,
                page_size)
            ksc = vsc = None
            new_cache = {"k": kc, "v": vc}
        if knobs.use_pallas:
            from repro.kernels import paged_prefill_attention as _pallas_pf

            if "gk" in cache:  # buffer unused on the fused path
                new_cache["gk"], new_cache["gv"] = cache["gk"], cache["gv"]
            ctx = _pallas_pf(q, kc, vc, page_idx, slot, offset,
                             window=window, k_scale=ksc, v_scale=vsc)
        else:
            if "gk" in cache:
                if gather:  # first chunk of a prefix hit: rebuild the view
                    gk, gv = attn.gather_slot_pages(kc, vc, page_idx, slot,
                                                    k_scale=ksc, v_scale=vsc)
                    gk = gk.astype(cache["gk"].dtype)
                    gv = gv.astype(cache["gv"].dtype)
                else:  # steady state: insert only this chunk's fresh K/V
                    if quant:  # round-trip so the buffer holds exactly
                        # what a page gather would return
                        k_ins = attn.dequantize_kv(
                            *attn.quantize_kv(k_new, kc.dtype))
                        v_ins = attn.dequantize_kv(
                            *attn.quantize_kv(v_new, vc.dtype))
                    else:
                        k_ins, v_ins = k_new, v_new
                    gk = jax.lax.dynamic_update_slice(
                        cache["gk"], k_ins.astype(cache["gk"].dtype),
                        (0, offset, 0, 0))
                    gv = jax.lax.dynamic_update_slice(
                        cache["gv"], v_ins.astype(cache["gv"].dtype),
                        (0, offset, 0, 0))
                new_cache["gk"], new_cache["gv"] = gk, gv
                k_slot, v_slot = gk, gv
            else:
                k_slot, v_slot = attn.gather_slot_pages(
                    kc, vc, page_idx, slot, k_scale=ksc, v_scale=vsc)
            ctx = attn.flash_attention_xla(q, k_slot, v_slot, causal=True,
                                           window=window,
                                           q_chunk=min(knobs.q_chunk, c),
                                           q_offset=offset)
    else:
        kc = jax.lax.dynamic_update_slice(cache["k"],
                                          k_new.astype(cache["k"].dtype),
                                          (slot, offset, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache["v"],
                                          v_new.astype(cache["v"].dtype),
                                          (slot, offset, 0, 0))
        new_cache = {"k": kc, "v": vc}
        k_slot = jax.lax.dynamic_slice_in_dim(kc, slot, 1, axis=0)
        v_slot = jax.lax.dynamic_slice_in_dim(vc, slot, 1, axis=0)
        ctx = attn.flash_attention_xla(q, k_slot, v_slot, causal=True,
                                       window=window,
                                       q_chunk=min(knobs.q_chunk, c),
                                       q_offset=offset)
    ctx = shard_fn("attn_out", ctx)
    x = x + attn.attn_output(p["attn"], ctx)
    h2 = rmsnorm(p["ln2"], x)
    return x + _ffn_out(p, h2, ffn, cfg=cfg, shard_fn=shard_fn), new_cache


def _apply_ssm_block(p, x, *, cfg, collect_cache, shard_fn,
                     use_pallas=False):
    h = rmsnorm(p["ln"], x)
    if collect_cache:
        y, state = ssm_forward(p["ssm"], h, cfg.d_model, cfg.ssm,
                               return_state=True, use_pallas=use_pallas)
    else:
        y = ssm_forward(p["ssm"], h, cfg.d_model, cfg.ssm,
                        use_pallas=use_pallas)
        state = None
    x = shard_fn("hidden", x + y)
    return x, {}, state


def _apply_ssm_block_decode(p, x, cache, *, cfg, shard_fn):
    h = rmsnorm(p["ln"], x)
    y, new_cache = ssm_decode_step(p["ssm"], cache, h, cfg.d_model, cfg.ssm)
    return x + y, new_cache


# ========================================================== sequence apply
def apply_blocks(blocks, x, positions, *, cfg, knobs, mode: str):
    """mode: 'train' (no caches) | 'prefill' (emit caches).

    Returns (x, aux, caches_or_None).
    """
    plan = build_plan(cfg)
    ffn = _ffn_kind(cfg)
    shard_fn = knobs.shard_fn
    collect = mode == "prefill"
    remat = knobs.remat and mode == "train"

    def inner_body(p, xx, window):
        if plan.inner_kind == "attn":
            return _apply_attn_block(p, xx, positions, cfg=cfg, window=window,
                                     knobs=knobs, collect_cache=collect,
                                     ffn=ffn, shard_fn=shard_fn)
        return _apply_ssm_block(p, xx, cfg=cfg, collect_cache=collect,
                                shard_fn=shard_fn,
                                use_pallas=knobs.use_pallas)

    def outer_body(p, xx):
        return _apply_attn_block(
            p, xx, positions, cfg=cfg, window=plan.outer_window, knobs=knobs,
            collect_cache=collect, ffn="mlp" if plan.outer_shared else ffn,
            shard_fn=shard_fn)

    def scan_stack(stack, carry, window):
        def body(c, p):
            xx, aux = c
            xx, a, cache = inner_body(p, xx, window)
            return (xx, _acc_aux(aux, a)), cache
        if remat:
            body = jax.checkpoint(body)
        return jax.lax.scan(body, carry, stack)

    carry = (x, _zero_aux(cfg))
    if plan.kind == "uniform":
        carry, caches = scan_stack(blocks["stack"], carry, plan.inner_window)
        x, aux = carry
        return x, aux, ({"stack": caches} if collect else None)

    # grouped
    def group_body(c, xs):
        inner_stack = xs["inner"]
        c, inner_caches = scan_stack(inner_stack, c, plan.inner_window)
        xx, aux = c
        op = blocks["outer"] if plan.outer_shared else xs["outer"]
        xx, a, ocache = outer_body(op, xx)
        return (xx, _acc_aux(aux, a)), {"inner": inner_caches, "outer": ocache}

    if remat:
        group_body = jax.checkpoint(group_body)
    xs = {"inner": blocks["inner"]}
    if not plan.outer_shared:
        xs["outer"] = blocks["outer"]
    carry, gcaches = jax.lax.scan(group_body, carry, xs)
    if plan.remainder:
        carry, rcaches = scan_stack(blocks["rem"], carry, plan.inner_window)
    x, aux = carry
    if not collect:
        return x, aux, None
    caches = {"groups": gcaches}
    if plan.remainder:
        caches["rem"] = rcaches
    return x, aux, caches


# ============================================================ decode apply
def _walk_plan_cached(blocks, x, caches, *, cfg, inner_fn, outer_fn):
    """Shared plan walker for the cached paths (decode and chunked
    prefill): thread x and per-layer caches through the plan's stacks.

    inner_fn(p, x, cache, window) and outer_fn(p, x, cache, window, ffn)
    each return (x, new_cache); ffn is pre-resolved ("mlp" for shared
    outer blocks).
    """
    plan = build_plan(cfg)
    ffn = _ffn_kind(cfg)

    def scan_stack(stack, cstack, xx, window):
        def body(c, inp):
            p, cache = inp
            return inner_fn(p, c, cache, window)
        return jax.lax.scan(body, xx, (stack, cstack))

    if plan.kind == "uniform":
        x, new = scan_stack(blocks["stack"], caches["stack"], x,
                            plan.inner_window)
        return x, {"stack": new}

    def group_body(xx, inp):
        xs, gcache = inp
        xx, new_inner = scan_stack(xs["inner"], gcache["inner"], xx,
                                   plan.inner_window)
        op = blocks["outer"] if plan.outer_shared else xs["outer"]
        xx, new_outer = outer_fn(op, xx, gcache["outer"], plan.outer_window,
                                 "mlp" if plan.outer_shared else ffn)
        return xx, {"inner": new_inner, "outer": new_outer}

    xs = {"inner": blocks["inner"]}
    if not plan.outer_shared:
        xs["outer"] = blocks["outer"]
    x, new_g = jax.lax.scan(group_body, x, (xs, caches["groups"]))
    new_caches = {"groups": new_g}
    if plan.remainder:
        x, new_rem = scan_stack(blocks["rem"], caches["rem"], x,
                                plan.inner_window)
        new_caches["rem"] = new_rem
    return x, new_caches


def apply_blocks_decode(blocks, x, caches, pos, *, cfg, knobs, paged=None):
    plan = build_plan(cfg)
    ffn = _ffn_kind(cfg)
    shard_fn = knobs.shard_fn
    if paged is not None and plan.inner_kind != "attn":
        raise NotImplementedError(
            f"paged KV cache unsupported for family={cfg.family!r}")
    if x.shape[1] > 1 and plan.inner_kind != "attn":
        raise NotImplementedError(
            f"multi-token (speculative) decode unsupported for "
            f"family={cfg.family!r} — SSM state advances one token at a "
            f"time")

    def inner_fn(p, xx, cache, window):
        if plan.inner_kind == "attn":
            return _apply_attn_block_decode(p, xx, cache, pos, cfg=cfg,
                                            window=window, knobs=knobs,
                                            ffn=ffn, shard_fn=shard_fn,
                                            paged=paged)
        return _apply_ssm_block_decode(p, xx, cache, cfg=cfg,
                                       shard_fn=shard_fn)

    def outer_fn(p, xx, cache, window, offn):
        return _apply_attn_block_decode(p, xx, cache, pos, cfg=cfg,
                                        window=window, knobs=knobs, ffn=offn,
                                        shard_fn=shard_fn, paged=paged)

    return _walk_plan_cached(blocks, x, caches, cfg=cfg, inner_fn=inner_fn,
                             outer_fn=outer_fn)


# ==================================================== chunked prefill apply
def supports_chunked_prefill(cfg) -> bool:
    """Chunked prefill needs every layer's prefix state to be recoverable
    from the KV cache alone; SSM/hybrid plans carry conv + SSD state across
    chunk boundaries and fall back to token feeding."""
    return build_plan(cfg).inner_kind == "attn"


def supports_paged_cache(cfg) -> bool:
    """Paged KV needs every cached layer to BE a KV cache; SSM/hybrid
    recurrent state is per-slot and position-free, so it cannot be paged."""
    return build_plan(cfg).inner_kind == "attn"


def supports_speculative(cfg) -> bool:
    """Speculative (multi-token) decode scores a whole draft block in one
    forward pass, which needs position-indexed caches only; SSM/hybrid
    recurrent state advances strictly one token at a time."""
    return build_plan(cfg).inner_kind == "attn"


def apply_blocks_prefill_chunk(blocks, x, caches, slot, offset, *, cfg,
                               knobs, paged=None, gather=False):
    """Run ONE slot's prompt chunk x (1,C,dm) through all layers, writing
    each layer's K/V into ``caches`` at (slot, offset) in place.  Returns
    (hidden (1,C,dm), new caches).  Attention-only plans.

    ``gather`` (paged XLA path with a zipped-in gather buffer only):
    re-initialize each layer's dense slot view from the page table before
    attending — the first chunk of a prefix-cache hit, where pages below
    ``offset`` were adopted rather than written by this prefill."""
    plan = build_plan(cfg)
    if plan.inner_kind != "attn":
        raise NotImplementedError(
            f"chunked prefill unsupported for family={cfg.family!r}")
    ffn = _ffn_kind(cfg)
    shard_fn = knobs.shard_fn

    def inner_fn(p, xx, cache, window):
        return _apply_attn_block_prefill_chunk(
            p, xx, cache, slot, offset, cfg=cfg, window=window, knobs=knobs,
            ffn=ffn, shard_fn=shard_fn, paged=paged, gather=gather)

    def outer_fn(p, xx, cache, window, offn):
        return _apply_attn_block_prefill_chunk(
            p, xx, cache, slot, offset, cfg=cfg, window=window, knobs=knobs,
            ffn=offn, shard_fn=shard_fn, paged=paged, gather=gather)

    return _walk_plan_cached(blocks, x, caches, cfg=cfg, inner_fn=inner_fn,
                             outer_fn=outer_fn)


# ------------------------------------------------- prefill gather buffer
def zip_prefill_buf(caches, buf):
    """Merge a dense per-slot gather buffer (an ``init_cache(1, max_len)``
    tree) into a paged cache tree as ``gk``/``gv`` keys on every attn
    leaf dict, so the plan walker threads buffer and pools through the
    layer scan together.  The buffer is the chunked-prefill fix: one
    (1, S, KV, D) view per layer reused across chunks instead of a fresh
    full-length gather per chunk."""
    if isinstance(caches, dict) and "k" in caches \
            and not isinstance(caches["k"], dict):
        out = dict(caches)
        out["gk"] = buf["k"]
        out["gv"] = buf["v"]
        return out
    return {key: zip_prefill_buf(caches[key], buf[key]) for key in caches}


def unzip_prefill_buf(merged):
    """Inverse of ``zip_prefill_buf``: (paged caches, buffer tree)."""
    if isinstance(merged, dict) and "gk" in merged \
            and not isinstance(merged["gk"], dict):
        cache = {key: val for key, val in merged.items()
                 if key not in ("gk", "gv")}
        return cache, {"k": merged["gk"], "v": merged["gv"]}
    pairs = {key: unzip_prefill_buf(merged[key]) for key in merged}
    return ({key: c for key, (c, _) in pairs.items()},
            {key: b for key, (_, b) in pairs.items()})


# ============================================================== cache init
def init_cache(cfg, knobs, batch: int, max_len: int):
    plan = build_plan(cfg)

    def attn_cache():
        return {
            "k": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim),
                           knobs.cache_dtype),
            "v": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim),
                           knobs.cache_dtype),
        }

    def inner_cache():
        if plan.inner_kind == "attn":
            return attn_cache()
        return ssm_init_cache(batch, cfg.d_model, cfg.ssm, knobs.cache_dtype)

    def stack(n, fn):
        return jax.tree.map(
            lambda z: jnp.broadcast_to(z, (n,) + z.shape).copy() if n else z,
            fn())

    if plan.kind == "uniform":
        return {"stack": stack(plan.n_layers, inner_cache)}
    caches = {"groups": {
        "inner": stack(plan.n_groups,
                       lambda: stack(plan.inner_per_group, inner_cache)),
        "outer": stack(plan.n_groups, attn_cache),
    }}
    if plan.remainder:
        caches["rem"] = stack(plan.remainder, inner_cache)
    return caches


def init_cache_paged(cfg, knobs, num_pages: int, page_size: int):
    """Paged KV pools: same plan tree as ``init_cache``, but every attn
    leaf is a global (num_pages, page_size, KV, D) pool shared by all
    slots instead of a per-slot (batch, max_len) stripe.  One page table
    addresses every layer — the stacked layer axes mean a (page, offset)
    coordinate is valid in each pool.

    ``knobs.kv_quant`` ("int8"/"fp8") stores quantized pools plus
    per-token/per-head scale leaves ``k_scale``/``v_scale``
    (num_pages, page_size, KV, 1) f32.  Scales keep the page axis at
    ndim-4 like every other paged leaf, so ``copy_cache_pages`` /
    ``copy_cache_pages_across`` move them with their pages automatically
    — CoW and disagg handoff need no special casing."""
    if not supports_paged_cache(cfg):
        raise NotImplementedError(
            f"paged KV cache unsupported for family={cfg.family!r}")
    plan = build_plan(cfg)

    def attn_cache():
        dt = (attn.KV_QUANT_DTYPES[knobs.kv_quant] if knobs.kv_quant
              else knobs.cache_dtype)
        cache = {
            "k": jnp.zeros((num_pages, page_size, cfg.num_kv_heads,
                            cfg.head_dim), dt),
            "v": jnp.zeros((num_pages, page_size, cfg.num_kv_heads,
                            cfg.head_dim), dt),
        }
        if knobs.kv_quant:
            cache["k_scale"] = jnp.zeros(
                (num_pages, page_size, cfg.num_kv_heads, 1), jnp.float32)
            cache["v_scale"] = jnp.zeros(
                (num_pages, page_size, cfg.num_kv_heads, 1), jnp.float32)
        return cache

    def stack(n, fn):
        return jax.tree.map(
            lambda z: jnp.broadcast_to(z, (n,) + z.shape).copy() if n else z,
            fn())

    if plan.kind == "uniform":
        return {"stack": stack(plan.n_layers, attn_cache)}
    caches = {"groups": {
        "inner": stack(plan.n_groups,
                       lambda: stack(plan.inner_per_group, attn_cache)),
        "outer": stack(plan.n_groups, attn_cache),
    }}
    if plan.remainder:
        caches["rem"] = stack(plan.remainder, attn_cache)
    return caches


def cache_batch_axes(cfg, knobs, max_len: int):
    """Per-leaf batch-axis index of the dense cache tree, found by
    diffing abstract cache shapes for two batch sizes (leaf layouts vary:
    stacked layer axes lead, SSM leaves differ from KV).  Pure host
    bookkeeping — drives ``copy_cache_out/in`` and the engine's slot
    reset without hardcoding any layout."""
    s1 = jax.eval_shape(lambda: init_cache(cfg, knobs, 1, max_len))
    s2 = jax.eval_shape(lambda: init_cache(cfg, knobs, 2, max_len))
    return jax.tree.map(
        lambda a, b: next(i for i, (x, y) in enumerate(zip(a.shape, b.shape))
                          if x != y), s1, s2)


def copy_cache_out(caches, slot, axes):
    """Slice one slot's stripe out of every dense cache leaf (keeping a
    size-1 batch dim) — the device half of a preemption checkpoint; the
    engine ``device_get``s the result to a host-side buffer.  ``axes`` is
    the ``cache_batch_axes`` tree."""
    return jax.tree.map(
        lambda c, ax: jax.lax.dynamic_slice_in_dim(c, slot, 1, axis=ax),
        caches, axes)


def copy_cache_in(caches, snapshot, slot, axes):
    """Write a ``copy_cache_out`` snapshot back into slot ``slot`` of
    every leaf — restore half of checkpoint/resume.  The full stripe is
    rewritten, so the slot's previous occupant leaves no residue and
    SSM/recurrent leaves restore exactly."""
    return jax.tree.map(
        lambda c, s, ax: jax.lax.dynamic_update_slice_in_dim(c, s, slot,
                                                             axis=ax),
        caches, snapshot, axes)


def copy_cache_pages(caches, src, dst):
    """Copy physical page ``src`` -> ``dst`` in every layer pool (the
    device half of copy-on-write).  The page axis of every paged leaf sits
    at ndim-4 — (..., num_pages, page_size, KV, D) under the stacked layer
    axes."""
    def cp(leaf):
        ax = leaf.ndim - 4
        page = jax.lax.dynamic_slice_in_dim(leaf, src, 1, axis=ax)
        return jax.lax.dynamic_update_slice_in_dim(leaf, page, dst, axis=ax)

    return jax.tree.map(cp, caches)


def copy_cache_pages_across(src_caches, dst_caches, src_idx, dst_idx):
    """Gather pages ``src_idx`` from one engine's paged pools and scatter
    them at ``dst_idx`` in another's — the device half of a cross-engine
    page-chain transfer (disaggregated prefill -> decode handoff).

    ``src_idx``/``dst_idx`` are equal-length int32 vectors; padding both
    with 0 makes the extra rows copy the source null page onto the
    destination null page, which no reader ever depends on, so the
    vectors can be padded to a static width and the copy compiles once
    per width.  Both trees must share the plan (same stacked layer axes)
    and page_size; pool sizes may differ."""
    def cp(s_leaf, d_leaf):
        ax = s_leaf.ndim - 4
        s0 = jnp.moveaxis(s_leaf, ax, 0)
        d0 = jnp.moveaxis(d_leaf, ax, 0)
        d0 = d0.at[dst_idx].set(s0[src_idx])
        return jnp.moveaxis(d0, 0, ax)

    return jax.tree.map(cp, src_caches, dst_caches)
