"""Atomic checkpoint save/restore with elastic resharding.

Layout:  <dir>/step_<n>/  arrays.npz  +  meta.json
Atomicity: write into ``<dir>/.tmp_step_<n>`` then ``os.replace`` — a
crash mid-write never corrupts the latest checkpoint (the paper's
fault-tolerance story requires restart-from-checkpoint to always succeed).

Elastic restore: arrays are stored *unsharded* (gathered) with their tree
paths; ``restore`` re-places them with ``jax.device_put`` against the
shardings of the CURRENT mesh — which may have a different shape than the
mesh that saved (host failure -> smaller gang; see runtime/fault.py).  On a
multi-host deployment this module's np.savez becomes one shard-file per
host plus a global index; the interface (save/restore against shardings)
is unchanged.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def save_checkpoint(ckpt_dir: str, step: int, state, extra: dict | None = None):
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step:08d}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays = _flatten(state)
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{k: v for k, v in arrays.items()})
    meta = {"step": step, "keys": sorted(arrays),
            "extra": extra or {}}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_")]
    return max(steps) if steps else None


def load_checkpoint(ckpt_dir: str, step: int | None = None):
    """Returns (flat dict key->np.array, meta)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    npz = np.load(os.path.join(d, "arrays.npz"))
    return {k: npz[k] for k in npz.files}, meta


def restore(ckpt_dir: str, target, shardings=None, step: int | None = None):
    """Restore into the structure of ``target`` (pytree of arrays or
    ShapeDtypeStructs), placing leaves with ``shardings`` (elastic: the mesh
    may differ from the one that saved)."""
    flat, meta = load_checkpoint(ckpt_dir, step)
    tpaths = jax.tree_util.tree_flatten_with_path(target)
    leaves, treedef = jax.tree_util.tree_flatten(target)
    spaths = (jax.tree_util.tree_flatten(shardings)[0]
              if shardings is not None else [None] * len(leaves))
    out = []
    for (path, leaf), sh in zip(tpaths[0], spaths):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(tpaths[1], out), meta


def prune_checkpoints(ckpt_dir: str, keep: int = 3):
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                   if d.startswith("step_"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
