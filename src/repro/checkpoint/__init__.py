from .checkpoint import (latest_step, load_checkpoint, prune_checkpoints,
                         restore, save_checkpoint)

__all__ = ["save_checkpoint", "load_checkpoint", "latest_step", "restore",
           "prune_checkpoints"]
