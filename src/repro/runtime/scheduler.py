"""Admission scheduling: which queued request does a freed slot take?

The serving mirror of ``core/policies.py``: admission policies are small
host-side objects registered in ``ADMISSION_POLICIES`` and resolved by
``get_admission_policy(name)``, exactly like placement policies.  A policy
only ever sees host bookkeeping — the queue, per-tenant accounting, a KV
reservation view — never device state; the engine's executor applies the
decisions (``ServeEngine._execute_admission``) and runs the compiled steps.

* ``fcfs``     — first come, first served (the PR 1/2 behavior).
* ``priority`` — highest ``Request.priority`` first, FIFO within a level.
* ``sjf``      — shortest job first by predicted work
  (``prompt_len + max_new_tokens``): minimizes mean wait on mixed traces,
  at the cost of starving long requests under sustained short load.
* ``drf-fair`` — Dominant Resource Fairness across *tenants*, charging
  each admission's slot and KV reservation through
  ``core/drf.py``'s ``DRFAllocator`` — the direct serving analogue of
  Scylla's Mesos-level DRF across frameworks: every freed slot goes to
  the tenant with the lowest dominant share, so a flooding tenant cannot
  starve a light one out of the pool.

The DRF resource vector is ``ServeResource(slots, kv)``: ``slots`` counts
decode slots held, ``kv`` counts the KV reservation (pages for the paged
cache, token positions for dense).  Whichever dimension a tenant uses the
most of *relative to the pool* is its dominant share.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Optional

from repro.core.drf import DRFAllocator


@dataclass(frozen=True)
class ServeResource:
    """DRF demand/allocation vector for serving: decode slots + KV."""

    slots: float = 0.0
    kv: float = 0.0

    def __add__(self, o: "ServeResource") -> "ServeResource":
        return ServeResource(self.slots + o.slots, self.kv + o.kv)

    def __sub__(self, o: "ServeResource") -> "ServeResource":
        return ServeResource(self.slots - o.slots, self.kv - o.kv)

    def nonneg(self) -> bool:
        return self.slots >= -1e-9 and self.kv >= -1e-9

    def dominant_share(self, total: "ServeResource") -> float:
        shares = []
        if total.slots:
            shares.append(self.slots / total.slots)
        if total.kv:
            shares.append(self.kv / total.kv)
        return max(shares) if shares else 0.0


# ---------------------------------------------------------------- policies
class AdmissionPolicy:
    """Chooses which queued request the next freed slot admits."""

    name = "base"

    def bind(self, total: ServeResource) -> None:
        """Called once by the scheduler with the pool totals."""

    def select(self, queue) -> int:
        """Index into ``queue`` of the request to admit next."""
        raise NotImplementedError

    def on_admit(self, req, demand: ServeResource) -> None:
        """Admission bookkeeping hook (host-side only)."""

    def on_finish(self, req) -> None:
        """Completion bookkeeping hook (host-side only)."""


class FCFSPolicy(AdmissionPolicy):
    """First come, first served — arrival order, the legacy behavior."""

    name = "fcfs"

    def select(self, queue) -> int:
        return 0


class PriorityPolicy(AdmissionPolicy):
    """Highest ``Request.priority`` first; FIFO within a priority level."""

    name = "priority"

    def select(self, queue) -> int:
        return max(range(len(queue)),
                   key=lambda i: (queue[i].priority, -i))


class SJFPolicy(AdmissionPolicy):
    """Shortest predicted job (prompt + budget) first; FIFO on ties."""

    name = "sjf"

    def select(self, queue) -> int:
        return min(range(len(queue)),
                   key=lambda i: (len(queue[i].prompt)
                                  + queue[i].max_new_tokens, i))


class DRFFairPolicy(AdmissionPolicy):
    """Per-tenant DRF: admit from the tenant with the lowest dominant
    share of (slots, KV); FIFO within the chosen tenant.  Shares are
    charged on admission and credited on finish, so a tenant's share is
    exactly what it holds *right now* — a flood from one tenant queues
    behind its own share instead of starving everyone else."""

    name = "drf-fair"

    def __init__(self):
        self.allocator: Optional[DRFAllocator] = None

    def bind(self, total: ServeResource) -> None:
        self.allocator = DRFAllocator(total, zero=ServeResource())

    def shares(self) -> dict:
        return {} if self.allocator is None else self.allocator.shares()

    def select(self, queue) -> int:
        assert self.allocator is not None, "policy not bound to a scheduler"
        tenants = sorted({r.tenant for r in queue})
        for t in tenants:
            self.allocator.register(t)
        t = self.allocator.next_framework(tenants)
        return next(i for i, r in enumerate(queue) if r.tenant == t)

    def on_admit(self, req, demand: ServeResource) -> None:
        self.allocator.charge(req.tenant, demand)
        req._drf_demand = demand

    def on_finish(self, req) -> None:
        demand = getattr(req, "_drf_demand", None)
        if demand is not None:
            self.allocator.credit(req.tenant, demand)
            req._drf_demand = None


ADMISSION_POLICIES = {
    "fcfs": FCFSPolicy,
    "priority": PriorityPolicy,
    "sjf": SJFPolicy,
    "drf-fair": DRFFairPolicy,
}


def get_admission_policy(name: str, **kw) -> AdmissionPolicy:
    if isinstance(name, AdmissionPolicy):
        return name
    return ADMISSION_POLICIES[name](**kw)


# --------------------------------------------------------------- scheduler
@dataclass
class Admission:
    """One decision: slot ``slot`` admits ``req`` (``kv`` carries the page
    reservation for the paged cache — prefill start, CoW copies)."""

    slot: int
    req: object
    kv: object = None


class Scheduler:
    """Owns the host-side admission state: queue, policy, DRF accounting.

    ``decide()`` is the pure host phase of the engine tick — it assigns
    queued requests to free slots (reserving KV pages for the paged
    cache, which is host bookkeeping) and returns the decisions for the
    engine's executor to apply.  Policies never see device arrays.
    """

    def __init__(self, policy, *, slots: int, max_len: int, kv=None):
        self.policy = get_admission_policy(policy)
        self.slots = slots
        self.max_len = max_len
        self.kv = kv
        self.queue: deque = deque()
        kv_total = (kv.pool.capacity if kv is not None
                    else slots * max_len)
        self.policy.bind(ServeResource(slots=slots, kv=kv_total))

    def submit(self, req) -> None:
        self.queue.append(req)

    def demand(self, req) -> ServeResource:
        """The DRF charge an admission of ``req`` carries."""
        if self.kv is not None:
            kv = self.kv.blocks_needed(len(req.prompt), req.max_new_tokens)
        else:
            kv = min(len(req.prompt) + req.max_new_tokens, self.max_len)
        return ServeResource(slots=1, kv=kv)

    def decide(self, active) -> list[Admission]:
        """Assign queued requests to free slots; [] = nothing to admit.

        Paged backpressure: if the policy's chosen request cannot reserve
        its pages the round stops — the choice stays queued (it is next
        in line by policy order) and retries when slots drain.
        """
        out: list[Admission] = []
        for s in range(self.slots):
            if active[s] is not None or not self.queue:
                continue
            i = self.policy.select(self.queue)
            req = self.queue[i]
            res = None
            if self.kv is not None:
                res = self.kv.admit(s, req.prompt, req.max_new_tokens)
                if res is None:
                    break  # pool exhausted: retry after slots drain
            del self.queue[i]
            self.policy.on_admit(req, self.demand(req))
            out.append(Admission(slot=s, req=req, kv=res))
        return out

    def on_finish(self, req) -> None:
        self.policy.on_finish(req)
