"""Admission + preemption scheduling: the host-side half of every tick.

The serving mirror of ``core/policies.py``: admission policies are small
host-side objects registered in ``ADMISSION_POLICIES`` and resolved by
``get_admission_policy(name)``, exactly like placement policies.  A policy
only ever sees host bookkeeping — the queue, per-tenant accounting, a KV
reservation view — never device state; the engine's executor applies the
decisions (``ServeEngine._execute_admission`` / ``_execute_preemption``)
and runs the compiled steps.

* ``fcfs``     — first come, first served (the PR 1/2 behavior).
* ``priority`` — highest ``Request.priority`` first, FIFO within a level.
* ``sjf``      — shortest job first by predicted work
  (``prompt_len + max_new_tokens``): minimizes mean wait on mixed traces,
  at the cost of starving long requests under sustained short load.
* ``drf-fair`` — Dominant Resource Fairness across *tenants*, charging
  each admission's slot and KV reservation through
  ``core/drf.py``'s ``DRFAllocator`` — the direct serving analogue of
  Scylla's Mesos-level DRF across frameworks: every freed slot goes to
  the tenant with the lowest (weighted) dominant share, so a flooding
  tenant cannot starve a light one out of the pool.

The DRF resource vector is ``ServeResource(slots, kv)``: ``slots`` counts
decode slots held, ``kv`` counts the KV reservation (pages for the paged
cache, token positions for dense).  Whichever dimension a tenant uses the
most of *relative to the pool* is its dominant share.

Preemption (``Scheduler(preempt=True)``)
----------------------------------------
Admission alone cannot undo a grab: a tenant that filled every slot while
alone keeps them, which is exactly the starvation DRF exists to prevent.
``decide()`` is therefore two-phase.  Phase 1 assigns queued requests to
free slots as before.  Phase 2 — only when the queue is still non-empty —
reclaims running slots Mesos-style: the policy's next queued choice is
admitted by preempting a victim whenever the swap *strictly* improves
weighted-DRF fairness, i.e. the admitting tenant's weighted share after
the admission stays below the victim tenant's weighted share before it
(strictness makes the loop terminate and forbids same-tenant churn).
Victims are chosen by a pluggable ``VictimPolicy`` registered in
``VICTIM_POLICIES`` (mirroring the admission registry):

* ``youngest-first``             — the most recently admitted eligible
  request, whatever its tenant: minimizes lost decode progress.
* ``lowest-weight-share-first``  — an eligible request of the tenant
  with the highest *weighted* share (lowest weight per unit of share,
  i.e. the most over its SLO entitlement); youngest within that tenant.

Per-tenant weights (``Scheduler(weights=...)``, from
``ServeConfig.tenant_weights``) map SLO tiers onto DRF shares: weight 3
vs 1 converges to a 3:1 slot split under contention.  The scheduler owns
the single ``DRFAllocator`` — admission charges, finishes credit,
preemption credits the slot (and, dense-only, the KV: a paged victim's
detached page chain still occupies the pool, so its KV charge stays).
The engine's executor performs the device half of a ``Preemption``
(checkpoint the slot) before any admission touches that slot.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Optional

from repro.core.drf import DRFAllocator

_EPS = 1e-9


@dataclass(frozen=True)
class ServeResource:
    """DRF demand/allocation vector for serving: decode slots + KV."""

    slots: float = 0.0
    kv: float = 0.0

    def __add__(self, o: "ServeResource") -> "ServeResource":
        return ServeResource(self.slots + o.slots, self.kv + o.kv)

    def __sub__(self, o: "ServeResource") -> "ServeResource":
        return ServeResource(self.slots - o.slots, self.kv - o.kv)

    def nonneg(self) -> bool:
        return self.slots >= -_EPS and self.kv >= -_EPS

    def dominant_share(self, total: "ServeResource") -> float:
        shares = []
        if total.slots:
            shares.append(self.slots / total.slots)
        if total.kv:
            shares.append(self.kv / total.kv)
        return max(shares) if shares else 0.0


# ---------------------------------------------------------------- policies
class AdmissionPolicy:
    """Chooses which queued request the next freed slot admits."""

    name = "base"

    def bind(self, total: ServeResource, allocator=None) -> None:
        """Called once by the scheduler with the pool totals and its
        shared DRF allocator (the single source of tenant accounting)."""

    def select(self, queue) -> int:
        """Index into ``queue`` of the request to admit next."""
        raise NotImplementedError

    def on_admit(self, req, demand: ServeResource) -> None:
        """Admission bookkeeping hook (host-side only)."""

    def on_finish(self, req) -> None:
        """Completion bookkeeping hook (host-side only)."""


class FCFSPolicy(AdmissionPolicy):
    """First come, first served — arrival order, the legacy behavior."""

    name = "fcfs"

    def select(self, queue) -> int:
        return 0


class PriorityPolicy(AdmissionPolicy):
    """Highest ``Request.priority`` first; FIFO within a priority level."""

    name = "priority"

    def select(self, queue) -> int:
        return max(range(len(queue)),
                   key=lambda i: (queue[i].priority, -i))


class SJFPolicy(AdmissionPolicy):
    """Shortest predicted job (prompt + budget) first; FIFO on ties."""

    name = "sjf"

    def select(self, queue) -> int:
        return min(range(len(queue)),
                   key=lambda i: (len(queue[i].prompt)
                                  + queue[i].max_new_tokens, i))


class DRFFairPolicy(AdmissionPolicy):
    """Per-tenant (weighted) DRF: admit from the tenant with the lowest
    weighted dominant share of (slots, KV); FIFO within the chosen
    tenant.  Shares are charged on admission and credited on finish by
    the owning ``Scheduler``, so a tenant's share is exactly what it
    holds *right now* — a flood from one tenant queues behind its own
    share instead of starving everyone else."""

    name = "drf-fair"

    def __init__(self, weights=None):
        # ``weights`` only matters for standalone use; a Scheduler-owned
        # policy is bound to the scheduler's (already weighted) allocator
        self._weights = weights
        self.allocator: Optional[DRFAllocator] = None

    def bind(self, total: ServeResource, allocator=None) -> None:
        self.allocator = allocator if allocator is not None else \
            DRFAllocator(total, zero=ServeResource(), weights=self._weights)

    def shares(self) -> dict:
        return {} if self.allocator is None else self.allocator.shares()

    def select(self, queue) -> int:
        assert self.allocator is not None, "policy not bound to a scheduler"
        tenants = sorted({r.tenant for r in queue})
        for t in tenants:
            self.allocator.register(t)
        t = self.allocator.next_framework(tenants)
        return next(i for i, r in enumerate(queue) if r.tenant == t)


ADMISSION_POLICIES = {
    "fcfs": FCFSPolicy,
    "priority": PriorityPolicy,
    "sjf": SJFPolicy,
    "drf-fair": DRFFairPolicy,
}


def get_admission_policy(name: str, **kw) -> AdmissionPolicy:
    if isinstance(name, AdmissionPolicy):
        return name
    return ADMISSION_POLICIES[name](**kw)


# --------------------------------------------------------- victim policies
@dataclass(frozen=True)
class VictimCandidate:
    """One preemptible slot: who holds it and its tenant's weighted
    share (the fairness headroom the preemption would reclaim)."""

    slot: int
    req: object
    weighted_share: float

    def _age_key(self):
        return getattr(self.req, "_admit_seq", -1)


class VictimPolicy:
    """Chooses which eligible running request a preemption evicts."""

    name = "base"

    def select(self, candidates: list) -> VictimCandidate:
        raise NotImplementedError


class YoungestFirstVictimPolicy(VictimPolicy):
    """Evict the most recently admitted eligible request, whatever its
    tenant: the victim has the least decode progress to lose (its
    checkpoint is cheapest to have wasted)."""

    name = "youngest-first"

    def select(self, candidates):
        return max(candidates, key=lambda c: c._age_key())


class LowestWeightShareFirstVictimPolicy(VictimPolicy):
    """Evict from the tenant with the highest weighted share — the one
    holding the most per unit of SLO weight, i.e. furthest over its
    entitlement; youngest request within that tenant."""

    name = "lowest-weight-share-first"

    def select(self, candidates):
        return max(candidates,
                   key=lambda c: (c.weighted_share, c._age_key()))


VICTIM_POLICIES = {
    "youngest-first": YoungestFirstVictimPolicy,
    "lowest-weight-share-first": LowestWeightShareFirstVictimPolicy,
}


def get_victim_policy(name: str, **kw) -> VictimPolicy:
    if isinstance(name, VictimPolicy):
        return name
    return VICTIM_POLICIES[name](**kw)


# --------------------------------------------------------------- scheduler
@dataclass
class Admission:
    """One decision: slot ``slot`` admits ``req`` (``kv`` carries the page
    reservation for the paged cache — prefill start, CoW copies;
    ``resume=True`` restores a preempted request at its checkpoint
    instead of prefilling)."""

    slot: int
    req: object
    kv: object = None
    resume: bool = False


@dataclass
class Preemption:
    """One decision: checkpoint slot ``slot`` and requeue its request.
    The executor captures the device state (position, last token, dense
    KV stripe); the scheduler has already done the host half (page-chain
    detach, DRF credit, requeue)."""

    slot: int
    req: object


@dataclass
class Plan:
    """A tick's host decisions.  The executor MUST apply ``preemptions``
    (checkpointing device state) before ``admissions`` — an admission may
    reuse a slot vacated in the same plan."""

    admissions: list = field(default_factory=list)
    preemptions: list = field(default_factory=list)

    def __bool__(self) -> bool:
        return bool(self.admissions or self.preemptions)


class Scheduler:
    """Owns the host-side admission state: queue, policy, DRF accounting.

    ``decide()`` is the pure host phase of the engine tick — it assigns
    queued requests to free slots (reserving KV pages for the paged
    cache, which is host bookkeeping), optionally reclaims running slots
    by preemption, and returns the ``Plan`` for the engine's executor to
    apply.  Policies never see device arrays.
    """

    def __init__(self, policy, *, slots: int, max_len: int, kv=None,
                 weights=None, preempt: bool = False,
                 victim="youngest-first"):
        self.policy = get_admission_policy(policy)
        self.slots = slots
        self.max_len = max_len
        self.kv = kv
        self.preempt = preempt
        self.victim = get_victim_policy(victim)
        self.queue: deque = deque()
        self.preempted_total = 0  # telemetry: preemptions ever decided
        self._admit_seq = 0
        kv_total = (kv.pool.capacity if kv is not None
                    else slots * max_len)
        total = ServeResource(slots=slots, kv=kv_total)
        # the single per-tenant account book: admission policies read it,
        # preemption compares weighted shares through it
        self.allocator = DRFAllocator(total, zero=ServeResource(),
                                      weights=weights)
        self.policy.bind(total, self.allocator)
        # metrics: a private registry by default; the owning engine
        # rebinds onto the shared one (ServeEngine.bind_telemetry)
        self.bind_metrics(None, 0)

    def bind_metrics(self, registry, replica: int) -> None:
        """Register this scheduler's series on ``registry`` (a private
        ``MetricsRegistry`` when None).  ``preempted_total`` stays the
        plain attribute — it is gauge-shaped (``_unpreempt_slot``
        decrements it on a rolled-back swap) — exposed function-backed;
        admissions/backpressure are prebound counter children."""
        from repro.runtime.telemetry import MetricsRegistry
        if registry is None:
            registry = MetricsRegistry()
        lbl = {"replica": str(replica)}
        self._m_admissions = registry.counter(
            "serve_admissions_total", "requests admitted into a slot",
            ("replica",)).labels(**lbl)
        self._m_backpressure = registry.counter(
            "serve_backpressure_total",
            "admissions deferred on page-pool exhaustion",
            ("replica",)).labels(**lbl)
        registry.gauge(
            "serve_preempted", "preemptions decided minus rollbacks",
            ("replica",)).labels(**lbl).set_function(
            lambda: self.preempted_total)

    def submit(self, req) -> None:
        self.queue.append(req)

    def demand(self, req) -> ServeResource:
        """The DRF charge an admission of ``req`` carries.  Resuming a
        paged checkpoint re-takes only the slot — its page chain never
        left the pool (and never stopped being charged).

        The KV charge covers in-flight speculative drafts too: the
        engine caps a draft at the request's remaining token budget
        (``ServeEngine._draft_cap``), so the deepest draft write stays
        inside the ``prompt + max_new`` span this reservation already
        accounts for — speculation changes *when* KV is written, never
        how much is reserved."""
        if getattr(req, "_preempted", False) and self.kv is not None:
            # an in-engine resume re-takes only the slot (the chain never
            # left this pool, its charge rode along on ``_drf_charged``);
            # a *handed-off* arrival adopted its chain into THIS pool
            # during the cross-engine transfer, so the charge lands here
            return ServeResource(
                slots=1, kv=float(getattr(req, "_handoff_kv", 0) or 0))
        if self.kv is not None:
            kv = self.kv.blocks_needed(len(req.prompt), req.max_new_tokens)
        else:
            kv = min(len(req.prompt) + req.max_new_tokens, self.max_len)
        return ServeResource(slots=1, kv=kv)

    # ------------------------------------------------------------- decide
    def decide(self, active) -> Plan:
        """Assign queued requests to free slots, then (``preempt=True``)
        reclaim running slots while a swap strictly improves weighted-DRF
        fairness.  An empty plan = nothing to do.

        Paged backpressure: if the policy's chosen request cannot reserve
        its pages the round stops — the choice stays queued (it is next
        in line by policy order) and retries when slots drain.
        """
        plan = Plan()
        view = list(active)  # host model of slot occupancy for this round
        for s in range(self.slots):
            if view[s] is not None or not self.queue:
                continue
            if not self._admit_into(s, plan, view):
                # pool exhausted for the policy's choice.  Before giving
                # up, resume a queued PREEMPTED request if any: a resume
                # allocates zero pages, and its detained page chain only
                # ever returns to the pool by running to completion — a
                # non-FIFO policy could otherwise park it behind an
                # unadmittable fresh request forever (livelock).
                held = next((r for r in self.queue
                             if getattr(r, "_preempted", False)), None)
                if held is None or not self._admit_into(s, plan, view,
                                                        req=held):
                    if (self.kv is not None
                            and getattr(self.kv, "num_hosts", 1) > 1):
                        # sharded pool: slot s's HOST sub-pool is full,
                        # not the whole pool — a later free slot mapping
                        # to another host may still admit the choice
                        continue
                    return plan  # retry after slots drain
        if self.preempt:
            self._decide_preemptions(plan, view)
        return plan

    def _admit_into(self, s: int, plan: Plan, view: list,
                    req=None) -> bool:
        """Admit ``req`` (or the policy's next choice) into free slot
        ``s`` (host bookkeeping: dequeue, KV reservation/attach, DRF
        charge).  False = paged backpressure, nothing consumed.  Phase 2
        pins ``req`` to the request its fairness test justified — a
        fresh ``select`` could pick the just-credited victim instead."""
        if req is None:
            i = self.policy.select(self.queue)
            req = self.queue[i]
        else:
            i = next(j for j, r in enumerate(self.queue) if r is req)
        resume = getattr(req, "_preempted", False)
        res = None
        if self.kv is not None:
            if resume:  # page chain still held: remap it to the new slot
                self.kv.attach_slot(s, req._ckpt_pages)
            else:
                res = self.kv.admit(s, req.prompt, req.max_new_tokens)
                if res is None:
                    self._m_backpressure.inc()
                    return False
        del self.queue[i]
        demand = self.demand(req)
        self.allocator.charge(req.tenant, demand)
        req._drf_charged = (getattr(req, "_drf_charged", None)
                            or ServeResource()) + demand
        req._admit_seq = self._admit_seq
        self._admit_seq += 1
        self.policy.on_admit(req, demand)
        view[s] = req
        self._m_admissions.inc()
        plan.admissions.append(Admission(slot=s, req=req, kv=res,
                                         resume=resume))
        return True

    def _decide_preemptions(self, plan: Plan, view: list) -> None:
        """Phase 2: while the queue holds a request whose admission keeps
        its tenant's weighted share strictly below some running tenant's,
        evict a victim (per the victim policy) and admit into its slot."""
        preempted_slots: set[int] = set()
        for _ in range(self.slots):  # each swap consumes one fresh victim
            if not self.queue:
                return
            i = self.policy.select(self.queue)
            req = self.queue[i]
            if (self.kv is not None
                    and not getattr(req, "_preempted", False)
                    and not self.kv.fits_now(req.prompt,
                                             req.max_new_tokens)):
                return  # evicting a victim frees no pages: backpressure
            ws_after = self.allocator.weighted_share_if(req.tenant,
                                                        self.demand(req))
            cands = [
                VictimCandidate(s, r,
                                self.allocator.weighted_share(r.tenant))
                for s, r in enumerate(view)
                if r is not None and s not in preempted_slots
                and r.tenant != req.tenant
                and self._preemptible(r)
                and self.allocator.weighted_share(r.tenant)
                > ws_after + _EPS]
            if not cands:
                return
            v = self.victim.select(cands)
            self._preempt_slot(v, plan, view)
            preempted_slots.add(v.slot)
            if not self._admit_into(v.slot, plan, view, req=req):
                # the swap's admission failed after all (fits_now is a
                # conservative host estimate): undo the preemption so
                # the victim keeps running — nothing device-side has
                # happened yet, the whole round is host bookkeeping
                self._unpreempt_slot(v, plan, view)
                preempted_slots.discard(v.slot)
                return

    @staticmethod
    def _preemptible(req) -> bool:
        """Only steadily decoding requests checkpoint cleanly: mid-prompt
        token-feed (SSM fallback) and mid-prefill states are skipped."""
        state = getattr(req, "state", None)
        return (getattr(state, "value", None) == "decode"
                and not getattr(req, "_feed", None)
                and bool(req.output))

    def _preempt_slot(self, v: VictimCandidate, plan: Plan,
                      view: list) -> None:
        """Host half of a preemption: detach the page chain (paged),
        credit the DRF account for what the tenant stops holding (the
        slot; plus the KV for dense — its stripe is about to leave the
        device), and requeue at the FRONT so the victim resumes at its
        tenant's next turn instead of behind fresh arrivals."""
        req = v.req
        if self.kv is not None:
            req._ckpt_pages = self.kv.detach_slot(v.slot)
            credit = ServeResource(slots=1, kv=0)
        else:
            credit = req._drf_charged
        self.allocator.credit(req.tenant, credit)
        req._drf_charged = req._drf_charged - credit
        req._drf_restore = credit  # _unpreempt_slot's exact inverse
        req._preempted = True
        self.preempted_total += 1
        view[v.slot] = None
        self.queue.appendleft(req)
        plan.preemptions.append(Preemption(slot=v.slot, req=req))

    def _unpreempt_slot(self, v: VictimCandidate, plan: Plan,
                        view: list) -> None:
        """Exact inverse of ``_preempt_slot`` — rolls a decided-but-not-
        executed preemption back when its paired admission fails."""
        req = v.req
        assert self.queue[0] is req, "victim no longer at queue front"
        self.queue.popleft()
        plan.preemptions.remove(next(p for p in plan.preemptions
                                     if p.req is req))
        if self.kv is not None:
            self.kv.attach_slot(v.slot, req._ckpt_pages)
            req._ckpt_pages = None
        charge = req._drf_restore
        self.allocator.charge(req.tenant, charge)
        req._drf_charged = req._drf_charged + charge
        req._preempted = False
        self.preempted_total -= 1
        view[v.slot] = req

    # ------------------------------------------------------------- finish
    def on_finish(self, req) -> None:
        charged = getattr(req, "_drf_charged", None)
        if charged is not None:
            self.allocator.credit(req.tenant, charged)
            req._drf_charged = None
        self.policy.on_finish(req)

    # ---------------------------------------------------------- telemetry
    def shares(self) -> dict:
        """Raw dominant shares per tenant (see also
        ``allocator.weighted_shares()``)."""
        return self.allocator.shares()
