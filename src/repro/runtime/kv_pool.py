"""Paged KV-cache subsystem: block allocator + prefix cache (host side).

The KV cache is serving's scarce resource, the way chips are the paper's:
continuous batching (PR 1) made decode work proportional to live tokens,
but every slot still *reserved* a dense ``(max_len)`` HBM stripe.  This
module is the allocator that fixes the reservation side — the serving
analogue of Scylla's policy-driven resource pool:

* ``PagePool`` — a global pool of fixed-size pages (``page_size`` token
  positions each), refcounted, with a free list kept per HBM *bank*.
  Physical page 0 is reserved as the **null page**: free slots' page
  tables point at it and inactive writes land there, so the device side
  never needs a branch.
* Allocation **policies** mirror ``core/policies.py``: ``pack``
  (MinHostPolicy analogue — fill the fewest banks, contiguous page runs)
  vs ``spread`` (SpreadPolicy analogue — round-robin the emptiest banks
  so concurrent slots stream from disjoint banks).  Registered in
  ``KV_PAGE_POLICIES`` just like ``POLICIES``.
* ``PrefixCache`` — content-addressed full pages: chain-hash each
  ``page_size``-token prompt chunk onto its parent hash and map it to
  the page holding its K/V.  A later prompt sharing the prefix is
  admitted at ``pos = matched`` with the cached pages mapped read-only
  (refcount shared); **copy-on-write** fires when the admission must
  write into a shared page (full-prompt hits re-run the last page to
  recover logits).  Cache-only pages (refcount 1) are evicted LRU-first
  under pool pressure.
* ``KVCacheManager`` — per-slot page tables gluing the above to
  ``ServeEngine``: admission reserves exactly the pages a request can
  touch (``ceil((prompt + max_new) / page_size)``, not ``max_len``),
  returns ``None`` for backpressure when the pool is exhausted, and
  frees pages the moment a request finishes.

Everything here is host-side bookkeeping (numpy + dicts); the device
side consumes only the ``(slots, max_pages)`` int32 page-table array and
the (src, dst) page-copy list that admission returns.
"""
from __future__ import annotations

import hashlib
import warnings
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Optional

import numpy as np


class PoolExhausted(RuntimeError):
    """Raised by ``PagePool.alloc`` when the free list cannot satisfy a
    request; ``KVCacheManager`` turns this into backpressure."""


# ---------------------------------------------------------------- policies
class PagePolicy:
    """Chooses which free pages an allocation takes (bank placement)."""

    name = "base"

    def select(self, free_by_bank: dict[int, list[int]],
               in_use_by_bank: dict[int, int], n: int) -> list[int]:
        raise NotImplementedError


class PackPagePolicy(PagePolicy):
    """Fill the fewest banks: partially-used banks first, lowest page ids
    within a bank (contiguous runs — the MinHostPolicy analogue: keep
    allocations dense so whole banks stay free for future jobs)."""

    name = "pack"

    def select(self, free_by_bank, in_use_by_bank, n):
        order = sorted(free_by_bank,
                       key=lambda b: (-in_use_by_bank[b], b))
        out: list[int] = []
        for b in order:
            take = free_by_bank[b][:n - len(out)]
            out.extend(take)
            if len(out) == n:
                break
        return out


class SpreadPagePolicy(PagePolicy):
    """Round-robin the emptiest banks (the SpreadPolicy analogue): one
    page per bank per round so concurrent slots stream KV from as many
    banks as possible, at the cost of fragmenting bank-contiguity."""

    name = "spread"

    def select(self, free_by_bank, in_use_by_bank, n):
        order = sorted(free_by_bank,
                       key=lambda b: (in_use_by_bank[b], b))
        out: list[int] = []
        idx = {b: 0 for b in order}
        while len(out) < n:
            progressed = False
            for b in order:
                if len(out) < n and idx[b] < len(free_by_bank[b]):
                    out.append(free_by_bank[b][idx[b]])
                    idx[b] += 1
                    progressed = True
            if not progressed:
                break
        return out


KV_PAGE_POLICIES = {
    "pack": PackPagePolicy,
    "spread": SpreadPagePolicy,
}


def get_page_policy(name: str) -> PagePolicy:
    return KV_PAGE_POLICIES[name]()


# -------------------------------------------------------------------- pool
class PagePool:
    """Refcounted fixed-size page pool with bank-aware placement.

    Pages are numbered 0..num_pages-1; page 0 is the reserved null page
    (never allocated, refcount pinned).  Banks stripe the pool into
    ``num_banks`` contiguous regions — the model of HBM channels the
    placement policies optimize over.

    ``num_hosts > 1`` (sharded serving) additionally partitions the pool
    into equal contiguous *host sub-pools*: the device-side page pools
    are sharded over the mesh's "data" axis, so pages
    ``[h * num_pages/H, (h+1) * num_pages/H)`` physically live on host
    (data row) ``h``.  ``alloc(host=h)`` then draws only from that
    host's banks, keeping a slot's whole page chain host-local — decode
    for the slot never gathers KV across hosts.  The null page sits in
    host 0's range (host 0 has one page less of capacity).
    """

    def __init__(self, num_pages: int, page_size: int, *,
                 policy: str | PagePolicy = "pack", num_banks: int = 8,
                 num_hosts: int = 1):
        assert num_pages >= 2, "need at least the null page + one real page"
        assert page_size >= 1
        assert num_hosts >= 1
        if num_hosts > 1 and num_pages % num_hosts:
            # host sub-pools must tile the pool evenly (the device page
            # dim shards over the data axes) — round capacity UP rather
            # than refuse, so a caller-sized pool never silently shrinks
            # and never hard-errors.  Callers that size device arrays
            # from the pool must read back ``pool.num_pages``.
            rounded = -(-num_pages // num_hosts) * num_hosts
            warnings.warn(
                f"num_pages {num_pages} not divisible by num_hosts "
                f"{num_hosts}; rounding up to {rounded} so host sub-pools "
                f"align with the device shard of the page dim",
                RuntimeWarning, stacklevel=2)
            num_pages = rounded
        self.num_pages = num_pages
        self.page_size = page_size
        self.num_banks = max(1, min(num_banks, num_pages - 1))
        self.num_hosts = num_hosts
        self._per_host = num_pages // num_hosts
        self.policy = (policy if isinstance(policy, PagePolicy)
                       else get_page_policy(policy))
        self._per_bank = -(-num_pages // self.num_banks)
        self.ref = np.zeros(num_pages, np.int32)
        self.ref[0] = 1  # null page: pinned, never on the free list
        self._free_by_bank: dict[int, list[int]] = {
            b: [] for b in range(self.num_banks)}
        for p in range(1, num_pages):
            self._free_by_bank[self.bank_of(p)].append(p)
        self._in_use_by_bank: dict[int, int] = {
            b: 0 for b in range(self.num_banks)}

    def bank_of(self, page: int) -> int:
        return page // self._per_bank

    def host_of(self, page: int) -> int:
        return page // self._per_host

    @property
    def available(self) -> int:
        return sum(len(v) for v in self._free_by_bank.values())

    @property
    def capacity(self) -> int:
        return self.num_pages - 1  # null page excluded

    @property
    def in_use(self) -> int:
        return self.capacity - self.available

    def free_by_host(self) -> list[int]:
        """Free-page count per host sub-pool (length ``num_hosts``) —
        what a sharded engine's ``offer()`` advertises."""
        counts = [0] * self.num_hosts
        for pages in self._free_by_bank.values():
            for p in pages:
                counts[self.host_of(p)] += 1
        return counts

    def free_in_host(self, host: int) -> int:
        return self.free_by_host()[host]

    def alloc(self, n: int = 1, *, host: Optional[int] = None) -> list[int]:
        """Take ``n`` pages (refcount 1 each) per the placement policy.

        ``host`` restricts the draw to one host sub-pool; ``None`` with
        ``num_hosts > 1`` picks the sub-pool with the most free pages
        (deterministic: lowest index on ties), so unconstrained chains —
        disagg adoptions, for instance — still stay host-local."""
        if n <= 0:
            return []
        if self.num_hosts > 1 and host is None:
            by_host = self.free_by_host()
            host = max(range(self.num_hosts), key=lambda h: (by_host[h], -h))
        if host is not None and self.num_hosts > 1:
            free = {b: [p for p in pages if self.host_of(p) == host]
                    for b, pages in self._free_by_bank.items()}
            free = {b: pages for b, pages in free.items() if pages}
            if sum(len(v) for v in free.values()) < n:
                raise PoolExhausted(
                    f"need {n} pages on host {host}, "
                    f"{self.free_in_host(host)} free of {self._per_host}")
        else:
            free = self._free_by_bank
            if self.available < n:
                raise PoolExhausted(
                    f"need {n} pages, {self.available} free of "
                    f"{self.capacity}")
        pages = self.policy.select(free, self._in_use_by_bank, n)
        assert len(pages) == n, (len(pages), n)
        for p in pages:
            self._free_by_bank[self.bank_of(p)].remove(p)
            self._in_use_by_bank[self.bank_of(p)] += 1
            assert self.ref[p] == 0, f"page {p} on free list with refs"
            self.ref[p] = 1
        return pages

    def incref(self, page: int):
        assert 0 < page < self.num_pages, page
        assert self.ref[page] > 0, f"incref of free page {page}"
        self.ref[page] += 1

    def decref(self, page: int):
        assert 0 < page < self.num_pages, page
        assert self.ref[page] > 0, f"double free of page {page}"
        self.ref[page] -= 1
        if self.ref[page] == 0:
            b = self.bank_of(page)
            self._free_by_bank[b].append(page)
            self._free_by_bank[b].sort()
            self._in_use_by_bank[b] -= 1

    def banks_touched(self, pages) -> int:
        return len({self.bank_of(p) for p in pages})


# ------------------------------------------------------------ prefix cache
def _chunk_key(parent: str, chunk: np.ndarray) -> str:
    h = hashlib.sha1()
    h.update(parent.encode())
    h.update(np.ascontiguousarray(chunk, np.int32).tobytes())
    return h.hexdigest()


class PrefixCache:
    """Content-addressed map of full prompt pages -> physical pages.

    Keys chain-hash each ``page_size``-token chunk with its parent's key,
    so a hit on chunk *i* implies chunks 0..i-1 all matched.  The cache
    holds one refcount per entry; entries whose page refcount has dropped
    to 1 (cache-only) are evictable, LRU order.
    """

    def __init__(self, pool: PagePool):
        self.pool = pool
        self._map: OrderedDict[str, int] = OrderedDict()
        self.hits = 0
        self.misses = 0

    def __len__(self):
        return len(self._map)

    def probe(self, prompt: np.ndarray) -> list[int]:
        """Read-only longest-cached-prefix pages — no increfs, no LRU
        moves, no hit/miss accounting.  ``KVCacheManager``'s sizing
        queries (``fits_now`` et al.) use this so a scheduler merely
        *considering* an admission never perturbs cache state."""
        ps = self.pool.page_size
        parent = ""
        pages: list[int] = []
        for i in range(len(prompt) // ps):
            key = _chunk_key(parent, prompt[i * ps:(i + 1) * ps])
            page = self._map.get(key)
            if page is None:
                break
            parent = key
            pages.append(page)
        return pages

    def evictable(self, exclude=(), host: Optional[int] = None) -> int:
        """Pages ``evict`` could free right now (cache-only, ref 1).
        ``exclude`` lists pages the prospective admission would itself
        use: its ``lookup`` increfs them *before* ``evict`` runs, so
        they must not be counted as reclaimable headroom.  ``host``
        counts only one host sub-pool (sharded serving: eviction there
        frees pages only that host's allocations can reuse)."""
        skip = set(exclude)
        return sum(1 for pg in self._map.values()
                   if self.pool.ref[pg] == 1 and pg not in skip
                   and (host is None or self.pool.host_of(pg) == host))

    def lookup(self, prompt: np.ndarray) -> tuple[list[int], int]:
        """Longest cached prefix of ``prompt`` in whole pages.

        Returns (pages, matched_tokens); each returned page has been
        incref'd on the caller's behalf (the caller decrefs on finish).
        """
        ps = self.pool.page_size
        pages: list[int] = []
        parent = ""
        for i in range(len(prompt) // ps):
            key = _chunk_key(parent, prompt[i * ps:(i + 1) * ps])
            page = self._map.get(key)
            if page is None:
                self.misses += 1
                break
            self._map.move_to_end(key)
            self.pool.incref(page)
            pages.append(page)
            parent = key
            self.hits += 1
        return pages, len(pages) * ps

    def insert(self, prompt: np.ndarray, blocks: list[int]):
        """Register ``prompt``'s full pages (blocks[i] holds tokens
        ``[i*ps, (i+1)*ps)``).  Existing entries are kept (first writer
        wins); new entries take one cache refcount."""
        ps = self.pool.page_size
        parent = ""
        for i in range(len(prompt) // ps):
            key = _chunk_key(parent, prompt[i * ps:(i + 1) * ps])
            if key not in self._map:
                self._map[key] = blocks[i]
                self.pool.incref(blocks[i])
            parent = key

    def evict(self, n_pages: int, host: Optional[int] = None) -> int:
        """Drop up to ``n_pages`` cache-only entries (page refcount 1),
        oldest first; ``host`` restricts to one host sub-pool.  Returns
        the number of pages actually freed."""
        freed = 0
        for key in list(self._map):
            if freed >= n_pages:
                break
            page = self._map[key]
            if self.pool.ref[page] == 1 and (
                    host is None or self.pool.host_of(page) == host):
                del self._map[key]
                self.pool.decref(page)
                freed += 1
        return freed


# ---------------------------------------------------------------- manager
@dataclass
class AdmitResult:
    """What the engine needs to act on an admission."""

    start: int  # prefill resumes here (tokens [start, len(prompt)) run)
    matched: int  # tokens satisfied by the prefix cache
    cow: list = field(default_factory=list)  # [(src_page, dst_page)] copies
    blocks: list = field(default_factory=list)


class KVCacheManager:
    """Per-slot page tables over a shared ``PagePool`` (+ prefix cache).

    The device contract is the ``page_table`` int32 array
    ``(slots, max_pages)``: logical block *i* of slot *s* lives in
    physical page ``page_table[s, i]`` (0 = null page for unmapped
    blocks).  One table serves every layer — layer pools are stacked, so
    a (page, offset) write lands at the same coordinates in each.

    ``num_hosts > 1`` (sharded serving): the device page pools are
    sharded over the mesh's "data" axis, so the manager partitions
    slots and pages alike — slot ``s`` belongs to host
    ``s * num_hosts // slots`` (the contiguous-block shard of the slot
    dim) and its admissions allocate only from that host's page
    sub-pool, keeping every chain's KV on the host that computes the
    slot's queries.  Prefix-cache chains are shared only within a host
    for the same reason.  Locality is a *placement* property — resumed
    or adopted chains from another host still decode correctly, just
    with cross-host gathers.
    """

    def __init__(self, *, slots: int, max_len: int, page_size: int,
                 num_pages: int, policy: str | PagePolicy = "pack",
                 prefix_cache: bool = True, num_banks: int = 8,
                 chunk: int = 0, num_hosts: int = 1):
        assert max_len % page_size == 0, (max_len, page_size)
        self.page_size = page_size
        self.max_pages = max_len // page_size
        self.max_len = max_len
        self.slots = slots
        self.num_hosts = num_hosts
        self.chunk = chunk or page_size  # engine's prefill-chunk grid
        assert self.chunk % page_size == 0, (self.chunk, page_size)
        self.pool = PagePool(num_pages, page_size, policy=policy,
                             num_banks=num_banks, num_hosts=num_hosts)
        self.prefix = PrefixCache(self.pool) if prefix_cache else None
        self.page_table = np.zeros((slots, self.max_pages), np.int32)
        self._held: list[list[int]] = [[] for _ in range(slots)]
        # metrics: a private registry by default; the owning engine
        # rebinds onto the shared one (ServeEngine.bind_telemetry)
        self.bind_metrics(None, 0)

    def slot_host(self, slot: int) -> Optional[int]:
        """Host (mesh "data" row) that computes ``slot``'s queries —
        the contiguous-block partition jax uses for the sharded slot
        dim.  None when unsharded (num_hosts == 1)."""
        if self.num_hosts == 1:
            return None
        return slot * self.num_hosts // self.slots

    def free_by_host(self) -> list[int]:
        """Per-host free-page counts (``offer()`` advertises these)."""
        return self.pool.free_by_host()

    def bind_metrics(self, registry, replica: int) -> None:
        """Register the pool's series on ``registry`` (private
        ``MetricsRegistry`` when None) as function-backed gauges — the
        allocator keeps its own bookkeeping hot; the registry reads it
        live at export time, and ``stats()`` reads back through the
        registry so the legacy dict stays a view, not a second ledger."""
        from repro.runtime.telemetry import MetricsRegistry
        if registry is None:
            registry = MetricsRegistry()
        self._registry = registry
        self._replica = int(replica)
        lbl = {"replica": str(replica)}
        for name, help, fn in (
                ("kv_page_size", "tokens per KV page",
                 lambda: self.page_size),
                ("kv_pages_capacity", "allocatable pages in the pool",
                 lambda: self.pool.capacity),
                ("kv_pages_in_use", "pages currently referenced",
                 lambda: self.pool.in_use),
                ("kv_prefix_entries", "prefix-cache chains resident",
                 lambda: 0 if self.prefix is None else len(self.prefix)),
                ("kv_prefix_hits", "prefix-cache probe hits",
                 lambda: 0 if self.prefix is None else self.prefix.hits),
                ("kv_prefix_misses", "prefix-cache probe misses",
                 lambda: 0 if self.prefix is None else self.prefix.misses)):
            registry.gauge(name, help, ("replica",)).labels(
                **lbl).set_function(fn)

    # ------------------------------------------------------------- sizing
    def blocks_needed(self, prompt_len: int, max_new: int) -> int:
        need = min(prompt_len + max_new, self.max_len)
        return -(-need // self.page_size)

    def _sizing(self, prompt: np.ndarray, max_new: int):
        """(fresh pages an ``admit`` would allocate, its cached-prefix
        pages) — the sizing half of ``admit`` with zero side effects."""
        prompt = np.asarray(prompt, np.int32)
        p = len(prompt)
        n_blocks = self.blocks_needed(p, max_new)
        cached = [] if self.prefix is None else self.prefix.probe(prompt)
        matched = len(cached) * self.page_size
        start = (min(matched, p - 1) // self.chunk) * self.chunk
        cow = max(0, len(cached) - start // self.page_size)
        return n_blocks - len(cached) + cow, cached

    def pages_needed_now(self, prompt: np.ndarray, max_new: int) -> int:
        """Fresh pages an ``admit`` of this request would allocate RIGHT
        NOW (prefix sharing and CoW headroom included), side-effect
        free — the testable spec of ``admit``'s pool consumption
        (tests/test_preemption.py holds them equal)."""
        return self._sizing(prompt, max_new)[0]

    def fits_now(self, prompt: np.ndarray, max_new: int,
                 slot: Optional[int] = None) -> bool:
        """Could ``admit`` succeed right now?  The scheduler's
        preemption phase gates swaps on this (an accurate estimate —
        over-estimating demand would suppress justified evictions).
        Evictable prefix-cache pages count as available (``admit``
        evicts them itself) — except the request's own cached prefix,
        which its lookup increfs before eviction runs.

        Sharded (num_hosts > 1): the answer is per host sub-pool —
        ``slot`` pins the host; without a slot the *best* host is
        assumed (a router-facing estimate; the admit of a specific
        slot on a fuller host can still backpressure)."""
        need, cached = self._sizing(prompt, max_new)
        if self.num_hosts == 1:
            avail = self.pool.available
            if self.prefix is not None:
                avail += self.prefix.evictable(exclude=cached)
            return need <= avail
        hosts = ([self.slot_host(slot)] if slot is not None
                 else range(self.num_hosts))
        by_host = self.pool.free_by_host()
        for h in hosts:
            avail = by_host[h]
            if self.prefix is not None:
                avail += self.prefix.evictable(exclude=cached, host=h)
            if need <= avail:
                return True
        return False

    def fits_ever(self, prompt_len: int, max_new: int) -> bool:
        """Could this request EVER be admitted (empty pool)?"""
        n = self.blocks_needed(prompt_len, max_new)
        # headroom: a prefix hit that re-runs the last chunk CoWs at most
        # chunk // page_size shared pages
        return (n <= self.max_pages
                and n + self.chunk // self.page_size <= self.pool.capacity)

    # ---------------------------------------------------------- admission
    def admit(self, slot: int, prompt: np.ndarray,
              max_new: int) -> Optional[AdmitResult]:
        """Reserve pages for a request; None = backpressure (try later).

        On success the slot's page-table row maps every block the request
        can touch; cached prefix pages are shared (read-only) and the
        result carries the (src, dst) device copies CoW demands.

        The prefill start is the largest multiple of ``self.chunk`` (the
        engine's prefill-chunk grid) not past the matched prefix; a
        full-prompt hit re-runs the last chunk to recover the logits that
        seed decode.  Every shared page the rewrite touches is CoW'd —
        the rewrite produces the same K/V, but the shared page must not
        see even an identical write while other slots read it.

        Sharded (num_hosts > 1): every fresh page comes from the slot's
        own host sub-pool, and a cached prefix chain is reused only when
        it lives on that host (otherwise it is released and re-run —
        correctness would survive a cross-host chain, locality would
        not).
        """
        assert not self._held[slot], f"slot {slot} already holds pages"
        host = self.slot_host(slot)
        prompt = np.asarray(prompt, np.int32)
        p = len(prompt)
        ps = self.page_size
        chunk = self.chunk
        n_blocks = self.blocks_needed(p, max_new)

        cached: list[int] = []
        matched = 0
        if self.prefix is not None:
            cached, matched = self.prefix.lookup(prompt)
            if host is not None and any(self.pool.host_of(pg) != host
                                        for pg in cached):
                for pg in cached:  # wrong host: treat as a miss
                    self.pool.decref(pg)
                cached, matched = [], 0
        start = (min(matched, p - 1) // chunk) * chunk
        first_write_block = start // ps
        cow_blocks = list(range(first_write_block, len(cached)))
        need_new = n_blocks - len(cached) + len(cow_blocks)
        free = (self.pool.available if host is None
                else self.pool.free_in_host(host))
        if free < need_new and self.prefix is not None:
            self.prefix.evict(need_new - free, host=host)
            free = (self.pool.available if host is None
                    else self.pool.free_in_host(host))
        if free < need_new:
            for pg in cached:  # roll back lookup refs; stay queued
                self.pool.decref(pg)
            return None
        fresh = self.pool.alloc(need_new, host=host)
        blocks = list(cached)
        cow = []
        for blk in cow_blocks:
            dst = fresh.pop()
            cow.append((blocks[blk], dst))
            self.pool.decref(blocks[blk])
            blocks[blk] = dst
        blocks.extend(fresh)
        assert len(blocks) == n_blocks, (len(blocks), n_blocks)
        self.page_table[slot, :] = 0
        self.page_table[slot, :n_blocks] = blocks
        self._held[slot] = blocks
        return AdmitResult(start=start, matched=matched, cow=cow,
                           blocks=blocks)

    def slot_span(self, slot: int) -> int:
        """Writable logical positions of ``slot``'s mapped page chain
        (``held pages * page_size``).  The speculative engine caps each
        tick's draft depth by this: admission reserved exactly
        ``ceil((prompt + max_new) / page_size)`` pages, and a draft
        never extends past the token budget, so in-flight drafts always
        fit the reservation — this is the belt-and-braces bound that
        keeps an off-by-one from ever writing through an unheld
        page-table entry."""
        return len(self._held[slot]) * self.page_size

    def register_prefix(self, slot: int, prompt: np.ndarray):
        """After prefill: publish the slot's full prompt pages for reuse."""
        if self.prefix is not None:
            self.prefix.insert(np.asarray(prompt, np.int32),
                               self._held[slot])

    def free_slot(self, slot: int):
        for pg in self._held[slot]:
            self.pool.decref(pg)
        self._held[slot] = []
        self.page_table[slot, :] = 0

    # --------------------------------------------------------- preemption
    def detach_slot(self, slot: int) -> list[int]:
        """Preemption: transfer the slot's page chain to the caller's
        checkpoint and unmap the row.  Zero-copy — refcounts are
        unchanged (the checkpoint now owns the slot's hold, so the pages
        can be neither reallocated nor prefix-evicted), and the K/V bytes
        never move.  ``attach_slot`` is the inverse at resume."""
        pages = self._held[slot]
        self._held[slot] = []
        self.page_table[slot, :] = 0
        return pages

    def attach_slot(self, slot: int, pages: list[int]):
        """Resume a detached page chain into ``slot`` (any free slot —
        page indirection makes the chain slot-independent)."""
        assert not self._held[slot], f"slot {slot} already holds pages"
        assert len(pages) <= self.max_pages, (len(pages), self.max_pages)
        self._held[slot] = list(pages)
        self.page_table[slot, :] = 0
        self.page_table[slot, :len(pages)] = pages

    # ------------------------------------------------- cross-engine transfer
    def can_adopt(self, n: int) -> bool:
        """Could ``adopt_chain(n)`` succeed right now?  Evictable
        prefix-cache pages count — ``adopt_chain`` evicts them itself.
        Sharded: the chain must fit one host sub-pool (chains stay
        host-local), so the best host decides."""
        if n > self.max_pages:
            return False
        if self.num_hosts == 1:
            avail = self.pool.available
            if self.prefix is not None:
                avail += self.prefix.evictable()
            return n <= avail
        by_host = self.pool.free_by_host()
        return any(n <= by_host[h] + (0 if self.prefix is None else
                                      self.prefix.evictable(host=h))
                   for h in range(self.num_hosts))

    def adopt_chain(self, n: int) -> Optional[list[int]]:
        """Allocate ``n`` fresh pages in THIS pool to receive a page
        chain detached from *another* engine's pool — the destination
        half of a cross-engine handoff.  ``None`` = backpressure (the
        handoff stays queued).  The caller copies the K/V bytes across
        (``copy_cache_pages_across``) and then calls the source pool's
        ``release_chain`` on the old pages, keeping both pools
        refcount-balanced.  Sharded: the adopted chain lands whole on
        the emptiest host sub-pool (``PagePool.alloc(host=None)``)."""
        if n > self.max_pages:
            return None
        if self.num_hosts == 1:
            if self.pool.available < n and self.prefix is not None:
                self.prefix.evict(n - self.pool.available)
            if self.pool.available < n:
                return None
            return self.pool.alloc(n)
        by_host = self.pool.free_by_host()
        best = max(range(self.num_hosts), key=lambda h: (by_host[h], -h))
        if by_host[best] < n and self.prefix is not None:
            self.prefix.evict(n - by_host[best], host=best)
        if self.pool.free_in_host(best) < n:
            return None
        return self.pool.alloc(n, host=best)

    def release_chain(self, pages: list[int]) -> None:
        """Drop a detached chain's hold on THIS pool — the source half of
        a completed cross-engine transfer (or a discarded checkpoint).
        The inverse of the hold ``detach_slot`` handed the caller."""
        for pg in pages:
            self.pool.decref(pg)

    # ------------------------------------------------------------- stats
    def stats(self) -> dict:
        """Legacy stats dict, read back through the metrics registry
        (the ``kv_*`` function-backed gauges registered in
        ``bind_metrics``) — key set is schema-stable
        (tests/test_telemetry.py)."""
        v = self._registry.value
        lbl = {"replica": str(self._replica)}
        return {
            "page_size": int(v("kv_page_size", **lbl)),
            "capacity_pages": int(v("kv_pages_capacity", **lbl)),
            "in_use_pages": int(v("kv_pages_in_use", **lbl)),
            "prefix_entries": int(v("kv_prefix_entries", **lbl)),
            "prefix_hits": int(v("kv_prefix_hits", **lbl)),
            "prefix_misses": int(v("kv_prefix_misses", **lbl)),
        }
