"""Draft proposers for speculative decode (host side).

A drafter guesses up to ``k`` continuation tokens for a slot from its
token history alone; the engine then scores the whole guess in ONE
multi-token verify step (``steps.make_spec_serve_step``) and keeps the
longest confirmed prefix (``sampling.speculative_accept``).  Drafters are
pure host-side objects registered in ``DRAFTERS`` and resolved by
``get_drafter(name)`` — the same registry pattern as
``core/policies.py`` / ``runtime/scheduler.py``'s admission policies —
so a small-model drafter can slot in later without touching the engine:
the contract is only ``propose(context, k) -> up-to-k tokens``.

``ngram`` (the default) is the model-free **prompt/n-gram lookup**
drafter (prompt-lookup decoding): match the tail n-gram of the slot's
context (prompt + emitted tokens) against its own earlier history and
propose the tokens that followed the most recent earlier occurrence.
Free to compute, and strong exactly where speculation pays — structured
traces that restate their own context (code, templated chat, greedy
decode loops) — while degrading to zero proposals (never wrong output:
rejected drafts cost only the wasted verify columns) on incompressible
streams.
"""
from __future__ import annotations

import numpy as np

__all__ = ["DRAFTERS", "Drafter", "NgramDrafter", "get_drafter"]


class Drafter:
    """Proposes draft continuations from a slot's token history.

    ``lookback`` bounds how much history the engine hands ``propose``
    (0 = unlimited).  Long-running requests would otherwise pay
    O(len(history)) host work per tick — quadratic over a request's
    life — on the path that sits between every device step."""

    name = "base"
    lookback = 0

    def propose(self, context: np.ndarray, k: int) -> np.ndarray:
        """Up to ``k`` proposed continuation tokens (int32, possibly
        empty) for a slot whose history is ``context`` (prompt followed
        by every emitted token — the verified stream, never rejected
        drafts).  Must be a pure function of ``context``: the engine
        replays requests bitwise, so a drafter may not carry hidden
        state across calls."""
        raise NotImplementedError


class NgramDrafter(Drafter):
    """Prompt/n-gram lookup: propose the continuation of the most recent
    earlier occurrence of the context's tail n-gram.

    Tries tail lengths ``max_n .. min_n`` (longer matches first — more
    context agreement, higher acceptance); within a tail length the most
    recent earlier occurrence with a full k-token continuation wins
    (recency beats frequency on decode loops).  Proposes at most ``k``
    tokens and never invents one: every proposal is a token copied from
    the slot's own history.
    """

    name = "ngram"

    def __init__(self, max_n: int = 3, min_n: int = 1,
                 lookback: int = 512):
        assert 1 <= min_n <= max_n, (min_n, max_n)
        self.max_n = max_n
        self.min_n = min_n
        self.lookback = lookback  # most recent tokens searched (0 = all)

    def propose(self, context: np.ndarray, k: int) -> np.ndarray:
        ctx = np.asarray(context, np.int32)
        n_ctx = len(ctx)
        empty = np.zeros(0, np.int32)
        if k <= 0 or n_ctx < self.min_n + 1:
            return empty
        for n in range(min(self.max_n, n_ctx - 1), self.min_n - 1, -1):
            tail = ctx[n_ctx - n:]
            # windows[j] == ctx[j : j + n]; candidate starts j < n_ctx - n
            # (the tail itself is excluded — it has no continuation yet)
            windows = np.lib.stride_tricks.sliding_window_view(ctx, n)
            hits = np.flatnonzero(
                (windows[:n_ctx - n] == tail[None, :]).all(axis=1))
            if hits.size:
                # prefer the most recent occurrence with a full k-token
                # continuation (a match near the context end — e.g. a
                # period-1 decode loop — would otherwise truncate the
                # proposal to the leftover suffix); fall back to the
                # most recent occurrence with whatever follows it
                full = hits[hits + n + k <= n_ctx]
                j = int(full[-1]) if full.size else int(hits[-1])
                return ctx[j + n:j + n + k].copy()
        return empty


DRAFTERS = {
    "ngram": NgramDrafter,
}


def get_drafter(name, **kw) -> Drafter:
    if isinstance(name, Drafter):
        return name
    return DRAFTERS[name](**kw)
