"""Fault tolerance around the training loop: restart + elastic rescale.

The real-cluster flow (mirrored by core/scheduler.py's simulation):

1. A host dies -> the gang's collectives fail -> the job process exits.
2. Scylla re-places the job on the surviving hosts (possibly fewer chips /
   a different submesh shape) and relaunches the driver.
3. The driver restores the last checkpoint *against the new mesh's
   shardings* (checkpoints are sharding-agnostic — see checkpoint/) and
   continues from the last checkpointed step.

``run_with_failures`` reproduces that flow in-process for tests/examples:
``FailureInjector`` raises ``SimulatedHostFailure`` at chosen steps; each
restart may present a different mesh (elastic).  Straggler mitigation at
the runtime level = per-step wall-time watchdog feeding the scheduler
(``StepWatchdog``); the placement change itself is the scheduler's call.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.runtime.train import TrainConfig, Trainer


class SimulatedHostFailure(RuntimeError):
    pass


@dataclass
class FailureInjector:
    fail_at_steps: tuple = ()
    fired: set = field(default_factory=set)

    def __call__(self, step: int, metrics):
        if step in self.fail_at_steps and step not in self.fired:
            self.fired.add(step)
            raise SimulatedHostFailure(f"injected host failure at step {step}")


@dataclass
class StepWatchdog:
    """Flags straggling steps (gang runs at the slowest host's pace)."""

    threshold: float = 3.0
    window: int = 20
    times: list = field(default_factory=list)
    flagged: list = field(default_factory=list)
    _last: float = 0.0

    def start(self):
        self._last = time.monotonic()

    def __call__(self, step: int, metrics):
        now = time.monotonic()
        dt = now - self._last
        self._last = now
        self.times.append(dt)
        hist = self.times[-self.window:-1]
        if len(hist) >= 5:
            med = sorted(hist)[len(hist) // 2]
            if dt > self.threshold * med:
                self.flagged.append((step, dt, med))


def run_with_failures(make_trainer: Callable[[int], Trainer], *,
                      injector: FailureInjector,
                      max_restarts: int = 5) -> dict:
    """Run to completion across simulated failures.

    ``make_trainer(attempt)`` builds a fresh Trainer per attempt — the
    elastic path passes a different mesh/shardings per attempt.  State comes
    back from the checkpoint directory each time.
    """
    attempt = 0
    while True:
        trainer = make_trainer(attempt)
        try:
            out = trainer.run(on_step=injector)
            out["restarts"] = attempt
            return out
        except SimulatedHostFailure:
            attempt += 1
            if attempt > max_restarts:
                raise
