"""Fault tolerance around the training AND serving loops.

Training half (the original seed flow, mirrored by core/scheduler.py's
simulation):

1. A host dies -> the gang's collectives fail -> the job process exits.
2. Scylla re-places the job on the surviving hosts (possibly fewer chips /
   a different submesh shape) and relaunches the driver.
3. The driver restores the last checkpoint *against the new mesh's
   shardings* (checkpoints are sharding-agnostic — see checkpoint/) and
   continues from the last checkpointed step.

``run_with_failures`` reproduces that flow in-process for tests/examples:
``FailureInjector`` raises ``SimulatedHostFailure`` at chosen steps; each
restart may present a different mesh (elastic).  Straggler mitigation at
the runtime level = per-step wall-time watchdog feeding the scheduler
(``StepWatchdog``); the placement change itself is the scheduler's call.

Serving half (PR 6): ``ReplicaFaultInjector`` drives chaos into a
``runtime.cluster.ClusterRouter`` — replica kill/rejoin, straggler
stalls (feeding the router's per-replica ``StepWatchdog``), heartbeat
drops, and page-pool pressure — from a *schedule* of ``FaultEvent``s, so
every chaos run is reproducible: either an explicit schedule (the
``parse`` format the launcher's ``--fault-schedule`` takes) or one
generated deterministically from a seed (``seeded``).
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.runtime.train import TrainConfig, Trainer


class SimulatedHostFailure(RuntimeError):
    pass


@dataclass
class FailureInjector:
    fail_at_steps: tuple = ()
    fired: set = field(default_factory=set)

    def __call__(self, step: int, metrics):
        if step in self.fail_at_steps and step not in self.fired:
            self.fired.add(step)
            raise SimulatedHostFailure(f"injected host failure at step {step}")


@dataclass
class StepWatchdog:
    """Flags straggling steps (gang runs at the slowest host's pace)."""

    threshold: float = 3.0
    window: int = 20
    times: list = field(default_factory=list)
    flagged: list = field(default_factory=list)
    _last: float = 0.0

    def start(self):
        self._last = time.monotonic()

    def __call__(self, step: int, metrics):
        now = time.monotonic()
        dt = now - self._last
        self._last = now
        self.times.append(dt)
        hist = self.times[-self.window:-1]
        if len(hist) >= 5:
            med = sorted(hist)[len(hist) // 2]
            if dt > self.threshold * med:
                self.flagged.append((step, dt, med))


# ------------------------------------------------------------------ serving
#: ``FaultEvent.action`` values understood by ``ClusterRouter``:
#:   kill      — the replica's process dies: heartbeats stop, steps stop;
#:               the router detects it after ``miss_threshold`` beats
#:   rejoin    — a LOST/DOWN replica comes back with a fresh engine
#:   stall     — straggle: every step sleeps ``arg`` seconds for ``ticks``
#:               ticks (feeds the router's per-replica StepWatchdog)
#:   hbdrop    — drop ``ticks`` consecutive heartbeats while the engine
#:               keeps serving (partition: below the miss threshold the
#:               router must tolerate it; at/above, it fences the replica)
#:   pressure  — hold ``arg`` (fraction, 0-1] of the replica's free KV
#:               pages for ``ticks`` ticks (paged engines only)
#:   drain     — operator drain: no new placements; in-flight finishes
FAULT_ACTIONS = ("kill", "rejoin", "stall", "hbdrop", "pressure", "drain")


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled chaos action against replica ``replica`` at router
    tick ``tick``.  ``arg``/``ticks`` meaning depends on ``action`` (see
    ``FAULT_ACTIONS``)."""

    tick: int
    action: str
    replica: int
    arg: float = 0.0
    ticks: int = 1

    def __post_init__(self):
        if self.action not in FAULT_ACTIONS:
            raise ValueError(f"unknown fault action {self.action!r}; "
                             f"known: {FAULT_ACTIONS}")
        if self.tick < 0 or self.ticks < 1:
            raise ValueError(f"bad fault timing: tick={self.tick} "
                             f"ticks={self.ticks}")


class ReplicaFaultInjector:
    """Replays a fixed ``FaultEvent`` schedule into the router's ticks.

    The schedule is data, never randomness at fire time — the same
    injector instance (or two built from the same seed/spec) drives the
    identical chaos run, which is what lets the benchmarks compare a
    chaos run bitwise against its fault-free twin.
    """

    def __init__(self, events=()):
        self.events = sorted(events, key=lambda e: e.tick)
        self._next = 0

    def pop(self, tick: int) -> list[FaultEvent]:
        """Events due at (or before — catch-up) ``tick``, each once."""
        due = []
        while (self._next < len(self.events)
               and self.events[self._next].tick <= tick):
            due.append(self.events[self._next])
            self._next += 1
        return due

    def reset(self) -> None:
        self._next = 0

    @classmethod
    def parse(cls, spec: str) -> "ReplicaFaultInjector":
        """Build from the launcher's ``--fault-schedule`` string.

        Comma-separated ``TICK:ACTION:REPLICA[:ARG[:TICKS]]`` entries,
        e.g. ``"8:kill:1,40:rejoin:1"`` or ``"5:stall:0:0.02:10"``; or
        ``"seed=SEED[:REPLICAS[:HORIZON]]"`` for a seeded random
        schedule (see ``seeded``)."""
        spec = spec.strip()
        if spec.startswith("seed="):
            parts = spec[len("seed="):].split(":")
            seed = int(parts[0])
            n_replicas = int(parts[1]) if len(parts) > 1 else 3
            horizon = int(parts[2]) if len(parts) > 2 else 60
            return cls.seeded(seed, n_replicas=n_replicas, horizon=horizon)
        events = []
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            fields = part.split(":")
            if len(fields) < 3:
                raise ValueError(
                    f"fault entry {part!r}: expected "
                    f"TICK:ACTION:REPLICA[:ARG[:TICKS]]")
            events.append(FaultEvent(
                tick=int(fields[0]), action=fields[1],
                replica=int(fields[2]),
                arg=float(fields[3]) if len(fields) > 3 else 0.0,
                ticks=int(fields[4]) if len(fields) > 4 else 1))
        return cls(events)

    @classmethod
    def seeded(cls, seed: int, *, n_replicas: int, horizon: int = 60,
               n_faults: int = 2, rejoin_after: int = 12,
               kinds=("kill", "stall", "hbdrop")) -> "ReplicaFaultInjector":
        """Deterministic schedule from a seed: ``n_faults`` events drawn
        over ``[1, horizon)``, each kill paired with a rejoin
        ``rejoin_after`` ticks later.  Replica 0 is never killed so at
        least one replica always survives to absorb recoveries."""
        import numpy as np

        rng = np.random.default_rng(seed)
        events = []
        for _ in range(n_faults):
            kind = kinds[int(rng.integers(0, len(kinds)))]
            t = int(rng.integers(1, max(horizon, 2)))
            if kind == "kill":
                rid = int(rng.integers(1, n_replicas)) if n_replicas > 1 \
                    else 0
                events.append(FaultEvent(t, "kill", rid))
                events.append(FaultEvent(t + rejoin_after, "rejoin", rid))
            elif kind == "stall":
                rid = int(rng.integers(0, n_replicas))
                events.append(FaultEvent(t, "stall", rid,
                                         arg=0.02, ticks=8))
            elif kind == "hbdrop":
                rid = int(rng.integers(0, n_replicas))
                events.append(FaultEvent(t, "hbdrop", rid, ticks=2))
            elif kind == "pressure":
                rid = int(rng.integers(0, n_replicas))
                events.append(FaultEvent(t, "pressure", rid,
                                         arg=0.5, ticks=6))
        return cls(events)


def run_with_failures(make_trainer: Callable[[int], Trainer], *,
                      injector: FailureInjector,
                      max_restarts: int = 5) -> dict:
    """Run to completion across simulated failures.

    ``make_trainer(attempt)`` builds a fresh Trainer per attempt — the
    elastic path passes a different mesh/shardings per attempt.  State comes
    back from the checkpoint directory each time.
    """
    attempt = 0
    while True:
        trainer = make_trainer(attempt)
        try:
            out = trainer.run(on_step=injector)
            out["restarts"] = attempt
            return out
        except SimulatedHostFailure:
            attempt += 1
            if attempt > max_restarts:
                raise
