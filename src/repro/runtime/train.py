"""Training loop: grad-accum microbatching, periodic checkpointing, metrics.

``Trainer`` is the per-job driver that Scylla's Task-0 analogue launches
after placement: it builds (or receives) the job's mesh, shards the state,
and runs lockstep SPMD steps.  Fault tolerance lives in
``runtime/fault.py`` (restart/elastic-rescale around this loop).
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, prune_checkpoints, restore, save_checkpoint
from repro.data import SyntheticDataset
from repro.models import LM, RuntimeKnobs
from repro.optim import AdamWConfig
from repro.runtime.steps import init_train_state, make_train_step


@dataclass
class TrainConfig:
    steps: int = 100
    grad_accum: int = 1
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    keep_checkpoints: int = 3
    log_every: int = 10
    seed: int = 0
    opt: AdamWConfig = field(default_factory=AdamWConfig)


class Trainer:
    def __init__(self, model: LM, dataset, tcfg: TrainConfig, *,
                 mesh=None, state_shardings=None, batch_shardings=None):
        self.model = model
        self.dataset = dataset
        self.tcfg = tcfg
        self.mesh = mesh
        self.state_shardings = state_shardings
        self.step_fn = jax.jit(
            make_train_step(model, tcfg.opt, tcfg.grad_accum),
            in_shardings=((state_shardings, batch_shardings)
                          if state_shardings is not None else None),
            out_shardings=((state_shardings, None)
                           if state_shardings is not None else None),
            donate_argnums=(0,))
        self.state = None
        self.step = 0
        self.history: list[dict] = []

    # ------------------------------------------------------------- state
    def init_state(self):
        self.state = init_train_state(self.model, jax.random.PRNGKey(
            self.tcfg.seed))
        if self.state_shardings is not None:
            self.state = jax.device_put(self.state, self.state_shardings)
        self.step = 0
        return self.state

    def maybe_restore(self) -> bool:
        d = self.tcfg.checkpoint_dir
        if not d or latest_step(d) is None:
            return False
        target = jax.eval_shape(lambda: init_train_state(
            self.model, jax.random.PRNGKey(self.tcfg.seed)))
        self.state, meta = restore(d, target, self.state_shardings)
        self.step = meta["step"]
        return True

    def save(self):
        if not self.tcfg.checkpoint_dir:
            return
        save_checkpoint(self.tcfg.checkpoint_dir, self.step, self.state,
                        extra={"arch": self.model.cfg.name})
        prune_checkpoints(self.tcfg.checkpoint_dir,
                          self.tcfg.keep_checkpoints)

    # -------------------------------------------------------------- run
    def run(self, *, until: Optional[int] = None,
            on_step: Optional[Callable] = None) -> dict:
        if self.state is None and not self.maybe_restore():
            self.init_state()
        until = min(until or self.tcfg.steps, self.tcfg.steps)
        ctx = self.mesh if self.mesh is not None else _nullctx()
        with ctx:
            while self.step < until:
                batch = self.dataset.batch(self.step)
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                self.state, metrics = self.step_fn(self.state, batch)
                self.step += 1
                if on_step is not None:
                    on_step(self.step, metrics)
                if self.step % self.tcfg.log_every == 0 or self.step == until:
                    m = {k: float(v) for k, v in metrics.items()}
                    m["step"] = self.step
                    self.history.append(m)
                if (self.tcfg.checkpoint_every
                        and self.step % self.tcfg.checkpoint_every == 0):
                    self.save()
        return {"step": self.step, "history": self.history}


class _nullctx:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False
