"""Per-request sampling: temperature / top-k / top-p with per-slot PRNG keys.

The serving engine decodes a fixed slot batch with static shapes, so the
sampling parameters ride along as *per-slot arrays* — ``temp[B]``,
``top_k[B]``, ``top_p[B]``, ``keys[B, 2]`` — and one compiled step serves
every mix of greedy and sampled requests.  Determinism is per request: a
request's key is derived from its seed once at admission and ``fold_in``'d
with the decode position each step, so replaying the same request (same
seed, same prompt) reproduces its tokens regardless of which slot it lands
in or what its neighbors are doing.

``temperature <= 0`` is the greedy contract: the returned token is the
plain fp32 ``argmax`` of the raw logits — bitwise identical to the
pre-sampling greedy path (``tests/test_serving_api.py`` holds the engine
to this across dense/paged caches).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling controls (the request-API half of ServeConfig).

    * ``temperature`` — 0 (default) decodes greedily; > 0 samples from the
      scaled distribution.
    * ``top_k`` — keep only the k highest-probability tokens (0 = off).
    * ``top_p`` — nucleus sampling: keep the smallest set of tokens whose
      cumulative probability reaches ``top_p`` (1.0 = off).
    * ``seed`` — per-request PRNG seed; ``None`` derives one from the
      request id so replays are deterministic by default.
    * ``stop`` — stop sequences: token ids (single-token stops, the
      ``eos_id`` generalization) or sequences of token ids (multi-token
      stops).  Generation finishes the step the output *ends with* any of
      them; matched tokens stay in the output.
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: Optional[int] = None
    stop: Tuple = ()

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0: {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0: {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1]: {self.top_p}")

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0

    @functools.cached_property
    def stop_sequences(self) -> Tuple[Tuple[int, ...], ...]:
        """``stop`` normalized to tuples of ints (bare ids become 1-grams).
        Cached — ``matches_stop`` consults this after every token (the
        cache writes straight into ``__dict__``, bypassing frozen)."""
        out = []
        for s in self.stop:
            if isinstance(s, (int, np.integer)):
                out.append((int(s),))
            else:
                seq = tuple(int(t) for t in s)
                if seq:
                    out.append(seq)
        return tuple(out)

    def key_data(self, req_id: int) -> np.ndarray:
        """Raw (2,) uint32 PRNG key for this request (seed or req_id)."""
        seed = self.seed if self.seed is not None else req_id
        return np.asarray(jax.random.PRNGKey(seed % (2 ** 31)), np.uint32)


def matches_stop(output: Sequence[int], params: SamplingParams,
                 eos_id: int = -1) -> Optional[str]:
    """Host-side stop check: the finish reason the tail of ``output``
    triggers ("eos" / "stop"), or None."""
    n = len(output)
    if not n:
        return None
    if eos_id >= 0 and output[-1] == eos_id:
        return "eos"
    for seq in params.stop_sequences:
        k = len(seq)
        if k <= n and tuple(output[n - k:]) == seq:
            return "stop"
    return None


def _topk_topp_mask(scaled, top_k, top_p):
    """Additive mask (0 keep / -inf drop) for per-row top-k + top-p.

    Both filters are applied in the sorted domain off one argsort, then
    scattered back through the inverse permutation; the best token is
    always kept so the row never masks to nothing.
    """
    v = scaled.shape[-1]
    sort_idx = jnp.argsort(-scaled, axis=-1)
    srt = jnp.take_along_axis(scaled, sort_idx, axis=-1)
    probs = jax.nn.softmax(srt, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    ranks = jnp.broadcast_to(jnp.arange(v)[None, :], srt.shape)
    keep = jnp.where(top_k[:, None] > 0, ranks < top_k[:, None], True)
    # exclusive cumulative mass below top_p keeps the crossing token too
    keep = keep & ((cum - probs) < top_p[:, None])
    keep = keep.at[:, 0].set(True)
    mask_sorted = jnp.where(keep, 0.0, -jnp.inf).astype(scaled.dtype)
    inv = jnp.argsort(sort_idx, axis=-1)
    return jnp.take_along_axis(mask_sorted, inv, axis=-1)


def sample_tokens(logits, pos, temp, top_k, top_p, keys):
    """Sample (or greedily pick) one token per row, static shapes.

    logits (B, V) fp32; pos (B,) int32 (folded into each row's key so every
    step draws fresh randomness deterministically); temp (B,) fp32;
    top_k (B,) int32 (0 = off); top_p (B,) fp32 (1 = off); keys (B, 2)
    uint32 raw PRNG key data.  Rows with ``temp <= 0`` return the raw-logit
    argmax — bitwise the greedy path, untouched by the sampling math.
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    safe_t = jnp.maximum(temp, 1e-6)[:, None]
    scaled = (logits / safe_t).astype(jnp.float32)
    masked = scaled + _topk_topp_mask(scaled, top_k, top_p)

    def draw(key, p, row):
        return jax.random.categorical(
            jax.random.fold_in(key, jnp.maximum(p, 0)), row)

    sampled = jax.vmap(draw)(keys, pos, masked).astype(jnp.int32)
    return jnp.where(temp > 0, sampled, greedy)


def sample_tokens_multi(logits, pos, temp, top_k, top_p, keys):
    """Per-row target draws for a speculative verify block, static shapes.

    logits (B, T, V) fp32 — row ``t`` of slot ``b`` is the target
    model's distribution at absolute position ``pos[b] + t`` (given the
    draft prefix); pos (B,) int32; temp/top_k/top_p (B,) and keys (B, 2)
    are the *per-slot* arrays, shared by every row of a slot.  Returns
    (B, T) int32.

    Each row folds its own absolute position into the slot's key —
    exactly the fold the non-speculative step would have used when it
    reached that position — so an accepted draw is **bitwise the token
    the baseline engine would have sampled there** (and rows with
    ``temp <= 0`` are the bitwise-greedy argmax).  That makes the
    accept-on-equality rule of ``speculative_accept`` an exact rejection
    sampler: every emitted token is a faithful draw from the target
    distribution conditioned on the (verified) prefix.
    """
    b, t, v = logits.shape
    pos = jnp.asarray(pos, jnp.int32).reshape(-1)
    pos_rows = (pos[:, None] + jnp.arange(t)[None, :]).reshape(-1)
    rep = lambda x: jnp.repeat(x, t, axis=0)
    out = sample_tokens(logits.reshape(b * t, v), pos_rows, rep(temp),
                        rep(top_k), rep(top_p), rep(keys))
    return out.reshape(b, t)


def speculative_accept(draft, target) -> int:
    """Host-side acceptance rule: the number of draft tokens confirmed by
    the verify pass.

    ``draft`` is the k <= T-1 proposed tokens; ``target`` is the (T,)
    verify-step output where ``target[t]`` is the token the target model
    emits *after* feed + draft[:t].  Draft token ``t`` survives iff every
    earlier draft survived and ``draft[t] == target[t]`` — the emitted
    tokens are then ``target[:m + 1]`` (the m accepted drafts, which
    equal the target's own choices, plus the free correction/bonus
    token), so the output stream is exactly what non-speculative decode
    would have produced token by token.  Greedy verify makes this
    deterministic lockstep; sampled verify compares against the
    position-keyed target draw, which preserves the target distribution
    exactly (see ``sample_tokens_multi``).
    """
    m = 0
    for d, t in zip(draft, target):
        if int(d) != int(t):
            break
        m += 1
    return m
