"""Fault-tolerant multi-replica serving: an offer-based cluster router.

The serving mirror of the seed's Mesos half (``core/cluster.py`` +
``core/scheduler.py``): each ``ServeEngine`` replica is a Scylla
framework task, and the ``ClusterRouter`` is the framework scheduler in
front of the pool.  Every router tick:

1. **Offers** — each placeable replica advertises ``ReplicaOffer(free
   slots, free KV pages, queue depth)`` (``ServeEngine.offer()``, the
   ``Cluster.advertise`` analogue).
2. **Health** — replicas heartbeat; ``miss_threshold`` consecutive
   misses mark a replica ``LOST`` (``ScyllaScheduler.on_host_failure``'s
   serving twin).  A LOST replica is *fenced* — its engine is discarded
   so a zombie (e.g. a partitioned replica that kept stepping) can never
   emit into a stream the router has already re-placed.
3. **Recovery** — every in-flight request on a lost replica re-enters
   the router queue at the FRONT and resumes on a surviving replica by
   **deterministic replay**: the prompt is extended with the tokens the
   client already received and re-prefilled, and PR 3's position-folded
   sampling makes the continuation bitwise-identical to the uninterrupted
   stream (greedy and seeded-sampled alike — gated in
   ``tests/test_cluster_serve.py``).  Each recovery consumes one unit of
   the request's ``retry_budget`` and backs off exponentially
   (``backoff_ticks * 2**(retries-1)``) before re-placement.
4. **Placement** — queued requests are placed through a registered
   ``RouterPolicy`` (``pack``/``spread``, mirroring
   ``core/policies.get_policy``): ``pack`` fills the busiest fitting
   replica (consolidate; keeps spare replicas drainable), ``spread``
   targets the emptiest (load-balance; the throughput default).
5. **Stepping** — each live replica runs one engine tick under a
   ``runtime.fault.StepWatchdog``; a flagged straggler is routed around
   (no new placements) until ``slow_cooldown`` ticks pass without a new
   flag.

Brown-out degradation: while any replica is LOST or flagged slow, the
pool is degraded and the router switches placement to strict weighted
order — requests from higher-``tenant_weights`` tiers (gold) place
first, and a lower tier only places once every higher-tier request has
(head-of-line).  Free-tier load is thereby shed exactly while capacity
is reduced, protecting the gold SLO; nothing is dropped — shed requests
simply wait for capacity to recover or the gold backlog to drain.

Chaos is injected through ``runtime.fault.ReplicaFaultInjector`` — a
seeded, reproducible schedule of kill / rejoin / stall / heartbeat-drop
/ page-pressure / drain events — so every chaos run can be compared
bitwise against its fault-free twin (``benchmarks/cluster_serve.py``).
"""
from __future__ import annotations

import enum
import time
from dataclasses import dataclass, field
from typing import Callable, Iterator, Optional

import numpy as np

from repro.runtime.fault import ReplicaFaultInjector, StepWatchdog
from repro.runtime.serve import Request, RequestState, ServeStalled
from repro.runtime.telemetry import ROUTER_PID, Telemetry

__all__ = ["ClusterRouter", "ReplicaHandle", "ReplicaOffer", "ReplicaState",
           "RouterHandle", "RouterPolicy", "ROUTER_POLICIES",
           "get_router_policy", "reset_for_replay"]


class ReplicaState(enum.Enum):
    UP = "up"            # serving; offers flow
    DRAINING = "draining"  # no new placements; in-flight finishes
    LOST = "lost"        # failed the heartbeat threshold; fenced
    DOWN = "down"        # drained out (or never joined); awaiting rejoin


@dataclass(frozen=True)
class ReplicaOffer:
    """One replica's advertised free resources for this router tick."""

    replica: int
    free_slots: int
    free_pages: Optional[int]  # None: dense cache (slots only)
    page_size: Optional[int]
    queue_depth: int
    # sharded paged replicas (ServeConfig.mesh_shape with > 1 data host)
    # advertise the per-host sub-pool split behind ``free_pages``; None
    # for dense or unsharded replicas.  Routing policies key on the
    # aggregate, so sharded and unsharded replicas mix in one pool.
    free_pages_by_host: Optional[list] = None


# ---------------------------------------------------------------- policies
class RouterPolicy:
    """Chooses which offering replica a queued request is placed on
    (registered in ``ROUTER_POLICIES``, mirroring
    ``core/policies.POLICIES``)."""

    name = "base"

    def select(self, offers: list) -> ReplicaOffer:
        """Pick from ``offers`` (every entry already fits the request)."""
        raise NotImplementedError


class PackRouterPolicy(RouterPolicy):
    """Fewest free slots first: consolidate load onto already-busy
    replicas so spare ones stay empty (cheap to drain, instant headroom
    for recovery bursts) — the serving analogue of ``minhost``."""

    name = "pack"

    def select(self, offers):
        return min(offers, key=lambda o: (o.free_slots, o.queue_depth,
                                          o.replica))


class SpreadRouterPolicy(RouterPolicy):
    """Most free slots first (shallowest backlog on ties): classic load
    balancing — keeps per-replica batch pressure even, the throughput
    default."""

    name = "spread"

    def select(self, offers):
        return min(offers, key=lambda o: (-o.free_slots, o.queue_depth,
                                          o.replica))


ROUTER_POLICIES = {
    "pack": PackRouterPolicy,
    "spread": SpreadRouterPolicy,
}


def get_router_policy(name) -> RouterPolicy:
    if isinstance(name, RouterPolicy):
        return name
    return ROUTER_POLICIES[name]()


# ----------------------------------------------------------------- replay
def reset_for_replay(req: Request) -> Request:
    """Rewind a request recovered from a dead replica into a submittable
    replay: the prompt absorbs every token the client already received
    (``output`` keeps them, so ``max_new_tokens`` accounting and stop
    sequences spanning the recovery boundary stay exact), and every
    engine-private field is cleared — in particular ``_preempted`` /
    ``_ckpt_pages``, which would otherwise point a fresh engine at the
    dead engine's page pool.

    Re-prefilling ``prompt + emitted`` continues the stream bitwise: the
    prefill samples at absolute position ``len(prompt') - 1`` with the
    request's own key — exactly the fold the lost replica's next decode
    step would have used.
    """
    emitted = np.asarray(req.output, np.int32)
    if emitted.size:
        req.prompt = np.concatenate(
            [np.asarray(req.prompt, np.int32), emitted])
    req.done = False
    req.state = RequestState.QUEUED
    req.finish_reason = None
    req._feed = None
    req._ckpt = None
    req._ckpt_pages = None
    req._preempted = False
    req._drf_charged = None
    req._handoff_kv = 0
    return req


# ---------------------------------------------------------------- replicas
class ReplicaHandle:
    """Router-side view of one engine replica: lifecycle state, health
    counters, the straggler watchdog, and the live fault-injection
    toggles the ``ReplicaFaultInjector`` flips."""

    def __init__(self, rid: int, make_engine: Callable[[int], object],
                 telemetry: Optional[Telemetry] = None,
                 start_down: bool = False):
        self.rid = rid
        self._make_engine = make_engine
        self.tm = telemetry
        if start_down:
            # a cold spare: no engine until an autoscaler (or operator)
            # rejoins it — costs a handle, not a model instance
            self.engine = None
            self.state = ReplicaState.DOWN
        else:
            self.engine = make_engine(rid)
            self._bind_engine()
            self.state = ReplicaState.UP
        self.misses = 0
        self.slow = False
        self.slow_until = -1
        self.watchdog = StepWatchdog()
        # fault-injection state
        self.killed = False
        self.stall_s = 0.0
        self.stall_until = -1
        self.hbdrop_until = -1
        self._pressure: list = []  # (release_tick, held_pages)
        # telemetry
        self.placements = 0
        self.steps = 0

    def _bind_engine(self) -> None:
        """Rebind the (possibly fresh) engine onto the router's shared
        telemetry sink: its series carry ``replica=rid`` labels, its
        trace spans land on pid ``rid``.  Rejoin reuses the same labels
        — the registry children are overwritten in place."""
        if self.tm is not None and hasattr(self.engine, "bind_telemetry"):
            self.engine.bind_telemetry(self.tm, replica=self.rid)

    # ------------------------------------------------------------ health
    def heartbeat(self, tick: int) -> bool:
        """Did this replica's beat arrive this tick?"""
        return not self.killed and tick > self.hbdrop_until

    def fence(self) -> None:
        """Discard the engine: a fenced replica can never write another
        token into a stream the router re-owns (zombie isolation)."""
        self.engine = None
        self.killed = True

    def rejoin(self, tick: int) -> None:
        """Fresh engine, clean health state (prefix cache and KV start
        cold — recovery correctness never depends on rejoined state)."""
        self.engine = self._make_engine(self.rid)
        self._bind_engine()
        self.state = ReplicaState.UP
        self.killed = False
        self.misses = 0
        self.slow = False
        self.slow_until = -1
        self.stall_s = 0.0
        self.stall_until = -1
        self.hbdrop_until = -1
        self._pressure = []
        self.watchdog = StepWatchdog()

    # ------------------------------------------------------------ offers
    def placeable(self, tick: int) -> bool:
        return (self.state is ReplicaState.UP and not self.killed
                and not self.slow and self.engine is not None)

    def offer(self) -> Optional[ReplicaOffer]:
        if self.engine is None:
            return None
        raw = self.engine.offer()
        return ReplicaOffer(replica=self.rid, **raw)

    def can_accept(self, req: Request) -> bool:
        return self.engine is not None and self.engine.can_accept(req)

    # ---------------------------------------------------------- stepping
    def step(self, tick: int) -> int:
        """One engine tick under the watchdog; returns tokens emitted.
        A scheduled stall sleeps first — the watchdog sees the inflated
        wall time exactly as it would a genuinely straggling host."""
        if self.engine is None:
            return 0
        if tick <= self.stall_until and self.stall_s > 0:
            time.sleep(self.stall_s)
        flagged_before = len(self.watchdog.flagged)
        self.watchdog.start()
        emitted = self.engine.step()
        self.watchdog(tick, None)
        self.steps += 1
        if len(self.watchdog.flagged) > flagged_before:
            self.slow = True
        return emitted

    # ----------------------------------------------------- page pressure
    def apply_pressure(self, tick: int, fraction: float, ticks: int):
        eng = self.engine
        if eng is None or eng.kv is None:
            return
        n = int(eng.kv.pool.available * min(max(fraction, 0.0), 1.0))
        if n:
            self._pressure.append((tick + ticks, eng.kv.pool.alloc(n)))

    def release_pressure(self, tick: int):
        keep = []
        for release_tick, pages in self._pressure:
            if tick >= release_tick and self.engine is not None:
                for pg in pages:
                    self.engine.kv.pool.decref(pg)
            else:
                keep.append((release_tick, pages))
        self._pressure = keep


# ------------------------------------------------------------------ router
@dataclass
class _RouterRequest:
    """Router-side bookkeeping for one submitted request."""

    req: Request
    seq: int                      # arrival order (FIFO key)
    t_submit: float               # router wall-clock submit stamp
    retries: int = 0              # recoveries consumed so far
    not_before: int = 0           # backoff: earliest placement tick
    replica: Optional[int] = None  # where it currently runs
    history: list = field(default_factory=list)  # replica ids tried


class RouterHandle:
    """Caller-facing view of a router-submitted request (the cluster
    twin of ``runtime.serve.RequestHandle``): ``tokens()`` streams the
    output, driving router ticks while the next token is pending."""

    def __init__(self, rr: _RouterRequest, router: "ClusterRouter"):
        self._rr = rr
        self.req = rr.req
        self._router = router

    @property
    def done(self) -> bool:
        return self.req.done

    @property
    def finish_reason(self) -> Optional[str]:
        return self.req.finish_reason

    @property
    def output(self) -> list:
        return list(self.req.output)

    @property
    def retries(self) -> int:
        return self._rr.retries

    def tokens(self, max_ticks: int = 100_000) -> Iterator[int]:
        i = stalled = 0
        while True:
            while i < len(self.req.output):
                stalled = 0
                yield self.req.output[i]
                i += 1
            if self.req.done:
                return
            self._router.step()
            stalled += 1
            if stalled > max_ticks:
                raise ServeStalled(
                    f"request {self.req.req_id} produced no token in "
                    f"{max_ticks} router ticks "
                    f"(state={self.req.state.value})")

    def result(self, max_ticks: int = 100_000) -> Request:
        for _ in self.tokens(max_ticks=max_ticks):
            pass
        return self.req

    def metrics(self) -> dict:
        """TTFT against the ROUTER submit stamp (engine restamps
        ``t_submit`` on replay; the router's is the client's)."""
        out = {"retries": self._rr.retries}
        if self.req.t_first is not None:
            out["ttft_s"] = self.req.t_first - self._rr.t_submit
        return out


class ClusterRouter:
    """Offer-based router over ``n_replicas`` engine replicas.

    ``make_engine(rid)`` builds one replica's ``ServeEngine`` (replicas
    over the same model share compiled steps through the
    ``runtime.steps`` module LRU, so N replicas cost one compile).  See
    the module docstring for the tick protocol; knobs:

    * ``policy``          — ``ROUTER_POLICIES`` name (or instance).
    * ``miss_threshold``  — consecutive heartbeat misses before LOST.
    * ``retry_budget``    — recoveries per request before it is failed
      (``finish_reason="failed"``; never silently dropped).
    * ``backoff_ticks``   — base of the per-request exponential backoff
      between recovery and re-placement.
    * ``tenant_weights``  — SLO tiers for brown-out shedding (and passed
      by callers to each engine's weighted-DRF scheduler).
    * ``injector``        — optional ``ReplicaFaultInjector`` schedule.
    * ``slow_cooldown``   — flag-free ticks before a slow replica
      re-enters the placement set.
    """

    def __init__(self, make_engine: Callable[[int], object],
                 n_replicas: int, *, policy="spread",
                 miss_threshold: int = 3, retry_budget: int = 3,
                 backoff_ticks: int = 2, tenant_weights: Optional[dict] = None,
                 injector: Optional[ReplicaFaultInjector] = None,
                 slow_cooldown: int = 20,
                 telemetry: Optional[Telemetry] = None,
                 start_down=()):
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1: {n_replicas}")
        if miss_threshold < 1:
            raise ValueError(f"miss_threshold must be >= 1: "
                             f"{miss_threshold}")
        self.policy = get_router_policy(policy)
        self.miss_threshold = miss_threshold
        self.retry_budget = retry_budget
        self.backoff_ticks = backoff_ticks
        self.tenant_weights = dict(tenant_weights or {})
        self.injector = injector
        self.slow_cooldown = slow_cooldown
        self.tm = telemetry if telemetry is not None else Telemetry()
        # ``start_down`` rids begin as cold spares (DOWN, no engine) an
        # autoscaler can rejoin later without paying for them up front
        self.replicas = [ReplicaHandle(i, make_engine, telemetry=self.tm,
                                       start_down=(i in set(start_down)))
                         for i in range(n_replicas)]
        self.tick_count = 0
        self.queue: list[_RouterRequest] = []
        self.placed: dict[int, list[_RouterRequest]] = {
            r.rid: [] for r in self.replicas}
        self.finished: list[_RouterRequest] = []
        self._seq = 0
        self._handles: list[RouterHandle] = []
        # counters stay plain attributes (hot, and tests poke them);
        # the registry reads them live through function-backed gauges
        # and stats() reads BACK through the registry
        self.recoveries = 0        # requests recovered off lost replicas
        self.replicas_lost = 0
        self.failed = 0            # retry budget exhausted
        self.brownout_ticks = 0
        self._brownout_prev = False
        reg = self.tm.registry
        for name, help, fn in (
                ("cluster_ticks", "router ticks stepped",
                 lambda: self.tick_count),
                ("cluster_recoveries", "requests recovered off lost "
                 "replicas by deterministic replay",
                 lambda: self.recoveries),
                ("cluster_replicas_lost", "replicas fenced as LOST",
                 lambda: self.replicas_lost),
                ("cluster_failed", "requests failed on retry-budget "
                 "exhaustion", lambda: self.failed),
                ("cluster_brownout_ticks", "ticks spent degraded "
                 "(brown-out shedding active)",
                 lambda: self.brownout_ticks),
                ("cluster_queue_depth", "router queue backlog",
                 lambda: len(self.queue))):
            reg.gauge(name, help).labels().set_function(fn)
        g_pl = reg.gauge("cluster_replica_placements",
                         "requests placed on this replica", ("replica",))
        g_st = reg.gauge("cluster_replica_steps",
                         "engine ticks this replica stepped", ("replica",))
        for rh in self.replicas:
            g_pl.labels(replica=str(rh.rid)).set_function(
                lambda h=rh: h.placements)
            g_st.labels(replica=str(rh.rid)).set_function(
                lambda h=rh: h.steps)
        if self.tm.trace.enabled:
            self.tm.trace.set_process_name(ROUTER_PID, "router")

    # ------------------------------------------------------------- submit
    def submit(self, req: Request) -> RouterHandle:
        rr = _RouterRequest(req=req, seq=self._seq,
                            t_submit=time.perf_counter())
        self._seq += 1
        self.queue.append(rr)
        h = RouterHandle(rr, self)
        self._handles.append(h)
        return h

    # ------------------------------------------------------------- health
    def _weight(self, tenant: str) -> float:
        return float(self.tenant_weights.get(tenant, 1.0))

    def degraded(self) -> bool:
        """Capacity below nominal: any replica LOST, undetected-dead, or
        flagged slow.  (Operator drains are intended capacity changes
        and do not trigger brown-out shedding.)"""
        return any(r.state is ReplicaState.LOST or r.killed or r.slow
                   for r in self.replicas
                   if r.state is not ReplicaState.DOWN)

    def _recover_rr(self, rr: _RouterRequest, lost_rid: int) -> bool:
        """Recover one request stranded by a lost replica: consume a
        retry, fail it on budget exhaustion, otherwise rewind it for
        deterministic replay and requeue at the FRONT with exponential
        backoff.  Returns True if the request was requeued."""
        tr = self.tm.trace
        rr.retries += 1
        rr.replica = None
        if rr.retries > self.retry_budget:
            rr.req.done = True
            rr.req.state = RequestState.FINISHED
            rr.req.finish_reason = "failed"
            rr.req.t_finish = time.perf_counter()
            self.failed += 1
            self.finished.append(rr)
            if tr.enabled:
                tr.instant(ROUTER_PID, "request_failed",
                           tid=rr.req.req_id, retries=rr.retries)
            return False
        reset_for_replay(rr.req)
        rr.not_before = (self.tick_count
                         + self.backoff_ticks * 2 ** (rr.retries - 1))
        self.queue.insert(0, rr)
        self.recoveries += 1
        if tr.enabled:
            # the REPLAY span covers backoff-to-re-placement; it
            # closes in _place when the request lands again
            tr.begin(ROUTER_PID, rr.req.req_id, "REPLAY",
                     lost_replica=lost_rid, retry=rr.retries,
                     not_before=rr.not_before)
        return True

    def _flight_extra(self) -> dict:
        """Extra context merged into every fence's flight dump
        (subclasses add in-transit state — e.g. the handoff queue)."""
        return {}

    def _sweep_lost(self, rh: ReplicaHandle) -> list:
        """Collect router-held requests (outside ``placed``) stranded by
        the loss of ``rh`` — DisaggRouter returns in-transit handoffs
        whose source died.  Each return is recovered like a placed
        victim."""
        return []

    def _mark_lost(self, rh: ReplicaHandle) -> None:
        rh.state = ReplicaState.LOST
        rh.fence()
        self.replicas_lost += 1
        tr = self.tm.trace
        # the fenced replica can never emit again: close every span it
        # had open (in-flight requests mid-PREFILL/DECODE) so chaos
        # leaves no orphans, then record the fence itself
        tr.end_all(rh.rid, fenced=True)
        if tr.enabled:
            tr.instant(ROUTER_PID, "replica_lost", replica=rh.rid,
                       tick=self.tick_count,
                       in_flight=len(self.placed[rh.rid]))
        failed_before = self.failed
        recovered_before = self.recoveries
        # snapshot in-transit state BEFORE the sweep removes dead-source
        # entries: the post-mortem must show what was mid-flight at the
        # instant of the fence
        extra = self._flight_extra()
        # recover every in-flight request: FRONT of the queue, newest
        # last, so recovered work resumes before fresh arrivals place
        victims = self.placed[rh.rid]
        self.placed[rh.rid] = []
        stranded = self._sweep_lost(rh)
        for rr in reversed(victims + stranded):
            if rr.req.done:
                self.finished.append(rr)
                continue
            self._recover_rr(rr, rh.rid)
        # every fence ships its own post-mortem (covers retry
        # exhaustion too — failures happen only here)
        self.tm.dump_flight(
            f"fence-replica{rh.rid}",
            extra={"tick": self.tick_count,
                   "recovered": self.recoveries - recovered_before,
                   "failed": self.failed - failed_before, **extra})

    def _heartbeats(self) -> None:
        for rh in self.replicas:
            if rh.state not in (ReplicaState.UP, ReplicaState.DRAINING):
                continue
            if rh.heartbeat(self.tick_count):
                rh.misses = 0
            else:
                rh.misses += 1
                if self.tm.trace.enabled:
                    self.tm.trace.instant(ROUTER_PID, "hb_miss",
                                          replica=rh.rid,
                                          misses=rh.misses)
                if rh.misses >= self.miss_threshold:
                    self._mark_lost(rh)

    # ---------------------------------------------------------- lifecycle
    def drain(self, rid: int) -> None:
        """Stop placing on ``rid``; it leaves the pool once in-flight
        work finishes (``DOWN``)."""
        rh = self.replicas[rid]
        if rh.state is ReplicaState.UP:
            rh.state = ReplicaState.DRAINING

    def rejoin(self, rid: int) -> None:
        rh = self.replicas[rid]
        if rh.state in (ReplicaState.LOST, ReplicaState.DOWN):
            rh.rejoin(self.tick_count)
        elif rh.state is ReplicaState.DRAINING:
            rh.state = ReplicaState.UP

    # ------------------------------------------------------------- faults
    def _apply_event(self, ev) -> None:
        rh = self.replicas[ev.replica]
        if ev.action == "kill":
            rh.killed = True  # beats stop; detection via miss threshold
        elif ev.action == "rejoin":
            self.rejoin(ev.replica)
        elif ev.action == "stall":
            rh.stall_s = ev.arg
            rh.stall_until = self.tick_count + ev.ticks
        elif ev.action == "hbdrop":
            rh.hbdrop_until = self.tick_count + ev.ticks - 1
        elif ev.action == "pressure":
            rh.apply_pressure(self.tick_count, ev.arg, ev.ticks)
        elif ev.action == "drain":
            self.drain(ev.replica)

    # ---------------------------------------------------------- placement
    def _placement_order(self) -> list:
        """Brown-out: strict weighted order (gold first) with FIFO
        within a tier; full capacity: plain FIFO."""
        if self.degraded():
            return sorted(self.queue,
                          key=lambda rr: (-self._weight(rr.req.tenant),
                                          rr.seq))
        return list(self.queue)

    def _accepts_new(self, rh: ReplicaHandle) -> bool:
        """May fresh (router-queued) requests place on ``rh``?
        DisaggRouter narrows this to prefill-capable roles — decode
        replicas only receive handoffs."""
        return True

    def _place(self) -> None:
        candidates = [rh for rh in self.replicas
                      if rh.placeable(self.tick_count)
                      and self._accepts_new(rh)]
        # a slow replica still serves its in-flight work, but only
        # receives new load when no healthy replica can take it
        fallback = [rh for rh in self.replicas
                    if rh.state is ReplicaState.UP and rh.slow
                    and not rh.killed and rh.engine is not None
                    and self._accepts_new(rh)]
        for rr in self._placement_order():
            if rr.not_before > self.tick_count:
                continue  # backing off; doesn't block the line
            rh = self._select_replica(rr.req, candidates) \
                or self._select_replica(rr.req, fallback)
            if rh is None:
                # head-of-line: preserves FIFO fairness, and under
                # brown-out it is exactly the shed — a free-tier request
                # never jumps a gold one that is still waiting
                break
            rh.engine.submit(rr.req)
            rh.placements += 1
            rr.replica = rh.rid
            rr.history.append(rh.rid)
            self.queue.remove(rr)
            self.placed[rh.rid].append(rr)
            tr = self.tm.trace
            if tr.enabled:
                # a re-placement after loss closes its REPLAY span here
                tr.end_if_open(ROUTER_PID, rr.req.req_id,
                               placed_on=rh.rid)
                tr.instant(ROUTER_PID, "place", tid=rr.req.req_id,
                           replica=rh.rid, retry=rr.retries)

    def _select_replica(self, req: Request,
                        pool: list) -> Optional[ReplicaHandle]:
        fitting = [rh.offer() for rh in pool if rh.can_accept(req)]
        if not fitting:
            return None
        return self.replicas[self.policy.select(fitting).replica]

    # ------------------------------------------------------------ harvest
    def _can_retire(self, rh: ReplicaHandle) -> bool:
        """May a drained-empty replica leave the pool?  DisaggRouter
        holds retirement while an in-transit handoff still points at
        ``rh``'s page pool."""
        return True

    def _harvest(self) -> None:
        for rh in self.replicas:
            still = []
            for rr in self.placed[rh.rid]:
                if rr.req.done:
                    self.finished.append(rr)
                else:
                    still.append(rr)
            self.placed[rh.rid] = still
            if (rh.state is ReplicaState.DRAINING and not still
                    and self._can_retire(rh)):
                rh.state = ReplicaState.DOWN
                rh.engine = None

    # ------------------------------------------------------------- ticking
    def step(self) -> int:
        """One router tick; returns tokens emitted across the pool."""
        self.tick_count += 1
        if self.injector is not None:
            for ev in self.injector.pop(self.tick_count):
                self._apply_event(ev)
        for rh in self.replicas:
            rh.release_pressure(self.tick_count)
        self._heartbeats()
        degraded = self.degraded()
        if degraded:
            self.brownout_ticks += 1
        tr = self.tm.trace
        if tr.enabled and degraded != self._brownout_prev:
            tr.instant(ROUTER_PID,
                       "brownout_enter" if degraded else "brownout_exit",
                       tick=self.tick_count)
        self._brownout_prev = degraded
        self._place()
        emitted = 0
        for rh in self.replicas:
            if rh.state not in (ReplicaState.UP, ReplicaState.DRAINING):
                continue
            if rh.killed or rh.engine is None:
                continue
            if self.placed[rh.rid] or rh.engine.queue:
                emitted += rh.step(self.tick_count)
            if rh.slow and self.tick_count >= rh.slow_until:
                # cooldown runs from the most recent flag
                if rh.watchdog.flagged:
                    last_flag = rh.watchdog.flagged[-1][0]
                    rh.slow_until = last_flag + self.slow_cooldown
                    if self.tick_count >= rh.slow_until:
                        rh.slow = False
                else:
                    rh.slow = False
        if tr.enabled:
            for rh in self.replicas:
                if rh.slow != getattr(rh, "_slow_seen", False):
                    tr.instant(ROUTER_PID,
                               "straggler_flagged" if rh.slow
                               else "straggler_cleared", replica=rh.rid,
                               tick=self.tick_count)
                    rh._slow_seen = rh.slow
            tr.counter(ROUTER_PID, "router",
                       {"queued": len(self.queue),
                        "recoveries": self.recoveries,
                        "replicas_lost": self.replicas_lost,
                        "failed": self.failed})
        self._harvest()
        return emitted

    def _pending_counts(self) -> tuple[int, int]:
        """(queued, in-flight) requests still owed an outcome — the
        ``run()`` loop condition.  DisaggRouter counts in-transit
        handoffs as in-flight so the loop never exits mid-transfer."""
        return (len(self.queue),
                sum(len(v) for v in self.placed.values()))

    def run(self, max_ticks: int = 10_000,
            on_stall: str = "raise") -> list[Request]:
        """Drive ticks until every submitted request is done (finished
        or failed).  Stalls are reported, never silently truncated —
        same contract as ``ServeEngine.run``."""
        import warnings

        if on_stall not in ("raise", "warn"):
            raise ValueError(f"on_stall must be 'raise' or 'warn': "
                             f"{on_stall!r}")
        ticks = 0
        while sum(self._pending_counts()):
            if ticks >= max_ticks:
                queued, live = self._pending_counts()
                msg = (f"{type(self).__name__}.run() exhausted "
                       f"{max_ticks} ticks "
                       f"with {queued + live} requests undrained "
                       f"({queued} queued, {live} in flight)")
                if on_stall == "raise":
                    raise ServeStalled(msg)
                warnings.warn(msg, RuntimeWarning, stacklevel=2)
                break
            self.step()
            ticks += 1
        out = [rr.req for rr in
               sorted(self.finished, key=lambda rr: rr.seq)]
        self.finished = []
        return out

    # ---------------------------------------------------------- telemetry
    def stats(self) -> dict:
        """Legacy router stats dict, read back through the metrics
        registry (the ``cluster_*`` function-backed gauges) — key set is
        schema-stable (tests/test_telemetry.py)."""
        v = self.tm.registry.value
        return {
            "replicas": {
                rh.rid: {"state": rh.state.value, "slow": rh.slow,
                         "placements": int(v("cluster_replica_placements",
                                             replica=str(rh.rid))),
                         "steps": int(v("cluster_replica_steps",
                                        replica=str(rh.rid))),
                         "flags": len(rh.watchdog.flagged)}
                for rh in self.replicas},
            "ticks": int(v("cluster_ticks")),
            "recoveries": int(v("cluster_recoveries")),
            "replicas_lost": int(v("cluster_replicas_lost")),
            "failed": int(v("cluster_failed")),
            "brownout_ticks": int(v("cluster_brownout_ticks")),
            "queued": int(v("cluster_queue_depth")),
        }

    def request_metrics(self) -> list[dict]:
        """Per-request router-level metrics (TTFT vs the router submit
        stamp survives replays; the engine's restamp does not)."""
        return [dict(req_id=h.req.req_id, tenant=h.req.tenant,
                     finish_reason=h.req.finish_reason, **h.metrics())
                for h in self._handles]
