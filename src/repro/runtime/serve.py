"""Batched serving loop: continuous batching over a fixed slot batch.

The decode step is the ``serve_step`` the dry-run lowers for the decode_32k
/ long_500k cells.  ``ServeEngine`` adds the production affordances around
it: a request queue, fixed decode slots (static shapes — no recompilation),
per-slot stop handling, and per-slot admission.

Admission policy (``mode="continuous"``, the default)
-----------------------------------------------------
Any freed slot immediately admits the next queued request at its *own*
position — there is no wave barrier.  The decode step takes a per-slot
position vector ``pos[B]`` (free slots parked at -1), so every slot attends
its own prefix length in one ragged kernel call and work is proportional to
the tokens actually alive, not ``max_len * wave``.  Prompts are consumed by
**chunked prefill** where the architecture allows it (attention-only
plans): the prompt runs through the stack in (1, C) blocks that write the
KV cache in place — one step per C prompt tokens instead of one step per
token.  SSM/hybrid plans (conv + SSD state crosses chunk boundaries) fall
back to per-slot token feeding, still without a wave barrier; their slot
state is zeroed on admission since SSM state is not masked by position.

``mode="wave"`` keeps the legacy lockstep engine — admit a fresh wave only
when every slot is free, all slots decode at one scalar position, prompts
fed token-by-token — as the baseline ``benchmarks/serve_throughput.py``
measures continuous batching against (the serving analogue of the paper's
exclusive, non-co-scheduled mode).

All step functions keep static shapes and donate the caches, so each mode
compiles exactly once per (slots, max_len) and decodes in place.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.steps import make_prefill_chunk_step, make_serve_step


@dataclass
class Request:
    req_id: int
    prompt: np.ndarray  # (prompt_len,) int32
    max_new_tokens: int = 16
    eos_id: int = -1  # -1: never stops early
    output: list = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model, params, *, batch_slots: int, max_len: int,
                 mode: str = "continuous", prefill_chunk: int = 32,
                 mesh=None, cache_shardings=None):
        assert mode in ("continuous", "wave"), mode
        self.model = model
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.mode = mode
        self.mesh = mesh
        self.queue: deque[Request] = deque()
        self.active: list[Optional[Request]] = [None] * batch_slots
        self.pos = np.full(batch_slots, -1, dtype=np.int32)
        self.caches = model.init_cache(batch_slots, max_len)
        if cache_shardings is not None:
            self.caches = jax.device_put(self.caches, cache_shardings)
        self.tokens = np.zeros((batch_slots, 1), dtype=np.int32)
        self._finished: list[Request] = []
        self._admit_emitted = 0  # tokens emitted by chunked prefill
        self._step = jax.jit(make_serve_step(model), donate_argnums=(1,))
        self._decode_one = jax.jit(model.decode_step, donate_argnums=(1,))
        # chunked prefill: one compiled (1, C) step reused for every slot
        # and offset; C rounded down to a divisor of max_len so padded
        # chunk writes never clamp out of bounds.
        self.chunked = (mode == "continuous" and prefill_chunk > 1
                        and model.supports_chunked_prefill())
        c = max(1, min(prefill_chunk, max_len))
        while max_len % c:
            c -= 1
        self.prefill_chunk = c
        if self.chunked:
            self._prefill = jax.jit(make_prefill_chunk_step(model),
                                    donate_argnums=(1,))
        # SSM/hybrid state is not position-masked: zero a slot on admission
        self._needs_reset = model.cfg.family in ("ssm", "hybrid")
        if self._needs_reset:
            self._reset = self._make_slot_reset(model, max_len)

    @staticmethod
    def _make_slot_reset(model, max_len):
        """Zero one slot's cache state.  The batch axis of each cache leaf
        is found by diffing abstract cache shapes for two batch sizes (leaf
        layouts vary: stacked layer axes lead, SSM leaves differ from KV)."""
        s1 = jax.eval_shape(lambda: model.init_cache(1, max_len))
        s2 = jax.eval_shape(lambda: model.init_cache(2, max_len))
        axes = jax.tree.map(
            lambda a, b: next(i for i, (x, y) in enumerate(zip(a.shape,
                                                               b.shape))
                              if x != y), s1, s2)

        def reset(caches, slot):
            def zero(c, ax):
                keep = jnp.arange(c.shape[ax]) != slot
                shape = [1] * c.ndim
                shape[ax] = c.shape[ax]
                return c * keep.reshape(shape).astype(c.dtype)

            return jax.tree.map(zero, caches, axes)

        return jax.jit(reset, donate_argnums=(0,))

    def submit(self, req: Request):
        if not 0 < len(req.prompt) < self.max_len:
            raise ValueError(
                f"prompt length {len(req.prompt)} outside [1, "
                f"{self.max_len - 1}] for max_len={self.max_len}")
        self.queue.append(req)

    # ------------------------------------------------------------ admission
    def _finish(self, s: int):
        req = self.active[s]
        req.done = True
        self.active[s] = None
        self.pos[s] = -1
        self.tokens[s, 0] = 0
        self._finished.append(req)

    def _admit_continuous(self):
        """Per-slot admission: every free slot takes the next request now."""
        for s in range(self.slots):
            while self.active[s] is None and self.queue:
                req = self.queue.popleft()
                self.active[s] = req
                if self._needs_reset:
                    self.caches = self._reset(self.caches, jnp.int32(s))
                if self.chunked:
                    self._prefill_slot(s, req)
                    # prefill already produced the first token; the request
                    # may complete before a single decode tick runs, in
                    # which case the freed slot admits again immediately
                    self._maybe_stop(s)
                else:
                    req._feed = deque(req.prompt.tolist())  # type: ignore
                    self.tokens[s, 0] = req._feed.popleft()
                    self.pos[s] = 0

    def _prefill_slot(self, s: int, req: Request):
        """Run the slot's prompt through the stack in (1, C) chunks,
        writing the KV cache in place; the last real token's logits seed
        decode at pos = prompt_len."""
        c = self.prefill_chunk
        prompt = np.asarray(req.prompt, np.int32)
        p = len(prompt)
        n_chunks = max(1, -(-p // c))
        padded = np.zeros(n_chunks * c, np.int32)
        padded[:p] = prompt
        req._feed = deque()  # type: ignore
        nxt = None
        for ci in range(n_chunks):
            chunk = jnp.asarray(padded[None, ci * c:(ci + 1) * c])
            nxt, self.caches = self._prefill(self.params, self.caches, chunk,
                                             jnp.int32(s), jnp.int32(ci * c))
        tok = int(np.asarray(nxt)[(p - 1) - (n_chunks - 1) * c])
        self.pos[s] = p
        self.tokens[s, 0] = tok
        req.output.append(tok)
        self._admit_emitted += 1

    def _maybe_stop(self, s: int) -> bool:
        req = self.active[s]
        if (len(req.output) >= req.max_new_tokens
                or (req.output and req.output[-1] == req.eos_id)
                or self.pos[s] >= self.max_len - 1):
            self._finish(s)
            return True
        return False

    # ----------------------------------------------------------- wave mode
    def _admit_wave(self):
        """Wave batching: admit a fresh wave only when every slot is free —
        all slots then decode in lockstep at one scalar position (static
        shapes, exact cache indexing).  Prompts are fed token-by-token."""
        if any(r is not None for r in self.active) or not self.queue:
            return
        self.caches = jax.tree.map(lambda c: jnp.zeros_like(c), self.caches)
        self.pos[:] = 0
        self.tokens[:] = 0
        for s in range(self.slots):
            if not self.queue:
                break
            req = self.queue.popleft()
            self.active[s] = req
            req._feed = deque(req.prompt.tolist())  # type: ignore
            self.tokens[s, 0] = req._feed.popleft()

    # ------------------------------------------------------------ stepping
    def step(self) -> int:
        """One engine tick = one decode step for every live slot."""
        if self.mode == "wave":
            return self._step_wave()
        return self._step_continuous()

    def _step_continuous(self) -> int:
        self._admit_emitted = 0
        self._admit_continuous()
        emitted = self._admit_emitted  # first tokens from chunked prefill
        if not any(r is not None for r in self.active):
            return emitted
        pos = jnp.asarray(self.pos)
        nxt_dev, self.caches = self._step(self.params, self.caches,
                                          jnp.asarray(self.tokens), pos)
        nxt = np.asarray(nxt_dev)
        for s, req in enumerate(self.active):
            if req is None:
                continue
            self.pos[s] += 1
            feed = getattr(req, "_feed")
            if feed:  # still consuming the prompt (token-feed path)
                self.tokens[s, 0] = feed.popleft()
                continue
            tok = int(nxt[s, 0])
            req.output.append(tok)
            emitted += 1
            self.tokens[s, 0] = tok
            self._maybe_stop(s)
        return emitted

    def _step_wave(self) -> int:
        self._admit_wave()
        if not any(r is not None for r in self.active):
            return 0
        pos = int(self.pos.max())  # lockstep position (wave batching)
        logits, self.caches = self._decode_one(self.params, self.caches,
                                               jnp.asarray(self.tokens),
                                               jnp.int32(pos))
        nxt = np.asarray(jnp.argmax(logits, axis=-1), dtype=np.int32)
        emitted = 0
        for s, req in enumerate(self.active):
            if req is None:
                continue
            self.pos[s] += 1
            feed = getattr(req, "_feed")
            if feed:  # still consuming the prompt
                self.tokens[s, 0] = feed.popleft()
                continue
            tok = int(nxt[s])
            req.output.append(tok)
            emitted += 1
            self.tokens[s, 0] = tok
            if (len(req.output) >= req.max_new_tokens
                    or tok == req.eos_id
                    or self.pos[s] >= self.max_len - 1):
                req.done = True
                self.active[s] = None
                self._finished.append(req)
        return emitted

    def run(self, max_ticks: int = 10_000) -> list[Request]:
        ticks = 0
        while ((self.queue or any(r is not None for r in self.active))
               and ticks < max_ticks):
            self.step()
            ticks += 1
        finished, self._finished = self._finished, []
        return finished
