"""Policy-driven serving front-end over the batched decode loop.

The decode step is the ``serve_step`` the dry-run lowers for the decode_32k
/ long_500k cells.  ``ServeEngine`` adds the production affordances around
it: a policy-driven admission scheduler, fixed decode slots (static shapes
— no recompilation), per-request sampling, per-slot stop handling, and
streaming request handles.

Request API
-----------
``submit`` takes a ``Request`` — prompt, token budget, ``SamplingParams``
(temperature / top-k / top-p / per-request seed / multi-token stop
sequences), a ``tenant`` for fairness accounting and a ``priority`` — and
returns a ``RequestHandle`` whose lifecycle walks ``QUEUED -> PREFILL ->
DECODE -> FINISHED(reason)`` and whose ``tokens()`` iterator streams output
tokens as the engine produces them.  Engine construction takes a
``ServeConfig``; the pre-PR-3 keyword sprawl still works through a
deprecation shim (see docs/serving.md for the migration table).

Admission scheduling (``ServeConfig.policy``)
---------------------------------------------
Each engine tick splits into a *decide* phase — ``runtime/scheduler.py``'s
``Scheduler.decide()`` assigns queued requests to freed slots under a
pluggable ``AdmissionPolicy`` (``fcfs`` / ``priority`` / ``sjf`` /
``drf-fair``), pure host bookkeeping — and an *execute* phase that runs
the compiled prefill/decode steps for the decisions.  ``drf-fair`` charges
each tenant's slot-and-KV usage through ``core/drf.py``'s ``DRFAllocator``
(the paper's Mesos DRF, pointed at serving), so no tenant starves the
pool.  Policies never touch device state.

Continuous batching (``mode="continuous"``, the default)
--------------------------------------------------------
Any freed slot immediately admits the scheduler's next choice at its *own*
position — there is no wave barrier.  The decode step takes a per-slot
position vector ``pos[B]`` (free slots parked at -1); when any live slot
samples, the tick dispatches to a sampled variant that additionally takes
the per-slot sampling arrays (``temp/top_k/top_p/keys``), so every slot
attends its own prefix and draws its own token in one ragged kernel call
— rows with ``temperature <= 0`` stay bitwise-greedy, and an all-greedy
tick never pays the sampling math.  Prompts are consumed by
**chunked prefill** where the architecture allows it; SSM/hybrid plans
fall back to per-slot token feeding with slot state zeroed on admission.

Preemption & SLO tiers (``ServeConfig.preempt``, ``tenant_weights``)
--------------------------------------------------------------------
Admission alone cannot undo a grab, so ``preempt=True`` makes the decide
phase two-phase (Mesos-style revocation): when a queued tenant's weighted
DRF share would stay strictly below a running tenant's, the scheduler
evicts a victim (``victim_policy``: ``youngest-first`` /
``lowest-weight-share-first``) and the executor checkpoints its slot —
decode position, last token, and KV state.  Paged checkpoints are
zero-copy (the page chain detaches from the slot, refcounts intact);
dense checkpoints snapshot the slot's cache stripe to a host buffer via
the models' ``copy_cache_out``/``copy_cache_in`` pair.  The request
re-enters the queue as ``PREEMPTED`` and later resumes into *any* free
slot at ``pos = checkpoint`` without re-running prefill, producing the
bitwise-identical token stream (sampling keys fold the absolute
position, never the slot).  ``tenant_weights`` maps SLO tiers onto DRF
shares — ``{"gold": 3, "free": 1}`` converges to a 3:1 slot split under
contention.

``mode="wave"`` keeps the legacy lockstep engine — admit a fresh wave only
when every slot is free, all slots decode at one scalar position — as the
baseline ``benchmarks/serve_throughput.py`` measures continuous batching
against (the serving analogue of the paper's exclusive, non-co-scheduled
mode).  Sampled requests are served by drawing host-side from the wave
logits through the same position-keyed ``sample_tokens``, so a seeded
request decodes the identical trajectory in either mode.

Speculative decode (``ServeConfig.draft_k``, continuous mode)
-------------------------------------------------------------
``draft_k > 0`` turns every decode tick into draft -> verify -> accept:
a host-side drafter (``runtime/draft.py``, default model-free n-gram
lookup over the slot's own history) proposes up to ``draft_k``
continuation tokens per slot, ONE compiled multi-token step scores the
feed token plus all drafts at per-slot positions (causal within the
draft), and the engine emits the longest verified prefix plus the free
correction token — one token minimum, ``draft_k + 1`` maximum per tick.
Greedy output is bitwise-identical to plain decode; sampled output is
bitwise-identical to the same seed's non-speculative trajectory (each
row folds its absolute position into the slot's key).  Rejected drafts
roll back by pure position truncation — dense: stale K/V beyond ``pos``
is never attended and is overwritten when reached; paged: draft writes
land only in the slot's already-reserved pages (padding past the span
hits the null page), so no page is ever allocated, freed, or leaked by
speculation and preemption checkpoints compose unchanged.

Paged KV cache (``cache="paged"``, continuous mode only)
--------------------------------------------------------
``cache="paged"`` swaps the dense per-slot ``(max_len)`` HBM stripes for a
global page pool (``runtime/kv_pool.py``): admission reserves exactly
``ceil((prompt + max_new) / page_size)`` pages, the scheduler queues with
**backpressure** when the pool is exhausted (``step`` never raises), and a
prefix cache admits shared prompts at ``pos = matched`` with copy-on-write
pages.  See docs/paged_kv.md.

All step functions keep static shapes and donate the caches, so each mode
compiles exactly once per (slots, max_len) and decodes in place.  Dense
continuous decode additionally picks its split-K fan-out per tick from
``(max(pos), live slots)`` (``steps.pick_decode_splits``) when
``RuntimeKnobs.decode_splits`` is 0 (auto).
"""
from __future__ import annotations

import dataclasses
import enum
import time
import warnings
from collections import deque
from dataclasses import dataclass, field
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.draft import get_drafter
from repro.runtime.kv_pool import KVCacheManager
from repro.runtime.sampling import (SamplingParams, matches_stop,
                                    sample_tokens, speculative_accept)
from repro.runtime.scheduler import Scheduler
from repro.runtime.steps import (compiled_fn, compiled_step,
                                 pick_decode_splits, step_cache_stats)
from repro.runtime.telemetry import Telemetry

__all__ = ["Checkpoint", "Request", "RequestHandle", "RequestState",
           "SamplingParams", "ServeConfig", "ServeEngine", "ServeStalled",
           "request_metrics"]


def request_metrics(req: "Request") -> dict:
    """Per-request latency from the lifecycle stamps: time-to-first-token
    (``ttft_s``, includes queue wait — the quantity admission policies
    trade) and time-per-output-token (``tpot_s``).  Entries whose stamps
    the lifecycle has not reached yet are omitted.  The single source of
    the formulas — ``RequestHandle.metrics()`` and the benchmarks'
    percentile aggregation both call this."""
    out = {}
    if req.t_submit is not None and req.t_first is not None:
        out["ttft_s"] = req.t_first - req.t_submit
    if req.t_first is not None and req.t_finish is not None \
            and len(req.output) > 1:
        out["tpot_s"] = (req.t_finish - req.t_first) / (len(req.output) - 1)
    return out


def _ckpt_fns(model, max_len: int):
    """(copy_out, copy_in) jitted pair for dense checkpoint/restore,
    memoized in ``runtime.steps``' shared compiled-callable LRU (keyed
    on (kind, cfg, knobs, max_len)) so replay/extra engines over the
    same model don't recompile."""
    def build_out():
        axes = model.cache_batch_axes(max_len)
        return lambda caches, slot: model.copy_cache_out(caches, slot,
                                                         axes)

    def build_in():
        axes = model.cache_batch_axes(max_len)
        return lambda caches, snap, slot: model.copy_cache_in(
            caches, snap, slot, axes)

    base = (model.cfg, model.knobs, max_len)
    return (compiled_fn(("copy_out",) + base, build_out),
            compiled_fn(("copy_in",) + base, build_in, donate=(0,)))


class ServeStalled(RuntimeError):
    """``run()`` exhausted its tick budget with requests undrained, or a
    streaming handle stopped making progress."""


class RequestState(enum.Enum):
    QUEUED = "queued"      # submitted, waiting for the scheduler
    PREFILL = "prefill"    # consuming the prompt (chunked or token feed)
    DECODE = "decode"      # generating
    PREEMPTED = "preempted"  # checkpointed + requeued; resumes at pos
    FINISHED = "finished"  # done; see Request.finish_reason


@dataclass
class Checkpoint:
    """A preempted request's resume point.  ``pages`` (paged cache) is
    the detached page chain — the K/V never left HBM; ``kv`` (dense) is
    the host-side snapshot of the slot's cache stripe."""

    pos: int  # decode position to resume at
    last_token: int  # the token to feed at ``pos``
    pages: Optional[list] = None
    kv: object = None


@dataclass
class Request:
    req_id: int
    prompt: np.ndarray  # (prompt_len,) int32
    max_new_tokens: int = 16
    eos_id: int = -1  # -1: never stops early
    sampling: SamplingParams = field(default_factory=SamplingParams)
    tenant: str = "default"  # drf-fair accounting unit
    priority: int = 0  # higher admits first under policy="priority"
    output: list = field(default_factory=list)
    done: bool = False
    state: RequestState = RequestState.QUEUED
    finish_reason: Optional[str] = None  # "eos" | "stop" | "length"
    preempt_count: int = 0  # times this request was checkpointed
    # wall-clock lifecycle stamps (time.perf_counter seconds)
    t_submit: Optional[float] = None
    t_first: Optional[float] = None
    t_finish: Optional[float] = None


class RequestHandle:
    """Caller-facing view of a submitted request.

    ``tokens()`` yields output tokens incrementally; when the engine has
    not yet produced the next token the iterator *drives* it (one
    ``engine.step()`` per attempt — which also serves every other live
    slot), so ``for tok in handle.tokens():`` streams a request to
    completion.  ``result()`` drains and returns the finished ``Request``.
    """

    def __init__(self, req: Request, engine: "ServeEngine"):
        self.req = req
        self._engine = engine

    @property
    def state(self) -> RequestState:
        return self.req.state

    @property
    def finish_reason(self) -> Optional[str]:
        return self.req.finish_reason

    @property
    def done(self) -> bool:
        return self.req.done

    @property
    def output(self) -> list:
        return list(self.req.output)

    def tokens(self, max_ticks: int = 100_000) -> Iterator[int]:
        i = stalled = 0
        while True:
            while i < len(self.req.output):
                stalled = 0
                yield self.req.output[i]
                i += 1
            if self.req.done:
                return
            self._engine.step()
            stalled += 1
            if stalled > max_ticks:
                raise ServeStalled(
                    f"request {self.req.req_id} produced no token in "
                    f"{max_ticks} ticks (state={self.req.state.value})")

    def result(self, max_ticks: int = 100_000) -> Request:
        for _ in self.tokens(max_ticks=max_ticks):
            pass
        return self.req

    def metrics(self) -> dict:
        """Per-request latency (see ``request_metrics``)."""
        return request_metrics(self.req)


@dataclass(frozen=True)
class ServeConfig:
    """Engine construction knobs, replacing the pre-PR-3 keyword sprawl.

    ``policy`` names an admission policy from
    ``runtime.scheduler.ADMISSION_POLICIES``; ``on_stall`` decides whether
    ``run()`` raises (``"raise"``, default) or warns and returns partial
    results (``"warn"``) when its tick budget is exhausted with requests
    undrained.

    ``tenant_weights`` maps tenant names onto weighted-DRF shares (SLO
    tiers; unlisted tenants weigh 1).  ``preempt=True`` lets the decide
    phase reclaim running slots when a swap strictly improves weighted
    fairness; ``victim_policy`` (``runtime.scheduler.VICTIM_POLICIES``)
    picks who gets checkpointed.

    ``draft_k > 0`` enables speculative decode (continuous mode,
    attention-only plans): every decode tick scores up to ``draft_k``
    drafted tokens per slot in one multi-token verify step and emits the
    accepted prefix plus the free correction token — bitwise-identical
    output, fewer ticks.  ``drafter`` names a ``runtime.draft.DRAFTERS``
    entry (default: model-free prompt/n-gram lookup)."""

    batch_slots: int = 4
    max_len: int = 128
    mode: str = "continuous"
    prefill_chunk: int = 32
    cache: str = "dense"
    page_size: int = 16
    num_pages: Optional[int] = None
    page_policy: str = "pack"
    prefix_cache: bool = True
    # quantized paged KV: "" (store at RuntimeKnobs.cache_dtype), "int8"
    # or "fp8" (float8_e4m3fn).  Pages hold quantized K/V with per-token
    # per-head f32 scales alongside ("k_scale"/"v_scale" pool leaves);
    # the attention kernels dequantize at read — ~2x pages per HBM byte
    # at int8.  Requires cache="paged"; composes with prefix sharing and
    # disaggregation (scales travel with pages).  See docs/paged_kv.md.
    kv_dtype: str = ""
    policy: str = "fcfs"
    on_stall: str = "raise"
    tenant_weights: Optional[dict] = None
    preempt: bool = False
    victim_policy: str = "youngest-first"
    draft_k: int = 0
    drafter: str = "ngram"
    # disaggregated serving role (runtime/disagg.py): "prefill" engines
    # run chunked prefill and surrender the finished slot to a handoff;
    # "decode" engines only accept handed-off (checkpointed) requests
    role: str = "unified"
    # device mesh for ONE sharded replica, e.g. (2, 4) = 2 data hosts x
    # TP 4 (see launch/mesh.py make_serve_mesh): the "model" axis carries
    # gather-form tensor parallelism through the layer stack, the leading
    # data axes shard the decode slots and split the KV page pool into
    # per-host sub-pools.  None (default): single-device engine.  The
    # sharded engine's token streams are bitwise-identical to the
    # unsharded one (docs/serving.md, tests/test_sharded_serve.py).
    mesh_shape: Optional[tuple] = None


_CONFIG_FIELDS = {f.name for f in dataclasses.fields(ServeConfig)}


class ServeEngine:
    def __init__(self, model, params, config: Optional[ServeConfig] = None,
                 *, mesh=None, cache_shardings=None, telemetry=None,
                 replica: int = 0, **legacy):
        if legacy:
            if config is not None:
                raise TypeError(
                    "pass either a ServeConfig or legacy keyword arguments, "
                    "not both")
            unknown = set(legacy) - _CONFIG_FIELDS
            if unknown:
                raise TypeError(f"unknown ServeEngine arguments: "
                                f"{sorted(unknown)}")
            warnings.warn(
                "ServeEngine(batch_slots=..., max_len=..., ...) keyword "
                "construction is deprecated; pass ServeConfig(...) instead "
                "(see docs/serving.md for the migration table)",
                DeprecationWarning, stacklevel=2)
            config = ServeConfig(**legacy)
        elif config is None:
            config = ServeConfig()
        assert config.mode in ("continuous", "wave"), config.mode
        assert config.cache in ("dense", "paged"), config.cache
        assert config.on_stall in ("raise", "warn"), config.on_stall
        if config.preempt and config.mode != "continuous":
            raise ValueError("preempt=True requires mode='continuous' "
                             "(wave slots drain in lockstep)")
        if config.draft_k < 0:
            raise ValueError(f"draft_k must be >= 0: {config.draft_k}")
        if config.role not in ("unified", "prefill", "decode"):
            raise ValueError(f"unknown role {config.role!r} "
                             f"(expected unified/prefill/decode)")
        if config.role != "unified":
            if config.mode != "continuous":
                raise ValueError("disaggregated roles require "
                                 "mode='continuous'")
            if not model.supports_chunked_prefill():
                raise ValueError(
                    f"disaggregated roles need chunked prefill, unsupported "
                    f"for family={model.cfg.family!r} (token-feed prefill "
                    f"cannot hand off mid-prompt)")
        if config.draft_k:
            if config.mode != "continuous":
                raise ValueError("speculative decode (draft_k > 0) requires "
                                 "mode='continuous'")
            if not model.supports_speculative():
                raise ValueError(
                    f"speculative decode unsupported for "
                    f"family={model.cfg.family!r} (SSM state advances one "
                    f"token at a time)")
            if config.draft_k + 1 >= config.max_len:
                raise ValueError(f"draft_k {config.draft_k} too deep for "
                                 f"max_len {config.max_len}")
        if config.kv_dtype:
            if config.cache != "paged":
                raise ValueError("kv_dtype requires cache='paged' (dense "
                                 "caches store at RuntimeKnobs.cache_dtype)")
            if config.kv_dtype not in ("int8", "fp8"):
                raise ValueError(f"unknown kv_dtype {config.kv_dtype!r} "
                                 f"(expected int8/fp8)")
            # quantization is a model-layout property: rebuild with the
            # kv_quant knob so cache init/update/attention all agree (the
            # knob keys the compiled-step cache, so quantized and plain
            # engines over one config never share a step)
            if model.knobs.kv_quant != config.kv_dtype:
                model = type(model)(
                    model.cfg,
                    model.knobs.with_(kv_quant=config.kv_dtype))
        # ---- device mesh: shard this replica without changing its output
        self._batch_sharding = None
        self._num_hosts = 1
        if mesh is None and config.mesh_shape is not None:
            from repro.launch.mesh import make_serve_mesh
            mesh = make_serve_mesh(config.mesh_shape)
        if mesh is not None:
            if config.mode != "continuous":
                raise ValueError("sharded serving (mesh / mesh_shape) "
                                 "requires mode='continuous'")
            if model.knobs.use_pallas:
                raise ValueError(
                    "sharded serving requires the XLA path "
                    "(RuntimeKnobs.use_pallas=False): the Pallas decode "
                    "kernels are single-device and do not partition")
            from repro.sharding import (ServeShardFn, serve_batch_sharding,
                                        serve_cache_shardings,
                                        serve_param_shardings)
            # rebuild the model with the gather-form TP seams threaded
            # through the layer stack; ServeShardFn hashes on the mesh,
            # so engines over the same mesh still share compiled steps
            model = type(model)(model.cfg,
                                model.knobs.with_(
                                    shard_fn=ServeShardFn(mesh)))
            params = jax.device_put(
                params, serve_param_shardings(mesh, model.cfg, params))
            self._batch_sharding = serve_batch_sharding(
                mesh, config.batch_slots)
            if self._batch_sharding is not None:
                # slot dim sharded over the data axes -> each host row
                # decodes a contiguous block of slots; the KV page pool
                # splits into per-host sub-pools so a slot's page chain
                # stays on the host that computes its queries
                self._num_hosts = 1
                for ax in ("pod", "data"):
                    self._num_hosts *= dict(mesh.shape).get(ax, 1)
        self.config = config
        self.model = model
        self.params = params
        self.role = config.role
        self.slots = config.batch_slots
        self.max_len = config.max_len
        self.mode = config.mode
        self.mesh = mesh
        self.cache = config.cache
        batch_slots, max_len = config.batch_slots, config.max_len
        self.active: list[Optional[Request]] = [None] * batch_slots
        self.pos = np.full(batch_slots, -1, dtype=np.int32)
        self.tokens = np.zeros((batch_slots, 1), dtype=np.int32)
        # per-slot sampling arrays: one compiled step serves any mix of
        # greedy (temp 0) and sampled requests
        self.samp_temp = np.zeros(batch_slots, np.float32)
        self.samp_topk = np.zeros(batch_slots, np.int32)
        self.samp_topp = np.ones(batch_slots, np.float32)
        self.samp_keys = np.zeros((batch_slots, 2), np.uint32)
        self._finished: list[Request] = []
        self._admit_emitted = 0  # tokens emitted by chunked prefill
        # jitted steps come from runtime.steps' module-level LRU: engines
        # over equal (cfg, knobs) share one compiled callable per step
        self._decode_one = compiled_step(model, "decode_one")
        # checkpoint/restore (dense): built on first preemption
        self._copy_out = self._copy_in = None
        self.kv: Optional[KVCacheManager] = None
        self._pf_buf = None  # dense (1, max_len) slot view, XLA paged only
        if config.cache == "paged":
            if config.mode != "continuous":
                raise ValueError("cache='paged' requires mode='continuous'")
            if not model.supports_paged_cache():
                raise ValueError(
                    f"paged KV cache unsupported for "
                    f"family={model.cfg.family!r}")
            page_size = config.page_size
            if max_len % page_size:
                raise ValueError(f"max_len {max_len} not a multiple of "
                                 f"page_size {page_size}")
            # prefill chunks must cover whole pages at page-aligned
            # offsets; C also divides max_len so chunk writes never clamp
            c = max(page_size,
                    (min(config.prefill_chunk, max_len) // page_size)
                    * page_size)
            while max_len % c:
                c -= page_size
            self.prefill_chunk = c
            self.chunked = True
            # dense-equivalent capacity by default (+ the null page);
            # benchmarks pass a smaller pool to realize the HBM saving
            num_pages = config.num_pages
            if num_pages is None:
                num_pages = batch_slots * (max_len // page_size) + 1
            if self._num_hosts > 1:
                # host sub-pools must tile the pool evenly (the device
                # page dim shards over the data axes): round capacity UP
                # so a caller-sized pool never silently shrinks
                num_pages = -(-num_pages // self._num_hosts) \
                    * self._num_hosts
            self.kv = KVCacheManager(
                slots=batch_slots, max_len=max_len, page_size=page_size,
                num_pages=num_pages, policy=config.page_policy,
                prefix_cache=config.prefix_cache, chunk=c,
                num_hosts=self._num_hosts)
            # the pool may round capacity up (num_hosts alignment): size
            # the device pools from what it actually holds, never the ask
            num_pages = self.kv.pool.num_pages
            self.caches = model.init_cache_paged(num_pages, page_size)
            # greedy and sampled variants both exist (jit is lazy — only
            # the ones a trace actually hits compile); a tick pays the
            # sampling math only when a live slot has temperature > 0
            self._step = compiled_step(model, "paged_serve",
                                       page_size=page_size)
            self._step_sampled = compiled_step(model, "paged_serve",
                                               page_size=page_size,
                                               sampled=True)
            if model.knobs.use_pallas:
                # fused paged prefill kernel reads K/V through the page
                # table — no dense slot view to maintain
                self._pf_buf = None
                self._prefill = compiled_step(
                    model, "paged_prefill_chunk", page_size=page_size)
                self._prefill_sampled = compiled_step(
                    model, "paged_prefill_chunk", page_size=page_size,
                    sampled=True)
            else:
                # XLA path: carry one dense (1, max_len) slot view across
                # the chunk loop so each chunk inserts C rows instead of
                # re-gathering the whole page chain (the gather variant
                # rebuilds the view once on a prefix-cache hit)
                self._pf_buf = model.init_cache(1, max_len)
                self._prefill = compiled_step(
                    model, "paged_prefill_chunk_buf", page_size=page_size)
                self._prefill_sampled = compiled_step(
                    model, "paged_prefill_chunk_buf", page_size=page_size,
                    sampled=True)
                self._prefill_gather = compiled_step(
                    model, "paged_prefill_chunk_buf_gather",
                    page_size=page_size)
                self._prefill_gather_sampled = compiled_step(
                    model, "paged_prefill_chunk_buf_gather",
                    page_size=page_size, sampled=True)
        else:
            self.caches = model.init_cache(batch_slots, max_len)
            self._step = compiled_step(model, "serve")
            self._step_sampled = compiled_step(model, "serve", sampled=True)
            # chunked prefill: one compiled (1, C) step reused for every
            # slot and offset; C rounded down to a divisor of max_len so
            # padded chunk writes never clamp out of bounds.
            self.chunked = (config.mode == "continuous"
                            and config.prefill_chunk > 1
                            and model.supports_chunked_prefill())
            c = max(1, min(config.prefill_chunk, max_len))
            while max_len % c:
                c -= 1
            self.prefill_chunk = c
            if self.chunked:
                self._prefill = compiled_step(model, "prefill_chunk")
                self._prefill_sampled = compiled_step(model, "prefill_chunk",
                                                      sampled=True)
        # speculative decode: one verify step of static width T = k + 1
        # per (cache layout, sampled) variant; the drafter is pure host
        self.draft_k = config.draft_k
        if self.draft_k:
            self.drafter = get_drafter(config.drafter)
            spec_kind = ("paged_spec_serve" if config.cache == "paged"
                         else "spec_serve")
            spec_ps = config.page_size if config.cache == "paged" else 0
            self._spec_step = compiled_step(
                model, spec_kind, page_size=spec_ps, draft_len=self.draft_k)
            self._spec_step_sampled = compiled_step(
                model, spec_kind, page_size=spec_ps, draft_len=self.draft_k,
                sampled=True)
            # acceptance telemetry: proposed/accepted draft tokens and
            # how many tokens each spec tick emitted
            self.spec_proposed = 0
            self.spec_accepted = 0
            self.spec_emitted = 0
            self.spec_ticks = 0
        if mesh is not None and cache_shardings is None:
            # default layout: KV-head dim over "model" (each TP shard
            # attends its own heads), slot/page dim over the data axes
            # (serve_cache_shardings — NOT the training cache rules,
            # which shard the sequence dim and would psum softmax stats)
            cache_shardings = serve_cache_shardings(
                mesh, self.caches, paged=(config.cache == "paged"))
        if cache_shardings is not None:
            self.caches = jax.device_put(self.caches, cache_shardings)
        # decide/execute split: the scheduler owns the queue, the policy,
        # the per-tenant (weighted) DRF accounting, and the preemption
        # victim policy — host state only
        self.scheduler = Scheduler(config.policy, slots=batch_slots,
                                   max_len=max_len, kv=self.kv,
                                   weights=config.tenant_weights,
                                   preempt=config.preempt,
                                   victim=config.victim_policy)
        # split-K autotune (Pallas decode, dense AND paged): pick the
        # fan-out per tick from (max(pos), live slots); each compiles
        # once.  The paged variant tiles by whole pages, so the picker
        # gets page_size and constrains splits to divide max_pages.
        self._autotune = (config.cache in ("dense", "paged")
                          and config.mode == "continuous"
                          and model.knobs.use_pallas
                          and model.knobs.decode_splits == 0)
        # SSM/hybrid state is not position-masked: zero a slot on admission
        self._needs_reset = model.cfg.family in ("ssm", "hybrid")
        if self._needs_reset:
            self._reset = self._make_slot_reset(model, max_len)
        # telemetry: every engine binds a Telemetry sink (a private one by
        # default — metrics always on, tracing off unless the caller
        # passes Telemetry(trace=True)); a ClusterRouter rebinds its
        # replicas onto one shared sink with per-replica labels
        self.bind_telemetry(telemetry, replica=replica)

    def bind_telemetry(self, telemetry: Optional[Telemetry] = None, *,
                       replica: int = 0) -> None:
        """Bind (or rebind — replica rejoin reuses this) the engine and
        its scheduler/KV manager to a ``Telemetry`` sink.  Registry
        series carry a ``replica`` label; trace events use the replica id
        as their ``pid`` track.  Hot-path counter children are prebound
        here so a tick increments a float, never does a dict lookup."""
        self.tm = telemetry if telemetry is not None else Telemetry()
        self.replica = int(replica)
        reg = self.tm.registry
        lbl = {"replica": str(self.replica)}
        self._m_ticks = reg.counter(
            "engine_ticks_total", "engine ticks stepped",
            ("replica",)).labels(**lbl)
        self._m_tokens = reg.counter(
            "engine_tokens_total", "output tokens emitted",
            ("replica",)).labels(**lbl)
        self._m_submitted = reg.counter(
            "engine_requests_submitted_total", "requests submitted",
            ("replica",)).labels(**lbl)
        self._m_finished = reg.counter(
            "engine_requests_finished_total",
            "requests finished, by finish reason", ("replica", "reason"))
        reg.gauge("engine_live_slots", "slots holding an active request",
                  ("replica",)).labels(**lbl).set_function(
            lambda: sum(r is not None for r in self.active))
        reg.gauge("engine_queue_depth", "requests awaiting admission",
                  ("replica",)).labels(**lbl).set_function(
            lambda: len(self.scheduler.queue))
        if self.draft_k:
            # function-backed: the spec tick's tight loop keeps bumping
            # plain attributes; the registry reads them at export time
            for name, attr in (("engine_spec_proposed", "spec_proposed"),
                               ("engine_spec_accepted", "spec_accepted"),
                               ("engine_spec_emitted", "spec_emitted"),
                               ("engine_spec_ticks", "spec_ticks")):
                reg.gauge(name, f"speculative decode: {attr}",
                          ("replica",)).labels(**lbl).set_function(
                    lambda a=attr: getattr(self, a))
        self.scheduler.bind_metrics(reg, self.replica)
        if self.kv is not None:
            self.kv.bind_metrics(reg, self.replica)
        if self.tm.trace.enabled:
            self.tm.trace.set_process_name(self.replica,
                                           f"replica {self.replica}")

    def _set_state(self, req: Request, state: RequestState, **args) -> None:
        """One request-lifecycle edge: flip ``req.state`` and roll the
        request's trace span over to the new state (no-op sink when
        tracing is off)."""
        req.state = state
        self.tm.req_transition(self.replica, req.req_id, state.name, **args)

    def _tick_telemetry(self, emitted: int) -> None:
        """Per-tick accounting: counters always (two float adds), plus a
        Chrome counter-track sample of the engine's vitals when tracing
        is live."""
        self._m_ticks.inc()
        if emitted:
            self._m_tokens.inc(emitted)
        tr = self.tm.trace
        if not tr.enabled:
            return
        vals = {"live_slots": sum(r is not None for r in self.active),
                "queue_depth": len(self.scheduler.queue)}
        if self.kv is not None:
            vals["free_pages"] = self.kv.pool.available
        if self.draft_k:
            vals["spec_proposed"] = self.spec_proposed
            vals["spec_accepted"] = self.spec_accepted
        vals["step_cache_hits"] = step_cache_stats()["hits"]
        tr.counter(self.replica, "engine", vals)

    @property
    def queue(self) -> deque:
        """The scheduler's admission queue (read-mostly; use submit())."""
        return self.scheduler.queue

    @staticmethod
    def _make_slot_reset(model, max_len):
        """Zero one slot's cache state (batch axes per leaf from
        ``model.cache_batch_axes`` — layouts vary across plans)."""
        axes = model.cache_batch_axes(max_len)

        def reset(caches, slot):
            def zero(c, ax):
                keep = jnp.arange(c.shape[ax]) != slot
                shape = [1] * c.ndim
                shape[ax] = c.shape[ax]
                return c * keep.reshape(shape).astype(c.dtype)

            return jax.tree.map(zero, caches, axes)

        return jax.jit(reset, donate_argnums=(0,))

    def submit(self, req: Request) -> RequestHandle:
        if self.role == "decode" and not getattr(req, "_preempted", False):
            raise ValueError(
                "decode-role engines only accept handed-off (checkpointed) "
                "requests — route fresh requests to a prefill replica")
        if not 0 < len(req.prompt) < self.max_len:
            raise ValueError(
                f"prompt length {len(req.prompt)} outside [1, "
                f"{self.max_len - 1}] for max_len={self.max_len}")
        if self.kv is not None and not self.kv.fits_ever(
                len(req.prompt), req.max_new_tokens):
            raise ValueError(
                f"request needs more pages than the pool can ever supply "
                f"(prompt {len(req.prompt)} + max_new {req.max_new_tokens} "
                f"vs {self.kv.pool.capacity} pages of "
                f"{self.kv.page_size})")
        self._set_state(req, RequestState.QUEUED, tenant=req.tenant)
        req.t_submit = time.perf_counter()
        self._m_submitted.inc()
        self.scheduler.submit(req)
        return RequestHandle(req, self)

    # ------------------------------------------------------------ admission
    def _emit(self, req: Request, tok: int):
        if not req.output:
            req.t_first = time.perf_counter()
        req.output.append(tok)

    def _clear_slot(self, s: int):
        """Park slot ``s``: no occupant, pos -1, sampling state neutral
        (finish and preemption both come through here)."""
        self.active[s] = None
        self.pos[s] = -1
        self.tokens[s, 0] = 0
        self.samp_temp[s] = 0.0
        self.samp_topk[s] = 0
        self.samp_topp[s] = 1.0
        self.samp_keys[s] = 0

    def _finish(self, s: int, reason: str):
        req = self.active[s]
        req.done = True
        req.state = RequestState.FINISHED
        req.finish_reason = reason
        req.t_finish = time.perf_counter()
        # close the request's span stream (FINISHED is terminal — an end,
        # not a new span) and count the finish by reason
        self.tm.req_end(self.replica, req.req_id, reason=reason,
                        tokens=len(req.output))
        self._m_finished.labels(replica=str(self.replica),
                                reason=reason).inc()
        self._clear_slot(s)
        if self.kv is not None:
            self.kv.free_slot(s)  # pages return to the pool immediately
        self.scheduler.on_finish(req)
        self._finished.append(req)

    # ----------------------------------------------------------- preempt
    def _ensure_ckpt_fns(self):
        """Dense checkpoint/restore steps, compiled on first preemption
        and shared module-wide (same memoization rationale as the step
        cache).  ``copy_out`` slices one slot's stripe (then device_get'd
        to a host buffer); ``copy_in`` rewrites it in place (donated)."""
        if self._copy_out is None:
            self._copy_out, self._copy_in = _ckpt_fns(self.model,
                                                      self.max_len)

    def _execute_preemption(self, pre):
        """Executor half of preemption: capture the slot's device state
        into the request's checkpoint and park the slot.  The scheduler
        already did the host half (page detach, DRF credit, requeue);
        this MUST run before any admission reuses the slot."""
        s, req = pre.slot, pre.req
        if self.kv is not None:
            kv_snap = None  # zero-copy: the detached page chain IS the KV
        else:
            self._ensure_ckpt_fns()
            kv_snap = jax.device_get(self._copy_out(self.caches,
                                                    jnp.int32(s)))
        req._ckpt = Checkpoint(pos=int(self.pos[s]),
                               last_token=int(self.tokens[s, 0]),
                               pages=getattr(req, "_ckpt_pages", None),
                               kv=kv_snap)
        self._set_state(req, RequestState.PREEMPTED, pos=req._ckpt.pos,
                        count=req.preempt_count + 1)
        req.preempt_count += 1
        self._clear_slot(s)

    def _execute_resume(self, s: int, req: Request):
        """Restore a checkpointed request into slot ``s`` at
        ``pos = checkpoint`` — no prefill re-run.  Paged: the page table
        row was remapped by the scheduler (attach_slot).  Dense: the
        host-side stripe snapshot is written back in place (full stripe,
        so SSM/recurrent state restores exactly and the previous
        occupant leaves no residue)."""
        ck = req._ckpt
        if self.kv is None:
            self._ensure_ckpt_fns()
            self.caches = self._copy_in(self.caches,
                                        jax.device_put(ck.kv),
                                        jnp.int32(s))
        self.pos[s] = ck.pos
        self.tokens[s, 0] = ck.last_token
        req._feed = deque()  # type: ignore
        req._ckpt = None
        req._ckpt_pages = None
        req._preempted = False
        req._handoff_kv = 0  # adopted chain now charged via _drf_charged
        self._set_state(req, RequestState.DECODE, resume=True,
                        pos=int(self.pos[s]))

    def release(self, req: Request) -> Checkpoint:
        """Voluntarily checkpoint a *running* request so its KV can move
        to another engine (the disagg handoff / drain-migration path).

        Same device capture as ``_execute_preemption`` — paged detaches
        the slot's page chain zero-copy, dense snapshots the cache stripe
        to host — but the request is *leaving this engine*: its trace
        span stream on this pid is ended (not transitioned), the
        scheduler is credited the full DRF charge (slot AND chain — the
        pages depart with the request), and the caller re-submits the
        checkpointed request to the destination engine, which resumes it
        at ``pos = checkpoint`` with no prefill re-run."""
        s = next(i for i, r in enumerate(self.active) if r is req)
        if self.kv is not None:
            req._ckpt_pages = self.kv.detach_slot(s)
            kv_snap = None
        else:
            self._ensure_ckpt_fns()
            kv_snap = jax.device_get(self._copy_out(self.caches,
                                                    jnp.int32(s)))
        req._ckpt = Checkpoint(pos=int(self.pos[s]),
                               last_token=int(self.tokens[s, 0]),
                               pages=getattr(req, "_ckpt_pages", None),
                               kv=kv_snap)
        req.state = RequestState.PREEMPTED
        self.tm.req_end(self.replica, req.req_id, reason="handoff",
                        pos=req._ckpt.pos)
        req.preempt_count += 1
        req._preempted = True
        self._clear_slot(s)
        self.scheduler.on_finish(req)  # full DRF credit: the chain leaves
        return req._ckpt

    def _execute_admission(self, adm):
        """Executor half of admission: apply one scheduler decision —
        device prefill / checkpoint restore / slot reset / token-feed
        setup."""
        s, req = adm.slot, adm.req
        self.active[s] = req
        sp = req.sampling
        self.samp_temp[s] = sp.temperature
        self.samp_topk[s] = sp.top_k
        self.samp_topp[s] = sp.top_p
        self.samp_keys[s] = sp.key_data(req.req_id)
        if adm.resume:
            self._execute_resume(s, req)
            return
        self._set_state(req, RequestState.PREFILL, slot=s)
        if self.kv is not None:
            # CoW pages (adm.kv.cow) need no device copy here: they span
            # [start, matched), so the first re-run prefill chunk rewrites
            # every one of them in full (chunks write whole pages) before
            # anything reads them
            self._prefill_slot(s, req, start=adm.kv.start)
            # prefill already produced the first token; the request may
            # complete before a single decode tick runs, in which case
            # the freed slot admits again immediately
            if not self._maybe_stop(s):
                self._set_state(req, RequestState.DECODE)
            return
        if self._needs_reset:
            self.caches = self._reset(self.caches, jnp.int32(s))
        if self.chunked:
            self._prefill_slot(s, req)
            if not self._maybe_stop(s):
                self._set_state(req, RequestState.DECODE)
        else:
            req._feed = deque(req.prompt.tolist())  # type: ignore
            self.tokens[s, 0] = req._feed.popleft()
            self.pos[s] = 0

    def _admit_continuous(self):
        """Decide/execute rounds until the scheduler has nothing to admit
        (a prefilled request can finish instantly and free its slot for
        the same tick, hence the loop).  Preemptions execute first: a
        slot must be checkpointed before its next occupant prefills."""
        while True:
            plan = self.scheduler.decide(self.active)
            if not plan:
                return
            for pre in plan.preemptions:
                self._execute_preemption(pre)
            for adm in plan.admissions:
                self._execute_admission(adm)

    def _prefill_slot(self, s: int, req: Request, start: int = 0):
        """Run the slot's prompt tokens [start, prompt_len) through the
        stack in (1, C) chunks, writing the KV cache in place; the token
        drawn from the last real token's logits (greedy or sampled, per
        the request) seeds decode at pos = prompt_len.

        ``start`` (paged mode, a multiple of C and <= prompt_len - 1) is
        where the prefix cache left off; the paged step additionally
        takes the page-table array, and the full prompt pages are
        published for future prefix hits afterwards."""
        c = self.prefill_chunk
        prompt = np.asarray(req.prompt, np.int32)
        p = len(prompt)
        n_chunks = max(1, -(-(p - start) // c))
        padded = np.zeros(n_chunks * c, np.int32)
        padded[:p - start] = prompt[start:]
        req._feed = deque()  # type: ignore
        sp = req.sampling
        sampling = sp.temperature > 0
        extra = (() if self.kv is None
                 else (jnp.asarray(self.kv.page_table),))
        samp = (() if not sampling else
                (jnp.float32(sp.temperature), jnp.int32(sp.top_k),
                 jnp.float32(sp.top_p),
                 jnp.asarray(sp.key_data(req.req_id))))
        prefill = self._prefill_sampled if sampling else self._prefill
        nxt = None
        for ci in range(n_chunks):
            last = (p - start - 1) - ci * c  # final-chunk row of the
            last_row = last if 0 <= last < c else 0  # last real token
            chunk = jnp.asarray(padded[None, ci * c:(ci + 1) * c])
            if self._pf_buf is not None:
                # buffered paged prefill: thread the dense slot view
                # through the chunk loop; a prefix-cache hit rebuilds it
                # from the page table on the first chunk only
                fn = prefill
                if ci == 0 and start > 0:
                    fn = (self._prefill_gather_sampled if sampling
                          else self._prefill_gather)
                if sampling:
                    nxt, self.caches, self._pf_buf = fn(
                        self.params, self.caches, chunk, jnp.int32(s),
                        jnp.int32(start + ci * c), *extra, self._pf_buf,
                        jnp.int32(last_row), *samp)
                else:
                    nxt, self.caches, self._pf_buf = fn(
                        self.params, self.caches, chunk, jnp.int32(s),
                        jnp.int32(start + ci * c), *extra, self._pf_buf)
            elif sampling:
                nxt, self.caches = prefill(
                    self.params, self.caches, chunk, jnp.int32(s),
                    jnp.int32(start + ci * c), *extra,
                    jnp.int32(last_row), *samp)
            else:
                nxt, self.caches = prefill(
                    self.params, self.caches, chunk, jnp.int32(s),
                    jnp.int32(start + ci * c), *extra)
        tok = (int(np.asarray(nxt)) if sampling
               else int(np.asarray(nxt)[(p - start - 1)
                                        - (n_chunks - 1) * c]))
        self.pos[s] = p
        self.tokens[s, 0] = tok
        self._emit(req, tok)
        self._admit_emitted += 1
        if self.kv is not None:
            self.kv.register_prefix(s, prompt)

    def _maybe_stop(self, s: int) -> bool:
        req = self.active[s]
        reason = matches_stop(req.output, req.sampling, req.eos_id)
        if reason is None and (len(req.output) >= req.max_new_tokens
                               or self.pos[s] >= self.max_len - 1):
            reason = "length"
        if reason is not None:
            self._finish(s, reason)
            return True
        return False

    # ----------------------------------------------------------- wave mode
    def _admit_wave(self):
        """Wave batching: admit a fresh wave only when every slot is free —
        all slots then decode in lockstep at one scalar position (static
        shapes, exact cache indexing).  Prompts are fed token-by-token;
        the admission *order* still follows the configured policy."""
        if any(r is not None for r in self.active) or not self.queue:
            return
        self.caches = jax.tree.map(lambda c: jnp.zeros_like(c), self.caches)
        self.pos[:] = 0
        self.tokens[:] = 0
        for adm in self.scheduler.decide(self.active).admissions:
            s, req = adm.slot, adm.req
            self.active[s] = req
            sp = req.sampling
            self.samp_temp[s] = sp.temperature
            self.samp_topk[s] = sp.top_k
            self.samp_topp[s] = sp.top_p
            self.samp_keys[s] = sp.key_data(req.req_id)
            self._set_state(req, RequestState.PREFILL, slot=s)
            req._feed = deque(req.prompt.tolist())  # type: ignore
            self.tokens[s, 0] = req._feed.popleft()

    # ------------------------------------------------------------ stepping
    def step(self) -> int:
        """One engine tick = one decode step for every live slot."""
        if self.mode == "wave":
            emitted = self._step_wave()
        else:
            emitted = self._step_continuous()
        self._tick_telemetry(emitted)
        return emitted

    def _put_b(self, x):
        """Slot-dim host array -> device.  Sharded engines place it over
        the mesh's data axes (the layout the compiled step expects for
        the slot dim); unsharded engines just convert.  The page table
        deliberately does NOT come through here — every host gathers
        pages, so it stays replicated."""
        a = jnp.asarray(x)
        if self._batch_sharding is not None:
            a = jax.device_put(a, self._batch_sharding)
        return a

    def _step_for_splits(self, splits: int, sampled: bool):
        """Decode step with a given split-K fan-out (fan-outs from the
        small set the heuristic emits: 1, 2, 4, 8), for whichever cache
        layout this engine runs.  Resolution goes through the
        module-level step cache, so every engine over the same model
        shares one compiled callable per fan-out."""
        if splits <= 1:
            return self._step_sampled if sampled else self._step
        if self.kv is not None:
            return compiled_step(self.model, "paged_serve", sampled=sampled,
                                 page_size=self.config.page_size,
                                 decode_splits=splits)
        return compiled_step(self.model, "serve", sampled=sampled,
                             decode_splits=splits)

    def _step_continuous(self) -> int:
        self._admit_emitted = 0
        self._admit_continuous()
        emitted = self._admit_emitted  # first tokens from chunked prefill
        if self.role == "prefill":
            # prefill workers never decode: chunked prefill completed
            # atomically inside admission (emitting the first token), and
            # the router extracts the finished slot as a handoff this same
            # tick — so the decode phase below would only burn a step
            return emitted
        live = sum(r is not None for r in self.active)
        if not live:
            return emitted
        if self.draft_k:
            return self._decode_tick_spec(emitted, live)
        return self._decode_tick_plain(emitted, live)

    def _decode_tick_plain(self, emitted: int, live: int) -> int:
        """One single-token decode step for every live slot (the
        baseline tick; also what a speculative engine dispatches on
        ticks where no slot proposed a draft — the T-wide verify step
        would pay ~T x attention/unembed work to emit the same one
        token per slot)."""
        pos = self._put_b(self.pos)
        # pay the sampling math only when a live slot actually samples
        # (finished slots reset their temp to 0)
        sampling = bool(self.samp_temp.max() > 0)
        samp = (() if not sampling else
                (self._put_b(self.samp_temp), self._put_b(self.samp_topk),
                 self._put_b(self.samp_topp), self._put_b(self.samp_keys)))
        if self.kv is not None:
            step = self._step_sampled if sampling else self._step
            if self._autotune:
                step = self._step_for_splits(pick_decode_splits(
                    int(self.pos.max()), live, max_len=self.max_len,
                    page_size=self.config.page_size), sampling)
            nxt_dev, self.caches = step(
                self.params, self.caches, self._put_b(self.tokens), pos,
                jnp.asarray(self.kv.page_table), *samp)
        else:
            step = self._step_sampled if sampling else self._step
            if self._autotune:
                step = self._step_for_splits(pick_decode_splits(
                    int(self.pos.max()), live, max_len=self.max_len),
                    sampling)
            nxt_dev, self.caches = step(self.params, self.caches,
                                        self._put_b(self.tokens), pos,
                                        *samp)
        nxt = np.asarray(nxt_dev)
        for s, req in enumerate(self.active):
            if req is None:
                continue
            self.pos[s] += 1
            feed = getattr(req, "_feed")
            if feed:  # still consuming the prompt (token-feed path)
                self.tokens[s, 0] = feed.popleft()
                continue
            if req.state is RequestState.PREFILL:  # token-feed path done
                self._set_state(req, RequestState.DECODE)
            tok = int(nxt[s, 0])
            self._emit(req, tok)
            emitted += 1
            self.tokens[s, 0] = tok
            self._maybe_stop(s)
        return emitted

    # ------------------------------------------------------- speculative
    def _draft_cap(self, s: int, req: Request) -> int:
        """Deepest draft slot ``s`` may carry this tick.

        Bounded by (a) the configured ``draft_k``; (b) the request's
        remaining token budget minus one (the verify tick always emits
        at least the correction token, so cap + 1 never overshoots
        ``max_new_tokens``); (c) the ``max_len`` window (after accepting
        everything, ``pos`` stays <= max_len - 1 — the same boundary the
        baseline length-stop enforces); and (d), paged only, the slot's
        mapped page span — admission reserved pages for the full token
        budget, so (b) already implies (d), but the explicit bound means
        an off-by-one can reject a draft, never write an unheld page.
        Draft padding beyond the cap still flows through the compiled
        step; its writes land clamped / in the null page and its rows
        are never read (rollback = position truncation).
        """
        cap = min(self.draft_k,
                  req.max_new_tokens - len(req.output) - 1,
                  self.max_len - 2 - int(self.pos[s]))
        if self.kv is not None:
            cap = min(cap, self.kv.slot_span(s) - 1 - int(self.pos[s]))
        return max(cap, 0)

    def _decode_tick_spec(self, emitted: int, live: int) -> int:
        """One speculative decode tick: draft per slot (host), verify all
        drafts in one compiled multi-token step (device), accept the
        longest confirmed prefix plus the free correction token (host).

        The emission loop replays the baseline tick ordering per token —
        advance ``pos``, emit, stop-check — so eos/stop/length fire at
        exactly the token they would have in sequential decode and any
        accepted-but-past-stop tokens are discarded, keeping the output
        stream bitwise-identical to the non-speculative engine.

        Ticks where no slot proposes a draft (incompressible output, or
        every slot at cap 0 near its budget) fall back to the plain
        single-token step — already compiled, and bitwise the same as a
        draft-less verify — instead of paying the T-wide verify work to
        emit one token per slot; ``spec_ticks`` therefore counts only
        the multi-token verify dispatches.
        """
        t_width = self.draft_k + 1
        feed = np.zeros((self.slots, t_width), np.int32)
        feed[:, 0] = self.tokens[:, 0]
        draft_len = np.zeros(self.slots, np.int32)
        for s, req in enumerate(self.active):
            if req is None or getattr(req, "_feed", None):
                continue  # parked / token-feeding slots carry no draft
            cap = self._draft_cap(s, req)
            if cap <= 0:
                continue
            # hand the drafter only its lookback window: per-tick host
            # work stays O(lookback), not O(tokens generated so far)
            lb = getattr(self.drafter, "lookback", 0)
            out = req.output
            if lb and len(out) >= lb:
                ctx = np.asarray(out[-lb:], np.int32)
            else:
                head = (req.prompt[max(len(req.prompt) + len(out) - lb, 0):]
                        if lb else req.prompt)
                ctx = np.concatenate([np.asarray(head, np.int32),
                                      np.asarray(out, np.int32)])
            d = self.drafter.propose(ctx, cap)
            if len(d):
                feed[s, 1:1 + len(d)] = d
                draft_len[s] = len(d)
        if not draft_len.any():
            return self._decode_tick_plain(emitted, live)
        pos = self._put_b(self.pos)
        sampling = bool(self.samp_temp.max() > 0)
        samp = (() if not sampling else
                (self._put_b(self.samp_temp), self._put_b(self.samp_topk),
                 self._put_b(self.samp_topp), self._put_b(self.samp_keys)))
        step = self._spec_step_sampled if sampling else self._spec_step
        extra = (() if self.kv is None
                 else (jnp.asarray(self.kv.page_table),))
        target_dev, self.caches = step(self.params, self.caches,
                                       self._put_b(feed), pos, *extra, *samp)
        target = np.asarray(target_dev)  # (B, T) per-row verified tokens
        self.spec_ticks += 1
        for s, req in enumerate(self.active):
            if req is None:
                continue
            fq = getattr(req, "_feed")
            if fq:  # still consuming the prompt (token-feed path)
                self.pos[s] += 1
                self.tokens[s, 0] = fq.popleft()
                continue
            if req.state is RequestState.PREFILL:  # token-feed path done
                self._set_state(req, RequestState.DECODE)
            k_s = int(draft_len[s])
            m = (speculative_accept(feed[s, 1:1 + k_s], target[s, :k_s])
                 if k_s else 0)
            self.spec_proposed += k_s
            self.spec_accepted += m
            for t in range(m + 1):
                self.pos[s] += 1
                tok = int(target[s, t])
                self._emit(req, tok)
                emitted += 1
                self.spec_emitted += 1
                self.tokens[s, 0] = tok
                if self._maybe_stop(s):
                    break  # accepted-but-past-stop tokens are discarded
        return emitted

    def spec_stats(self) -> dict:
        """Speculative-decode telemetry: draft acceptance rate and the
        average tokens emitted per verify tick (1.0 = plain decode).
        Values are read back through the metrics registry (the
        function-backed ``engine_spec_*`` gauges), keeping this legacy
        dict a view over the one telemetry source of truth."""
        if not self.draft_k:
            return {"draft_k": 0}
        v = self.tm.registry.value
        lbl = {"replica": str(self.replica)}
        proposed = int(v("engine_spec_proposed", **lbl))
        accepted = int(v("engine_spec_accepted", **lbl))
        emitted = int(v("engine_spec_emitted", **lbl))
        ticks = int(v("engine_spec_ticks", **lbl))
        return {
            "draft_k": self.draft_k,
            "drafter": self.config.drafter,
            "proposed": proposed,
            "accepted": accepted,
            "acceptance_rate": accepted / max(proposed, 1),
            "spec_ticks": ticks,
            "tokens_per_tick": emitted / max(ticks, 1),
        }

    def _step_wave(self) -> int:
        self._admit_wave()
        if not any(r is not None for r in self.active):
            return 0
        pos = int(self.pos.max())  # lockstep position (wave batching)
        logits, self.caches = self._decode_one(self.params, self.caches,
                                               jnp.asarray(self.tokens),
                                               jnp.int32(pos))
        if bool(self.samp_temp.max() > 0):
            # sampled wave mode: host-side draw from the wave logits.
            # Slots advance in lockstep from position 0, so each slot's
            # absolute token position IS the wave position — the same
            # (key, position) fold as the continuous sampled step, hence
            # the same trajectory for a given seed; greedy (temp 0) rows
            # stay the bitwise argmax inside sample_tokens.
            sampler = compiled_fn(("wave_sample",), lambda: sample_tokens)
            nxt = np.asarray(sampler(
                logits, jnp.asarray(self.pos),
                jnp.asarray(self.samp_temp), jnp.asarray(self.samp_topk),
                jnp.asarray(self.samp_topp), jnp.asarray(self.samp_keys)),
                dtype=np.int32)
        else:
            nxt = np.asarray(jnp.argmax(logits, axis=-1), dtype=np.int32)
        emitted = 0
        for s, req in enumerate(self.active):
            if req is None:
                continue
            self.pos[s] += 1
            feed = getattr(req, "_feed")
            if feed:  # still consuming the prompt
                self.tokens[s, 0] = feed.popleft()
                continue
            if req.state is RequestState.PREFILL:
                self._set_state(req, RequestState.DECODE)
            tok = int(nxt[s])
            self._emit(req, tok)
            emitted += 1
            self.tokens[s, 0] = tok
            self._maybe_stop(s)
        return emitted

    # --------------------------------------------------------- cluster hooks
    def free_slots(self) -> int:
        """Slots a router may target right now: parked slots minus the
        queue the engine already owes admissions to (queued requests
        claim freed slots before any new placement lands)."""
        return max(0, sum(r is None for r in self.active)
                   - len(self.scheduler.queue))

    def offer(self) -> dict:
        """Resource offer for a cluster router (the Mesos ``advertise``
        analogue, per engine replica): free decode slots, free KV pages
        (``None`` for the dense cache — slots are the only currency),
        and the backlog depth a placement would queue behind.

        Sharded paged engines (``mesh_shape`` with > 1 data host)
        additionally advertise ``free_pages_by_host`` — the per-host
        sub-pool balance.  The aggregate ``free_pages`` stays in the
        offer unchanged, so unsharded routers compose as before; a
        host-aware router can see that 40 free pages split 40/0 admit
        less than 20/20."""
        out = {
            "free_slots": self.free_slots(),
            "free_pages": (None if self.kv is None
                           else self.kv.pool.available),
            "page_size": None if self.kv is None else self.kv.page_size,
            "queue_depth": len(self.scheduler.queue),
        }
        if self.kv is not None and self.kv.num_hosts > 1:
            out["free_pages_by_host"] = self.kv.free_by_host()
        return out

    def live_requests(self) -> list:
        """Every unfinished request this engine holds — running slots
        plus its admission queue (which includes PREEMPTED requests
        waiting to resume).  A router recovering a lost replica replays
        exactly this set."""
        return ([r for r in self.active if r is not None]
                + [r for r in self.queue])

    def can_accept(self, req: Request) -> bool:
        """Could a router place ``req`` here without queuing it behind
        backpressure?  Host-side sizing only (free slot + page fit);
        optimistic across multiple placements in one tick — the engine's
        own scheduler absorbs any overshoot as ordinary backpressure."""
        if self.free_slots() < 1:
            return False
        if self.kv is not None:
            return (self.kv.fits_ever(len(req.prompt), req.max_new_tokens)
                    and self.kv.fits_now(req.prompt, req.max_new_tokens))
        return 0 < len(req.prompt) < self.max_len

    # ------------------------------------------------------------- metrics
    def kv_reserved_bytes(self) -> int:
        """HBM bytes held by the KV cache (dense stripes or page pools)."""
        return sum(leaf.size * leaf.dtype.itemsize
                   for leaf in jax.tree.leaves(self.caches))

    def kv_stats(self) -> dict:
        stats = {"cache": self.cache,
                 "kv_reserved_bytes": self.kv_reserved_bytes()}
        if self.kv is not None:
            stats.update(self.kv.stats())
        return stats

    def run(self, max_ticks: int = 10_000,
            on_stall: Optional[str] = None) -> list[Request]:
        """Drive the engine until every request drains.

        If ``max_ticks`` is exhausted with requests still queued or
        active, the stall is *reported*, never silently truncated:
        ``on_stall="raise"`` (the default, from ``ServeConfig``) raises
        ``ServeStalled``; ``"warn"`` emits a ``RuntimeWarning`` carrying
        the undrained counts and returns the partial results."""
        stall_mode = on_stall or self.config.on_stall
        if stall_mode not in ("raise", "warn"):
            raise ValueError(f"on_stall must be 'raise' or 'warn': "
                             f"{stall_mode!r}")
        ticks = 0
        while self.queue or any(r is not None for r in self.active):
            if ticks >= max_ticks:
                queued = len(self.queue)
                live = sum(r is not None for r in self.active)
                msg = (f"ServeEngine.run() exhausted {max_ticks} ticks "
                       f"with {queued + live} requests undrained "
                       f"({queued} queued, {live} active)")
                if stall_mode == "raise":
                    raise ServeStalled(msg)
                warnings.warn(msg, RuntimeWarning, stacklevel=2)
                break
            self.step()
            ticks += 1
        finished, self._finished = self._finished, []
        return finished
