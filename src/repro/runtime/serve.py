"""Batched serving loop: prefill + greedy decode over a fixed slot batch.

The decode step is the ``serve_step`` the dry-run lowers for the decode_32k
/ long_500k cells.  ``ServeEngine`` adds the minimal production affordances
around it: a request queue, fixed decode slots (static shapes — no
recompilation), per-slot stop handling, and slot recycling (continuous-
batching-lite).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.steps import make_serve_step


@dataclass
class Request:
    req_id: int
    prompt: np.ndarray  # (prompt_len,) int32
    max_new_tokens: int = 16
    eos_id: int = -1  # -1: never stops early
    output: list = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model, params, *, batch_slots: int, max_len: int,
                 mesh=None, cache_shardings=None):
        self.model = model
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.mesh = mesh
        self.queue: deque[Request] = deque()
        self.active: list[Optional[Request]] = [None] * batch_slots
        self.pos = np.zeros(batch_slots, dtype=np.int32)
        self.caches = model.init_cache(batch_slots, max_len)
        if cache_shardings is not None:
            self.caches = jax.device_put(self.caches, cache_shardings)
        self.tokens = jnp.zeros((batch_slots, 1), jnp.int32)
        self._step = jax.jit(make_serve_step(model), donate_argnums=(1,))
        self._decode_one = jax.jit(model.decode_step, donate_argnums=(1,))

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        """Wave batching: admit a fresh wave only when every slot is free —
        all slots then decode in lockstep at one scalar position (static
        shapes, exact cache indexing).  Prompts are fed token-by-token."""
        if any(r is not None for r in self.active) or not self.queue:
            return
        self.caches = jax.tree.map(lambda c: jnp.zeros_like(c), self.caches)
        self.pos[:] = 0
        new_tokens = np.zeros((self.slots, 1), dtype=np.int32)
        for s in range(self.slots):
            if not self.queue:
                break
            req = self.queue.popleft()
            self.active[s] = req
            req._feed = deque(req.prompt.tolist())  # type: ignore
            new_tokens[s, 0] = req._feed.popleft()
        self.tokens = jnp.asarray(new_tokens)

    def step(self) -> int:
        """One engine tick = one decode step for every active slot."""
        self._admit()
        if not any(r is not None for r in self.active):
            return 0
        pos = int(self.pos.max())  # lockstep position (wave batching)
        logits, self.caches = self._decode_one(self.params, self.caches,
                                               self.tokens, jnp.int32(pos))
        nxt = np.asarray(jnp.argmax(logits, axis=-1), dtype=np.int32)
        emitted = 0
        new_tokens = np.asarray(self.tokens).copy()
        for s, req in enumerate(self.active):
            if req is None:
                continue
            self.pos[s] += 1
            feed = getattr(req, "_feed")
            if feed:  # still consuming the prompt
                new_tokens[s, 0] = feed.popleft()
                continue
            tok = int(nxt[s])
            req.output.append(tok)
            emitted += 1
            new_tokens[s, 0] = tok
            if (len(req.output) >= req.max_new_tokens
                    or tok == req.eos_id
                    or self.pos[s] >= self.max_len - 1):
                req.done = True
                self.active[s] = None
        self.tokens = jnp.asarray(new_tokens)
        return emitted

    def run(self, max_ticks: int = 10_000) -> list[Request]:
        finished: list[Request] = []
        ticks = 0
        while (self.queue or any(self.active)) and ticks < max_ticks:
            before = [r for r in self.active if r]
            self.step()
            for r in before:
                if r.done:
                    finished.append(r)
            ticks += 1
        return finished
