"""Batched serving loop: continuous batching over a fixed slot batch.

The decode step is the ``serve_step`` the dry-run lowers for the decode_32k
/ long_500k cells.  ``ServeEngine`` adds the production affordances around
it: a request queue, fixed decode slots (static shapes — no recompilation),
per-slot stop handling, and per-slot admission.

Admission policy (``mode="continuous"``, the default)
-----------------------------------------------------
Any freed slot immediately admits the next queued request at its *own*
position — there is no wave barrier.  The decode step takes a per-slot
position vector ``pos[B]`` (free slots parked at -1), so every slot attends
its own prefix length in one ragged kernel call and work is proportional to
the tokens actually alive, not ``max_len * wave``.  Prompts are consumed by
**chunked prefill** where the architecture allows it (attention-only
plans): the prompt runs through the stack in (1, C) blocks that write the
KV cache in place — one step per C prompt tokens instead of one step per
token.  SSM/hybrid plans (conv + SSD state crosses chunk boundaries) fall
back to per-slot token feeding, still without a wave barrier; their slot
state is zeroed on admission since SSM state is not masked by position.

``mode="wave"`` keeps the legacy lockstep engine — admit a fresh wave only
when every slot is free, all slots decode at one scalar position, prompts
fed token-by-token — as the baseline ``benchmarks/serve_throughput.py``
measures continuous batching against (the serving analogue of the paper's
exclusive, non-co-scheduled mode).

Paged KV cache (``cache="paged"``, continuous mode only)
--------------------------------------------------------
The dense layout reserves a ``(max_len)`` HBM stripe per slot no matter
how short the request.  ``cache="paged"`` swaps it for a global page pool
(``runtime/kv_pool.py``): admission reserves exactly
``ceil((prompt + max_new) / page_size)`` pages under a pluggable
placement policy, ``submit`` queues with **backpressure** when the pool
is exhausted (``step`` never raises), and pages return to the pool the
moment a request finishes.  A prefix cache hashes full prompt pages so a
request sharing a cached prefix is admitted at ``pos = matched`` with the
shared pages mapped read-only — copy-on-write duplicates a shared page
only when the admission must write into it.  The decode step consumes
the ``(slots, max_pages)`` page-table array through the paged Pallas
kernel's scalar-prefetch contract (``kernels/paged_attention.py``).

All step functions keep static shapes and donate the caches, so each mode
compiles exactly once per (slots, max_len) and decodes in place.  Dense
continuous decode additionally picks its split-K fan-out per tick from
``(max(pos), live slots)`` (``steps.pick_decode_splits``) when
``RuntimeKnobs.decode_splits`` is 0 (auto); each chosen fan-out compiles
once and is cached.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.kv_pool import KVCacheManager
from repro.runtime.steps import (make_paged_prefill_chunk_step,
                                 make_paged_serve_step,
                                 make_prefill_chunk_step, make_serve_step,
                                 pick_decode_splits)


@dataclass
class Request:
    req_id: int
    prompt: np.ndarray  # (prompt_len,) int32
    max_new_tokens: int = 16
    eos_id: int = -1  # -1: never stops early
    output: list = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model, params, *, batch_slots: int, max_len: int,
                 mode: str = "continuous", prefill_chunk: int = 32,
                 mesh=None, cache_shardings=None, cache: str = "dense",
                 page_size: int = 16, num_pages: Optional[int] = None,
                 page_policy: str = "pack", prefix_cache: bool = True):
        assert mode in ("continuous", "wave"), mode
        assert cache in ("dense", "paged"), cache
        self.model = model
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.mode = mode
        self.mesh = mesh
        self.cache = cache
        self.queue: deque[Request] = deque()
        self.active: list[Optional[Request]] = [None] * batch_slots
        self.pos = np.full(batch_slots, -1, dtype=np.int32)
        self.tokens = np.zeros((batch_slots, 1), dtype=np.int32)
        self._finished: list[Request] = []
        self._admit_emitted = 0  # tokens emitted by chunked prefill
        self._decode_one = jax.jit(model.decode_step, donate_argnums=(1,))
        self.kv: Optional[KVCacheManager] = None
        if cache == "paged":
            if mode != "continuous":
                raise ValueError("cache='paged' requires mode='continuous'")
            if not model.supports_paged_cache():
                raise ValueError(
                    f"paged KV cache unsupported for "
                    f"family={model.cfg.family!r}")
            if max_len % page_size:
                raise ValueError(f"max_len {max_len} not a multiple of "
                                 f"page_size {page_size}")
            # prefill chunks must cover whole pages at page-aligned
            # offsets; C also divides max_len so chunk writes never clamp
            c = max(page_size, (min(prefill_chunk, max_len) // page_size)
                    * page_size)
            while max_len % c:
                c -= page_size
            self.prefill_chunk = c
            self.chunked = True
            # dense-equivalent capacity by default (+ the null page);
            # benchmarks pass a smaller pool to realize the HBM saving
            if num_pages is None:
                num_pages = batch_slots * (max_len // page_size) + 1
            self.kv = KVCacheManager(
                slots=batch_slots, max_len=max_len, page_size=page_size,
                num_pages=num_pages, policy=page_policy,
                prefix_cache=prefix_cache, chunk=c)
            self.caches = model.init_cache_paged(num_pages, page_size)
            self._step = jax.jit(make_paged_serve_step(model, page_size),
                                 donate_argnums=(1,))
            self._prefill = jax.jit(
                make_paged_prefill_chunk_step(model, page_size),
                donate_argnums=(1,))
        else:
            self.caches = model.init_cache(batch_slots, max_len)
            self._step = jax.jit(make_serve_step(model), donate_argnums=(1,))
            # chunked prefill: one compiled (1, C) step reused for every
            # slot and offset; C rounded down to a divisor of max_len so
            # padded chunk writes never clamp out of bounds.
            self.chunked = (mode == "continuous" and prefill_chunk > 1
                            and model.supports_chunked_prefill())
            c = max(1, min(prefill_chunk, max_len))
            while max_len % c:
                c -= 1
            self.prefill_chunk = c
            if self.chunked:
                self._prefill = jax.jit(make_prefill_chunk_step(model),
                                        donate_argnums=(1,))
        if cache_shardings is not None:
            self.caches = jax.device_put(self.caches, cache_shardings)
        # split-K autotune (dense Pallas decode only): pick the fan-out
        # per tick from (max(pos), live slots); each compiles once.
        self._autotune = (cache == "dense" and mode == "continuous"
                          and model.knobs.use_pallas
                          and model.knobs.decode_splits == 0)
        self._step_by_splits = {1: self._step}
        # SSM/hybrid state is not position-masked: zero a slot on admission
        self._needs_reset = model.cfg.family in ("ssm", "hybrid")
        if self._needs_reset:
            self._reset = self._make_slot_reset(model, max_len)

    @staticmethod
    def _make_slot_reset(model, max_len):
        """Zero one slot's cache state.  The batch axis of each cache leaf
        is found by diffing abstract cache shapes for two batch sizes (leaf
        layouts vary: stacked layer axes lead, SSM leaves differ from KV)."""
        s1 = jax.eval_shape(lambda: model.init_cache(1, max_len))
        s2 = jax.eval_shape(lambda: model.init_cache(2, max_len))
        axes = jax.tree.map(
            lambda a, b: next(i for i, (x, y) in enumerate(zip(a.shape,
                                                               b.shape))
                              if x != y), s1, s2)

        def reset(caches, slot):
            def zero(c, ax):
                keep = jnp.arange(c.shape[ax]) != slot
                shape = [1] * c.ndim
                shape[ax] = c.shape[ax]
                return c * keep.reshape(shape).astype(c.dtype)

            return jax.tree.map(zero, caches, axes)

        return jax.jit(reset, donate_argnums=(0,))

    def submit(self, req: Request):
        if not 0 < len(req.prompt) < self.max_len:
            raise ValueError(
                f"prompt length {len(req.prompt)} outside [1, "
                f"{self.max_len - 1}] for max_len={self.max_len}")
        if self.kv is not None and not self.kv.fits_ever(
                len(req.prompt), req.max_new_tokens):
            raise ValueError(
                f"request needs more pages than the pool can ever supply "
                f"(prompt {len(req.prompt)} + max_new {req.max_new_tokens} "
                f"vs {self.kv.pool.capacity} pages of "
                f"{self.kv.page_size})")
        self.queue.append(req)

    # ------------------------------------------------------------ admission
    def _finish(self, s: int):
        req = self.active[s]
        req.done = True
        self.active[s] = None
        self.pos[s] = -1
        self.tokens[s, 0] = 0
        if self.kv is not None:
            self.kv.free_slot(s)  # pages return to the pool immediately
        self._finished.append(req)

    def _admit_continuous(self):
        """Per-slot admission: every free slot takes the next request now.

        Paged mode reserves the request's pages first; if the pool cannot
        supply them the request stays queued (FIFO backpressure) and the
        tick proceeds with the slots already live — ``step`` never raises
        on exhaustion.
        """
        for s in range(self.slots):
            while self.active[s] is None and self.queue:
                if self.kv is not None:
                    req = self.queue[0]
                    res = self.kv.admit(s, req.prompt, req.max_new_tokens)
                    if res is None:
                        return  # backpressure: retry after slots drain
                    self.queue.popleft()
                    self.active[s] = req
                    # CoW pages (res.cow) need no device copy here: they
                    # span [start, matched), so the first re-run prefill
                    # chunk rewrites every one of them in full (chunks
                    # write whole pages) before anything reads them
                    self._prefill_slot(s, req, start=res.start)
                    self._maybe_stop(s)
                    continue
                req = self.queue.popleft()
                self.active[s] = req
                if self._needs_reset:
                    self.caches = self._reset(self.caches, jnp.int32(s))
                if self.chunked:
                    self._prefill_slot(s, req)
                    # prefill already produced the first token; the request
                    # may complete before a single decode tick runs, in
                    # which case the freed slot admits again immediately
                    self._maybe_stop(s)
                else:
                    req._feed = deque(req.prompt.tolist())  # type: ignore
                    self.tokens[s, 0] = req._feed.popleft()
                    self.pos[s] = 0

    def _prefill_slot(self, s: int, req: Request, start: int = 0):
        """Run the slot's prompt tokens [start, prompt_len) through the
        stack in (1, C) chunks, writing the KV cache in place; the last
        real token's logits seed decode at pos = prompt_len.

        ``start`` (paged mode, a multiple of C and <= prompt_len - 1) is
        where the prefix cache left off; the paged step additionally
        takes the page-table array, and the full prompt pages are
        published for future prefix hits afterwards."""
        c = self.prefill_chunk
        prompt = np.asarray(req.prompt, np.int32)
        p = len(prompt)
        n_chunks = max(1, -(-(p - start) // c))
        padded = np.zeros(n_chunks * c, np.int32)
        padded[:p - start] = prompt[start:]
        req._feed = deque()  # type: ignore
        extra = (() if self.kv is None
                 else (jnp.asarray(self.kv.page_table),))
        nxt = None
        for ci in range(n_chunks):
            chunk = jnp.asarray(padded[None, ci * c:(ci + 1) * c])
            nxt, self.caches = self._prefill(
                self.params, self.caches, chunk, jnp.int32(s),
                jnp.int32(start + ci * c), *extra)
        tok = int(np.asarray(nxt)[(p - start - 1) - (n_chunks - 1) * c])
        self.pos[s] = p
        self.tokens[s, 0] = tok
        req.output.append(tok)
        self._admit_emitted += 1
        if self.kv is not None:
            self.kv.register_prefix(s, prompt)

    def _maybe_stop(self, s: int) -> bool:
        req = self.active[s]
        if (len(req.output) >= req.max_new_tokens
                or (req.output and req.output[-1] == req.eos_id)
                or self.pos[s] >= self.max_len - 1):
            self._finish(s)
            return True
        return False

    # ----------------------------------------------------------- wave mode
    def _admit_wave(self):
        """Wave batching: admit a fresh wave only when every slot is free —
        all slots then decode in lockstep at one scalar position (static
        shapes, exact cache indexing).  Prompts are fed token-by-token."""
        if any(r is not None for r in self.active) or not self.queue:
            return
        self.caches = jax.tree.map(lambda c: jnp.zeros_like(c), self.caches)
        self.pos[:] = 0
        self.tokens[:] = 0
        for s in range(self.slots):
            if not self.queue:
                break
            req = self.queue.popleft()
            self.active[s] = req
            req._feed = deque(req.prompt.tolist())  # type: ignore
            self.tokens[s, 0] = req._feed.popleft()

    # ------------------------------------------------------------ stepping
    def step(self) -> int:
        """One engine tick = one decode step for every live slot."""
        if self.mode == "wave":
            return self._step_wave()
        return self._step_continuous()

    def _step_for_splits(self, splits: int):
        """Dense decode step with a given split-K fan-out, compiled once
        per fan-out (the small set the heuristic emits: 1, 2, 4, 8)."""
        fn = self._step_by_splits.get(splits)
        if fn is None:
            model = type(self.model)(
                self.model.cfg,
                self.model.knobs.with_(decode_splits=splits))
            fn = jax.jit(make_serve_step(model), donate_argnums=(1,))
            self._step_by_splits[splits] = fn
        return fn

    def _step_continuous(self) -> int:
        self._admit_emitted = 0
        self._admit_continuous()
        emitted = self._admit_emitted  # first tokens from chunked prefill
        live = sum(r is not None for r in self.active)
        if not live:
            return emitted
        pos = jnp.asarray(self.pos)
        if self.kv is not None:
            nxt_dev, self.caches = self._step(
                self.params, self.caches, jnp.asarray(self.tokens), pos,
                jnp.asarray(self.kv.page_table))
        else:
            step = self._step
            if self._autotune:
                step = self._step_for_splits(pick_decode_splits(
                    int(self.pos.max()), live, max_len=self.max_len))
            nxt_dev, self.caches = step(self.params, self.caches,
                                        jnp.asarray(self.tokens), pos)
        nxt = np.asarray(nxt_dev)
        for s, req in enumerate(self.active):
            if req is None:
                continue
            self.pos[s] += 1
            feed = getattr(req, "_feed")
            if feed:  # still consuming the prompt (token-feed path)
                self.tokens[s, 0] = feed.popleft()
                continue
            tok = int(nxt[s, 0])
            req.output.append(tok)
            emitted += 1
            self.tokens[s, 0] = tok
            self._maybe_stop(s)
        return emitted

    def _step_wave(self) -> int:
        self._admit_wave()
        if not any(r is not None for r in self.active):
            return 0
        pos = int(self.pos.max())  # lockstep position (wave batching)
        logits, self.caches = self._decode_one(self.params, self.caches,
                                               jnp.asarray(self.tokens),
                                               jnp.int32(pos))
        nxt = np.asarray(jnp.argmax(logits, axis=-1), dtype=np.int32)
        emitted = 0
        for s, req in enumerate(self.active):
            if req is None:
                continue
            self.pos[s] += 1
            feed = getattr(req, "_feed")
            if feed:  # still consuming the prompt
                self.tokens[s, 0] = feed.popleft()
                continue
            tok = int(nxt[s])
            req.output.append(tok)
            emitted += 1
            self.tokens[s, 0] = tok
            if (len(req.output) >= req.max_new_tokens
                    or tok == req.eos_id
                    or self.pos[s] >= self.max_len - 1):
                req.done = True
                self.active[s] = None
                self._finished.append(req)
        return emitted

    # ------------------------------------------------------------- metrics
    def kv_reserved_bytes(self) -> int:
        """HBM bytes held by the KV cache (dense stripes or page pools)."""
        return sum(leaf.size * leaf.dtype.itemsize
                   for leaf in jax.tree.leaves(self.caches))

    def kv_stats(self) -> dict:
        stats = {"cache": self.cache,
                 "kv_reserved_bytes": self.kv_reserved_bytes()}
        if self.kv is not None:
            stats.update(self.kv.stats())
        return stats

    def run(self, max_ticks: int = 10_000) -> list[Request]:
        ticks = 0
        while ((self.queue or any(r is not None for r in self.active))
               and ticks < max_ticks):
            self.step()
            ticks += 1
        finished, self._finished = self._finished, []
        return finished
