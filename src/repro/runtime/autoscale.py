"""Elastic per-role replica scaling: policy registry + autoscaler core.

The serving analogue of the auto-scaling Docker HPC clusters in
PAPERS.md (Yu & Huang 1509.08231; Vaillancourt et al. 2006.14784): a
policy watches each role's backlog and grows or shrinks that role's
replica set between ``min``/``max`` bounds.  The module is pure host
bookkeeping — no jax — so ``core/simulator.py`` drives the *same*
``Autoscaler`` against a fake cluster at thousands-of-requests scale
that ``runtime/disagg.py`` runs against real engines.

Policies mirror ``core/policies.get_policy``: small objects registered
in ``AUTOSCALE_POLICIES``, resolved by ``get_autoscale_policy(name)``:

* ``queue-depth``  — scale up when a role's backlog exceeds one
  replica's worth of slots; scale down when the backlog is empty and at
  least two replicas' worth of slots sit free (the asymmetric
  thresholds are the hysteresis band).
* ``slo-backlog``  — same shape, but the upward pressure is the
  *weighted* backlog (``tenant_weights`` — gold requests push the
  trigger 3x harder), so the pool grows for a gold burst before a
  free-tier flood of the same depth would.

**Invariant — anti-flap rules** (the autoscaler's, not the policy's;
a policy only votes a direction, it cannot flap the pool):

1. a direction must hold for ``sustain`` consecutive ticks to fire —
   any opposing or neutral vote resets the streak;
2. after any scale event the role is frozen for ``cooldown`` ticks
   (both directions — a scale-up cannot be "corrected" into an
   immediate scale-down);
3. at most one scale event per role per tick, and never past the
   role's ``min``/``max`` replica bounds.

Scale-down is graceful — the
adapter's ``begin_scale_down`` drains the victim through the existing
preemption-checkpoint path (running work migrates, pools empty, THEN
the replica leaves), and the autoscaler keeps the SCALE_DOWN telemetry
span open until the adapter reports the replica DOWN.

Adapter protocol (``DisaggRouter`` and ``core.simulator.ServeChurnSim``
both implement it)::

    scale_roles() -> list[str]            # roles under management
    observe(role) -> RoleObservation      # live/backlog/free_slots ...
    replica_state(rid) -> str             # "up"/"draining"/"down"/...
    scale_up(role) -> Optional[int]       # rejoin a spare; rid or None
    begin_scale_down(role) -> Optional[int]  # start draining; rid/None

Scale events land in the PR 7 telemetry spine: ``autoscale_*`` gauges
(per-role replica counts, backlog, event counts) plus SCALE_UP /
SCALE_DOWN spans on the router's trace track.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

from repro.runtime.telemetry import ROUTER_PID, Telemetry

__all__ = ["Autoscaler", "AutoscalePolicy", "AUTOSCALE_POLICIES",
           "RoleObservation", "ScaleEvent", "get_autoscale_policy"]

# SCALE_* span tids live far above request ids on the router track
_SCALE_TID_BASE = 90_000


@dataclass(frozen=True)
class RoleObservation:
    """One role's load signal for a policy tick."""

    role: str
    live: int               # UP replicas of this role
    backlog: int            # requests awaiting this role's stage
    weighted_backlog: float  # backlog weighted by SLO tier
    free_slots: int         # idle slots across the role's UP replicas
    slots_per_replica: int  # capacity one more replica would add


# ---------------------------------------------------------------- policies
class AutoscalePolicy:
    """Maps one ``RoleObservation`` to a desired direction:
    +1 (grow), -1 (shrink), 0 (hold)."""

    name = "base"

    def desire(self, obs: RoleObservation) -> int:
        raise NotImplementedError

    def _pressure_up(self, obs: RoleObservation) -> bool:
        raise NotImplementedError

    def _band(self, obs: RoleObservation) -> int:
        """Shared hysteresis shape: grow under pressure, shrink only
        when idle by a clear margin, hold in between."""
        if self._pressure_up(obs):
            return 1
        if (obs.backlog == 0
                and obs.free_slots >= 2 * max(obs.slots_per_replica, 1)):
            return -1
        return 0


class QueueDepthPolicy(AutoscalePolicy):
    """Raw backlog vs one replica's slot capacity."""

    name = "queue-depth"

    def _pressure_up(self, obs):
        return obs.backlog > max(obs.slots_per_replica, 1)

    def desire(self, obs):
        return self._band(obs)


class SLOBacklogPolicy(AutoscalePolicy):
    """Weighted backlog: gold-tier demand triggers growth sooner (a
    weight-3 request counts as three toward the threshold), while the
    shrink side stays unweighted — capacity only leaves when the whole
    backlog is empty."""

    name = "slo-backlog"

    def _pressure_up(self, obs):
        return obs.weighted_backlog > max(obs.slots_per_replica, 1)

    def desire(self, obs):
        return self._band(obs)


AUTOSCALE_POLICIES = {
    "queue-depth": QueueDepthPolicy,
    "slo-backlog": SLOBacklogPolicy,
}


def get_autoscale_policy(name) -> AutoscalePolicy:
    if isinstance(name, AutoscalePolicy):
        return name
    return AUTOSCALE_POLICIES[name]()


# -------------------------------------------------------------- autoscaler
@dataclass(frozen=True)
class ScaleEvent:
    """One scaling decision, as recorded in ``Autoscaler.events``."""

    tick: int
    role: str
    action: str  # "up" | "down"
    replica: int
    backlog: int
    live: int


def _bound(spec, role: str, default: int) -> int:
    """Resolve an int-or-per-role-dict bound."""
    if spec is None:
        return default
    if isinstance(spec, dict):
        return int(spec.get(role, default))
    return int(spec)


class Autoscaler:
    """Drives an adapter's per-role replica counts from a policy.

    * ``min_replicas`` / ``max_replicas`` — int or ``{role: int}``
      bounds on each role's UP+DRAINING population (min defaults to 1,
      max to the adapter's current population — no growth unless spares
      exist).
    * ``cooldown`` — ticks a role is frozen after any event.
    * ``sustain`` — consecutive ticks a direction must hold to fire
      (with ``cooldown``, the anti-flap pair).
    """

    def __init__(self, adapter, policy="queue-depth", *,
                 min_replicas=1, max_replicas=None, cooldown: int = 10,
                 sustain: int = 3, telemetry: Optional[Telemetry] = None):
        if cooldown < 0:
            raise ValueError(f"cooldown must be >= 0: {cooldown}")
        if sustain < 1:
            raise ValueError(f"sustain must be >= 1: {sustain}")
        self.adapter = adapter
        self.policy = get_autoscale_policy(policy)
        self._min = min_replicas
        self._max = max_replicas
        self.cooldown = cooldown
        self.sustain = sustain
        self.tm = telemetry if telemetry is not None else Telemetry()
        self.events: list[ScaleEvent] = []
        self.scale_ups = 0
        self.scale_downs = 0
        self._streak: dict[str, int] = {}
        self._last_event: dict[str, int] = {}
        self._retiring: dict[int, str] = {}  # rid -> role, span open
        reg = self.tm.registry
        self._g_replicas = reg.gauge(
            "autoscale_replicas", "UP replicas per role", ("role",))
        self._g_backlog = reg.gauge(
            "autoscale_backlog", "requests awaiting the role's stage",
            ("role",))
        for role in adapter.scale_roles():
            self._g_replicas.labels(role=role).set_function(
                lambda r=role: self.adapter.observe(r).live)
            self._g_backlog.labels(role=role).set_function(
                lambda r=role: self.adapter.observe(r).backlog)
        for name, help, fn in (
                ("autoscale_scale_ups", "scale-up events issued",
                 lambda: self.scale_ups),
                ("autoscale_scale_downs", "scale-down events issued",
                 lambda: self.scale_downs),
                ("autoscale_retiring", "replicas draining toward DOWN",
                 lambda: len(self._retiring))):
            reg.gauge(name, help).labels().set_function(fn)

    def bounds(self, role: str, population: int) -> tuple[int, int]:
        """(min, max) UP+DRAINING replicas for ``role``."""
        lo = _bound(self._min, role, 1)
        hi = _bound(self._max, role, population)
        return lo, max(lo, hi)

    # ------------------------------------------------------------ ticking
    def _retiring_of(self, role: str) -> int:
        return sum(1 for r in self._retiring.values() if r == role)

    def _finish_retirements(self) -> None:
        """Close the SCALE_DOWN span of every retiree that reached DOWN
        — the drain (checkpoint-migrate, pools emptied) completed."""
        tr = self.tm.trace
        for rid in [r for r, _ in list(self._retiring.items())
                    if self.adapter.replica_state(r) == "down"]:
            del self._retiring[rid]
            if tr.enabled:
                tr.end_if_open(ROUTER_PID, _SCALE_TID_BASE + rid,
                               drained=True)

    def tick(self, tick: int) -> None:
        """One autoscaler pass — call once per router/sim tick."""
        self._finish_retirements()
        tr = self.tm.trace
        for role in self.adapter.scale_roles():
            obs = self.adapter.observe(role)
            d = self.policy.desire(obs)
            streak = self._streak.get(role, 0)
            streak = (max(streak, 0) + 1 if d > 0
                      else min(streak, 0) - 1 if d < 0 else 0)
            self._streak[role] = streak
            last = self._last_event.get(role)
            if last is not None and tick - last < self.cooldown:
                continue  # frozen: sustained pressure still accumulates
            population = obs.live + self._retiring_of(role)
            lo, hi = self.bounds(role, population)
            if streak >= self.sustain and obs.live < hi:
                rid = self.adapter.scale_up(role)
                if rid is None:
                    continue  # no spare to rejoin
                self.scale_ups += 1
                self._record(tick, role, "up", rid, obs)
                if tr.enabled:
                    tr.begin(ROUTER_PID, _SCALE_TID_BASE + rid,
                             "SCALE_UP", role=role, tick=tick,
                             backlog=obs.backlog)
                    tr.end(ROUTER_PID, _SCALE_TID_BASE + rid,
                           replicas=obs.live + 1)
            elif (streak <= -self.sustain
                  and obs.live - self._retiring_of(role) > lo):
                rid = self.adapter.begin_scale_down(role)
                if rid is None:
                    continue
                self.scale_downs += 1
                self._record(tick, role, "down", rid, obs)
                self._retiring[rid] = role
                if tr.enabled:
                    # stays open until the drain completes (replica DOWN)
                    tr.begin(ROUTER_PID, _SCALE_TID_BASE + rid,
                             "SCALE_DOWN", role=role, tick=tick,
                             free_slots=obs.free_slots)

    def _record(self, tick: int, role: str, action: str, rid: int,
                obs: RoleObservation) -> None:
        self.events.append(ScaleEvent(tick=tick, role=role, action=action,
                                      replica=rid, backlog=obs.backlog,
                                      live=obs.live))
        self._last_event[role] = tick
        self._streak[role] = 0

    # ----------------------------------------------------------- telemetry
    def stats(self) -> dict:
        v = self.tm.registry.value
        return {
            "policy": self.policy.name,
            "scale_ups": int(v("autoscale_scale_ups")),
            "scale_downs": int(v("autoscale_scale_downs")),
            "retiring": int(v("autoscale_retiring")),
            "events": [dataclasses.asdict(e) for e in self.events],
        }
