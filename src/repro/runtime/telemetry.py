"""Unified telemetry: a metrics registry, span tracing, and a flight
recorder — the observability substrate every runtime layer reports into.

The reproduction's telemetry used to be a patchwork of ad-hoc dicts
(``ServeEngine.kv_stats()/spec_stats()``, ``ClusterRouter.stats()``,
``KVCacheManager.stats()``) with no time dimension and no export format.
This module gives the stack one spine, in three layers:

``MetricsRegistry``
    Labeled counters / gauges / histograms with Prometheus text
    exposition (``to_prometheus()``) and a JSON dump (``to_dict()``).
    Gauges may be *function-backed* (``set_function``): the child reads
    live state (pool occupancy, scheduler counters) at export time, so
    hot paths never double-book — the legacy stats dicts are now thin
    views over registry values, which is what keeps their schemas from
    drifting (gated by ``tests/test_telemetry.py``).

``TraceRecorder``
    Structured events in Chrome trace-event form (open
    ``chrome://tracing`` or https://ui.perfetto.dev on the JSON):
    per-request lifecycle spans (QUEUED → PREFILL → DECODE, with
    PREEMPTED / REPLAY sub-spans), per-tick engine counter tracks (live
    slots, queue depth, free pages, draft acceptance, step-cache hits),
    and router instants (heartbeat misses, LOST/fence, placement,
    straggler route-around, brown-out).  ``pid`` is the replica id
    (router events use ``ROUTER_PID``), ``tid`` the request id, so
    Perfetto renders one track per replica and one row per request.
    **Invariant — span pairing**: every ``begin_span`` is closed by
    exactly one matching ``end_span`` on the same ``(pid, tid)`` track,
    in LIFO order within the track; open spans are tracked per
    ``(pid, tid)`` and ``end_all(pid)`` closes a fenced replica's spans
    so chaos never leaks an orphan.  ``validate_chrome_trace`` reports
    any ``(pid, tid)`` stack still holding an open begin, and the
    ``python -m repro.runtime.telemetry`` CLI fails on them (unless
    ``--allow-unbalanced``, for partial dumps) — an emitted trace that
    fails it is a bug in the emitter.  An optional ``limit``
    turns the event store into a bounded ring buffer (``dropped``
    counts evictions; span balance is only guaranteed for spans whose
    begin survived the ring).

``Telemetry``
    The facade the engine/router/launcher bind to: always carries a
    real registry (cheap), and either a live ``TraceRecorder`` or the
    shared ``NULL_TRACE`` no-op — the null-sink fast path that makes
    disabled tracing cost near zero (gated at ≤2% tokens/s overhead
    *with tracing fully on* in ``benchmarks/serve_throughput.py``).
    ``dump_flight(reason)`` writes the last ``flight`` events plus a
    full metrics snapshot to ``flight_dir`` — ``ClusterRouter`` calls
    it automatically on fence/retry-exhaustion, so every chaos anomaly
    ships its own post-mortem.

``python -m repro.runtime.telemetry <trace.json>`` validates an emitted
trace (shape + span balance); ``scripts/ci.sh`` runs it over the
launcher's ``--trace-out`` output.  See docs/observability.md.
"""
from __future__ import annotations

import json
import os
import time
from collections import deque
from typing import Callable, Iterable, Optional

__all__ = ["MetricsRegistry", "TraceRecorder", "NullTrace", "NULL_TRACE",
           "Telemetry", "ROUTER_PID", "validate_chrome_trace"]

ROUTER_PID = 10_000  # trace track for cluster-router events (pid space
#                      0..N-1 belongs to the engine replicas)

_DEFAULT_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
                    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)


def _escape(v: str) -> str:
    return (str(v).replace("\\", r"\\").replace("\n", r"\n")
            .replace('"', r'\"'))


def _fmt_labels(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_escape(v)}"'
                     for k, v in sorted(labels.items()))
    return "{" + inner + "}"


class _Child:
    """One (metric, label-set) series.  Counters/gauges store a float;
    a gauge may instead be function-backed (``set_function``), reading
    live state at export time."""

    __slots__ = ("value", "_fn")

    def __init__(self):
        self.value = 0.0
        self._fn: Optional[Callable[[], float]] = None

    def inc(self, v: float = 1.0) -> None:
        self.value += v

    def dec(self, v: float = 1.0) -> None:
        self.value -= v

    def set(self, v: float) -> None:
        self.value = float(v)

    def set_function(self, fn: Callable[[], float]) -> None:
        self._fn = fn

    def get(self) -> float:
        return float(self._fn()) if self._fn is not None else self.value


class _HistChild:
    """One histogram series: cumulative buckets + sum + count."""

    __slots__ = ("buckets", "counts", "sum", "count")

    def __init__(self, buckets):
        self.buckets = tuple(buckets)
        self.counts = [0] * len(self.buckets)
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        self.sum += v
        self.count += 1
        for i, le in enumerate(self.buckets):
            if v <= le:
                self.counts[i] += 1
                break  # per-bucket counts; get() accumulates

    def get(self) -> dict:
        return {"buckets": {str(le): int(sum(self.counts[:i + 1]))
                            for i, le in enumerate(self.buckets)},
                "sum": self.sum, "count": self.count}


class MetricFamily:
    """A named metric plus its labeled children.  ``labels(**kv)``
    returns (creating on first use) the child for one label set; the
    unlabeled child is ``labels()``."""

    def __init__(self, name: str, help: str, type: str,
                 labelnames: Iterable[str] = (), buckets=None):
        self.name = name
        self.help = help
        self.type = type
        self.labelnames = tuple(labelnames)
        self.buckets = tuple(buckets or _DEFAULT_BUCKETS)
        self._children: dict[tuple, object] = {}

    def _key(self, kv: dict) -> tuple:
        if set(kv) != set(self.labelnames):
            raise ValueError(
                f"metric {self.name}: labels {sorted(kv)} != declared "
                f"{sorted(self.labelnames)}")
        return tuple(str(kv[k]) for k in self.labelnames)

    def labels(self, **kv):
        key = self._key(kv)
        child = self._children.get(key)
        if child is None:
            child = (_HistChild(self.buckets) if self.type == "histogram"
                     else _Child())
            self._children[key] = child
        return child

    def samples(self):
        """Yield (labels_dict, child) pairs, label-sorted."""
        for key in sorted(self._children):
            yield (dict(zip(self.labelnames, key)), self._children[key])


class MetricsRegistry:
    """Process-local registry of labeled counters/gauges/histograms.

    Re-registering an existing name returns the existing family (so N
    engine replicas binding into one shared registry each get their own
    ``replica=...``-labeled children of the same families)."""

    def __init__(self):
        self._families: dict[str, MetricFamily] = {}

    def _register(self, name, help, type, labelnames, buckets=None):
        fam = self._families.get(name)
        if fam is None:
            fam = MetricFamily(name, help, type, labelnames, buckets)
            self._families[name] = fam
        elif fam.type != type:
            raise ValueError(f"metric {name} already registered as "
                             f"{fam.type}, not {type}")
        return fam

    def counter(self, name: str, help: str = "",
                labelnames: Iterable[str] = ()) -> MetricFamily:
        return self._register(name, help, "counter", labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Iterable[str] = ()) -> MetricFamily:
        return self._register(name, help, "gauge", labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Iterable[str] = (),
                  buckets=None) -> MetricFamily:
        return self._register(name, help, "histogram", labelnames, buckets)

    def names(self) -> list[str]:
        return sorted(self._families)

    def value(self, name: str, **labels) -> float:
        """Read one series' current value (resolving function-backed
        gauges) — what the legacy stats dicts are built from."""
        child = self._families[name].labels(**labels)
        v = child.get()
        return v if isinstance(v, (int, float)) else v  # hist: dict

    # ------------------------------------------------------------ export
    def to_dict(self) -> dict:
        """JSON-dumpable snapshot: {name: {type, help, series: [...]}}."""
        out = {}
        for name in sorted(self._families):
            fam = self._families[name]
            series = [{"labels": labels, "value": child.get()}
                      for labels, child in fam.samples()]
            out[name] = {"type": fam.type, "help": fam.help,
                         "series": series}
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (0.0.4)."""
        lines = []
        for name in sorted(self._families):
            fam = self._families[name]
            if fam.help:
                lines.append(f"# HELP {name} {_escape(fam.help)}")
            lines.append(f"# TYPE {name} {fam.type}")
            for labels, child in fam.samples():
                if fam.type == "histogram":
                    h = child.get()
                    for le, cum in h["buckets"].items():
                        lb = _fmt_labels({**labels, "le": le})
                        lines.append(f"{name}_bucket{lb} {cum}")
                    lb = _fmt_labels({**labels, "le": "+Inf"})
                    lines.append(f"{name}_bucket{lb} {h['count']}")
                    lines.append(
                        f"{name}_sum{_fmt_labels(labels)} {h['sum']:g}")
                    lines.append(
                        f"{name}_count{_fmt_labels(labels)} {h['count']}")
                else:
                    lines.append(
                        f"{name}{_fmt_labels(labels)} {child.get():g}")
        return "\n".join(lines) + "\n"

    def write(self, path: str) -> str:
        """Write the snapshot: ``.prom``/``.txt`` → Prometheus text,
        anything else → JSON."""
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            if path.endswith((".prom", ".txt")):
                f.write(self.to_prometheus())
            else:
                json.dump(self.to_dict(), f, indent=1)
        return path


# ------------------------------------------------------------------ tracing
class TraceRecorder:
    """Chrome trace-event recorder (ph: B/E spans, i instants, C
    counters, M metadata), microsecond timestamps from a shared t0.

    ``limit`` bounds the event store as a ring buffer (the flight-
    recorder memory cap); open-span bookkeeping is separate, so span
    balance survives ring eviction."""

    enabled = True

    def __init__(self, limit: Optional[int] = None):
        self._t0 = time.perf_counter()
        self.events: deque = deque(maxlen=limit)
        self.total = 0   # events ever recorded (ring drops: total - len)
        self._open: dict[tuple, list] = {}  # (pid, tid) -> [names]

    @property
    def dropped(self) -> int:
        return self.total - len(self.events)

    def now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    def _push(self, ev: dict) -> None:
        self.events.append(ev)
        self.total += 1

    # ------------------------------------------------------------- spans
    def begin(self, pid: int, tid: int, name: str, **args) -> None:
        self._open.setdefault((pid, tid), []).append(name)
        ev = {"ph": "B", "pid": pid, "tid": tid, "name": name,
              "ts": self.now_us()}
        if args:
            ev["args"] = args
        self._push(ev)

    def end(self, pid: int, tid: int, **args) -> None:
        stack = self._open.get((pid, tid))
        assert stack, f"end() without begin() on (pid={pid}, tid={tid})"
        stack.pop()
        if not stack:
            del self._open[(pid, tid)]
        ev = {"ph": "E", "pid": pid, "tid": tid, "ts": self.now_us()}
        if args:
            ev["args"] = args
        self._push(ev)

    def end_if_open(self, pid: int, tid: int, **args) -> bool:
        if (pid, tid) in self._open:
            self.end(pid, tid, **args)
            return True
        return False

    def end_all(self, pid: int, **args) -> int:
        """Close every open span on ``pid`` (innermost first) — a fenced
        replica's streams end here, never dangle.  Returns spans
        closed."""
        n = 0
        for (p, tid) in [k for k in self._open if k[0] == pid]:
            while self.end_if_open(p, tid, **args):
                n += 1
        return n

    def open_spans(self) -> dict:
        """{(pid, tid): [open span names]} — empty means balanced."""
        return {k: list(v) for k, v in self._open.items()}

    # ---------------------------------------------------- instants etc.
    def instant(self, pid: int, name: str, tid: int = 0, **args) -> None:
        ev = {"ph": "i", "pid": pid, "tid": tid, "name": name, "s": "p",
              "ts": self.now_us()}
        if args:
            ev["args"] = args
        self._push(ev)

    def counter(self, pid: int, name: str, values: dict) -> None:
        self._push({"ph": "C", "pid": pid, "tid": 0, "name": name,
                    "ts": self.now_us(), "args": dict(values)})

    def set_process_name(self, pid: int, name: str) -> None:
        self._push({"ph": "M", "pid": pid, "tid": 0,
                    "name": "process_name", "ts": 0,
                    "args": {"name": name}})

    # ------------------------------------------------------------ export
    def tail(self, n: int) -> list[dict]:
        if n <= 0 or n >= len(self.events):
            return list(self.events)
        return list(self.events)[-n:]

    def to_chrome(self, events: Optional[list] = None) -> dict:
        return {"traceEvents": (list(self.events) if events is None
                                else list(events)),
                "displayTimeUnit": "ms"}

    def write(self, path: str) -> str:
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
        return path


class NullTrace:
    """No-op sink with ``TraceRecorder``'s surface: the fast path when
    tracing is off.  Every hot-path call site guards on ``.enabled``
    before building args, so disabled telemetry costs one attribute
    read per event site."""

    enabled = False
    events: tuple = ()
    total = 0
    dropped = 0

    def begin(self, *a, **kw):
        pass

    def end(self, *a, **kw):
        pass

    def end_if_open(self, *a, **kw):
        return False

    def end_all(self, *a, **kw):
        return 0

    def instant(self, *a, **kw):
        pass

    def counter(self, *a, **kw):
        pass

    def set_process_name(self, *a, **kw):
        pass

    def open_spans(self):
        return {}

    def tail(self, n):
        return []

    def to_chrome(self, events=None):
        return {"traceEvents": [], "displayTimeUnit": "ms"}


NULL_TRACE = NullTrace()


# ------------------------------------------------------------------ facade
class Telemetry:
    """What the engine / router / launcher bind to.

    * ``registry`` is always real — metrics are cheap and every legacy
      stats dict reads from them.
    * ``trace`` is a live ``TraceRecorder`` when ``trace=True`` (with
      ``ring`` bounding the event store), else the shared no-op
      ``NULL_TRACE``.
    * ``flight`` > 0 arms the flight recorder: ``dump_flight(reason)``
      writes the last ``flight`` trace events + a metrics snapshot to
      ``flight_dir`` (``ClusterRouter`` calls it on fence / retry
      exhaustion).
    """

    def __init__(self, *, trace: bool = False, flight: int = 0,
                 flight_dir: str = "artifacts", ring: Optional[int] = None,
                 registry: Optional[MetricsRegistry] = None):
        self.registry = registry if registry is not None else \
            MetricsRegistry()
        self.trace = TraceRecorder(limit=ring) if (trace or flight) \
            else NULL_TRACE
        self.flight = int(flight)
        self.flight_dir = flight_dir
        self.flight_dumps: list[str] = []

    # ------------------------------------------------- request lifecycle
    def req_transition(self, pid: int, req_id: int, state: str,
                       **args) -> None:
        """Close the request's open span (if any) and open ``state`` —
        one call per lifecycle edge keeps B/E balanced by
        construction."""
        tr = self.trace
        if not tr.enabled:
            return
        tr.end_if_open(pid, req_id)
        tr.begin(pid, req_id, state, req=req_id, **args)

    def req_end(self, pid: int, req_id: int, **args) -> None:
        tr = self.trace
        if tr.enabled:
            tr.end_if_open(pid, req_id, **args)

    # ----------------------------------------------------------- flight
    def dump_flight(self, reason: str, extra: Optional[dict] = None
                    ) -> Optional[str]:
        """Write the post-mortem: last ``flight`` trace events + full
        metrics snapshot.  Returns the path (None when disarmed)."""
        if self.flight <= 0:
            return None
        os.makedirs(self.flight_dir, exist_ok=True)
        seq = len(self.flight_dumps)
        safe = "".join(c if c.isalnum() or c in "-_" else "-"
                       for c in reason)
        path = os.path.join(self.flight_dir, f"flight_{seq:03d}_{safe}.json")
        payload = {
            "reason": reason,
            "unix_time": time.time(),
            "events_recorded": self.trace.total,
            "events_dropped": self.trace.dropped,
            "open_spans": {f"{pid}/{tid}": names for (pid, tid), names
                           in self.trace.open_spans().items()},
            "events": self.trace.tail(self.flight),
            "metrics": self.registry.to_dict(),
        }
        if extra:
            payload.update(extra)
        with open(path, "w") as f:
            json.dump(payload, f, indent=1)
        self.flight_dumps.append(path)
        return path

    # ------------------------------------------------------------ export
    def write_trace(self, path: str) -> str:
        if not self.trace.enabled:
            raise ValueError("tracing is disabled (Telemetry(trace=True))")
        return self.trace.write(path)

    def write_metrics(self, path: str) -> str:
        return self.registry.write(path)


# -------------------------------------------------------------- validation
def validate_chrome_trace(trace) -> dict:
    """Validate a Chrome trace-event JSON (path, dict, or event list).

    Raises ``ValueError`` on malformed input; returns a summary dict
    (event/span/instant/counter counts, pids, unbalanced span stacks).
    A trace cut from a ring buffer may open with orphan "E" events —
    those are tolerated and counted, but a "B" left open is not.
    """
    if isinstance(trace, str):
        with open(trace) as f:
            trace = json.load(f)
    if isinstance(trace, dict):
        events = trace.get("traceEvents")
        if not isinstance(events, list):
            raise ValueError("trace JSON must carry a 'traceEvents' list")
    elif isinstance(trace, list):
        events = trace
    else:
        raise ValueError(f"not a trace: {type(trace).__name__}")
    counts = {"B": 0, "E": 0, "i": 0, "C": 0, "M": 0}
    stacks: dict[tuple, list] = {}
    orphan_ends = 0
    pids = set()
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event #{i} is not an object")
        ph = ev.get("ph")
        if ph not in counts:
            raise ValueError(f"event #{i}: unknown phase {ph!r}")
        for field in ("pid", "tid", "ts"):
            if not isinstance(ev.get(field), (int, float)):
                raise ValueError(f"event #{i} ({ph}): missing numeric "
                                 f"{field!r}")
        if ph != "E" and not isinstance(ev.get("name"), str):
            raise ValueError(f"event #{i} ({ph}): missing 'name'")
        counts[ph] += 1
        pids.add(ev["pid"])
        key = (ev["pid"], ev["tid"])
        if ph == "B":
            stacks.setdefault(key, []).append(ev["name"])
        elif ph == "E":
            if stacks.get(key):
                stacks[key].pop()
            else:
                orphan_ends += 1  # ring-buffer cut: B evicted, E kept
    unbalanced = {f"{pid}/{tid}": names
                  for (pid, tid), names in stacks.items() if names}
    return {"events": len(events), "counts": counts,
            "pids": sorted(pids), "orphan_ends": orphan_ends,
            "unbalanced": unbalanced, "balanced": not unbalanced}


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="validate a Chrome trace-event JSON emitted via "
                    "--trace-out (shape + span balance)")
    ap.add_argument("trace", help="path to the trace JSON")
    ap.add_argument("--allow-unbalanced", action="store_true",
                    help="do not fail on open spans (partial dumps)")
    args = ap.parse_args(argv)
    try:
        summary = validate_chrome_trace(args.trace)
    except (ValueError, OSError, json.JSONDecodeError) as e:
        print(f"INVALID trace {args.trace}: {e}")
        return 1
    c = summary["counts"]
    print(f"trace {args.trace}: {summary['events']} events "
          f"({c['B']} span begins, {c['i']} instants, {c['C']} counter "
          f"samples) across pids {summary['pids']}")
    if summary["unbalanced"] and not args.allow_unbalanced:
        print(f"UNBALANCED spans: {summary['unbalanced']}")
        return 1
    print("trace OK" + ("" if summary["balanced"]
                        else " (unbalanced spans allowed)"))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
