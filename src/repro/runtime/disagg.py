"""Disaggregated prefill/decode serving: role-aware routing + KV handoff.

Splits the replica pool by *role* the way Scylla splits a cluster by
framework: **prefill** replicas run chunked prefill only (admission
completes the whole prompt atomically and emits the first token — the
engine never runs a decode phase), **decode** replicas only accept
handed-off requests, and **unified** replicas behave exactly like a PR 6
pool member.  The ``DisaggRouter`` extends ``ClusterRouter`` with a
handoff pipeline between the two halves:

1. **Extract** — after the replicas step, every prefill replica's
   finished-prefill requests (state DECODE, first token emitted) are
   checkpointed out of their slots via ``ServeEngine.release``: paged
   engines detach the slot's page chain zero-copy (PR 4's preemption
   primitive), dense engines snapshot the cache stripe to host.  The
   request moves into the router's **handoff queue**.
2. **Transfer** — each queued handoff targets a decode/unified replica
   chosen by the router's placement policy among those with a free slot
   and (paged) room to **adopt** the chain: ``KVCacheManager.adopt_chain``
   allocates fresh pages in the destination pool, one compiled
   gather/scatter (``copy_cache_pages_across``) moves the K/V bytes
   between the two engines' page pools, and ``release_chain`` drops the
   source pool's hold — both pools stay refcount-balanced
   (tests/test_disagg.py).  Dense checkpoints are engine-independent
   host snapshots, so their transfer is free.
3. **Resume** — the destination engine admits the checkpointed request
   through the ordinary resume path (``attach_slot``; no prefill re-run)
   and decodes from ``pos = prompt_len``.  Sampling keys fold (request
   key, absolute position) — never slot or replica — so the disagg
   output stream is **bitwise-identical** to the unified engine's,
   greedy and seeded-sampled alike.

**Invariant — refcount balance across pools**: at every tick boundary,
each replica's page pool satisfies ``used = sum(refcounts of mapped
pages)`` *independently*, and a chain in transit is owned by exactly
one side — the source pool until ``adopt_chain`` returns, the
destination pool after.  No step of the handoff (extract, transfer,
resume, chaos sweep, retire-drain) may leave a page referenced by both
pools or by neither; ``tests/test_disagg.py`` asserts both pools drain
to zero held pages after every run, chaos included.

**Backpressure**: a handoff with no fitting destination stays queued
(``handoff_backpressure`` counts the deferrals); ``run()`` counts
in-transit handoffs as in-flight work so the loop never exits
mid-transfer.

**Chaos**: a prefill replica lost mid-handoff strands its queued
handoffs — their page chains died with the fenced pool — so the sweep
(``_sweep_lost``) feeds them through the same deterministic-replay
recovery as placed requests: re-prefill ``prompt + emitted`` on a
surviving prefill-capable replica, hand off again, continuation bitwise
intact.  Every fence's flight dump carries the in-transit handoff queue
snapshot (request id, source replica, pages in flight) taken *before*
the sweep, so a red chaos run shows what was mid-flight at the instant
of death.

**Elasticity**: the router implements the adapter protocol
``runtime/autoscale.py``'s ``Autoscaler`` drives — per-role
observations, ``scale_up`` (rejoin a cold spare), ``begin_scale_down``
(retire the idlest replica).  ``retire`` drains via the checkpoint
path: running decodes hand off to a sibling, never-admitted queued
requests return to the router queue, and the replica only reaches DOWN
once no in-transit handoff still points at its page pool
(``_can_retire``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from repro.runtime.cluster import (ClusterRouter, ReplicaHandle,
                                   ReplicaState, _RouterRequest)
from repro.runtime.steps import compiled_fn
from repro.runtime.telemetry import ROUTER_PID, Telemetry

__all__ = ["DisaggRouter", "Handoff", "ROLES", "transfer_chain"]

ROLES = ("prefill", "decode", "unified")

# roles fresh router-queued requests may place on / handoffs may target
_PREFILL_CAPABLE = ("prefill", "unified")
_DECODE_CAPABLE = ("decode", "unified")


@dataclass
class Handoff:
    """One finished prefill awaiting a decode slot.  The request holds
    its own checkpoint (``req._ckpt``); ``src`` names the replica whose
    page pool still backs a paged chain until the transfer completes."""

    rr: _RouterRequest
    src: int
    n_pages: int  # 0 for dense (host-snapshot) checkpoints
    tick: int
    retries: int = 0  # placement attempts deferred by backpressure


def _releasable(req) -> bool:
    """May this request's slot be checkpointed out cleanly?  Same
    predicate as ``Scheduler._preemptible``: steadily decoding, not
    mid-token-feed, first token emitted (chunked prefill done)."""
    state = getattr(req, "state", None)
    return (getattr(state, "value", None) == "decode"
            and not getattr(req, "_feed", None)
            and bool(req.output))


def transfer_chain(src_engine, dst_engine, req) -> bool:
    """Move ``req``'s checkpointed KV from ``src_engine`` to
    ``dst_engine``; True on success, False on destination backpressure.

    Dense checkpoints (``ckpt.pages is None``) are host snapshots —
    engine-independent, nothing to do.  Paged: adopt fresh pages in the
    destination pool, run one compiled cross-pool gather/scatter over
    every layer's page pool, then release the source pool's hold.  Index
    vectors are padded to the destination's static ``max_pages`` width
    with zeros — padding rows copy the source null page onto the
    destination null page, whose content no reader ever depends on — so
    the copy compiles once per width, not per chain length."""
    ck = req._ckpt
    if ck.pages is None:
        return True
    n = len(ck.pages)
    dst_kv = dst_engine.kv
    new_pages = dst_kv.adopt_chain(n)
    if new_pages is None:
        return False
    width = dst_kv.max_pages
    src_idx = np.zeros(width, np.int32)
    dst_idx = np.zeros(width, np.int32)
    src_idx[:n] = ck.pages
    dst_idx[:n] = new_pages
    model = dst_engine.model
    xfer = compiled_fn(("page_xfer", model.cfg, model.knobs, width),
                       lambda: model.copy_cache_pages_across, donate=(1,))
    dst_engine.caches = xfer(src_engine.caches, dst_engine.caches,
                             jnp.asarray(src_idx), jnp.asarray(dst_idx))
    src_engine.kv.release_chain(ck.pages)
    ck.pages = new_pages
    req._ckpt_pages = new_pages
    req._handoff_kv = n  # the resume's DRF charge lands in the dst pool
    return True


class DisaggRouter(ClusterRouter):
    """``ClusterRouter`` with per-replica roles and a handoff queue.

    ``roles[rid]`` assigns each replica ``prefill`` / ``decode`` /
    ``unified``; ``make_engine(rid)`` must build the engine with the
    matching ``ServeConfig.role``.  ``start_down`` rids begin as cold
    spares for an ``Autoscaler`` (attach one via ``autoscaler=``, or
    set ``router.autoscaler`` later) to rejoin under load.
    """

    def __init__(self, make_engine: Callable[[int], object],
                 n_replicas: int, *, roles, start_down=(), **kw):
        roles = list(roles)
        if len(roles) != n_replicas:
            raise ValueError(f"roles has {len(roles)} entries for "
                             f"{n_replicas} replicas")
        bad = sorted(set(roles) - set(ROLES))
        if bad:
            raise ValueError(f"unknown roles {bad} (expected {ROLES})")
        up = [r for i, r in enumerate(roles) if i not in set(start_down)]
        if not any(r in _PREFILL_CAPABLE for r in up):
            raise ValueError("no initially-up prefill-capable replica "
                             "(role prefill or unified)")
        if not any(r in _DECODE_CAPABLE for r in up):
            raise ValueError("no initially-up decode-capable replica "
                             "(role decode or unified)")
        self.roles = roles
        self.handoffs: list[Handoff] = []
        self.handoffs_done = 0
        self.handoff_backpressure = 0
        self.autoscaler = None
        super().__init__(make_engine, n_replicas, start_down=start_down,
                         **kw)
        reg = self.tm.registry
        for name, help, fn in (
                ("disagg_handoffs_done", "prefill->decode handoffs "
                 "completed", lambda: self.handoffs_done),
                ("disagg_handoffs_in_transit", "handoffs awaiting a "
                 "decode slot", lambda: len(self.handoffs)),
                ("disagg_handoff_backpressure", "handoff placements "
                 "deferred (no slot / no pages)",
                 lambda: self.handoff_backpressure)):
            reg.gauge(name, help).labels().set_function(fn)

    # ------------------------------------------------------------ roles
    def role_of(self, rid: int) -> str:
        return self.roles[rid]

    def _accepts_new(self, rh: ReplicaHandle) -> bool:
        return self.roles[rh.rid] in _PREFILL_CAPABLE

    # ---------------------------------------------------------- handoff
    def _extract_handoffs(self) -> None:
        """Checkpoint every finished prefill off its prefill replica and
        queue it for transfer (DRAINING prefill replicas drain faster
        this way too — their slots empty the same tick)."""
        tr = self.tm.trace
        for rh in self.replicas:
            if self.roles[rh.rid] != "prefill":
                continue
            if rh.state not in (ReplicaState.UP, ReplicaState.DRAINING):
                continue
            if rh.killed or rh.engine is None:
                continue
            for rr in [r for r in self.placed[rh.rid]
                       if _releasable(r.req)]:
                ck = rh.engine.release(rr.req)
                self.placed[rh.rid].remove(rr)
                rr.replica = None
                n = 0 if ck.pages is None else len(ck.pages)
                self.handoffs.append(Handoff(rr=rr, src=rh.rid, n_pages=n,
                                             tick=self.tick_count))
                if tr.enabled:
                    tr.begin(ROUTER_PID, rr.req.req_id, "HANDOFF",
                             src=rh.rid, pages=n, pos=ck.pos)

    def _handoff_target(self, h: Handoff) -> Optional[ReplicaHandle]:
        """Pick a decode-capable replica that can adopt the chain right
        now, via the router's placement policy over their offers."""
        fitting = []
        for rh in self.replicas:
            if self.roles[rh.rid] not in _DECODE_CAPABLE:
                continue
            if (rh.state is not ReplicaState.UP or rh.killed
                    or rh.slow or rh.engine is None):
                continue
            eng = rh.engine
            if eng.free_slots() < 1:
                continue
            if h.n_pages and not eng.kv.can_adopt(h.n_pages):
                continue
            fitting.append(rh.offer())
        if not fitting:
            return None
        return self.replicas[self.policy.select(fitting).replica]

    def _drain_handoffs(self) -> None:
        """FIFO-place queued handoffs onto decode slots; a handoff with
        no fitting destination stays queued (backpressure, counted)."""
        tr = self.tm.trace
        for h in list(self.handoffs):
            rh = self._handoff_target(h)
            if rh is None or not transfer_chain(
                    self._src_engine(h), rh.engine, h.rr.req):
                h.retries += 1
                self.handoff_backpressure += 1
                continue
            self.handoffs.remove(h)
            rh.engine.submit(h.rr.req)
            rh.placements += 1
            h.rr.replica = rh.rid
            h.rr.history.append(rh.rid)
            self.placed[rh.rid].append(h.rr)
            self.handoffs_done += 1
            if tr.enabled:
                tr.end_if_open(ROUTER_PID, h.rr.req.req_id,
                               placed_on=rh.rid)
                tr.instant(ROUTER_PID, "handoff", tid=h.rr.req.req_id,
                           src=h.src, dst=rh.rid, pages=h.n_pages,
                           wait=self.tick_count - h.tick)

    def _src_engine(self, h: Handoff):
        """The engine whose pool still holds a paged handoff's chain.
        The sweep removes handoffs whose source died, so a queued
        handoff's source engine is always alive."""
        eng = self.replicas[h.src].engine
        assert eng is not None, f"handoff source {h.src} fenced un-swept"
        return eng

    # ------------------------------------------------------------- chaos
    def _sweep_lost(self, rh: ReplicaHandle) -> list:
        """Handoffs whose source pool just died are unrecoverable as
        checkpoints (paged chains lived in the fenced engine; dense
        snapshots replay too — one uniform recovery path): close their
        HANDOFF spans and hand the requests to deterministic replay."""
        stranded = [h for h in self.handoffs if h.src == rh.rid]
        tr = self.tm.trace
        for h in stranded:
            self.handoffs.remove(h)
            if tr.enabled:
                tr.end_if_open(ROUTER_PID, h.rr.req.req_id,
                               lost_src=rh.rid)
        return [h.rr for h in stranded]

    def _flight_extra(self) -> dict:
        return {"handoffs_in_transit": [
            {"req_id": h.rr.req.req_id, "src_replica": h.src,
             "dst_replica": None, "target_role": "decode",
             "pages_in_flight": h.n_pages, "queued_tick": h.tick}
            for h in self.handoffs]}

    # ------------------------------------------------------ retire/drain
    def _can_retire(self, rh: ReplicaHandle) -> bool:
        return not any(h.src == rh.rid for h in self.handoffs)

    def retire(self, rid: int) -> None:
        """Drain ``rid`` for scale-down, actively migrating its work:
        running decodes checkpoint out and re-enter the handoff queue
        (their chains transfer to a sibling pool before the replica can
        reach DOWN — ``_can_retire``), checkpointed requests parked in
        its admission queue do the same, and never-admitted queued
        requests return to the router queue.  Mid-prefill/token-feed
        occupants drain naturally."""
        rh = self.replicas[rid]
        if rh.state is not ReplicaState.UP or rh.engine is None:
            return
        rh.state = ReplicaState.DRAINING
        eng = rh.engine
        tr = self.tm.trace
        for rr in list(self.placed[rid]):
            req = rr.req
            if _releasable(req):
                ck = eng.release(req)
                n = 0 if ck.pages is None else len(ck.pages)
            elif req in eng.scheduler.queue:
                eng.scheduler.queue.remove(req)
                self.tm.req_end(rid, req.req_id, reason="migrate")
                if getattr(req, "_preempted", False):
                    # checkpoint intact, pages (if paged) in THIS pool;
                    # the request leaves this engine for good — credit
                    # whatever DRF charge still rides on it
                    eng.scheduler.on_finish(req)
                    ck = req._ckpt
                    n = 0 if ck.pages is None else len(ck.pages)
                else:
                    # never admitted: nothing held here — requeue fresh
                    self.placed[rid].remove(rr)
                    rr.replica = None
                    self.queue.insert(0, rr)
                    continue
            else:
                continue  # mid-prefill / token-feed: drains naturally
            self.placed[rid].remove(rr)
            rr.replica = None
            self.handoffs.append(Handoff(rr=rr, src=rid, n_pages=n,
                                         tick=self.tick_count))
            if tr.enabled:
                tr.begin(ROUTER_PID, req.req_id, "HANDOFF", src=rid,
                         pages=n, migrate=True)

    # -------------------------------------------- autoscaler adapter
    def scale_roles(self) -> list[str]:
        seen = []
        for r in self.roles:
            if r not in seen:
                seen.append(r)
        return seen

    def replica_state(self, rid: int) -> str:
        return self.replicas[rid].state.value

    def observe(self, role: str):
        from repro.runtime.autoscale import RoleObservation
        live = [rh for rh in self.replicas
                if self.roles[rh.rid] == role
                and rh.state is ReplicaState.UP and not rh.killed
                and rh.engine is not None]
        if role in _PREFILL_CAPABLE:
            backlog = [rr.req for rr in self.queue]
        else:
            backlog = []
        if role in _DECODE_CAPABLE:
            backlog = backlog + [h.rr.req for h in self.handoffs]
        slots = live[0].engine.slots if live else 0
        return RoleObservation(
            role=role, live=len(live), backlog=len(backlog),
            weighted_backlog=sum(self._weight(r.tenant) for r in backlog),
            free_slots=sum(rh.engine.free_slots() for rh in live),
            slots_per_replica=slots)

    def scale_up(self, role: str) -> Optional[int]:
        for rh in self.replicas:
            if (self.roles[rh.rid] == role
                    and rh.state in (ReplicaState.DOWN, ReplicaState.LOST)):
                self.rejoin(rh.rid)
                return rh.rid
        return None

    def begin_scale_down(self, role: str) -> Optional[int]:
        up = [rh for rh in self.replicas
              if self.roles[rh.rid] == role
              and rh.state is ReplicaState.UP and not rh.killed
              and rh.engine is not None]
        if not up:
            return None
        # idlest first: fewest in-flight requests, then highest rid so
        # the original low-rid replicas are the last to go
        rh = min(up, key=lambda rh: (len(self.placed[rh.rid]), -rh.rid))
        self.retire(rh.rid)
        return rh.rid

    # ------------------------------------------------------------ ticking
    def _pending_counts(self) -> tuple[int, int]:
        queued, live = super()._pending_counts()
        return queued, live + len(self.handoffs)

    def step(self) -> int:
        emitted = super().step()
        self._extract_handoffs()
        if self.autoscaler is not None:
            self.autoscaler.tick(self.tick_count)
        self._drain_handoffs()
        return emitted

    # ---------------------------------------------------------- telemetry
    def stats(self) -> dict:
        out = super().stats()
        v = self.tm.registry.value
        out["roles"] = {rh.rid: self.roles[rh.rid]
                        for rh in self.replicas}
        out["handoffs_done"] = int(v("disagg_handoffs_done"))
        out["handoffs_in_transit"] = int(v("disagg_handoffs_in_transit"))
        out["handoff_backpressure"] = int(
            v("disagg_handoff_backpressure"))
        return out
