from .cluster import (ROUTER_POLICIES, ClusterRouter, ReplicaOffer,
                      ReplicaState, RouterHandle, get_router_policy)
from .fault import FaultEvent, ReplicaFaultInjector
from .sampling import SamplingParams
from .scheduler import (ADMISSION_POLICIES, AdmissionPolicy,
                        get_admission_policy)
from .steps import (init_train_state, make_prefill_step, make_serve_step,
                    make_train_step)

__all__ = ["make_train_step", "make_prefill_step", "make_serve_step",
           "init_train_state", "SamplingParams", "AdmissionPolicy",
           "ADMISSION_POLICIES", "get_admission_policy",
           "ClusterRouter", "ReplicaState", "ReplicaOffer", "RouterHandle",
           "ROUTER_POLICIES", "get_router_policy",
           "FaultEvent", "ReplicaFaultInjector"]
