"""Step functions: the jit/pjit units the launcher lowers and the scheduler
places.  One train step (grad-accum microbatching + AdamW), one prefill
step, one serve (decode) step — these are the "MPI tasks" of DESIGN.md §2.
"""
from __future__ import annotations

import time
from collections import OrderedDict
from typing import Callable

import jax
import jax.numpy as jnp

from repro.optim import AdamWConfig, adamw_init, adamw_update, warmup_cosine
from repro.runtime.sampling import sample_tokens, sample_tokens_multi


def init_train_state(model, rng, moments_dtype=jnp.float32) -> dict:
    params_f32 = model.init(rng)
    params = jax.tree.map(
        lambda p: p.astype(model.knobs.param_dtype)
        if p.dtype == jnp.float32 else p, params_f32)
    return {"params": params,
            "opt": adamw_init(params_f32, moments_dtype)}


def train_state_specs(model, moments_dtype=jnp.float32) -> dict:
    """Abstract train state (dry-run: no allocation)."""
    return jax.eval_shape(lambda: init_train_state(
        model, jax.random.PRNGKey(0), moments_dtype))


def make_train_step(model, opt_cfg: AdamWConfig, grad_accum: int = 1,
                    accum_dtype=jnp.float32, grad_shardings=None) -> Callable:
    """``accum_dtype=bf16`` halves the gradient-accumulator HBM for 100B+
    models (the AdamW update still runs in fp32).  ``grad_shardings``
    (typically the ZeRO optimizer-state shardings) pins the accumulator to
    a data-sharded layout — ZeRO-2: each microbatch's grads reduce-scatter
    into the shard instead of living replicated across the data axis."""
    schedule = warmup_cosine(opt_cfg)

    def loss_fn(params, mb):
        return model.loss(params, mb)

    def train_step(state, batch):
        params = state["params"]
        if grad_accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            grads = jax.tree.map(lambda g: g.astype(accum_dtype), grads)
        else:
            mbs = jax.tree.map(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum)
                                    + x.shape[1:]), batch)
            mbs = jax.tree.map(
                lambda x: model.knobs.shard_fn("microbatch", x), mbs)

            def _pin(tree):
                if grad_shardings is None:
                    return tree
                return jax.lax.with_sharding_constraint(tree, grad_shardings)

            def micro(carry, mb):
                gacc, lacc = carry
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params,
                                                                      mb)
                gacc = _pin(jax.tree.map(
                    lambda a, b: a + b.astype(accum_dtype), gacc, g))
                return (gacc, lacc + l), m

            zeros = _pin(jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params))
            (gacc, lsum), ms = jax.lax.scan(micro, (zeros, jnp.float32(0.0)),
                                            mbs)
            grads = jax.tree.map(lambda g: g / grad_accum, gacc)
            metrics = jax.tree.map(lambda m: m.mean(), ms)
            metrics["loss"] = lsum / grad_accum
        new_master, new_opt, om = adamw_update(grads, state["opt"], opt_cfg,
                                               schedule)
        new_params = jax.tree.map(lambda m, p: m.astype(p.dtype), new_master,
                                  params)
        metrics.update(om)
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_prefill_step(model) -> Callable:
    def prefill_step(params, batch):
        logits, caches = model.prefill(params, batch)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_tokens, caches

    return prefill_step


def make_serve_step(model, sampled: bool = False) -> Callable:
    """Decode step.  ``pos`` is a scalar (lockstep wave batching) or a (B,)
    vector of per-slot positions (ragged continuous batching; free slots
    parked at -1 issue no attention work on the Pallas path).

    ``sampled=True`` grows the signature by the per-slot sampling arrays
    (``temp[B]``, ``top_k[B]``, ``top_p[B]``, ``keys[B, 2]``) and draws
    through ``runtime.sampling.sample_tokens`` — rows with ``temp <= 0``
    still return the bitwise-greedy argmax, so one compiled step serves
    any mix of greedy and sampled requests."""
    def serve_step(params, caches, tokens, pos):
        logits, new_caches = model.decode_step(params, caches, tokens, pos)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_tokens, new_caches

    def sampled_serve_step(params, caches, tokens, pos, temp, top_k, top_p,
                           keys):
        logits, new_caches = model.decode_step(params, caches, tokens, pos)
        next_tokens = sample_tokens(logits, pos, temp, top_k, top_p,
                                    keys)[:, None]
        return next_tokens, new_caches

    return sampled_serve_step if sampled else serve_step


def make_prefill_chunk_step(model, sampled: bool = False) -> Callable:
    """Chunked prefill step: run ONE slot's prompt chunk (1, C) at absolute
    offset through the stack, writing K/V into the batched cache in place.
    Returns (next-token int32 per chunk row (C,), new caches) so the engine
    can read the row of the last real prompt token.

    ``sampled=True`` instead returns a scalar int32: the token drawn from
    logits row ``last_row`` (the last real prompt token on the final
    chunk; pass 0 for don't-care earlier chunks) under the request's
    sampling params — the first generated token.  The fold position is
    the token's absolute position ``offset + last_row``, one below the
    first decode-step fold, so prefill and decode draws never collide."""
    def prefill_chunk_step(params, caches, tokens, slot, offset):
        logits, new_caches = model.prefill_chunk_step(params, caches, tokens,
                                                      slot, offset)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tokens, new_caches

    def sampled_chunk_step(params, caches, tokens, slot, offset, last_row,
                           temp, top_k, top_p, key):
        logits, new_caches = model.prefill_chunk_step(params, caches, tokens,
                                                      slot, offset)
        row = jax.lax.dynamic_index_in_dim(logits, last_row, 0,
                                           keepdims=True)
        tok = sample_tokens(row, (offset + last_row)[None], temp[None],
                            top_k[None], top_p[None], key[None])[0]
        return tok, new_caches

    return sampled_chunk_step if sampled else prefill_chunk_step


def make_paged_prefill_chunk_buf_step(model, page_size: int,
                                      sampled: bool = False,
                                      gather: bool = False) -> Callable:
    """Buffered paged chunked prefill (XLA path): threads the per-layer
    dense gather buffer through the step so chunk N reuses chunk N-1's
    slot view instead of re-gathering the full page chain.  Signature
    grows ``buf`` after ``page_idx`` and the step returns
    (tokens, new caches, new buf); ``gather=True`` is the first-chunk
    variant of a prefix-cache hit (rebuilds the view from the table)."""
    def prefill_chunk_step(params, caches, tokens, slot, offset, page_idx,
                           buf):
        logits, new_caches, new_buf = model.prefill_chunk_step_paged_buf(
            params, caches, tokens, slot, offset, page_idx, buf,
            page_size=page_size, gather=gather)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tokens, new_caches, new_buf

    def sampled_chunk_step(params, caches, tokens, slot, offset, page_idx,
                           buf, last_row, temp, top_k, top_p, key):
        logits, new_caches, new_buf = model.prefill_chunk_step_paged_buf(
            params, caches, tokens, slot, offset, page_idx, buf,
            page_size=page_size, gather=gather)
        row = jax.lax.dynamic_index_in_dim(logits, last_row, 0,
                                           keepdims=True)
        tok = sample_tokens(row, (offset + last_row)[None], temp[None],
                            top_k[None], top_p[None], key[None])[0]
        return tok, new_caches, new_buf

    return sampled_chunk_step if sampled else prefill_chunk_step


# ------------------------------------------------------------- speculative
def make_spec_serve_step(model, draft_len: int,
                         sampled: bool = False) -> Callable:
    """Speculative verify step: score the feed token plus up to
    ``draft_len`` drafted continuations in ONE forward pass.

    tokens (B, T = draft_len + 1) int32 at absolute positions
    ``pos[b] .. pos[b] + T - 1``; returns (target (B, T) int32, new
    caches) where ``target[b, t]`` is the token the target model emits
    after feed + drafts[:t] — the greedy argmax, or (``sampled=True``)
    the draw of ``sampling.sample_tokens_multi`` with each row's
    absolute position folded into the slot's key.  The engine's host
    side compares drafts against ``target`` (``speculative_accept``) and
    rolls rejected positions back by truncation.
    """
    def spec_step(params, caches, tokens, pos):
        logits, new_caches = model.decode_step_spec(params, caches, tokens,
                                                    pos)
        target = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return target, new_caches

    def sampled_spec_step(params, caches, tokens, pos, temp, top_k, top_p,
                          keys):
        logits, new_caches = model.decode_step_spec(params, caches, tokens,
                                                    pos)
        target = sample_tokens_multi(logits, pos, temp, top_k, top_p, keys)
        return target, new_caches

    return sampled_spec_step if sampled else spec_step


def make_paged_spec_serve_step(model, page_size: int, draft_len: int,
                               sampled: bool = False) -> Callable:
    """Paged mirror of ``make_spec_serve_step`` (adds the page-table
    array; draft K/V land in the slot's mapped pages)."""
    def spec_step(params, caches, tokens, pos, page_idx):
        logits, new_caches = model.decode_step_spec_paged(
            params, caches, tokens, pos, page_idx, page_size=page_size)
        target = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return target, new_caches

    def sampled_spec_step(params, caches, tokens, pos, page_idx, temp,
                          top_k, top_p, keys):
        logits, new_caches = model.decode_step_spec_paged(
            params, caches, tokens, pos, page_idx, page_size=page_size)
        target = sample_tokens_multi(logits, pos, temp, top_k, top_p, keys)
        return target, new_caches

    return sampled_spec_step if sampled else spec_step


# ------------------------------------------------------------------- paged
def make_paged_serve_step(model, page_size: int,
                          sampled: bool = False) -> Callable:
    """Decode step over a paged KV cache: identical to ``make_serve_step``
    plus the scalar-prefetched ``page_idx (B, max_pages)`` page-table
    array (``page_size`` is static); ``sampled=True`` appends the same
    per-slot sampling arrays as the dense variant."""
    def serve_step(params, caches, tokens, pos, page_idx):
        logits, new_caches = model.decode_step_paged(params, caches, tokens,
                                                     pos, page_idx,
                                                     page_size=page_size)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_tokens, new_caches

    def sampled_serve_step(params, caches, tokens, pos, page_idx, temp,
                           top_k, top_p, keys):
        logits, new_caches = model.decode_step_paged(params, caches, tokens,
                                                     pos, page_idx,
                                                     page_size=page_size)
        next_tokens = sample_tokens(logits, pos, temp, top_k, top_p,
                                    keys)[:, None]
        return next_tokens, new_caches

    return sampled_serve_step if sampled else serve_step


def make_paged_prefill_chunk_step(model, page_size: int,
                                  sampled: bool = False) -> Callable:
    """Paged chunked prefill: the (1, C) chunk lands in the physical pages
    the slot's page-table row maps (C a page multiple, offset aligned);
    ``sampled=True`` mirrors ``make_prefill_chunk_step(sampled=True)``."""
    def prefill_chunk_step(params, caches, tokens, slot, offset, page_idx):
        logits, new_caches = model.prefill_chunk_step_paged(
            params, caches, tokens, slot, offset, page_idx,
            page_size=page_size)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tokens, new_caches

    def sampled_chunk_step(params, caches, tokens, slot, offset, page_idx,
                           last_row, temp, top_k, top_p, key):
        logits, new_caches = model.prefill_chunk_step_paged(
            params, caches, tokens, slot, offset, page_idx,
            page_size=page_size)
        row = jax.lax.dynamic_index_in_dim(logits, last_row, 0,
                                           keepdims=True)
        tok = sample_tokens(row, (offset + last_row)[None], temp[None],
                            top_k[None], top_p[None], key[None])[0]
        return tok, new_caches

    return sampled_chunk_step if sampled else prefill_chunk_step


# ------------------------------------------------- compiled-step LRU cache
# One module-level cache for every serving step the engines jit.  The
# pre-PR-4 per-engine dict meant each ServeEngine recompiled identical
# steps — every benchmark mode/policy sweep and ci.sh smoke paid XLA
# compilation again for the same (model config, step kind).  Keyed on
# (cfg, knobs, kind, sampled, page_size, draft_len): cfg and RuntimeKnobs
# are frozen dataclasses, so two engines over equal configs share one
# jitted callable (and with it jax's compilation cache).  Bounded LRU;
# falls back to an uncached build if a config is unhashable (custom
# shard_fn closures etc.).
_STEP_KINDS = {
    "serve": lambda m, ps, s, dl: make_serve_step(m, sampled=s),
    "prefill_chunk":
        lambda m, ps, s, dl: make_prefill_chunk_step(m, sampled=s),
    "paged_serve":
        lambda m, ps, s, dl: make_paged_serve_step(m, ps, sampled=s),
    "paged_prefill_chunk":
        lambda m, ps, s, dl: make_paged_prefill_chunk_step(m, ps, sampled=s),
    "paged_prefill_chunk_buf":
        lambda m, ps, s, dl: make_paged_prefill_chunk_buf_step(
            m, ps, sampled=s, gather=False),
    "paged_prefill_chunk_buf_gather":
        lambda m, ps, s, dl: make_paged_prefill_chunk_buf_step(
            m, ps, sampled=s, gather=True),
    "spec_serve": lambda m, ps, s, dl: make_spec_serve_step(m, dl, sampled=s),
    "paged_spec_serve":
        lambda m, ps, s, dl: make_paged_spec_serve_step(m, ps, dl, sampled=s),
    "decode_one": lambda m, ps, s, dl: m.decode_step,
}
# Steps that thread extra donatable state beyond the caches (argnum 1).
# The buffered prefill steps also consume/return the dense gather buffer
# at argnum 6, so donate it too and XLA reuses the allocation per chunk.
_STEP_DONATE = {
    "paged_prefill_chunk_buf": (1, 6),
    "paged_prefill_chunk_buf_gather": (1, 6),
}
_STEP_CACHE: OrderedDict = OrderedDict()
_STEP_CACHE_MAX = 64
_step_cache_hits = 0
_step_cache_misses = 0
_step_build_s = 0.0  # wall seconds spent building/jit-wrapping on misses


def step_cache_stats() -> dict:
    return {"hits": _step_cache_hits, "misses": _step_cache_misses,
            "size": len(_STEP_CACHE), "build_s": _step_build_s}


def compiled_fn(key, build: Callable, donate=()) -> Callable:
    """``jax.jit(build(), donate_argnums=donate)``, memoized in the
    shared bounded LRU.  ``build`` runs only on a miss.  Unhashable keys
    (custom shard_fn closures etc.) fall back to an uncached build.
    The serving engine routes every compiled callable — decode/prefill
    steps and the checkpoint copy_out/copy_in pair — through here, so
    there is exactly one cache to size and instrument."""
    global _step_cache_hits, _step_cache_misses, _step_build_s
    try:
        fn = _STEP_CACHE.get(key)
    except TypeError:
        key = None  # unhashable: build uncached
        fn = None
    if fn is not None:
        _step_cache_hits += 1
        _STEP_CACHE.move_to_end(key)
        return fn
    _step_cache_misses += 1
    t0 = time.perf_counter()
    fn = jax.jit(build(), donate_argnums=donate)
    _step_build_s += time.perf_counter() - t0
    if key is not None:
        _STEP_CACHE[key] = fn
        while len(_STEP_CACHE) > _STEP_CACHE_MAX:
            _STEP_CACHE.popitem(last=False)
    return fn


def compiled_step(model, kind: str, *, sampled: bool = False,
                  page_size: int = 0, decode_splits=None,
                  draft_len: int = 0) -> Callable:
    """Jitted serving step for ``model`` (donating the caches), memoized
    module-wide.  ``decode_splits`` overrides the knob for the split-K
    variants (the autotuner's per-fanout steps share the cache too);
    ``draft_len`` sizes the speculative verify block (spec kinds only —
    each draft depth is its own compiled step)."""
    knobs = (model.knobs if decode_splits is None
             else model.knobs.with_(decode_splits=decode_splits))

    def build():
        mdl = (model if knobs is model.knobs
               else type(model)(model.cfg, knobs))
        return _STEP_KINDS[kind](mdl, page_size, sampled, draft_len)

    return compiled_fn((model.cfg, knobs, kind, sampled, page_size,
                        draft_len), build,
                       donate=_STEP_DONATE.get(kind, (1,)))


# -------------------------------------------------------- split-K autotune
def pick_decode_splits(max_pos: int, batch: int, *, max_len: int,
                       page_size: int = 0, override: int = 0) -> int:
    """Choose the split-K fan-out for this decode tick.

    Split-K buys concurrency on the KV HBM stream: with few live slots
    and a long prefix, one sequential stream under-subscribes the memory
    system, so we split it.  With many live slots the batch axis already
    provides the parallelism and extra splits only pay combine overhead.

    Heuristic: double the splits while (a) each split still covers >= 2k
    tokens of live prefix, (b) total concurrent streams (batch * splits)
    stay <= 32, and (c) the split count divides the kernel's partition
    axis.  The dense kernel partitions the padded cache axis
    (``max_len``); the paged kernel tiles by whole pages, so with
    ``page_size > 0`` the splits must divide ``max_len // page_size``
    (the per-slot page count) — dividing ``max_len`` alone is not
    enough (e.g. max_len=96, page_size=16: 4 divides 96 but not the
    6 pages).  ``override >= 1`` (the ``RuntimeKnobs.decode_splits``
    static knob) bypasses the heuristic but is still clamped down to a
    divisor of the partition axis so a misconfigured knob cannot hand
    the kernel a ragged tiling.
    """
    units = max_len // page_size if page_size > 0 else max_len
    if override >= 1:
        splits = override
        while splits > 1 and units % splits:
            splits -= 1
        return splits
    if max_pos < 2048:
        return 1
    splits = 1
    while (splits < 8
           and max_pos // (2 * splits) >= 2048
           and 2 * splits * max(batch, 1) <= 32
           and units % (2 * splits) == 0):
        splits *= 2
    return splits
