"""AdamW with fp32 master weights, global-norm clipping, LR schedules.

Pure pytree implementation (no optax dependency).  The optimizer state is
the big memory consumer at scale; its sharding (ZeRO over pod+data axes) is
decided by ``sharding.opt_state_shardings`` — this module is sharding-
agnostic math.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def warmup_cosine(cfg: AdamWConfig) -> Callable:
    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
        t = jnp.clip((step - cfg.warmup_steps)
                     / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                     0.0, 1.0)
        cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return cfg.lr * warm * cos

    return schedule


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_init(params, moments_dtype=jnp.float32):
    """State: fp32 master copy + first/second moments + step counter.

    ``moments_dtype=bf16`` halves optimizer HBM for 100B+ models (update
    math still runs in fp32); the master copy always stays fp32.
    """
    # force a copy even for fp32 params: master must never alias the model
    # params (both live in the donated train state)
    master = jax.tree.map(lambda p: jnp.array(p, jnp.float32, copy=True),
                          params)
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, moments_dtype), params)
    return {"master": master, "mu": zeros,
            "nu": jax.tree.map(jnp.zeros_like, zeros),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(grads, opt_state, cfg: AdamWConfig,
                 schedule: Callable | None = None):
    """Returns (new_params_in_param_dtype_of_master?, new_state, metrics).

    The caller casts master -> param dtype; we return both.
    """
    schedule = schedule or warmup_cosine(cfg)
    step = opt_state["step"] + 1
    lr = schedule(step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12)) \
        if cfg.clip_norm else jnp.float32(1.0)

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu32 = cfg.b1 * mu.astype(jnp.float32) + (1 - cfg.b1) * g
        nu32 = cfg.b2 * nu.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = mu32 / b1c
        nhat = nu32 / b2c
        m = m - lr * (mhat / (jnp.sqrt(nhat) + cfg.eps)
                      + cfg.weight_decay * m)
        return m, mu32.astype(mu.dtype), nu32.astype(nu.dtype)

    flat_m, treedef = jax.tree.flatten(opt_state["master"])
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(opt_state["mu"])
    flat_nu = treedef.flatten_up_to(opt_state["nu"])
    out = [upd(g, m, mu, nu) for g, m, mu, nu
           in zip(flat_g, flat_m, flat_mu, flat_nu)]
    new_master = treedef.unflatten([o[0] for o in out])
    new_state = {
        "master": new_master,
        "mu": treedef.unflatten([o[1] for o in out]),
        "nu": treedef.unflatten([o[2] for o in out]),
        "step": step,
    }
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_master, new_state, metrics
