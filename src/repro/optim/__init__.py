from .adamw import (AdamWConfig, adamw_init, adamw_update, global_norm,
                    warmup_cosine)
from .compression import (CompressionState, compress_error_feedback,
                          dequantize_int8, quantize_int8)

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm",
           "warmup_cosine", "quantize_int8", "dequantize_int8",
           "CompressionState", "compress_error_feedback"]
