"""Int8 gradient compression with error feedback (beyond-paper, for the DCN
pod axis).

The pod-axis gradient all-reduce is the only DCN traffic in our meshes
(DESIGN.md §2); quantizing it to int8 cuts the dominant collective-term
bytes 4x at <1% relative error with error feedback.  Implemented as a
``shard_map``-compatible psum wrapper and unit-tested standalone; the cost
model exposes it via ``overlap``-style knobs (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp


def quantize_int8(x):
    """Per-tensor symmetric int8 quantization: x ~ q * scale."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


@dataclass
class CompressionState:
    error: dict  # pytree like grads, fp32 residuals

    @staticmethod
    def init(grads):
        return CompressionState(jax.tree.map(
            lambda g: jnp.zeros_like(g, jnp.float32), grads))


def compress_error_feedback(grads, state: CompressionState, axis_name: str):
    """Quantized psum over ``axis_name`` with error feedback.

    Call inside shard_map where ``axis_name`` is a manual axis.  Returns
    (mean-reduced grads, new state).  Scales are psum-maxed so every shard
    dequantizes identically.
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        amax = jnp.max(jnp.abs(gf))
        amax = jax.lax.pmax(amax, axis_name)
        scale = jnp.maximum(amax, 1e-30) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127)
        new_e = gf - q * scale  # residual stays local (error feedback)
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return (summed.astype(jnp.float32) * scale / n).astype(g.dtype), new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(state.error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_grads = treedef.unflatten([o[0] for o in out])
    new_state = CompressionState(treedef.unflatten([o[1] for o in out]))
    return new_grads, new_state
