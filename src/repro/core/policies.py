"""Placement policies — the paper's core contribution (Section V.C).

* ``SpreadPolicy``  — distribute a job's chips across as many (and as empty)
  hosts as possible: minimizes host-level contention (input pipeline, DCN
  NIC), at the cost of crossing pods -> DP collectives on DCN.
* ``MinHostPolicy`` — pack into the fewest hosts, preferring a single pod:
  keeps collectives on ICI, at the cost of sharing hosts with other jobs.
* ``AutoPolicy``    — beyond-paper: generates both candidates (plus a
  spread-within-one-pod hybrid) and picks the one whose *predicted* step
  time under the roofline cost model is lowest.  This generalizes the
  paper's static per-application policy choice into a cost-driven decision.

A placement is an assignment {agent_id -> chips}; gang semantics — either
the full demand is satisfiable from the offers or the job stays pending.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

from . import costmodel
from .jobs import JobSpec
from .resources import Offer


@dataclass(frozen=True)
class Placement:
    assignment: dict  # agent_id -> chips
    policy: str

    @property
    def n_hosts(self) -> int:
        return len(self.assignment)

    def n_pods(self, offers_by_id) -> int:
        return len({offers_by_id[a].agent.pod_id for a in self.assignment})


def _by_pod(offers):
    pods = {}
    for o in offers:
        pods.setdefault(o.agent.pod_id, []).append(o)
    return pods


class PlacementPolicy:
    name = "base"

    def place(self, job: JobSpec, offers: list[Offer],
              cluster=None) -> Optional[Placement]:
        raise NotImplementedError


class SpreadPolicy(PlacementPolicy):
    name = "spread"

    def place(self, job, offers, cluster=None):
        total_free = sum(o.available.chips for o in offers)
        if total_free < job.chips:
            return None
        # emptiest hosts first (avoid co-location), round-robin across pods
        pods = _by_pod(offers)
        for p in pods:
            pods[p] = sorted(pods[p], key=lambda o: -o.available.chips)
        order = []
        idx = {p: 0 for p in pods}
        pod_ids = sorted(pods)
        while any(idx[p] < len(pods[p]) for p in pod_ids):
            for p in pod_ids:
                if idx[p] < len(pods[p]):
                    order.append(pods[p][idx[p]])
                    idx[p] += 1
        # one chip per host per round until demand met
        remaining = job.chips
        free = {o.agent.agent_id: o.available.chips for o in order}
        assignment = {o.agent.agent_id: 0 for o in order}
        while remaining > 0:
            progressed = False
            for o in order:
                aid = o.agent.agent_id
                if remaining > 0 and assignment[aid] < free[aid]:
                    assignment[aid] += 1
                    remaining -= 1
                    progressed = True
            if not progressed:
                return None
        return Placement({a: c for a, c in assignment.items() if c}, self.name)


class MinHostPolicy(PlacementPolicy):
    name = "minhost"

    def place(self, job, offers, cluster=None):
        total_free = sum(o.available.chips for o in offers)
        if total_free < job.chips:
            return None
        # prefer the single pod with the most free capacity; within a pod,
        # fullest-fitting hosts first (fewest hosts overall)
        pods = _by_pod(offers)
        pod_order = sorted(pods, key=lambda p: -sum(o.available.chips
                                                    for o in pods[p]))
        assignment: dict = {}
        remaining = job.chips
        for p in pod_order:
            for o in sorted(pods[p], key=lambda o: -o.available.chips):
                if remaining <= 0:
                    break
                take = min(o.available.chips, remaining)
                assignment[o.agent.agent_id] = take
                remaining -= take
            if remaining <= 0:
                break
        if remaining > 0:
            return None
        return Placement(assignment, self.name)


class AutoPolicy(PlacementPolicy):
    """Cost-model-driven policy (beyond paper, see DESIGN.md §5)."""

    name = "auto"

    def __init__(self, dryrun_profiles: dict | None = None,
                 overlap: float = 0.0):
        self.dryrun_profiles = dryrun_profiles or {}
        self.overlap = overlap

    def place(self, job, offers, cluster=None):
        candidates = []
        for pol in (SpreadPolicy(), MinHostPolicy(), _SpreadOnePod()):
            pl = pol.place(job, offers, cluster)
            if pl is not None:
                candidates.append(pl)
        if not candidates:
            return None
        profile, infeed = costmodel.job_profile(job, self.dryrun_profiles)
        agents = {o.agent.agent_id: o.agent for o in offers}

        def predict(pl: Placement) -> float:
            sharing = 1.0
            if cluster is not None:
                shares = [len(cluster.hosts[a].jobs) + 1 for a in pl.assignment]
                sharing = sum(shares) / len(shares)
            view = costmodel.PlacementView(
                chips=job.chips, n_hosts=pl.n_hosts,
                n_pods=len({agents[a].pod_id for a in pl.assignment}),
                host_sharing=sharing)
            return costmodel.step_time(profile, infeed, view,
                                       overlap=self.overlap)["step_s"]

        best = min(candidates, key=predict)
        return dataclasses.replace(best, policy=f"auto->{best.policy}")


class _SpreadOnePod(PlacementPolicy):
    """Spread across hosts but constrained to the fewest pods possible."""

    name = "spread1pod"

    def place(self, job, offers, cluster=None):
        pods = _by_pod(offers)
        # try single pods with enough capacity, emptiest-host spread inside
        for p in sorted(pods, key=lambda p: -sum(o.available.chips
                                                 for o in pods[p])):
            if sum(o.available.chips for o in pods[p]) >= job.chips:
                return SpreadPolicy().place(job, pods[p], cluster)
        return None


POLICIES = {
    "spread": SpreadPolicy,
    "minhost": MinHostPolicy,
    "auto": AutoPolicy,
    "spread1pod": _SpreadOnePod,
}


def get_policy(name: str, **kw) -> PlacementPolicy:
    cls = POLICIES[name]
    try:
        return cls(**kw)
    except TypeError:
        return cls()
