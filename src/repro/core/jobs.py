"""Job model: an MPI job in the paper = a gang-scheduled SPMD JAX program.

A job names an (arch, shape) cell from the assigned pool, a chip demand, and
a placement policy.  Its roofline profile (FLOPs / HBM bytes / collective
bytes per step) either comes from the dry-run artifact
(``launch/roofline.py`` output) or from the closed-form estimate in
``costmodel.analytic_profile``.
"""
from __future__ import annotations

import dataclasses
import enum
from dataclasses import dataclass, field
from typing import Optional


class JobPhase(enum.Enum):
    PENDING = "pending"
    RUNNING = "running"
    DONE = "done"
    FAILED = "failed"


@dataclass(frozen=True)
class RooflineProfile:
    """Per-step, whole-job quantities (not per-chip)."""

    flops: float
    hbm_bytes: float
    ici_bytes: float  # collective bytes that stay on ICI
    dcn_bytes: float = 0.0  # collective bytes crossing pods (placement-dep.)

    def scaled(self, f: float) -> "RooflineProfile":
        return RooflineProfile(self.flops * f, self.hbm_bytes * f,
                               self.ici_bytes * f, self.dcn_bytes * f)


@dataclass(frozen=True)
class JobSpec:
    job_id: str
    arch: str
    shape: str
    chips: int  # gang size
    policy: str = "spread"  # spread | minhost | auto
    steps: int = 1000
    framework: str = "default"  # DRF principal
    priority: int = 0
    # profile override; None -> costmodel.analytic_profile(arch, shape)
    profile: Optional[RooflineProfile] = None
    checkpoint_every: int = 100  # steps between checkpoints (fault tolerance)


@dataclass
class JobState:
    spec: JobSpec
    phase: JobPhase = JobPhase.PENDING
    assignment: dict = field(default_factory=dict)  # agent_id -> chips
    layout: str = "tp"  # parallelism layout chosen at placement (§Perf H3)
    submit_time: float = 0.0
    start_time: float = -1.0
    finish_time: float = -1.0
    steps_done: int = 0
    last_checkpoint_step: int = 0
    restarts: int = 0

    @property
    def n_hosts(self) -> int:
        return len(self.assignment)

    def pods_used(self, cluster) -> set:
        return {cluster.hosts[a].agent.pod_id for a in self.assignment}
