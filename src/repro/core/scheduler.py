"""Scylla scheduler: offer negotiation + DRF + gang placement + lifecycle.

The control flow mirrors the paper's Figure 3 event flow:

1. agents advertise free resources (``cluster.advertise``),
2. the broker offers them to frameworks in DRF order,
3. the framework's placement policy packs the job onto accepted offers
   (gang semantics: all-or-nothing),
4. launch = XLA compile (the container-creation overhead analogue) + run.

Fault tolerance: host failure kills every gang with chips on that host; the
scheduler rolls each victim back to its last checkpoint and re-queues it —
re-placement may land on a *different* submesh shape (elastic restart,
mirrored by checkpoint/reshard in the real runtime).  Straggler mitigation:
a slowed host inflates its gangs' step time (gang = lockstep SPMD); jobs can
be migrated off when the slowdown exceeds a threshold.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from . import costmodel
from .cluster import Cluster
from .drf import DRFAllocator
from .jobs import JobPhase, JobSpec, JobState
from .policies import get_policy
from .resources import ResourceSpec

CHECKPOINT_WRITE_S = 2.0
RESTORE_BW_PER_HOST = 10e9  # bytes/s checkpoint restore


class ScyllaScheduler:
    def __init__(self, cluster: Cluster, *, co_schedule: bool = True,
                 default_policy: str = "spread",
                 dryrun_profiles: Optional[dict] = None,
                 overlap: float = 0.0,
                 straggler_threshold: float = 2.0,
                 compile_cache: bool = False):
        self.cluster = cluster
        self.co_schedule = co_schedule
        self.default_policy = default_policy
        self.dryrun_profiles = dryrun_profiles or {}
        self.overlap = overlap
        self.straggler_threshold = straggler_threshold
        self.compile_cache = compile_cache
        self._compiled: set = set()
        self.drf = DRFAllocator(cluster.total())
        self.pending: list[JobState] = []
        self.running: dict[str, JobState] = {}
        self.done: dict[str, JobState] = {}

    # ------------------------------------------------------------- submit
    def submit(self, spec: JobSpec, now: float) -> JobState:
        js = JobState(spec=spec, submit_time=now)
        self.pending.append(js)
        self.drf.register(spec.framework)
        return js

    # ---------------------------------------------------------- negotiate
    def try_schedule(self, now: float) -> list[JobState]:
        """One negotiation round; returns jobs started this round."""
        started = []
        candidates = {js.spec.framework for js in self.pending}
        while True:
            if not self.co_schedule and self.running:
                break  # exclusive (traditional HPC) mode: one gang at a time
            offers = self.cluster.advertise()
            # straggler mitigation: never place new gangs on flagged hosts
            offers = [o for o in offers
                      if self.cluster.hosts[o.agent.agent_id].slowdown
                      < self.straggler_threshold]
            if not offers or not candidates:
                break
            fw = self.drf.next_framework(sorted(candidates))
            if fw is None:
                break
            job = next((j for j in self.pending if j.spec.framework == fw),
                       None)
            if job is None:
                candidates.discard(fw)
                continue
            pol_name = job.spec.policy or self.default_policy
            policy = get_policy(pol_name, dryrun_profiles=self.dryrun_profiles,
                                overlap=self.overlap) \
                if pol_name == "auto" else get_policy(pol_name)
            placement = policy.place(job.spec, offers, self.cluster)
            if placement is None:
                candidates.discard(fw)  # framework declines this round
                continue
            self.cluster.allocate(job.spec.job_id, placement.assignment)
            res = ResourceSpec(job.spec.chips,
                               job.spec.chips * 16e9)
            self.drf.charge(fw, res)
            self.pending.remove(job)
            job.phase = JobPhase.RUNNING
            job.assignment = dict(placement.assignment)
            job.layout = costmodel.recommended_layout(job.spec.arch)
            job.start_time = now + self.launch_overhead_s(job.spec)
            self.running[job.spec.job_id] = job
            started.append(job)
        return started

    def launch_overhead_s(self, spec: JobSpec) -> float:
        key = (spec.arch, spec.shape, spec.chips)
        if self.compile_cache and key in self._compiled:
            return 1.0  # warm cache: dispatch/layout only
        self._compiled.add(key)
        return costmodel.compile_overhead_s(spec.arch)

    # ------------------------------------------------------------ timing
    def placement_view(self, job: JobState) -> costmodel.PlacementView:
        hosts = [self.cluster.hosts[a] for a in job.assignment]
        sharing = (sum(len(h.jobs) for h in hosts) / len(hosts)) if hosts else 1.0
        return costmodel.PlacementView(
            chips=job.spec.chips,
            n_hosts=len(hosts),
            n_pods=len({h.agent.pod_id for h in hosts}),
            max_host_slowdown=max((h.slowdown for h in hosts), default=1.0),
            host_sharing=max(sharing, 1.0),
        )

    def step_time_s(self, job: JobState) -> float:
        profile, infeed = costmodel.job_profile(job.spec, self.dryrun_profiles)
        terms = costmodel.step_time(profile, infeed, self.placement_view(job),
                                    overlap=self.overlap)
        return terms["step_s"]

    # ----------------------------------------------------------- endings
    def finish(self, job_id: str, now: float) -> JobState:
        job = self.running.pop(job_id)
        job.phase = JobPhase.DONE
        job.finish_time = now
        job.steps_done = job.spec.steps
        self.cluster.release(job_id)
        self.drf.credit(job.spec.framework,
                        ResourceSpec(job.spec.chips, job.spec.chips * 16e9))
        self.done[job_id] = job
        return job

    def evict(self, job_id: str, now: float, *, to_checkpoint: bool) -> JobState:
        """Kill a running gang; roll back and requeue (fault tolerance)."""
        job = self.running.pop(job_id)
        self.cluster.release(job_id)
        self.drf.credit(job.spec.framework,
                        ResourceSpec(job.spec.chips, job.spec.chips * 16e9))
        if to_checkpoint:
            job.steps_done = job.last_checkpoint_step
        job.assignment = {}
        job.phase = JobPhase.PENDING
        job.restarts += 1
        self.pending.insert(0, job)
        return job

    def on_host_failure(self, agent_id: str, now: float) -> list[JobState]:
        victims = self.cluster.fail_host(agent_id)
        out = []
        for jid in victims:
            # chips on the dead host are already gone; release the rest
            out.append(self.evict(jid, now, to_checkpoint=True))
        return out

    def stragglers_to_migrate(self) -> list[str]:
        out = []
        for jid, job in self.running.items():
            v = self.placement_view(job)
            if v.max_host_slowdown >= self.straggler_threshold:
                out.append(jid)
        return out

    def restore_overhead_s(self, spec: JobSpec, n_hosts: int) -> float:
        from repro.configs import get_config

        nbytes = get_config(spec.arch).param_count() * 12.0
        return CHECKPOINT_WRITE_S + nbytes / (max(n_hosts, 1)
                                              * RESTORE_BW_PER_HOST)
