"""Cluster state: agents (TPU hosts), allocations, failures, stragglers.

Mirrors the Mesos master's view of the world.  The cluster is organized as
``n_pods`` pods of ``hosts_per_pod`` hosts of ``CHIPS_PER_HOST`` chips;
allocation granularity is whole chips (TPUs are space-shared, not
time-sliced — DESIGN.md §2 note 4).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional

from . import hw
from .resources import AgentInfo, Offer, ResourceSpec


@dataclass(frozen=True)
class ClusterSpec:
    n_pods: int = 2
    hosts_per_pod: int = hw.HOSTS_PER_POD
    chips_per_host: int = hw.CHIPS_PER_HOST

    @property
    def n_hosts(self) -> int:
        return self.n_pods * self.hosts_per_pod

    @property
    def n_chips(self) -> int:
        return self.n_hosts * self.chips_per_host


@dataclass
class HostState:
    agent: AgentInfo
    alive: bool = True
    slowdown: float = 1.0  # >1.0 -> straggler
    used_chips: int = 0
    jobs: dict = field(default_factory=dict)  # job_id -> chips on this host

    @property
    def free_chips(self) -> int:
        return (hw.CHIPS_PER_HOST - self.used_chips) if self.alive else 0

    @property
    def free(self) -> ResourceSpec:
        return ResourceSpec(self.free_chips, self.free_chips * hw.HBM_PER_CHIP)


class Cluster:
    def __init__(self, spec: ClusterSpec):
        self.spec = spec
        self.hosts: dict[str, HostState] = {}
        for p in range(spec.n_pods):
            for h in range(spec.hosts_per_pod):
                aid = f"pod{p}/host{h:03d}"
                self.hosts[aid] = HostState(AgentInfo(aid, p, h))
        self._offer_seq = 0

    # ------------------------------------------------------------- offers
    def advertise(self) -> list[Offer]:
        """All agents advertise their free resources (Mesos step 1)."""
        offers = []
        for hs in self.hosts.values():
            if hs.alive and hs.free_chips > 0:
                self._offer_seq += 1
                offers.append(Offer(f"offer-{self._offer_seq}", hs.agent,
                                    hs.free))
        return offers

    # --------------------------------------------------------- allocation
    def allocate(self, job_id: str, assignment: dict[str, int]) -> None:
        """assignment: agent_id -> chips.  All-or-nothing (gang)."""
        for aid, chips in assignment.items():
            hs = self.hosts[aid]
            if not hs.alive or hs.free_chips < chips:
                raise ValueError(f"over-allocation on {aid} for {job_id}")
        for aid, chips in assignment.items():
            hs = self.hosts[aid]
            hs.used_chips += chips
            hs.jobs[job_id] = hs.jobs.get(job_id, 0) + chips

    def release(self, job_id: str) -> None:
        for hs in self.hosts.values():
            if job_id in hs.jobs:
                hs.used_chips -= hs.jobs.pop(job_id)

    def job_hosts(self, job_id: str) -> dict[str, int]:
        return {aid: hs.jobs[job_id] for aid, hs in self.hosts.items()
                if job_id in hs.jobs}

    # ------------------------------------------------------ fault events
    def fail_host(self, agent_id: str) -> list[str]:
        """Kill a host; returns the job_ids that were running on it."""
        hs = self.hosts[agent_id]
        hs.alive = False
        victims = list(hs.jobs)
        hs.used_chips = 0
        hs.jobs.clear()
        return victims

    def heal_host(self, agent_id: str) -> None:
        self.hosts[agent_id].alive = True
        self.hosts[agent_id].slowdown = 1.0

    def set_straggler(self, agent_id: str, slowdown: float) -> list[str]:
        self.hosts[agent_id].slowdown = slowdown
        return list(self.hosts[agent_id].jobs)

    # ----------------------------------------------------------- metrics
    def total(self) -> ResourceSpec:
        alive = [h for h in self.hosts.values() if h.alive]
        chips = sum(hw.CHIPS_PER_HOST for _ in alive)
        return ResourceSpec(chips, chips * hw.HBM_PER_CHIP)

    def used(self) -> ResourceSpec:
        chips = sum(h.used_chips for h in self.hosts.values())
        return ResourceSpec(chips, chips * hw.HBM_PER_CHIP)

    def utilization(self) -> float:
        tot = self.total()
        return (self.used().chips / tot.chips) if tot.chips else 0.0
