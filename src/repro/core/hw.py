"""Hardware constants (TPU v5e target) used by the cost model and roofline.

These are the single source of truth — launch/roofline.py and core/costmodel
both import from here.  Documented assumptions (DESIGN.md §2):

* ICI: ~50 GB/s per link; we charge collectives at 50 GB/s per chip
  (conservative single-link effective bandwidth).
* DCN: 12.5 GB/s per host (100 Gbps NIC) — only traffic on the "pod" mesh
  axis pays this.
"""

PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per chip (effective, collectives)
DCN_BW_PER_HOST = 12.5e9  # bytes/s per host NIC

CHIPS_PER_HOST = 4
HBM_PER_CHIP = 16e9  # bytes
HOSTS_PER_POD = 64  # 16x16 = 256 chips / 4 chips-per-host

# XLA compile + first-dispatch overhead model for the "container creation"
# analogue (benchmarks/container_overhead.py fits these from measurement).
COMPILE_BASE_S = 20.0
COMPILE_PER_GPARAM_S = 3.0
