"""Roofline cost model: job step-time as a function of placement.

This is where the paper's Spread-vs-MinHost tension becomes quantitative on
TPU (DESIGN.md §2):

* **Comm locality** — collectives on the "pod" axis pay DCN (12.5 GB/s/host)
  instead of ICI (50 GB/s/chip).  Packing (MinHost) keeps traffic on ICI.
* **Host contention** — chips are dedicated, but the *host* CPU (input
  pipeline) and the host DCN NIC are shared by co-located jobs.  Spreading
  onto whole, otherwise-idle hosts avoids it.
* **Stragglers** — a gang runs at the pace of its slowest host.

Profiles come from the dry-run artifact when available (exact HLO numbers,
see launch/roofline.py) and from ``analytic_profile`` otherwise.

step_time = max(compute, memory, infeed) + (ici + dcn) * (1 - overlap)
(overlap=0 is the paper-faithful baseline; compute/comm overlap is a
beyond-paper optimization recorded separately in EXPERIMENTS.md §Perf.)
"""
from __future__ import annotations

import dataclasses
import json
import os
from dataclasses import dataclass

from . import hw
from .jobs import JobSpec, RooflineProfile

INFEED_BW_PER_HOST = 2e9  # bytes/s of host-CPU input pipeline


# ---------------------------------------------------------------- profiles
def analytic_profile(arch: str, shape: str) -> RooflineProfile:
    """Closed-form roofline estimate for one (arch, shape) cell."""
    from repro.configs import SHAPES, get_config

    cfg = get_config(arch)
    sh = SHAPES[shape]
    n_active = cfg.param_count(active_only=True)
    n_total = cfg.param_count()
    b, s = sh.global_batch, sh.seq_len
    lk = cfg.layer_kinds()
    n_attn = sum(1 for k in lk if k in ("attn", "moe", "local", "global"))

    def attn_flops(tokens_q, tokens_k):
        return 4.0 * n_attn * cfg.num_heads * cfg.head_dim * tokens_q * tokens_k

    if sh.kind == "train":
        tokens = b * s
        flops = 6.0 * n_active * tokens + 3 * attn_flops(tokens, s / 2)
        hbm = 30.0 * n_total + 4.0 * tokens * cfg.d_model * 2
        # DP gradient all-reduce (~2x payload, bf16) + per-layer TP collectives
        ici = 4.0 * n_total * 2.0 + 4.0 * tokens * cfg.d_model * 2
        infeed = tokens * 4.0
        if cfg.input_mode == "embeddings":  # vlm: patch embeds stream in
            infeed = tokens * cfg.d_model * 2.0
    elif sh.kind == "prefill":
        tokens = b * s
        flops = 2.0 * n_active * tokens + attn_flops(tokens, s / 2)
        hbm = 2.0 * n_total + 4.0 * tokens * cfg.d_model * 2
        ici = 2.0 * tokens * cfg.d_model * 2
        infeed = tokens * 4.0
        if cfg.input_mode == "embeddings":
            infeed = tokens * cfg.d_model * 2.0
    else:  # decode: one token per sequence against a seq_len cache
        tokens = b
        kv_bytes = (2 * n_attn * cfg.num_kv_heads * cfg.head_dim * s * b * 2.0
                    if cfg.num_heads else 0.0)
        if cfg.ssm is not None:
            nh = cfg.ssm.n_heads(cfg.d_model)
            kv_bytes += (cfg.num_layers * b * nh * cfg.ssm.head_dim
                         * cfg.ssm.d_state * 4.0)
        flops = 2.0 * n_active * tokens + 2.0 * kv_bytes  # cache dot ~ 2F/byte
        hbm = 2.0 * n_total + kv_bytes
        ici = 2.0 * tokens * cfg.d_model * 2.0
        infeed = tokens * 4.0
    return RooflineProfile(flops=flops, hbm_bytes=hbm, ici_bytes=ici,
                           dcn_bytes=0.0), infeed


def load_dryrun_profiles(path: str) -> dict:
    """Optional exact profiles from the dry-run artifact (roofline.json)."""
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        rows = json.load(f)
    out = {}
    for r in rows:
        if (r.get("skipped") or r.get("error")
                or r.get("tag", "baseline") != "baseline"
                or r.get("mesh") != "single"):
            continue
        key = (r["arch"], r["shape"])
        out[key] = RooflineProfile(
            flops=r["hlo_flops"], hbm_bytes=r["hlo_bytes"],
            ici_bytes=r["collective_bytes"], dcn_bytes=0.0)
    return out


# --------------------------------------------------------------- step time
@dataclass(frozen=True)
class PlacementView:
    """What the cost model needs to know about where a job landed."""

    chips: int
    n_hosts: int
    n_pods: int
    max_host_slowdown: float = 1.0
    # mean number of jobs sharing this job's hosts (>=1)
    host_sharing: float = 1.0


def step_time(profile: RooflineProfile, infeed_bytes: float,
              view: PlacementView, *, overlap: float = 0.0,
              dp_fraction_cross_pod: float | None = None) -> dict:
    """Returns the roofline terms (seconds) and the combined step time."""
    chips = max(view.chips, 1)
    compute = profile.flops / (chips * hw.PEAK_FLOPS_BF16)
    memory = profile.hbm_bytes / (chips * hw.HBM_BW)
    ici = profile.ici_bytes / (chips * hw.ICI_BW)
    # DCN: the DP gradient/activation sync that crosses pods.  By default,
    # spanning P pods sends the (P-1)/P share of the DP all-reduce over DCN.
    dcn_bytes = profile.dcn_bytes
    if view.n_pods > 1:
        frac = ((view.n_pods - 1) / view.n_pods
                if dp_fraction_cross_pod is None else dp_fraction_cross_pod)
        dcn_bytes = max(dcn_bytes, profile.ici_bytes * frac)
    dcn = dcn_bytes / max(view.n_hosts, 1) / (hw.DCN_BW_PER_HOST
                                              / max(view.host_sharing, 1.0))
    infeed = (infeed_bytes * view.host_sharing
              / (max(view.n_hosts, 1) * INFEED_BW_PER_HOST))
    comm = (ici + dcn) * (1.0 - overlap)
    t = (max(compute, memory, infeed) + comm) * view.max_host_slowdown
    return {"compute_s": compute, "memory_s": memory, "infeed_s": infeed,
            "ici_s": ici, "dcn_s": dcn, "step_s": t,
            "bottleneck": max(
                [("compute", compute), ("memory", memory),
                 ("infeed", infeed), ("collective", ici + dcn)],
                key=lambda kv: kv[1])[0]}


def job_profile(spec: JobSpec, dryrun_profiles: dict | None = None):
    """(profile, infeed_bytes) for a job, preferring dry-run numbers."""
    _, infeed = analytic_profile(spec.arch, spec.shape)
    if spec.profile is not None:
        return spec.profile, infeed
    if dryrun_profiles:
        p = dryrun_profiles.get((spec.arch, spec.shape))
        if p is not None:
            return p, infeed
    return analytic_profile(spec.arch, spec.shape)[0], infeed


def recommended_layout(arch: str, *, tokens_per_step: float = 1e6) -> str:
    """Pick the parallelism layout from the job profile (§Perf H3).

    Napkin: pure-DP pays one grad all-reduce (~4·N bytes/step) while TP
    pays per-layer activation all-reduces (~4·L·tokens·d_model·2 bytes,
    measured 240 GB/dev on internlm2). DP wins while params are small
    relative to the activation stream — measured crossover ~4B params for
    1M-token steps (internlm2 1.7B: 7.0× faster under dp).
    """
    from repro.configs import get_config

    n = get_config(arch).param_count()
    return "dp" if n < 4e9 * (tokens_per_step / 1e6) else "tp"


def compile_overhead_s(arch: str) -> float:
    """XLA compile + dispatch setup — the container-creation analogue."""
    from repro.configs import get_config

    n = get_config(arch).param_count() / 1e9
    return hw.COMPILE_BASE_S + hw.COMPILE_PER_GPARAM_S * n
