"""Discrete-event simulator driving the Scylla scheduler.

Deterministic (no wall clock, no unseeded randomness).  Because step time
depends on *current* contention/stragglers, running jobs are re-modeled on
every cluster change: progress is integrated up to the event time, then the
finish event is re-issued (stale events are dropped via versioning).

Produces the data behind the paper's figures: utilization traces (Figs
8-11), makespan/throughput comparisons (co-scheduled vs exclusive), policy
comparisons (Figs 12-13), and overhead amortization (Fig 5).
"""
from __future__ import annotations

import dataclasses
import heapq
from dataclasses import dataclass, field
from typing import Callable, Optional

from .cluster import Cluster, ClusterSpec
from .jobs import JobPhase, JobSpec, JobState
from .scheduler import ScyllaScheduler


@dataclass(order=True)
class _Event:
    time: float
    seq: int
    kind: str = field(compare=False)
    payload: dict = field(compare=False, default_factory=dict)


class Simulator:
    def __init__(self, cluster_spec: ClusterSpec, *, co_schedule=True,
                 default_policy="spread", dryrun_profiles=None, overlap=0.0,
                 compile_cache=False, migrate_stragglers=False):
        self.cluster = Cluster(cluster_spec)
        self.sched = ScyllaScheduler(
            self.cluster, co_schedule=co_schedule,
            default_policy=default_policy, dryrun_profiles=dryrun_profiles,
            overlap=overlap, compile_cache=compile_cache)
        self.migrate_stragglers = migrate_stragglers
        self._heap: list[_Event] = []
        self._seq = 0
        self._job_version: dict[str, int] = {}
        self._progress_at: dict[str, tuple[float, float]] = {}  # jid -> (t, steps)
        self.now = 0.0
        self.util_trace: list[tuple[float, float]] = []
        self.events_log: list[tuple[float, str, str]] = []
        self.events_processed = 0

    # ------------------------------------------------------------ seeding
    def _push(self, time: float, kind: str, **payload):
        self._seq += 1
        heapq.heappush(self._heap, _Event(time, self._seq, kind, payload))

    def submit_at(self, t: float, spec: JobSpec):
        self._push(t, "submit", spec=spec)

    def fail_host_at(self, t: float, agent_id: str):
        self._push(t, "fail_host", agent_id=agent_id)

    def heal_host_at(self, t: float, agent_id: str):
        self._push(t, "heal_host", agent_id=agent_id)

    def straggle_at(self, t: float, agent_id: str, slowdown: float):
        self._push(t, "straggler", agent_id=agent_id, slowdown=slowdown)

    # ------------------------------------------------------- progress math
    def _integrate_progress(self, job: JobState):
        """Advance steps_done up to self.now under the old step time."""
        jid = job.spec.job_id
        t0, steps0 = self._progress_at.get(jid, (job.start_time, 0.0))
        if self.now <= t0:
            return steps0
        st = self.sched.step_time_s(job)
        steps = steps0 + max(0.0, (self.now - t0)) / max(st, 1e-12)
        steps = min(steps, float(job.spec.steps))
        job.steps_done = int(steps)
        cpe = job.spec.checkpoint_every
        job.last_checkpoint_step = (job.steps_done // cpe) * cpe
        self._progress_at[jid] = (self.now, steps)
        return steps

    def _reissue_finish(self, job: JobState):
        jid = job.spec.job_id
        steps = self._progress_at.get(jid, (job.start_time, 0.0))[1]
        st = self.sched.step_time_s(job)
        t_fin = max(self.now, job.start_time) + (job.spec.steps - steps) * st
        self._job_version[jid] = self._job_version.get(jid, 0) + 1
        self._push(t_fin, "finish", job_id=jid,
                   version=self._job_version[jid])

    def _remodel_running(self):
        for job in list(self.sched.running.values()):
            self._integrate_progress(job)
            self._reissue_finish(job)

    def _record_util(self):
        """One sample per processed event, taken after all of the event's
        state changes (run() is the only caller).  Recording inside the
        handlers too used to emit duplicate/mid-update samples at the same
        timestamp, skewing the time-weighted average in results()."""
        self.util_trace.append((self.now, self.cluster.utilization()))

    # ----------------------------------------------------------- main loop
    def _schedule_round(self):
        started = self.sched.try_schedule(self.now)
        for job in started:
            jid = job.spec.job_id
            self._progress_at[jid] = (job.start_time, 0.0)
            self._reissue_finish(job)
            self.events_log.append((self.now, "start", jid))
        if started:
            self._remodel_running()

    def run(self, until: float = float("inf")) -> dict:
        while self._heap and self._heap[0].time <= until:
            ev = heapq.heappop(self._heap)
            self.now = ev.time
            if ev.kind == "submit":
                self.sched.submit(ev.payload["spec"], self.now)
                self.events_log.append((self.now, "submit",
                                        ev.payload["spec"].job_id))
                self._remodel_running()
                self._schedule_round()
            elif ev.kind == "finish":
                jid = ev.payload["job_id"]
                if ev.payload["version"] != self._job_version.get(jid):
                    continue  # stale
                if jid not in self.sched.running:
                    continue
                self.sched.finish(jid, self.now)
                self._progress_at.pop(jid, None)
                self.events_log.append((self.now, "finish", jid))
                self._remodel_running()
                self._schedule_round()
            elif ev.kind == "fail_host":
                self._remodel_running()
                victims = self.sched.on_host_failure(ev.payload["agent_id"],
                                                     self.now)
                for job in victims:
                    self._job_version[job.spec.job_id] = \
                        self._job_version.get(job.spec.job_id, 0) + 1
                    self._progress_at.pop(job.spec.job_id, None)
                    self.events_log.append((self.now, "evict",
                                            job.spec.job_id))
                self._remodel_running()
                self._schedule_round()
            elif ev.kind == "heal_host":
                self.cluster.heal_host(ev.payload["agent_id"])
                self._schedule_round()
            elif ev.kind == "straggler":
                self._remodel_running()
                self.cluster.set_straggler(ev.payload["agent_id"],
                                           ev.payload["slowdown"])
                self._remodel_running()
                if self.migrate_stragglers:
                    for jid in self.sched.stragglers_to_migrate():
                        job = self.sched.running[jid]
                        self._integrate_progress(job)
                        self.sched.evict(jid, self.now, to_checkpoint=True)
                        self._job_version[jid] = \
                            self._job_version.get(jid, 0) + 1
                        self._progress_at.pop(jid, None)
                        self.events_log.append((self.now, "migrate", jid))
                    self._schedule_round()
            self.events_processed += 1
            self._record_util()
        return self.results()

    # ------------------------------------------------------------ results
    def results(self) -> dict:
        jobs = dict(self.sched.done)
        makespan = max((j.finish_time for j in jobs.values()), default=0.0)
        trace = sorted(self.util_trace)
        # time-weighted average utilization over [0, makespan]
        avg_util = 0.0
        if makespan > 0 and len(trace) > 1:
            area, prev_t, prev_u = 0.0, 0.0, 0.0
            for t, u in trace:
                t = min(t, makespan)
                area += (t - prev_t) * prev_u
                prev_t, prev_u = t, u
            area += (makespan - prev_t) * prev_u
            avg_util = area / makespan
        waits = [max(0.0, j.start_time - j.submit_time)
                 for j in jobs.values()]
        return {
            "jobs": jobs,
            "makespan": makespan,
            "avg_utilization": avg_util,
            "util_trace": trace,
            "mean_wait_s": sum(waits) / len(waits) if waits else 0.0,
            "restarts": sum(j.restarts for j in jobs.values()),
            "pending": len(self.sched.pending),
            "running": len(self.sched.running),
        }
