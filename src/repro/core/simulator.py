"""Discrete-event simulator driving the Scylla scheduler.

Deterministic (no wall clock, no unseeded randomness).  Because step time
depends on *current* contention/stragglers, running jobs are re-modeled on
every cluster change: progress is integrated up to the event time, then the
finish event is re-issued (stale events are dropped via versioning).

Produces the data behind the paper's figures: utilization traces (Figs
8-11), makespan/throughput comparisons (co-scheduled vs exclusive), policy
comparisons (Figs 12-13), and overhead amortization (Fig 5).
"""
from __future__ import annotations

import dataclasses
import heapq
from dataclasses import dataclass, field
from typing import Callable, Optional

from .cluster import Cluster, ClusterSpec
from .jobs import JobPhase, JobSpec, JobState
from .scheduler import ScyllaScheduler


@dataclass(order=True)
class _Event:
    time: float
    seq: int
    kind: str = field(compare=False)
    payload: dict = field(compare=False, default_factory=dict)


class Simulator:
    def __init__(self, cluster_spec: ClusterSpec, *, co_schedule=True,
                 default_policy="spread", dryrun_profiles=None, overlap=0.0,
                 compile_cache=False, migrate_stragglers=False):
        self.cluster = Cluster(cluster_spec)
        self.sched = ScyllaScheduler(
            self.cluster, co_schedule=co_schedule,
            default_policy=default_policy, dryrun_profiles=dryrun_profiles,
            overlap=overlap, compile_cache=compile_cache)
        self.migrate_stragglers = migrate_stragglers
        self._heap: list[_Event] = []
        self._seq = 0
        self._job_version: dict[str, int] = {}
        self._progress_at: dict[str, tuple[float, float]] = {}  # jid -> (t, steps)
        self.now = 0.0
        self.util_trace: list[tuple[float, float]] = []
        self.events_log: list[tuple[float, str, str]] = []
        self.events_processed = 0

    # ------------------------------------------------------------ seeding
    def _push(self, time: float, kind: str, **payload):
        self._seq += 1
        heapq.heappush(self._heap, _Event(time, self._seq, kind, payload))

    def submit_at(self, t: float, spec: JobSpec):
        self._push(t, "submit", spec=spec)

    def fail_host_at(self, t: float, agent_id: str):
        self._push(t, "fail_host", agent_id=agent_id)

    def heal_host_at(self, t: float, agent_id: str):
        self._push(t, "heal_host", agent_id=agent_id)

    def straggle_at(self, t: float, agent_id: str, slowdown: float):
        self._push(t, "straggler", agent_id=agent_id, slowdown=slowdown)

    # ------------------------------------------------------- progress math
    def _integrate_progress(self, job: JobState):
        """Advance steps_done up to self.now under the old step time."""
        jid = job.spec.job_id
        t0, steps0 = self._progress_at.get(jid, (job.start_time, 0.0))
        if self.now <= t0:
            return steps0
        st = self.sched.step_time_s(job)
        steps = steps0 + max(0.0, (self.now - t0)) / max(st, 1e-12)
        steps = min(steps, float(job.spec.steps))
        job.steps_done = int(steps)
        cpe = job.spec.checkpoint_every
        job.last_checkpoint_step = (job.steps_done // cpe) * cpe
        self._progress_at[jid] = (self.now, steps)
        return steps

    def _reissue_finish(self, job: JobState):
        jid = job.spec.job_id
        steps = self._progress_at.get(jid, (job.start_time, 0.0))[1]
        st = self.sched.step_time_s(job)
        t_fin = max(self.now, job.start_time) + (job.spec.steps - steps) * st
        self._job_version[jid] = self._job_version.get(jid, 0) + 1
        self._push(t_fin, "finish", job_id=jid,
                   version=self._job_version[jid])

    def _remodel_running(self):
        for job in list(self.sched.running.values()):
            self._integrate_progress(job)
            self._reissue_finish(job)

    def _record_util(self):
        """One sample per processed event, taken after all of the event's
        state changes (run() is the only caller).  Recording inside the
        handlers too used to emit duplicate/mid-update samples at the same
        timestamp, skewing the time-weighted average in results()."""
        self.util_trace.append((self.now, self.cluster.utilization()))

    # ----------------------------------------------------------- main loop
    def _schedule_round(self):
        started = self.sched.try_schedule(self.now)
        for job in started:
            jid = job.spec.job_id
            self._progress_at[jid] = (job.start_time, 0.0)
            self._reissue_finish(job)
            self.events_log.append((self.now, "start", jid))
        if started:
            self._remodel_running()

    def run(self, until: float = float("inf")) -> dict:
        while self._heap and self._heap[0].time <= until:
            ev = heapq.heappop(self._heap)
            self.now = ev.time
            if ev.kind == "submit":
                self.sched.submit(ev.payload["spec"], self.now)
                self.events_log.append((self.now, "submit",
                                        ev.payload["spec"].job_id))
                self._remodel_running()
                self._schedule_round()
            elif ev.kind == "finish":
                jid = ev.payload["job_id"]
                if ev.payload["version"] != self._job_version.get(jid):
                    continue  # stale
                if jid not in self.sched.running:
                    continue
                self.sched.finish(jid, self.now)
                self._progress_at.pop(jid, None)
                self.events_log.append((self.now, "finish", jid))
                self._remodel_running()
                self._schedule_round()
            elif ev.kind == "fail_host":
                self._remodel_running()
                victims = self.sched.on_host_failure(ev.payload["agent_id"],
                                                     self.now)
                for job in victims:
                    self._job_version[job.spec.job_id] = \
                        self._job_version.get(job.spec.job_id, 0) + 1
                    self._progress_at.pop(job.spec.job_id, None)
                    self.events_log.append((self.now, "evict",
                                            job.spec.job_id))
                self._remodel_running()
                self._schedule_round()
            elif ev.kind == "heal_host":
                self.cluster.heal_host(ev.payload["agent_id"])
                self._schedule_round()
            elif ev.kind == "straggler":
                self._remodel_running()
                self.cluster.set_straggler(ev.payload["agent_id"],
                                           ev.payload["slowdown"])
                self._remodel_running()
                if self.migrate_stragglers:
                    for jid in self.sched.stragglers_to_migrate():
                        job = self.sched.running[jid]
                        self._integrate_progress(job)
                        self.sched.evict(jid, self.now, to_checkpoint=True)
                        self._job_version[jid] = \
                            self._job_version.get(jid, 0) + 1
                        self._progress_at.pop(jid, None)
                        self.events_log.append((self.now, "migrate", jid))
                    self._schedule_round()
            self.events_processed += 1
            self._record_util()
        return self.results()

    # ------------------------------------------------------------ results
    def results(self) -> dict:
        jobs = dict(self.sched.done)
        makespan = max((j.finish_time for j in jobs.values()), default=0.0)
        trace = sorted(self.util_trace)
        # time-weighted average utilization over [0, makespan]
        avg_util = 0.0
        if makespan > 0 and len(trace) > 1:
            area, prev_t, prev_u = 0.0, 0.0, 0.0
            for t, u in trace:
                t = min(t, makespan)
                area += (t - prev_t) * prev_u
                prev_t, prev_u = t, u
            area += (makespan - prev_t) * prev_u
            avg_util = area / makespan
        waits = [max(0.0, j.start_time - j.submit_time)
                 for j in jobs.values()]
        return {
            "jobs": jobs,
            "makespan": makespan,
            "avg_utilization": avg_util,
            "util_trace": trace,
            "mean_wait_s": sum(waits) / len(waits) if waits else 0.0,
            "restarts": sum(j.restarts for j in jobs.values()),
            "pending": len(self.sched.pending),
            "running": len(self.sched.running),
        }


# ===================================================================== churn
# Serving-side churn simulation: drives the REAL runtime Autoscaler over a
# fake disaggregated replica pool at thousands-of-requests scale.  No jax,
# no engines — requests are (prefill_ticks, decode_ticks) work items — so
# scaling scenarios the real cluster validates at small scale
# (tests/test_disagg.py) can run 1000x larger here, deterministically.

@dataclass
class SimRequest:
    """One synthetic request: remaining ticks of prefill/decode work."""

    rid: int
    prefill_left: int
    decode_left: int
    tenant: str = "free"


@dataclass
class SimReplica:
    """One fake replica: role, slot capacity, lifecycle state, and the
    requests currently occupying its slots."""

    rid: int
    role: str
    slots: int
    state: str = "up"  # up | draining | down
    active: list = field(default_factory=list)

    def free(self) -> int:
        return self.slots - len(self.active)


class ServeChurnSim:
    """Churn harness implementing the ``Autoscaler`` adapter protocol.

    The tick loop mirrors ``DisaggRouter.step``: arrivals queue for
    prefill, finished prefills move to a handoff queue, decode replicas
    adopt them, completions drain out.  The autoscaler under test is the
    same object the real router runs; the sim only fakes the replicas.

    ``trace`` is the per-tick arrival count (the default is a
    burst / idle / burst shape that forces scale-ups AND scale-downs);
    ``prefill_ticks`` / ``decode_ticks`` are (lo, hi) work ranges drawn
    per request from the seeded rng.
    """

    ROLE_SPECS = ("prefill", "decode")

    def __init__(self, *, slots: int = 4, init_replicas: int = 1,
                 max_replicas: int = 4, min_replicas: int = 1,
                 policy: str = "queue-depth", cooldown: int = 10,
                 sustain: int = 3, trace=None, seed: int = 0,
                 prefill_ticks=(1, 3), decode_ticks=(4, 12),
                 tenant_weights=None):
        import numpy as _np

        from repro.runtime.autoscale import Autoscaler

        self.rng = _np.random.default_rng(seed)
        self.slots = slots
        self.prefill_ticks = prefill_ticks
        self.decode_ticks = decode_ticks
        self.tenant_weights = dict(tenant_weights
                                   or {"gold": 3.0, "free": 1.0})
        if trace is None:
            trace = [3] * 60 + [0] * 80 + [2] * 60
        self.trace = list(trace)
        self.replicas: list[SimReplica] = []
        for role in self.ROLE_SPECS:
            for i in range(max_replicas):
                self.replicas.append(SimReplica(
                    rid=len(self.replicas), role=role, slots=slots,
                    state="up" if i < init_replicas else "down"))
        self.prefill_queue: list[SimRequest] = []
        self.handoff_queue: list[SimRequest] = []
        self.completed = 0
        self.arrived = 0
        self.tick_now = 0
        self.bounds_ok = True
        self.replica_trace: list[dict] = []
        self.autoscaler = Autoscaler(
            self, policy, min_replicas=min_replicas,
            max_replicas=max_replicas, cooldown=cooldown, sustain=sustain)

    # ----------------------------------------------- autoscaler adapter
    def scale_roles(self):
        return list(self.ROLE_SPECS)

    def _of_role(self, role, *states):
        return [r for r in self.replicas
                if r.role == role and r.state in states]

    def replica_state(self, rid: int) -> str:
        return self.replicas[rid].state

    def observe(self, role: str):
        from repro.runtime.autoscale import RoleObservation
        live = self._of_role(role, "up")
        backlog = (self.prefill_queue if role == "prefill"
                   else self.handoff_queue)
        return RoleObservation(
            role=role, live=len(live), backlog=len(backlog),
            weighted_backlog=sum(
                self.tenant_weights.get(r.tenant, 1.0) for r in backlog),
            free_slots=sum(r.free() for r in live),
            slots_per_replica=self.slots)

    def scale_up(self, role: str):
        down = self._of_role(role, "down")
        if not down:
            return None
        down[0].state = "up"
        return down[0].rid

    def begin_scale_down(self, role: str):
        up = self._of_role(role, "up")
        if not up:
            return None
        victim = min(up, key=lambda r: (len(r.active), -r.rid))
        victim.state = "draining"
        # drain-migrate, as the real router does through release():
        # prefill work requeues (its progress is a few ticks), decode
        # work re-enters the handoff queue checkpoint-style
        if victim.role == "prefill":
            self.prefill_queue = victim.active + self.prefill_queue
        else:
            self.handoff_queue = victim.active + self.handoff_queue
        victim.active = []
        return victim.rid

    # ------------------------------------------------------------ ticking
    def _arrive(self, n: int) -> None:
        for _ in range(n):
            self.arrived += 1
            self.prefill_queue.append(SimRequest(
                rid=self.arrived,
                prefill_left=int(self.rng.integers(*self.prefill_ticks,
                                                   endpoint=True)),
                decode_left=int(self.rng.integers(*self.decode_ticks,
                                                  endpoint=True)),
                tenant=("gold" if self.rng.random() < 0.3 else "free")))

    def _place(self, queue: list, role: str) -> None:
        for rep in self._of_role(role, "up"):
            while queue and rep.free() > 0:
                rep.active.append(queue.pop(0))

    def step(self) -> None:
        t = self.tick_now
        self._arrive(self.trace[t] if t < len(self.trace) else 0)
        self.autoscaler.tick(t)
        # advance + harvest both stages (draining replicas keep working)
        for rep in self._of_role("prefill", "up", "draining"):
            done = []
            for req in rep.active:
                req.prefill_left -= 1
                if req.prefill_left <= 0:
                    done.append(req)
            for req in done:
                rep.active.remove(req)
                self.handoff_queue.append(req)
        for rep in self._of_role("decode", "up", "draining"):
            done = []
            for req in rep.active:
                req.decode_left -= 1
                if req.decode_left <= 0:
                    done.append(req)
            for req in done:
                rep.active.remove(req)
                self.completed += 1
        self._place(self.prefill_queue, "prefill")
        self._place(self.handoff_queue, "decode")
        for rep in self.replicas:
            if rep.state == "draining" and not rep.active:
                rep.state = "down"
        counts = {}
        for role in self.ROLE_SPECS:
            n = len(self._of_role(role, "up", "draining"))
            counts[role] = n
            lo, hi = self.autoscaler.bounds(
                role, len(self._of_role(role, "up", "draining", "down")))
            if not lo <= n <= hi:
                self.bounds_ok = False
        self.replica_trace.append(counts)
        self.tick_now += 1

    def pending(self) -> int:
        return (len(self.prefill_queue) + len(self.handoff_queue)
                + sum(len(r.active) for r in self.replicas))

    def run(self, max_ticks: int = 10_000) -> dict:
        while (self.tick_now < len(self.trace) or self.pending()):
            if self.tick_now >= max_ticks:
                break
            self.step()
        return self.results()

    def results(self) -> dict:
        peak = {role: max(tr[role] for tr in self.replica_trace)
                for role in self.ROLE_SPECS} if self.replica_trace else {}
        return {
            "arrived": self.arrived,
            "completed": self.completed,
            "lost": self.arrived - self.completed - self.pending(),
            "pending": self.pending(),
            "ticks": self.tick_now,
            "bounds_respected": self.bounds_ok,
            "peak_replicas": peak,
            "scale_ups": self.autoscaler.scale_ups,
            "scale_downs": self.autoscaler.scale_downs,
            "events": [dataclasses.asdict(e)
                       for e in self.autoscaler.events],
        }
