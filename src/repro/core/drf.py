"""Dominant Resource Fairness (Ghodsi et al.) — Mesos' default allocator.

The broker offers resources to the framework with the *lowest dominant
share*; dominant share = max over resource dimensions of
(framework's allocation / cluster total).  The paper relies on Mesos/DRF for
multi-framework fairness; we reproduce it so multi-tenant experiments
(benchmarks/cosched_utilization.py) carry the same semantics.

The allocator is generic over the resource vector: any type supporting
``+``/``-``, ``nonneg()`` and ``dominant_share(total)`` works.  The
cluster scheduler accounts in ``ResourceSpec`` (chips, HBM); the serving
front-end reuses the same allocator with its own (slots, KV) vector
(``runtime/scheduler.ServeResource``) for per-tenant admission fairness.

Weighted DRF (Ghodsi et al. §4.2): each framework carries a weight and
the offer order is by *weighted* dominant share — ``dominant_share /
weight`` — so a weight-3 framework converges to 3x the share of a
weight-1 one.  Serving maps SLO tiers onto these weights
(``ServeConfig.tenant_weights``); unweighted callers see identical
behavior (all weights default to 1).
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .resources import ResourceSpec


@dataclass
class FrameworkAccount:
    name: str
    allocated: ResourceSpec = field(default_factory=ResourceSpec)


class DRFAllocator:
    def __init__(self, total, zero=None, weights=None):
        self.total = total
        self._zero = zero if zero is not None else type(total)()
        self.weights: dict[str, float] = dict(weights or {})
        self.accounts: dict[str, FrameworkAccount] = {}

    def register(self, name: str) -> None:
        self.accounts.setdefault(name, FrameworkAccount(name, self._zero))

    def weight(self, name: str) -> float:
        w = float(self.weights.get(name, 1.0))
        assert w > 0, f"non-positive DRF weight for {name}: {w}"
        return w

    def dominant_share(self, name: str) -> float:
        return self.accounts[name].allocated.dominant_share(self.total)

    def weighted_share(self, name: str) -> float:
        """Dominant share normalized by the framework's weight — the
        quantity weighted DRF equalizes at convergence."""
        return self.dominant_share(name) / self.weight(name)

    def weighted_share_if(self, name: str, extra) -> float:
        """Weighted share ``name`` would have after an extra charge —
        what an admission/preemption decision compares before committing."""
        self.register(name)
        alloc = self.accounts[name].allocated + extra
        return alloc.dominant_share(self.total) / self.weight(name)

    def next_framework(self, candidates=None) -> str | None:
        """Framework with the lowest weighted dominant share (Mesos offer
        order; plain DRF when no weights are set)."""
        names = [n for n in (candidates if candidates is not None
                             else self.accounts) if n in self.accounts]
        if not names:
            return None
        return min(names, key=lambda n: (self.weighted_share(n), n))

    def charge(self, name: str, res: ResourceSpec) -> None:
        self.register(name)
        self.accounts[name].allocated = self.accounts[name].allocated + res

    def credit(self, name: str, res: ResourceSpec) -> None:
        acct = self.accounts[name]
        acct.allocated = acct.allocated - res
        assert acct.allocated.nonneg(), f"negative allocation for {name}"

    def shares(self) -> dict[str, float]:
        """Dominant-share snapshot per framework (fairness telemetry)."""
        return {n: self.dominant_share(n) for n in self.accounts}

    def weighted_shares(self) -> dict[str, float]:
        """Weighted-share snapshot — equal values mean weighted-DRF
        convergence (each framework at its entitlement)."""
        return {n: self.weighted_share(n) for n in self.accounts}

    def set_total(self, total: ResourceSpec) -> None:
        self.total = total
