"""Dominant Resource Fairness (Ghodsi et al.) — Mesos' default allocator.

The broker offers resources to the framework with the *lowest dominant
share*; dominant share = max over resource dimensions of
(framework's allocation / cluster total).  The paper relies on Mesos/DRF for
multi-framework fairness; we reproduce it so multi-tenant experiments
(benchmarks/cosched_utilization.py) carry the same semantics.

The allocator is generic over the resource vector: any type supporting
``+``/``-``, ``nonneg()`` and ``dominant_share(total)`` works.  The
cluster scheduler accounts in ``ResourceSpec`` (chips, HBM); the serving
front-end reuses the same allocator with its own (slots, KV) vector
(``runtime/scheduler.ServeResource``) for per-tenant admission fairness.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .resources import ResourceSpec


@dataclass
class FrameworkAccount:
    name: str
    allocated: ResourceSpec = field(default_factory=ResourceSpec)


class DRFAllocator:
    def __init__(self, total, zero=None):
        self.total = total
        self._zero = zero if zero is not None else type(total)()
        self.accounts: dict[str, FrameworkAccount] = {}

    def register(self, name: str) -> None:
        self.accounts.setdefault(name, FrameworkAccount(name, self._zero))

    def dominant_share(self, name: str) -> float:
        return self.accounts[name].allocated.dominant_share(self.total)

    def next_framework(self, candidates=None) -> str | None:
        """Framework with the lowest dominant share (Mesos offer order)."""
        names = [n for n in (candidates if candidates is not None
                             else self.accounts) if n in self.accounts]
        if not names:
            return None
        return min(names, key=lambda n: (self.dominant_share(n), n))

    def charge(self, name: str, res: ResourceSpec) -> None:
        self.register(name)
        self.accounts[name].allocated = self.accounts[name].allocated + res

    def credit(self, name: str, res: ResourceSpec) -> None:
        acct = self.accounts[name]
        acct.allocated = acct.allocated - res
        assert acct.allocated.nonneg(), f"negative allocation for {name}"

    def shares(self) -> dict[str, float]:
        """Dominant-share snapshot per framework (fairness telemetry)."""
        return {n: self.dominant_share(n) for n in self.accounts}

    def set_total(self, total: ResourceSpec) -> None:
        self.total = total
