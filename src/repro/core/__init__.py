# Scylla core: Mesos-style resource brokering + policy-driven gang placement
# of SPMD JAX jobs (the paper's contribution, adapted to TPU pods).
from . import hw
from .cluster import Cluster, ClusterSpec
from .costmodel import PlacementView, analytic_profile, job_profile, step_time
from .drf import DRFAllocator
from .jobs import JobPhase, JobSpec, JobState, RooflineProfile
from .policies import (AutoPolicy, MinHostPolicy, Placement, SpreadPolicy,
                       get_policy)
from .resources import AgentInfo, Offer, ResourceSpec
from .scheduler import ScyllaScheduler
from .simulator import Simulator

__all__ = [
    "hw", "Cluster", "ClusterSpec", "DRFAllocator", "JobPhase", "JobSpec",
    "JobState", "RooflineProfile", "AutoPolicy", "MinHostPolicy",
    "SpreadPolicy", "Placement", "get_policy", "AgentInfo", "Offer",
    "ResourceSpec", "ScyllaScheduler", "Simulator", "PlacementView",
    "analytic_profile", "job_profile", "step_time",
]
