"""Mesos-style resource primitives adapted to TPU pods.

A Mesos agent advertises (cpu, mem); our agent is a TPU *host* advertising
(chips, hbm_bytes).  Offers carry the host's free resources plus its
topology coordinates so placement policies can reason about ICI vs DCN
locality — the TPU-native generalization of Docker Swarm's flat overlay
network (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

from . import hw


@dataclass(frozen=True, order=True)
class ResourceSpec:
    """A resource vector (the DRF demand/allocation unit)."""

    chips: int = 0
    hbm_bytes: float = 0.0

    def __add__(self, o: "ResourceSpec") -> "ResourceSpec":
        return ResourceSpec(self.chips + o.chips, self.hbm_bytes + o.hbm_bytes)

    def __sub__(self, o: "ResourceSpec") -> "ResourceSpec":
        return ResourceSpec(self.chips - o.chips, self.hbm_bytes - o.hbm_bytes)

    def fits_in(self, o: "ResourceSpec") -> bool:
        return self.chips <= o.chips and self.hbm_bytes <= o.hbm_bytes + 1e-6

    def nonneg(self) -> bool:
        return self.chips >= 0 and self.hbm_bytes >= -1e-6

    def dominant_share(self, total: "ResourceSpec") -> float:
        shares = []
        if total.chips:
            shares.append(self.chips / total.chips)
        if total.hbm_bytes:
            shares.append(self.hbm_bytes / total.hbm_bytes)
        return max(shares) if shares else 0.0

    @staticmethod
    def per_host() -> "ResourceSpec":
        return ResourceSpec(hw.CHIPS_PER_HOST,
                            hw.CHIPS_PER_HOST * hw.HBM_PER_CHIP)


@dataclass(frozen=True)
class AgentInfo:
    """One TPU host (= Mesos agent)."""

    agent_id: str
    pod_id: int
    host_index: int  # index within the pod

    @property
    def capacity(self) -> ResourceSpec:
        return ResourceSpec.per_host()


@dataclass(frozen=True)
class Offer:
    """A resource offer: free resources on one agent."""

    offer_id: str
    agent: AgentInfo
    available: ResourceSpec
