"""Pallas TPU paged flash-decode: one query token vs a page-table KV pool.

The dense ragged kernel (``decode_attention.py``) streams a per-slot
``(max_len)`` KV stripe; this kernel streams only the pages a slot's page
table maps.  K/V live in a global pool ``(P, KV, page_size, D)`` shared by
every slot, and the indirection is resolved **before** the kernel body runs:
``page_idx (B, max_pages)`` rides the same scalar-prefetch channel as
``pos (B,)`` / ``active (B,)``, and the K/V BlockSpec index_maps read it —
grid step ``(b, h, ip)`` DMAs physical page ``page_idx[b, ip]``.  The
gather is therefore free: Mosaic issues the indirected DMA directly, no
materialized (B, S) copy of the cache ever exists.

Contract (a strict extension of the ragged dense kernel's):

* ``pos (B,)`` int32 (scalar broadcasts): slot ``b`` attends key positions
  ``kpos <= pos[b]`` (and ``pos[b] - kpos < window`` when windowed), where
  ``kpos = ip * page_size + offset`` is the *logical* position — page
  indirection never changes the mask math.
* ``active (B,)`` 0/1 (default ``pos >= 0``): inactive slots and fully
  masked pages issue no MXU work via ``pl.when`` and write zeros.
* Unmapped page-table entries MUST be 0 (the pool's reserved null page):
  they are still DMA'd on the prefetch stream but never computed on, so
  their contents are don't-care.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .decode_attention import NEG_INF, _block_needed, _normalize_pos


def _paged_decode_kernel(page_ref, pos_ref, act_ref, q_ref, k_ref, v_ref,
                         o_ref, m_ref, l_ref, acc_ref, *, window: int,
                         page_size: int, scale: float, tq: int):
    ib = pl.program_id(0)
    ip = pl.program_id(2)
    n_pages = pl.num_programs(2)
    pos = pos_ref[ib]
    active = act_ref[ib]

    @pl.when(ip == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    k_start = ip * page_size  # logical position of this page's first key

    @pl.when(_block_needed(pos, active, k_start, page_size, window, tq))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (tq, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (page_size, D)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (tq, page_size),
                                                  1)
        qpos = pos + jax.lax.broadcasted_iota(jnp.int32, (tq, page_size), 0)
        mask = kpos <= qpos
        if window:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        # mask-gated exp — see _decode_kernel: draft rows fully masked in
        # a needed page must contribute exactly zero
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        pv = jax.lax.dot_general(p.astype(v.dtype), v,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new

    @pl.when(ip == n_pages - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def paged_decode_attention_tpu(q, k_pages, v_pages, page_idx, pos, *,
                               active=None, window=0, interpret=False):
    """q (B, H, T, D); pools (P, KV, page_size, D); page_idx (B, max_pages)
    int32; pos scalar or (B,) int32.  Returns (B, H, T, D).

    ``max_pages * page_size`` is the logical max_len.  Unmapped page-table
    entries must be 0 (the null page); ``active`` defaults to ``pos >= 0``.
    T > 1 is the speculative multi-token verify block: query row ``t``
    attends logical keys ``kpos <= pos[b] + t`` — the page indirection
    never changes the mask math.
    """
    b, h, tq, d = q.shape
    n_pool, kv, page_size, _ = k_pages.shape
    max_pages = page_idx.shape[1]
    assert page_idx.shape[0] == b, (page_idx.shape, b)
    g = h // kv
    scale = d ** -0.5
    pos = _normalize_pos(pos, b)
    page_idx = jnp.asarray(page_idx, jnp.int32)
    if active is None:
        active = (pos >= 0).astype(jnp.int32)
    else:
        active = jnp.broadcast_to(
            jnp.asarray(active, jnp.int32).reshape(-1), (b,))

    kernel = functools.partial(_paged_decode_kernel, window=window,
                               page_size=page_size, scale=scale, tq=tq)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,  # page_idx, pos, active
        grid=(b, h, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, tq, d),
                         lambda b_, h_, ip, pt_, pos_, act_: (b_, h_, 0, 0)),
            # the paged gather: DMA physical page pt_[b, ip] of the pool
            pl.BlockSpec((1, 1, page_size, d),
                         lambda b_, h_, ip, pt_, pos_, act_:
                         (pt_[b_, ip], h_ // g, 0, 0)),
            pl.BlockSpec((1, 1, page_size, d),
                         lambda b_, h_, ip, pt_, pos_, act_:
                         (pt_[b_, ip], h_ // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, tq, d),
                               lambda b_, h_, ip, pt_, pos_, act_:
                               (b_, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((tq, 1), jnp.float32),
            pltpu.VMEM((tq, 1), jnp.float32),
            pltpu.VMEM((tq, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, tq, d), q.dtype),
        interpret=interpret,
    )(page_idx, pos, active, q, k_pages, v_pages)
