"""Pallas TPU paged kernels: flash-decode, fused prefill, split-K decode.

The dense ragged kernel (``decode_attention.py``) streams a per-slot
``(max_len)`` KV stripe; these kernels stream only the pages a slot's page
table maps.  K/V live in a global pool ``(P, KV, page_size, D)`` shared by
every slot, and the indirection is resolved **before** the kernel body runs:
``page_idx (B, max_pages)`` rides the same scalar-prefetch channel as
``pos (B,)`` / ``active (B,)``, and the K/V BlockSpec index_maps read it —
grid step ``(b, h, ip)`` DMAs physical page ``page_idx[b, ip]``.  The
gather is therefore free: Mosaic issues the indirected DMA directly, no
materialized (B, S) copy of the cache ever exists.

Three variants share one online-softmax page accumulator:

* ``paged_decode_attention_tpu`` — single pass over a slot's pages,
  T >= 1 query rows (speculative verify blocks ride the same kernel).
* ``paged_prefill_attention_tpu`` — one slot's prefill *chunk*
  (C query rows at absolute offset ``q_offset``) against its own page
  chain.  This replaces the XLA path's dense per-slot gather: chunked
  prefill never materializes a (max_len) copy of the cache.
* ``paged_decode_attention_splitk_tpu`` — two-phase long-context decode.
  Phase 1 runs ``num_splits`` independent partial softmaxes over disjoint
  *page ranges* (splits tile by whole pages, never by raw key counts —
  see ``pick_decode_splits``), phase 2 reuses the dense combine kernel.

Quantized pools: every variant accepts optional per-token/per-head scale
pools ``(P, KV, page_size, 1)`` f32 riding the same page indirection as
K/V.  Values are dequantized **inside** the kernel right after the VMEM
load (``k * k_scale``), so int8/fp8 pools halve/quarter the HBM bytes per
page while the MXU math stays fp32.

Contract (a strict extension of the ragged dense kernel's):

* ``pos (B,)`` int32 (scalar broadcasts): slot ``b`` attends key positions
  ``kpos <= pos[b]`` (and ``pos[b] - kpos < window`` when windowed), where
  ``kpos = ip * page_size + offset`` is the *logical* position — page
  indirection never changes the mask math.
* ``active (B,)`` 0/1 (default ``pos >= 0``): inactive slots and fully
  masked pages issue no MXU work via ``pl.when`` and write zeros.
* Unmapped page-table entries MUST be 0 (the pool's reserved null page):
  they are still DMA'd on the prefetch stream but never computed on, so
  their contents are don't-care.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .decode_attention import (NEG_INF, _block_needed, _normalize_pos,
                               _splitk_combine_kernel)


def _page_scale_spec(page_size, index_map):
    return pl.BlockSpec((1, 1, page_size, 1), index_map)


def _accumulate_page(q_ref, k_ref, v_ref, ks_ref, vs_ref, m_ref, l_ref,
                     acc_ref, *, k_start, pos, window, scale, tq, page_size,
                     quant):
    """One online-softmax step over one page (shared by all variants).

    ``quant`` dequantizes K/V with the per-token scale blocks right after
    the VMEM load; fp math is otherwise identical to the unquantized path.
    """
    q = q_ref[0, 0].astype(jnp.float32)  # (tq, D)
    k = k_ref[0, 0].astype(jnp.float32)  # (page_size, D)
    v = v_ref[0, 0]
    if quant:
        k = k * ks_ref[0, 0]                       # (page_size, D) * (ps, 1)
        v = v.astype(jnp.float32) * vs_ref[0, 0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (tq, page_size), 1)
    qpos = pos + jax.lax.broadcasted_iota(jnp.int32, (tq, page_size), 0)
    mask = kpos <= qpos
    if window:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    # mask-gated exp — see _decode_kernel: draft rows fully masked in
    # a needed page must contribute exactly zero
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
    pv = jax.lax.dot_general(p.astype(v.dtype), v,
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha + pv
    m_ref[...] = m_new


def _paged_decode_kernel(page_ref, pos_ref, act_ref, q_ref, k_ref, v_ref,
                         *rest, window: int, page_size: int, scale: float,
                         tq: int, quant: bool):
    if quant:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        ks_ref = vs_ref = None
        o_ref, m_ref, l_ref, acc_ref = rest
    ib = pl.program_id(0)
    ip = pl.program_id(2)
    n_pages = pl.num_programs(2)
    pos = pos_ref[ib]
    active = act_ref[ib]

    @pl.when(ip == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    k_start = ip * page_size  # logical position of this page's first key

    @pl.when(_block_needed(pos, active, k_start, page_size, window, tq))
    def _compute():
        _accumulate_page(q_ref, k_ref, v_ref, ks_ref, vs_ref, m_ref, l_ref,
                         acc_ref, k_start=k_start, pos=pos, window=window,
                         scale=scale, tq=tq, page_size=page_size, quant=quant)

    @pl.when(ip == n_pages - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def paged_decode_attention_tpu(q, k_pages, v_pages, page_idx, pos, *,
                               active=None, window=0, k_scale=None,
                               v_scale=None, interpret=False):
    """q (B, H, T, D); pools (P, KV, page_size, D); page_idx (B, max_pages)
    int32; pos scalar or (B,) int32.  Returns (B, H, T, D).

    ``max_pages * page_size`` is the logical max_len.  Unmapped page-table
    entries must be 0 (the null page); ``active`` defaults to ``pos >= 0``.
    T > 1 is the speculative multi-token verify block: query row ``t``
    attends logical keys ``kpos <= pos[b] + t`` — the page indirection
    never changes the mask math.  ``k_scale``/``v_scale``
    (P, KV, page_size, 1) f32 select the quantized path: K/V blocks are
    dequantized in VMEM right after the page DMA.
    """
    b, h, tq, d = q.shape
    n_pool, kv, page_size, _ = k_pages.shape
    max_pages = page_idx.shape[1]
    assert page_idx.shape[0] == b, (page_idx.shape, b)
    quant = k_scale is not None
    g = h // kv
    scale = d ** -0.5
    pos = _normalize_pos(pos, b)
    page_idx = jnp.asarray(page_idx, jnp.int32)
    if active is None:
        active = (pos >= 0).astype(jnp.int32)
    else:
        active = jnp.broadcast_to(
            jnp.asarray(active, jnp.int32).reshape(-1), (b,))

    kernel = functools.partial(_paged_decode_kernel, window=window,
                               page_size=page_size, scale=scale, tq=tq,
                               quant=quant)
    # the paged gather: DMA physical page pt_[b, ip] of the pool
    kv_map = lambda b_, h_, ip, pt_, pos_, act_: (pt_[b_, ip], h_ // g, 0, 0)
    in_specs = [
        pl.BlockSpec((1, 1, tq, d),
                     lambda b_, h_, ip, pt_, pos_, act_: (b_, h_, 0, 0)),
        pl.BlockSpec((1, 1, page_size, d), kv_map),
        pl.BlockSpec((1, 1, page_size, d), kv_map),
    ]
    operands = [q, k_pages, v_pages]
    if quant:
        in_specs += [_page_scale_spec(page_size, kv_map)] * 2
        operands += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,  # page_idx, pos, active
        grid=(b, h, max_pages),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, tq, d),
                               lambda b_, h_, ip, pt_, pos_, act_:
                               (b_, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((tq, 1), jnp.float32),
            pltpu.VMEM((tq, 1), jnp.float32),
            pltpu.VMEM((tq, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, tq, d), q.dtype),
        interpret=interpret,
    )(page_idx, pos, active, *operands)


# --------------------------------------------------------------- prefill
def _paged_prefill_kernel(page_ref, off_ref, q_ref, k_ref, v_ref, *rest,
                          window: int, page_size: int, scale: float, tq: int,
                          quant: bool):
    if quant:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        ks_ref = vs_ref = None
        o_ref, m_ref, l_ref, acc_ref = rest
    ip = pl.program_id(1)
    n_pages = pl.num_programs(1)
    pos = off_ref[0]  # absolute position of query row 0

    @pl.when(ip == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    k_start = ip * page_size

    @pl.when(_block_needed(pos, 1, k_start, page_size, window, tq))
    def _compute():
        _accumulate_page(q_ref, k_ref, v_ref, ks_ref, vs_ref, m_ref, l_ref,
                         acc_ref, k_start=k_start, pos=pos, window=window,
                         scale=scale, tq=tq, page_size=page_size, quant=quant)

    @pl.when(ip == n_pages - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def paged_prefill_attention_tpu(q, k_pages, v_pages, page_row, q_offset, *,
                                window=0, k_scale=None, v_scale=None,
                                interpret=False):
    """Fused paged prefill: q (1, H, C, D) — one slot's chunk of C query
    rows at absolute offset ``q_offset`` — vs pools (P, KV, page_size, D)
    through that slot's page-table row ``page_row (max_pages,)`` int32.
    Returns (1, H, C, D).

    The chunk's own K/V must already be written to the pages (the update
    runs first), so row ``t`` attends logical keys
    ``kpos <= q_offset + t`` — causal against the prefix *and* within the
    chunk, exactly ``flash_attention_xla(..., q_offset=offset)`` over the
    gathered view, with the gather folded into the page DMA.
    """
    b, h, tq, d = q.shape
    assert b == 1, ("fused paged prefill is one slot per call", q.shape)
    _, kv, page_size, _ = k_pages.shape
    max_pages = page_row.shape[0]
    quant = k_scale is not None
    g = h // kv
    scale = d ** -0.5
    page_row = jnp.asarray(page_row, jnp.int32).reshape(-1)
    off = jnp.asarray(q_offset, jnp.int32).reshape(1)

    kernel = functools.partial(_paged_prefill_kernel, window=window,
                               page_size=page_size, scale=scale, tq=tq,
                               quant=quant)
    kv_map = lambda h_, ip, pr_, off_: (pr_[ip], h_ // g, 0, 0)
    in_specs = [
        pl.BlockSpec((1, 1, tq, d), lambda h_, ip, pr_, off_: (0, h_, 0, 0)),
        pl.BlockSpec((1, 1, page_size, d), kv_map),
        pl.BlockSpec((1, 1, page_size, d), kv_map),
    ]
    operands = [q, k_pages, v_pages]
    if quant:
        in_specs += [_page_scale_spec(page_size, kv_map)] * 2
        operands += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # page_row, q_offset
        grid=(h, max_pages),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, tq, d),
                               lambda h_, ip, pr_, off_: (0, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((tq, 1), jnp.float32),
            pltpu.VMEM((tq, 1), jnp.float32),
            pltpu.VMEM((tq, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((1, h, tq, d), q.dtype),
        interpret=interpret,
    )(page_row, off, *operands)


# --------------------------------------------------------------- split-K
def _paged_splitk_partial_kernel(page_ref, pos_ref, act_ref, q_ref, k_ref,
                                 v_ref, *rest, window: int, page_size: int,
                                 pages_per_split: int, scale: float,
                                 quant: bool):
    if quant:
        (ks_ref, vs_ref, o_ref, ms_ref, ls_ref,
         m_ref, l_ref, acc_ref) = rest
    else:
        ks_ref = vs_ref = None
        o_ref, ms_ref, ls_ref, m_ref, l_ref, acc_ref = rest
    ib = pl.program_id(0)
    isp = pl.program_id(2)
    ip = pl.program_id(3)
    pos = pos_ref[ib]
    active = act_ref[ib]

    @pl.when(ip == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # splits tile by whole pages: split isp owns logical pages
    # [isp * pages_per_split, (isp + 1) * pages_per_split)
    k_start = (isp * pages_per_split + ip) * page_size

    @pl.when(_block_needed(pos, active, k_start, page_size, window))
    def _compute():
        _accumulate_page(q_ref, k_ref, v_ref, ks_ref, vs_ref, m_ref, l_ref,
                         acc_ref, k_start=k_start, pos=pos, window=window,
                         scale=scale, tq=1, page_size=page_size, quant=quant)

    @pl.when(ip == pages_per_split - 1)
    def _emit():
        # unnormalized: combine phase rescales by exp(m_i - m*) / sum l
        o_ref[0, 0, 0] = acc_ref[...]
        ms_ref[0, 0, 0] = m_ref[...]
        ls_ref[0, 0, 0] = l_ref[...]


def paged_decode_attention_splitk_tpu(q, k_pages, v_pages, page_idx, pos, *,
                                      active=None, window=0, num_splits=4,
                                      k_scale=None, v_scale=None,
                                      interpret=False):
    """Two-phase (split-K) paged flash-decode; same contract as
    ``paged_decode_attention_tpu`` but phase 1 partitions the *page table*
    into ``num_splits`` disjoint page ranges (``max_pages % num_splits``
    must be 0 — splits align to page boundaries, never raw key counts) and
    phase 2 reuses the dense combine kernel.  Single-token only.
    """
    b, h, tq, d = q.shape
    assert tq == 1, ("split-K paged decode is single-token; multi-token "
                     "verify uses paged_decode_attention_tpu", q.shape)
    _, kv, page_size, _ = k_pages.shape
    max_pages = page_idx.shape[1]
    ns = num_splits
    assert max_pages % ns == 0, (
        "split count must divide max_pages so splits tile whole pages",
        max_pages, ns)
    pps = max_pages // ns
    quant = k_scale is not None
    g = h // kv
    scale = d ** -0.5
    pos = _normalize_pos(pos, b)
    page_idx = jnp.asarray(page_idx, jnp.int32)
    if active is None:
        active = (pos >= 0).astype(jnp.int32)
    else:
        active = jnp.broadcast_to(
            jnp.asarray(active, jnp.int32).reshape(-1), (b,))

    kernel = functools.partial(_paged_splitk_partial_kernel, window=window,
                               page_size=page_size, pages_per_split=pps,
                               scale=scale, quant=quant)
    kv_map = (lambda b_, h_, isp, ip, pt_, pos_, act_:
              (pt_[b_, isp * pps + ip], h_ // g, 0, 0))
    part_map = lambda b_, h_, isp, ip, pt_, pos_, act_: (b_, h_, isp, 0, 0)
    in_specs = [
        pl.BlockSpec((1, 1, 1, d),
                     lambda b_, h_, isp, ip, pt_, pos_, act_: (b_, h_, 0, 0)),
        pl.BlockSpec((1, 1, page_size, d), kv_map),
        pl.BlockSpec((1, 1, page_size, d), kv_map),
    ]
    operands = [q, k_pages, v_pages]
    if quant:
        in_specs += [_page_scale_spec(page_size, kv_map)] * 2
        operands += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, h, ns, pps),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, 1, 1, d), part_map),
            pl.BlockSpec((1, 1, 1, 1, 1), part_map),
            pl.BlockSpec((1, 1, 1, 1, 1), part_map),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
    )
    o_parts, ms, ls = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, h, ns, 1, d), jnp.float32),
            jax.ShapeDtypeStruct((b, h, ns, 1, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, h, ns, 1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(page_idx, pos, active, *operands)

    return pl.pallas_call(
        _splitk_combine_kernel,
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((1, 1, ns, d), lambda b_, h_: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, ns, 1), lambda b_, h_: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, ns, 1), lambda b_, h_: (b_, h_, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d), lambda b_, h_: (b_, h_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, 1, d), q.dtype),
        interpret=interpret,
    )(o_parts.reshape(b, h, ns, d), ms.reshape(b, h, ns, 1),
      ls.reshape(b, h, ns, 1))
