"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

These are deliberately naive: full score matrices, explicit masks, fp32
throughout.  Tests sweep shapes/dtypes and assert the kernels (interpret
mode on CPU) match these within dtype tolerance.
"""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True, window=0):
    """q (B,H,Sq,D); k,v (B,KV,Sk,D) -> (B,H,Sq,D).  Naive full softmax."""
    b, h, sq, d = q.shape
    kv, sk = k.shape[1], k.shape[2]
    g = h // kv
    kx = jnp.repeat(k, g, axis=1).astype(jnp.float32)
    vx = jnp.repeat(v, g, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kx) * d ** -0.5
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vx).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, pos, *, window=0):
    """q (B,H,T,D); caches (B,KV,S,D) -> (B,H,T,D).

    Ragged: ``pos`` may be a scalar (all slots at one position) or a (B,)
    vector of per-slot positions; slots with pos < 0 are inactive and
    return zeros (the serving engine parks free slots at -1).

    Multi-token (speculative verify): query row ``t`` of slot ``b`` sits
    at absolute position ``pos[b] + t`` and attends keys
    ``kpos <= pos[b] + t`` — causal *within* the draft block as well as
    against the prefix.  T = 1 reduces to the classic one-token decode.
    """
    b, h, t, d = q.shape
    kv, s = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    kx = jnp.repeat(k_cache, g, axis=1).astype(jnp.float32)
    vx = jnp.repeat(v_cache, g, axis=1).astype(jnp.float32)
    sc = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kx) * d ** -0.5
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (b,))
    qpos = pos[:, None] + jnp.arange(t)[None, :]  # (B, T)
    kpos = jnp.arange(s)
    mask = kpos[None, None, :] <= qpos[:, :, None]  # (B, T, S)
    if window:
        mask &= qpos[:, :, None] - kpos[None, None, :] < window
    sc = jnp.where(mask[:, None, :, :], sc, -1e30)
    p = jnp.exp(sc - sc.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vx)
    out = jnp.where((pos >= 0)[:, None, None, None], out, 0.0)
    return out.astype(q.dtype)


def paged_decode_attention_ref(q, k_pages, v_pages, page_idx, pos, *,
                               window=0):
    """Oracle for the paged flash-decode kernel.

    q (B,H,T,D); pools (P,KV,page_size,D); page_idx (B,max_pages) int32
    (0 = null page for unmapped blocks) -> (B,H,T,D).  Gathers each slot's
    pages into a dense (B,KV,S,D) view (S = max_pages * page_size) and
    defers to ``decode_attention_ref`` — logical masking (including the
    multi-token intra-draft causal mask) is untouched by the physical
    indirection.
    """
    b = q.shape[0]
    _, kv, page_size, d = k_pages.shape
    max_pages = page_idx.shape[1]
    idx = jnp.asarray(page_idx, jnp.int32)
    # (B, max_pages, KV, page_size, D) -> (B, KV, S, D)
    k = jnp.take(k_pages, idx, axis=0).transpose(0, 2, 1, 3, 4).reshape(
        b, kv, max_pages * page_size, d)
    v = jnp.take(v_pages, idx, axis=0).transpose(0, 2, 1, 3, 4).reshape(
        b, kv, max_pages * page_size, d)
    return decode_attention_ref(q, k, v, pos, window=window)


def dequantize_ref(pages, scales):
    """Per-token/per-head dequant: pages (..., page_size, D) int8/fp8,
    scales (..., page_size, 1) f32 -> f32 values."""
    return pages.astype(jnp.float32) * scales


def paged_decode_attention_quant_ref(q, k_pages, v_pages, k_scale, v_scale,
                                     page_idx, pos, *, window=0):
    """Oracle for the quantized paged flash-decode kernel.

    Pools (P,KV,page_size,D) int8/fp8 with per-token scales
    (P,KV,page_size,1) f32.  Dequantizes the whole pool and defers to
    ``paged_decode_attention_ref`` — the kernel must match this within
    fp tolerance because both read the *same* quantized values; quant
    error itself is bounded separately (see tests/test_quant_kv.py).
    """
    k = dequantize_ref(k_pages, k_scale)
    v = dequantize_ref(v_pages, v_scale)
    return paged_decode_attention_ref(q, k, v, page_idx, pos, window=window)


def paged_prefill_attention_ref(q, k_pages, v_pages, page_row, q_offset, *,
                                window=0):
    """Oracle for the fused paged prefill kernel.

    q (1,H,C,D) — one slot's prefill chunk at absolute offset
    ``q_offset``; pools (P,KV,page_size,D); page_row (max_pages,) int32.
    Query row ``t`` sits at position ``q_offset + t`` and attends keys
    ``kpos <= q_offset + t`` — exactly the multi-token ragged contract,
    so this is ``paged_decode_attention_ref`` with T = C and
    pos = q_offset.
    """
    idx = jnp.asarray(page_row, jnp.int32)[None, :]
    return paged_decode_attention_ref(q, k_pages, v_pages, idx, q_offset,
                                      window=window)


def ssd_chunk_ref(x, b, c, dt, cum):
    """Oracle for ssd_chunk_tpu (same shapes/contract)."""
    bb, nc, nh, q, hp = x.shape
    g = b.shape[2]
    rep = nh // g
    bx = jnp.repeat(b, rep, axis=2).astype(jnp.float32)  # (B,NC,NH,Q,ds)
    cx = jnp.repeat(c, rep, axis=2).astype(jnp.float32)
    cb = jnp.einsum("bnhqs,bnhks->bnhqk", cx, bx)
    decay = jnp.exp(cum[..., :, None] - cum[..., None, :])  # (B,NC,NH,Q,Q)
    att = cb * decay * dt[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), dtype=bool))
    att = jnp.where(mask, att, 0.0)
    y = jnp.einsum("bnhqk,bnhkp->bnhqp", att,
                   x.astype(jnp.float32)).astype(x.dtype)
    w = jnp.exp(cum[..., -1:] - cum) * dt  # (B,NC,NH,Q)
    st = jnp.einsum("bnhqs,bnhqp->bnhsp", bx * w[..., None],
                    x.astype(jnp.float32))
    return y, st
