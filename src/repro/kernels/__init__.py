# Perf-critical compute of the workloads Scylla schedules: flash attention
# (prefill), flash-decode, and the Mamba2 SSD intra-chunk kernel.  Each has a
# pure-jnp oracle in ref.py; kernels are validated in interpret mode on CPU.
from .ops import (attention_ref, decode_attention, decode_attention_ref,
                  flash_attention, paged_decode_attention,
                  paged_decode_attention_quant_ref, paged_decode_attention_ref,
                  paged_prefill_attention, paged_prefill_attention_ref,
                  ssd_chunk, ssd_chunk_ref)

__all__ = ["flash_attention", "decode_attention", "paged_decode_attention",
           "paged_prefill_attention", "ssd_chunk", "attention_ref",
           "decode_attention_ref", "paged_decode_attention_ref",
           "paged_decode_attention_quant_ref", "paged_prefill_attention_ref",
           "ssd_chunk_ref"]
