"""Pallas TPU flash-decode: one query token per slot vs a long KV cache.

Decode is memory-bound (the live KV prefix streams HBM->VMEM once); the
kernel's job is to keep that stream dense and do the partial-softmax combine
in VMEM.  Two variants share the online-softmax contract of the prefill
kernel:

* ``decode_attention_tpu`` — single pass.  Grid = (batch, q_heads,
  kv_blocks), kv innermost/sequential with a running (max, denom, acc)
  triple in scratch.
* ``decode_attention_splitk_tpu`` — two phase.  Phase 1 runs ``num_splits``
  *independent* partial softmaxes over disjoint KV ranges (grid = (batch,
  q_heads, splits, kv_blocks)), emitting unnormalized accumulators plus the
  per-split (max, denom) statistics; phase 2 is a small combine kernel over
  the split axis.  Long-context decode is therefore no longer serialized
  over one KV stream: the splits carry no sequential dependency, so the
  compiler is free to overlap their HBM reads.

Ragged kernel contract (the serving hot path relies on this):

* ``pos`` is a **per-sequence position vector** ``(B,)`` delivered via
  scalar prefetch: slot ``b`` attends keys ``kpos <= pos[b]`` (and, when
  ``window > 0``, ``pos[b] - kpos < window``).  Every slot of a
  continuously-batched engine decodes at its own prefix length in one call.
* ``active`` is a per-slot 0/1 mask (also prefetched).  Inactive slots —
  and KV blocks fully masked for a short slot — issue **no** MXU work via
  ``pl.when``; inactive slots write zeros.  A scalar ``pos`` is still
  accepted (broadcast) for the legacy lockstep path.
* **Multi-token (speculative verify)**: ``q`` may carry ``T > 1`` query
  rows per slot (``(B, H, T, D)``).  Row ``t`` sits at absolute position
  ``pos[b] + t`` and attends keys ``kpos <= pos[b] + t`` — causal against
  the prefix and *within* the draft block (whose K/V were written before
  the call).  The online softmax keeps a per-row (max, denom, acc)
  triple; rows fully masked in a needed block contribute exactly zero.
  The split-K variant stays single-token (speculative ticks use the
  single-pass kernel).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _normalize_pos(pos, b):
    """Scalar or (B,) -> (B,) int32 position vector."""
    pos = jnp.asarray(pos, jnp.int32).reshape(-1)
    return jnp.broadcast_to(pos, (b,))


def _block_needed(pos, active, k_start, block_k, window, tq: int = 1):
    """Any of the ``tq`` query rows (absolute positions pos..pos+tq-1)
    attends a key in [k_start, k_start + block_k)."""
    needed = jnp.logical_and(k_start <= pos + (tq - 1), active > 0)
    if window:
        # lowest window bound across rows is row 0's: kpos > pos - window
        needed = jnp.logical_and(needed, k_start + block_k - 1 > pos - window)
    return needed


def _decode_kernel(pos_ref, act_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, window: int, block_k: int,
                   scale: float, tq: int):
    ib = pl.program_id(0)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)
    pos = pos_ref[ib]
    active = act_ref[ib]

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    k_start = ik * block_k

    @pl.when(_block_needed(pos, active, k_start, block_k, window, tq))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (tq, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, D)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (tq, block_k), 1)
        qpos = pos + jax.lax.broadcasted_iota(jnp.int32, (tq, block_k), 0)
        mask = kpos <= qpos
        if window:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        # mask-gated exp: a row fully masked in a *needed* block (short
        # draft rows under windowing) has m_new == NEG_INF, where bare
        # exp(s - m_new) would contribute spurious ones — valid entries
        # are bitwise unchanged (masked s underflows to 0 either way)
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        pv = jax.lax.dot_general(p.astype(v.dtype), v,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def _prep(q, k_cache, pos, active, block_k):
    b, h, _, d = q.shape
    kv, s = k_cache.shape[1], k_cache.shape[2]
    block_k = min(block_k, s)
    assert s % block_k == 0, (s, block_k)
    pos = _normalize_pos(pos, b)
    if active is None:
        active = (pos >= 0).astype(jnp.int32)
    else:
        active = jnp.asarray(active, jnp.int32).reshape(-1)
        active = jnp.broadcast_to(active, (b,))
    return b, h, d, kv, s, block_k, pos, active


def decode_attention_tpu(q, k_cache, v_cache, pos, *, active=None, window=0,
                         block_k=512, interpret=False):
    """q (B, H, T, D); caches (B, KV, S, D); pos scalar or (B,) int32.

    Returns (B, H, T, D).  ``active`` (B,) 0/1 gates per-slot work; defaults
    to ``pos >= 0`` so an engine can park free slots at pos = -1.  T > 1 is
    the speculative multi-token verify block: query row ``t`` attends keys
    ``kpos <= pos[b] + t``.
    """
    b, h, d, kv, s, block_k, pos, active = _prep(q, k_cache, pos, active,
                                                 block_k)
    tq = q.shape[2]
    g = h // kv
    nk = s // block_k
    scale = d ** -0.5
    kernel = functools.partial(_decode_kernel, window=window, block_k=block_k,
                               scale=scale, tq=tq)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, h, nk),
        in_specs=[
            pl.BlockSpec((1, 1, tq, d),
                         lambda b_, h_, ik, pos_, act_: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, ik, pos_, act_: (b_, h_ // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, ik, pos_, act_: (b_, h_ // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, tq, d),
                               lambda b_, h_, ik, pos_, act_: (b_, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((tq, 1), jnp.float32),
            pltpu.VMEM((tq, 1), jnp.float32),
            pltpu.VMEM((tq, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, tq, d), q.dtype),
        interpret=interpret,
    )(pos, active, q, k_cache, v_cache)


# ------------------------------------------------------------------ split-K
def _splitk_partial_kernel(pos_ref, act_ref, q_ref, k_ref, v_ref,
                           o_ref, ms_ref, ls_ref, m_ref, l_ref, acc_ref, *,
                           window: int, block_k: int, split_len: int,
                           scale: float):
    ib = pl.program_id(0)
    isp = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)
    pos = pos_ref[ib]
    active = act_ref[ib]

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    k_start = isp * split_len + ik * block_k

    @pl.when(_block_needed(pos, active, k_start, block_k, window))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (1, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, D)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
        mask = kpos <= pos
        if window:
            mask &= pos - kpos < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        pv = jax.lax.dot_general(p.astype(v.dtype), v,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _emit():
        # unnormalized: combine phase rescales by exp(m_i - m*) / sum l
        o_ref[0, 0, 0] = acc_ref[...]
        ms_ref[0, 0, 0] = m_ref[...]
        ls_ref[0, 0, 0] = l_ref[...]


def _splitk_combine_kernel(o_parts_ref, ms_ref, ls_ref, o_ref):
    m = ms_ref[0, 0]      # (ns, 1)
    l = ls_ref[0, 0]      # (ns, 1)
    acc = o_parts_ref[0, 0]  # (ns, D)
    m_star = jnp.max(m)
    alpha = jnp.exp(m - m_star)  # empty splits: exp(NEG_INF - m*) == 0
    denom = jnp.maximum(jnp.sum(l * alpha), 1e-30)
    out = jnp.sum(acc * alpha, axis=0, keepdims=True) / denom
    o_ref[0, 0] = out.astype(o_ref.dtype)


def decode_attention_splitk_tpu(q, k_cache, v_cache, pos, *, active=None,
                                window=0, block_k=512, num_splits=4,
                                interpret=False):
    """Two-phase (split-K) ragged flash-decode; same contract as
    ``decode_attention_tpu``.

    Phase 1 partitions the KV axis into ``num_splits`` disjoint ranges and
    computes an independent online softmax per range; phase 2 combines the
    per-split (max, denom, acc) triples.  Use for long contexts where a
    single sequential KV stream leaves the memory system under-subscribed.
    Single-token only — speculative (T > 1) ticks take the single-pass
    kernel instead.
    """
    assert q.shape[2] == 1, ("split-K decode is single-token; multi-token "
                             "verify uses decode_attention_tpu", q.shape)
    b, h, d, kv, s, block_k, pos, active = _prep(q, k_cache, pos, active,
                                                 block_k)
    g = h // kv
    ns = num_splits
    assert s % ns == 0, (s, ns)
    split_len = s // ns
    block_k = min(block_k, split_len)
    assert split_len % block_k == 0, (split_len, block_k)
    nk = split_len // block_k
    scale = d ** -0.5

    kernel = functools.partial(_splitk_partial_kernel, window=window,
                               block_k=block_k, split_len=split_len,
                               scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, h, ns, nk),
        in_specs=[
            pl.BlockSpec((1, 1, 1, d),
                         lambda b_, h_, isp, ik, pos_, act_: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, isp, ik, pos_, act_:
                         (b_, h_ // g, isp * nk + ik, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, isp, ik, pos_, act_:
                         (b_, h_ // g, isp * nk + ik, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, 1, d),
                         lambda b_, h_, isp, ik, pos_, act_:
                         (b_, h_, isp, 0, 0)),
            pl.BlockSpec((1, 1, 1, 1, 1),
                         lambda b_, h_, isp, ik, pos_, act_:
                         (b_, h_, isp, 0, 0)),
            pl.BlockSpec((1, 1, 1, 1, 1),
                         lambda b_, h_, isp, ik, pos_, act_:
                         (b_, h_, isp, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
    )
    o_parts, ms, ls = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, h, ns, 1, d), jnp.float32),
            jax.ShapeDtypeStruct((b, h, ns, 1, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, h, ns, 1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(pos, active, q, k_cache, v_cache)

    return pl.pallas_call(
        _splitk_combine_kernel,
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((1, 1, ns, d), lambda b_, h_: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, ns, 1), lambda b_, h_: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, ns, 1), lambda b_, h_: (b_, h_, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d), lambda b_, h_: (b_, h_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, 1, d), q.dtype),
        interpret=interpret,
    )(o_parts.reshape(b, h, ns, d), ms.reshape(b, h, ns, 1),
      ls.reshape(b, h, ns, 1))
