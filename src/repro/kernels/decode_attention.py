"""Pallas TPU flash-decode: one query token vs a long KV cache.

Decode is memory-bound (the whole KV cache streams HBM->VMEM once); the
kernel's job is to keep that stream dense and do the partial-softmax combine
in VMEM.  Grid = (batch, q_heads, kv_blocks), kv innermost/sequential with a
running (max, denom, acc) in scratch — the same online-softmax contract as
the prefill kernel.  The current decode position arrives via scalar prefetch
so fully-masked KV blocks issue no work.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                   *, window: int, block_k: int, scale: float):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)
    pos = pos_ref[0]

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    k_start = ik * block_k
    needed = k_start <= pos
    if window:
        needed = jnp.logical_and(needed, k_start + block_k - 1 > pos - window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (1, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, D)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
        mask = kpos <= pos
        if window:
            mask &= pos - kpos < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        pv = jax.lax.dot_general(p.astype(v.dtype), v,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def decode_attention_tpu(q, k_cache, v_cache, pos, *, window=0, block_k=512,
                         interpret=False):
    """q (B, H, 1, D); caches (B, KV, S, D); pos scalar int32 -> (B, H, 1, D)."""
    b, h, _, d = q.shape
    kv, s = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    block_k = min(block_k, s)
    assert s % block_k == 0, (s, block_k)
    nk = s // block_k
    scale = d ** -0.5
    kernel = functools.partial(_decode_kernel, window=window, block_k=block_k,
                               scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, h, nk),
        in_specs=[
            pl.BlockSpec((1, 1, 1, d), lambda b_, h_, ik, pos_: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, ik, pos_: (b_, h_ // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, ik, pos_: (b_, h_ // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d),
                               lambda b_, h_, ik, pos_: (b_, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, 1, d), q.dtype),
        interpret=interpret,
    )(jnp.asarray(pos, jnp.int32).reshape(1), q, k_cache, v_cache)
