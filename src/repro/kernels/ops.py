"""Jit'd public wrappers around the Pallas kernels.

The model layer calls these with its own (B, S, H, D) layout; wrappers
transpose to the kernels' (B, H, S, D) layout, choose interpret mode
automatically off-TPU, and fall back to the jnp reference when a shape can't
be tiled (tiny smoke configs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .decode_attention import decode_attention_splitk_tpu, decode_attention_tpu
from .flash_attention import flash_attention_tpu
from .paged_attention import (paged_decode_attention_splitk_tpu,
                              paged_decode_attention_tpu,
                              paged_prefill_attention_tpu)
from .ssd_scan import ssd_chunk_tpu


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, block_q=512,
                    block_k=512, interpret=None):
    """Model layout: q (B,S,H,D); k,v (B,S,KV,D) -> (B,S,H,D)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    qt = q.swapaxes(1, 2)
    kt = k.swapaxes(1, 2)
    vt = v.swapaxes(1, 2)
    out = flash_attention_tpu(qt, kt, vt, causal=causal, window=window,
                              block_q=block_q, block_k=block_k,
                              interpret=interpret)
    return out.swapaxes(1, 2)


@functools.partial(jax.jit, static_argnames=("window", "block_k",
                                             "num_splits", "interpret"))
def decode_attention(q, k_cache, v_cache, pos, *, active=None, window=0,
                     block_k=512, num_splits=1, interpret=None):
    """Model layout: q (B,T,H,D); caches (B,S,KV,D) -> (B,T,H,D).

    ``pos`` may be a scalar (lockstep) or a (B,) vector (ragged continuous
    batching); ``active`` (B,) 0/1 gates per-slot work (default pos >= 0).
    ``num_splits > 1`` selects the two-phase split-K path for long contexts.
    T > 1 is the speculative multi-token verify block (query row ``t``
    attends keys <= pos + t); it always takes the single-pass kernel —
    the split-K variant is single-token only.
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    qt = q.swapaxes(1, 2)
    kt = k_cache.swapaxes(1, 2)
    vt = v_cache.swapaxes(1, 2)
    if num_splits > 1 and q.shape[1] == 1:
        out = decode_attention_splitk_tpu(qt, kt, vt, pos, active=active,
                                          window=window, block_k=block_k,
                                          num_splits=num_splits,
                                          interpret=interpret)
    else:
        out = decode_attention_tpu(qt, kt, vt, pos, active=active,
                                   window=window, block_k=block_k,
                                   interpret=interpret)
    return out.swapaxes(1, 2)


@functools.partial(jax.jit, static_argnames=("window", "num_splits",
                                             "interpret"))
def paged_decode_attention(q, k_pages, v_pages, page_idx, pos, *, active=None,
                           window=0, k_scale=None, v_scale=None, num_splits=1,
                           interpret=None):
    """Model layout: q (B,T,H,D); pools (P, page_size, KV, D); page_idx
    (B, max_pages) int32 -> (B,T,H,D).

    Paged mirror of ``decode_attention``: the KV stream is gathered
    through the page table by the kernel's scalar-prefetched index_map.
    Unmapped entries must be 0 (null page); ``pos``/``active`` follow the
    ragged contract.  ``k_scale``/``v_scale`` (P, page_size, KV, 1) f32
    select the quantized (int8/fp8 pool) path; ``num_splits > 1`` selects
    the two-phase split-K path (single-token only, splits must divide
    max_pages — see ``pick_decode_splits``).
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    qt = q.swapaxes(1, 2)
    kt = k_pages.swapaxes(1, 2)
    vt = v_pages.swapaxes(1, 2)
    kst = k_scale.swapaxes(1, 2) if k_scale is not None else None
    vst = v_scale.swapaxes(1, 2) if v_scale is not None else None
    if num_splits > 1 and q.shape[1] == 1:
        out = paged_decode_attention_splitk_tpu(
            qt, kt, vt, page_idx, pos, active=active, window=window,
            num_splits=num_splits, k_scale=kst, v_scale=vst,
            interpret=interpret)
    else:
        out = paged_decode_attention_tpu(qt, kt, vt, page_idx, pos,
                                         active=active, window=window,
                                         k_scale=kst, v_scale=vst,
                                         interpret=interpret)
    return out.swapaxes(1, 2)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_prefill_attention(q, k_pages, v_pages, page_idx, slot, offset, *,
                            window=0, k_scale=None, v_scale=None,
                            interpret=None):
    """Model layout: q (1,C,H,D) — one slot's prefill chunk at absolute
    ``offset`` — vs pools (P, page_size, KV, D) through row ``slot`` of
    ``page_idx (slots, max_pages)``.  Returns (1,C,H,D).

    Fused paged prefill: the chunk's K/V must already be written to the
    pages; no dense per-slot gather is materialized.
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    qt = q.swapaxes(1, 2)
    kt = k_pages.swapaxes(1, 2)
    vt = v_pages.swapaxes(1, 2)
    kst = k_scale.swapaxes(1, 2) if k_scale is not None else None
    vst = v_scale.swapaxes(1, 2) if v_scale is not None else None
    page_row = jnp.take(jnp.asarray(page_idx, jnp.int32), slot, axis=0)
    out = paged_prefill_attention_tpu(qt, kt, vt, page_row, offset,
                                      window=window, k_scale=kst,
                                      v_scale=vst, interpret=interpret)
    return out.swapaxes(1, 2)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk(x, b, c, dt, cum, *, interpret=None):
    """SSD intra-chunk compute; shapes per ssd_chunk_tpu docstring."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    return ssd_chunk_tpu(x, b, c, dt, cum, interpret=interpret)


# jnp oracles re-exported for convenience
attention_ref = ref.attention_ref
decode_attention_ref = ref.decode_attention_ref
paged_decode_attention_ref = ref.paged_decode_attention_ref
paged_decode_attention_quant_ref = ref.paged_decode_attention_quant_ref
paged_prefill_attention_ref = ref.paged_prefill_attention_ref
ssd_chunk_ref = ref.ssd_chunk_ref
