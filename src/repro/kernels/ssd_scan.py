"""Pallas TPU kernel for the Mamba2 SSD intra-chunk compute.

The chunked SSD algorithm splits into (a) per-chunk quadratic token mixing +
per-chunk state contribution — all MXU matmuls, done here — and (b) a tiny
sequential inter-chunk state recurrence, left to XLA (O(nc * heads * hp * ds),
negligible).  Grid = (batch*chunks, heads), one grid cell per (chunk, head):

    att[i,j] = (C_i . B_j) * exp(cum_i - cum_j) * dt_j   (j <= i)
    y_intra  = att @ x                                    (Q,hp)
    state    = (B * exp(cum_last - cum) * dt)^T @ x       (ds,hp)

``dt``/``cum`` (softplus'd step and its inclusive cumsum) are precomputed in
XLA — elementwise, fusable, and needed by the inter-chunk scan anyway.
Mamba2 n_groups < heads is handled via the B/C index_map (no replication).
Chunk Q=256 with hp/ds of 64..128 keeps the (Q,Q) tile and operands in VMEM
(~1 MB/cell at bf16).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, b_ref, c_ref, dt_ref, cum_ref, y_ref, st_ref, *,
                chunk: int):
    x = x_ref[0, 0]  # (Q, hp)
    bmat = b_ref[0, 0].astype(jnp.float32)  # (Q, ds)
    cmat = c_ref[0, 0].astype(jnp.float32)  # (Q, ds)
    dt = dt_ref[0, 0]  # (Q, 1) f32
    cum = cum_ref[0, 0]  # (Q, 1) f32

    cb = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q,Q)
    decay = jnp.exp(cum - cum.T)  # (Q,Q): exp(cum_i - cum_j)
    att = cb * decay * dt.T
    qi = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    kj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    att = jnp.where(kj <= qi, att, 0.0)
    y = jax.lax.dot_general(att.astype(x.dtype), x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y_ref[0, 0] = y.astype(y_ref.dtype)

    w = jnp.exp(cum[-1:, :] - cum) * dt  # (Q, 1)
    bw = bmat * w  # (Q, ds)
    st = jax.lax.dot_general(bw, x.astype(jnp.float32),
                             (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (ds, hp)
    st_ref[0, 0] = st


def ssd_chunk_tpu(x, b, c, dt, cum, *, interpret=False):
    """Per-chunk SSD intra compute.

    x   (B, NC, NH, Q, hp)
    b,c (B, NC, G,  Q, ds)   (groups indexed via head // (NH // G))
    dt  (B, NC, NH, Q) f32   softplus'd step
    cum (B, NC, NH, Q) f32   inclusive cumsum of dt * a

    Returns: y_intra (B, NC, NH, Q, hp), state (B, NC, NH, ds, hp) f32.
    """
    bb, nc, nh, q, hp = x.shape
    g, ds = b.shape[2], b.shape[4]
    rep = nh // g
    dt4 = dt[..., None]
    cum4 = cum[..., None]
    kernel = functools.partial(_ssd_kernel, chunk=q)
    grid = (bb * nc, nh)
    xr = x.reshape(bb * nc, nh, q, hp)
    br = b.reshape(bb * nc, g, q, ds)
    cr = c.reshape(bb * nc, g, q, ds)
    dtr = dt4.reshape(bb * nc, nh, q, 1)
    cumr = cum4.reshape(bb * nc, nh, q, 1)
    y, st = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q, hp), lambda i, h: (i, h, 0, 0)),
            pl.BlockSpec((1, 1, q, ds), lambda i, h: (i, h // rep, 0, 0)),
            pl.BlockSpec((1, 1, q, ds), lambda i, h: (i, h // rep, 0, 0)),
            pl.BlockSpec((1, 1, q, 1), lambda i, h: (i, h, 0, 0)),
            pl.BlockSpec((1, 1, q, 1), lambda i, h: (i, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, q, hp), lambda i, h: (i, h, 0, 0)),
            pl.BlockSpec((1, 1, ds, hp), lambda i, h: (i, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bb * nc, nh, q, hp), x.dtype),
            jax.ShapeDtypeStruct((bb * nc, nh, ds, hp), jnp.float32),
        ],
        interpret=interpret,
    )(xr, br, cr, dtr, cumr)
    return (y.reshape(bb, nc, nh, q, hp), st.reshape(bb, nc, nh, ds, hp))
