"""Pallas TPU flash attention (training/prefill).

TPU-native tiling: the (Sq, Sk) score matrix never leaves VMEM — the grid is
(batch, q_heads, q_blocks, kv_blocks) with the kv dimension innermost and
"arbitrary" (sequential), accumulating a running (max, denom, out) triple in
VMEM scratch.  GQA is handled with *zero* KV replication by pointing the K/V
BlockSpec index_map at ``q_head // group_size``.

Causal and sliding-window masking skip fully-masked KV blocks via ``pl.when``
(no MXU work is issued for them), so compiled FLOPs are ~S*window for
windowed layers and ~S^2/2 for causal ones — matching the roofline model.

Block sizes default to (512, 512) with the last dim = head_dim (128-aligned
for the MXU); validated against ``ref.attention_ref`` in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  causal: bool, window: int, block_q: int, block_k: int,
                  scale: float):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * block_q
    k_start = ik * block_k
    needed = k_start <= q_start + block_q - 1 if causal else True
    if window:
        lo = k_start + block_k - 1 >= q_start - window + 1
        needed = jnp.logical_and(needed, lo) if causal else lo

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), dtype=bool)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]  # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        pv = jax.lax.dot_general(p.astype(v.dtype), v,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_tpu(q, k, v, *, causal=True, window=0, block_q=512,
                        block_k=512, interpret=False):
    """q (B, H, Sq, D); k, v (B, KV, Sk, D) -> (B, H, Sq, D)."""
    b, h, sq, d = q.shape
    kv, sk = k.shape[1], k.shape[2]
    g = h // kv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    nq, nk = sq // block_q, sk // block_k
    scale = d ** -0.5

    kernel = functools.partial(_flash_kernel, causal=causal, window=window,
                               block_q=block_q, block_k=block_k, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, iq, ik: (b_, h_ // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, iq, ik: (b_, h_ // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
