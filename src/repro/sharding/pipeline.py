"""GPipe-style pipeline parallelism over a "stage" mesh axis.

Completes the parallelism menu (DP/TP/EP/SP in rules.py; PP here) for
depth-dominated models (94-layer qwen3) where TP+DP alone leave the mesh
under-used.  Implementation is the standard JAX SPMD pipeline: run inside
``shard_map`` over the stage axis, with layers stacked (n_stages,
layers_per_stage, ...) so each device holds one stage's slice; activations
flow stage-to-stage via ``lax.ppermute`` across M + S - 1 ticks (the last
S - 1 are the drain bubble).

The schedule is expressed with ``jax.lax`` control flow only — it lowers
to a single fori-style scan whose body contains one stage compute + one
collective-permute, exactly the schedule a production pipeline runs.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def pipeline_apply(stage_fn: Callable, stage_params, microbatches, *,
                   axis_name: str = "stage"):
    """Run ``microbatches`` through all pipeline stages.  Call INSIDE
    shard_map where ``axis_name`` is a manual mesh axis.

    stage_fn:      (params_for_one_stage, x) -> x      (same shape)
    stage_params:  this device's stage slice (leading dims already local)
    microbatches:  (M, mb, ...) — identical replica on every stage; stage 0
                   feeds microbatch t at tick t.

    Returns (M, mb, ...): outputs of the LAST stage in microbatch order
    (valid on the last stage; other stages hold zeros — callers psum or
    ppermute them home as needed).
    """
    n_stages = jax.lax.psum(1, axis_name)
    stage_id = jax.lax.axis_index(axis_name)
    m = microbatches.shape[0]
    ticks = m + n_stages - 1
    mb_shape = microbatches.shape[1:]

    fwd_perm = [(i, i + 1) for i in range(n_stages - 1)]

    def tick(carry, t):
        inflight, outputs = carry
        # stage 0 injects microbatch t (zeros once the feed is exhausted)
        feed = jnp.where(
            t < m,
            jax.lax.dynamic_index_in_dim(microbatches, jnp.minimum(t, m - 1),
                                         keepdims=False),
            jnp.zeros(mb_shape, microbatches.dtype))
        x = jnp.where(stage_id == 0, feed, inflight)
        y = stage_fn(stage_params, x)
        # last stage banks its result for microbatch (t - n_stages + 1)
        out_idx = jnp.clip(t - n_stages + 1, 0, m - 1)
        is_valid = jnp.logical_and(stage_id == n_stages - 1,
                                   t >= n_stages - 1)
        outputs = jnp.where(
            is_valid,
            jax.lax.dynamic_update_index_in_dim(outputs, y, out_idx, 0),
            outputs)
        # everyone ships their activation rightwards for the next tick
        inflight = jax.lax.ppermute(y, axis_name, fwd_perm)
        return (inflight, outputs), None

    init = (jnp.zeros(mb_shape, microbatches.dtype),
            jnp.zeros((m,) + mb_shape, microbatches.dtype))
    (_, outputs), _ = jax.lax.scan(tick, init, jnp.arange(ticks))
    return outputs


def make_pipelined_forward(stage_fn: Callable, mesh: Mesh, *,
                           axis_name: str = "stage"):
    """Wrap ``pipeline_apply`` in shard_map on ``mesh``.

    Returns f(stacked_params, microbatches) -> (M, mb, ...) where
    stacked_params leaves have leading dim n_stages (sharded over the stage
    axis) and the result is gathered to every stage.
    """
    from jax.experimental.shard_map import shard_map

    def inner(params, microbatches):
        out = pipeline_apply(stage_fn, jax.tree.map(lambda p: p[0], params),
                             microbatches, axis_name=axis_name)
        # broadcast the last stage's outputs to all stages
        n = jax.lax.psum(1, axis_name)
        last = n - 1
        mask = (jax.lax.axis_index(axis_name) == last).astype(out.dtype)
        return jax.lax.psum(out * mask, axis_name)

    # P(axis_name) acts as a pytree *prefix*: every param leaf is sharded
    # on its leading (stage) dim; microbatches are replicated.
    return shard_map(inner, mesh=mesh, in_specs=(P(axis_name), P()),
                     out_specs=P(), check_rep=False)
