"""Sharding rules: param/activation/cache -> NamedSharding on the mesh.

Axis convention (launch/mesh.py):
  "model"         — tensor parallel: attention heads, MLP hidden, experts,
                    vocab.
  "data"          — batch; with ``fsdp=True`` also shards a weight dim
                    (FSDP/ZeRO-3 style, all-gathered per layer inside scan).
  "pod" (optional)— pure data parallelism across pods; the only axis whose
                    collectives cross DCN.  Optimizer state is additionally
                    sharded over it (ZeRO-1 across pods).

Rules are name-based with a divisibility fallback chain: each candidate
PartitionSpec is tried in order and the first one where every named dim
divides the mesh axis size wins; otherwise that dim is replicated.  This is
what makes one rule set serve all 10 architectures (kv heads 1..32, experts
8/128, uneven mamba projections) without per-arch tables.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# --------------------------------------------------------------- utilities
def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def _fits(mesh: Mesh, shape, spec) -> bool:
    for dim, axis in zip(shape, spec):
        if axis is not None and dim % _axis_size(mesh, axis) != 0:
            return False
    return True


def _choose(mesh: Mesh, shape, *candidates) -> P:
    """First candidate whose named axes all divide evenly; else drop axes."""
    for spec in candidates:
        if len(spec) == len(shape) and _fits(mesh, shape, spec):
            return P(*spec)
    # last resort: keep only the axes that fit, dim by dim
    spec = candidates[0] if candidates else (None,) * len(shape)
    fixed = [a if (a is not None and dim % _axis_size(mesh, a) == 0) else None
             for dim, a in zip(shape, spec)]
    return P(*fixed)


def _dp_axes(mesh: Mesh):
    axes = [a for a in ("pod", "data") if a in mesh.shape]
    if not axes:
        return None
    return tuple(axes) if len(axes) > 1 else axes[0]


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


# ----------------------------------------------------------- param rules
def _trailing_spec(pstr: str, key: str, shape, mesh, fsdp: bool,
                   zero_axis) -> P:
    """PartitionSpec for the *semantic* (trailing) dims of one param."""
    fs = zero_axis if zero_axis is not None else ("data" if fsdp else None)
    rank = len(shape)

    def c(*cands):
        return _choose(mesh, shape, *cands)

    if key in ("table", "head"):  # (V, dm); vocab may not divide (mamba2)
        # never FSDP-shard dm of the embedding: the token-gather then needs
        # an involuntary replicate-repartition EVERY microbatch (measured:
        # ~350 GB/dev/step on qwen3 multipod — §Perf H1)
        if zero_axis is None:
            fs = None
        return c(("model", fs), ("model", None), (fs, "model"),
                 (None, "model"), (None, None))
    if key == "wq":  # (dm, H, hd); head-count may not divide |model| (40H)
        return c((fs, "model", None), (None, "model", None),
                 ("model", None, None), (None, None, None))
    if key in ("wk", "wv"):  # (dm, KV, hd)
        return c((fs, "model", None), ("model", None, None),
                 (None, None, None))
    if key == "wo":  # (H, hd, dm)
        return c(("model", None, fs), ("model", None, None),
                 (None, None, "model"), (None, None, None))
    if key in ("bq", "bk", "bv"):  # (H, hd)
        return c(("model", None), (None, None))
    if "moe" in pstr:
        if key == "router":  # (dm, E)
            return P(*([None] * rank))
        if key in ("w_gate", "w_up"):  # (E, dm, dff)
            return c(("model", fs, None), ("model", None, None),
                     (None, fs, "model"), (None, None, "model"),
                     (None, None, None))
        if key == "w_down":  # (E, dff, dm)
            return c(("model", None, fs), ("model", None, None),
                     (None, "model", fs), (None, "model", None),
                     (None, None, None))
    if key in ("w_gate", "w_up"):  # mlp (dm, ff)
        return c((fs, "model"), (None, "model"), (None, None))
    if key == "w_down":  # (ff, dm)
        return c(("model", fs), ("model", None), (None, None))
    if key == "in_proj":  # (dm, d_in)
        return c((fs, "model"), (None, "model"), (None, None))
    if key == "out_proj":  # (di, dm)
        return c(("model", fs), ("model", None), (None, None))
    # conv_w, conv_b, A_log, dt_bias, D, norm scales, biases: replicate
    return P(*([None] * rank))


_SEMANTIC_RANK = {
    "table": 2, "head": 2, "wq": 3, "wk": 3, "wv": 3, "wo": 3,
    "bq": 2, "bk": 2, "bv": 2, "router": 2, "in_proj": 2, "out_proj": 2,
    "w_gate": 2, "w_up": 2, "w_down": 2,  # dense MLP (moe overrides to 3)
    "conv_w": 2, "conv_b": 1, "A_log": 1, "dt_bias": 1, "D": 1,
    "norm_scale": 1, "scale": 1,
}


def _param_spec(path, leaf, mesh, cfg, fsdp, zero_axis=None) -> P:
    pstr = _path_str(path)
    key = pstr.rsplit("/", 1)[-1]
    shape = leaf.shape
    if "moe" in pstr and key in ("w_gate", "w_up", "w_down"):
        rank = 3
    else:
        rank = _SEMANTIC_RANK.get(key, len(shape))
    lead = len(shape) - rank  # stacked layer dims, never sharded
    spec = _trailing_spec(pstr, key, shape[lead:], mesh, fsdp, zero_axis)
    return P(*([None] * lead + list(spec)))


def param_shardings(mesh: Mesh, cfg, param_specs, *, fsdp: bool,
                    layout: str = "tp"):
    """NamedShardings for the parameter pytree (abstract or concrete).

    layout="dp": pure data parallelism — weights replicated, every mesh
    axis used for batch (the right layout for small models on big meshes,
    where TP activation all-reduces dwarf the compute; §Perf H3).
    """
    if layout == "dp":
        return jax.tree.map(
            lambda leaf: NamedSharding(mesh, P(*([None] * len(leaf.shape)))),
            param_specs)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, _param_spec(path, leaf, mesh, cfg, fsdp)),
        param_specs)


def opt_state_shardings(mesh: Mesh, cfg, param_specs, *, fsdp: bool,
                        layout: str = "tp"):
    """Optimizer state (master + moments): ZeRO — FSDP dim extends over
    ("pod","data") when both exist, halving per-chip optimizer bytes.
    Under layout="dp" the optimizer state still shards (ZeRO-1)."""
    zero = _dp_axes(mesh)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, _param_spec(path, leaf, mesh, cfg, True, zero_axis=zero)),
        param_specs)


def grad_shardings(mesh: Mesh, cfg, param_specs):
    """Gradient-accumulator shardings (ZeRO-2) — over "data" ONLY.

    Pinning the accumulator across the pod axis makes XLA reduce every
    microbatch's grads over DCN (measured ~470 GB/dev/step on qwen3
    multipod); keeping grads data-sharded defers the pod-axis reduce to
    once per step, at the cost of pod-replicated accumulators.
    """
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, _param_spec(path, leaf, mesh, cfg, True,
                              zero_axis="data")),
        param_specs)


def _all_axes(mesh: Mesh):
    axes = tuple(mesh.shape.keys())
    return axes if len(axes) > 1 else axes[0]


# ------------------------------------------------- serving (gather-form TP)
# Training TP (above) lets GSPMD split contraction dims and psum the
# partials — fastest, but the partial-sum order differs from the
# single-device reduction, so results drift by ~1 ulp per matmul.  Serving
# promises BITWISE-identical output on any mesh (tests pin it), so the
# serving layout uses *gather-form* tensor parallelism instead: every
# matmul whose contraction dim would be sharded keeps that operand
# replicated, and the activation feeding it is all-gathered first (the
# ``attn_out`` / ``mlp_up`` / ``moe_expert_out`` shard_fn seams in
# models/).  What IS sharded: the QKV projections and per-head attention
# over the KV cache (heads are embarrassingly parallel), the MLP up/gate
# projections (ff columns independent), per-expert MoE matmuls (expert is
# a batch dim), and the slot/batch dim over "data".  Reductions — ``wo``,
# ``w_down``, the MoE combine, rmsnorm, unembed — run replicated in the
# exact single-device order.  More all-gather traffic than psum TP; the
# memory- and FLOP-heavy half (attention reads over the KV cache, up
# projections) still scales with the mesh.


def _serve_trailing_spec(pstr: str, key: str, shape, mesh) -> P:
    def c(*cands):
        return _choose(mesh, shape, *cands)

    if key == "wq":  # (dm, H, hd): shard heads
        return c((None, "model", None), (None, None, None))
    if key in ("wk", "wv"):  # (dm, KV, hd)
        return c((None, "model", None), (None, None, None))
    if key in ("bq", "bk", "bv"):  # (H|KV, hd)
        return c(("model", None), (None, None))
    if "moe" in pstr and key in ("w_gate", "w_up", "w_down"):
        # (E, dm, dff) / (E, dff, dm): expert is a batch dim — per-expert
        # matmuls are independent, so sharding E is reduction-free
        return c(("model", None, None), (None, None, None))
    if key in ("w_gate", "w_up"):  # mlp (dm, ff): columns independent
        return c((None, "model"), (None, None))
    # wo, w_down, router, embed table/head, norms, ssm leaves: replicated —
    # these feed (or are) the contractions that must keep reduction order
    return P(*([None] * len(shape)))


def serve_param_shardings(mesh: Mesh, cfg, param_specs):
    """Gather-form TP parameter layout for the serving engine (bitwise-
    preserving; see the block comment above)."""
    def spec(path, leaf):
        pstr = _path_str(path)
        key = pstr.rsplit("/", 1)[-1]
        shape = leaf.shape
        if "moe" in pstr and key in ("w_gate", "w_up", "w_down"):
            rank = 3
        else:
            rank = _SEMANTIC_RANK.get(key, len(shape))
        lead = len(shape) - rank  # stacked layer dims, never sharded
        tail = _serve_trailing_spec(pstr, key, shape[lead:], mesh)
        return NamedSharding(mesh, P(*([None] * lead + list(tail))))

    return jax.tree_util.tree_map_with_path(spec, param_specs)


def serve_cache_shardings(mesh: Mesh, cache_specs, *, paged: bool = False):
    """Serving-cache layout: slots (dense) or pages (paged) over "data",
    KV heads over "model"; never the sequence dim (sequence-sharded
    attention psums softmax stats, breaking bitwise identity).

    Dense attn leaves are (L..., B, S, KV, hd): B -> "data", KV ->
    "model".  Paged pool leaves are (L..., P, page_size, KV, hd): the
    page dim P -> "data" — each data row physically holds one host's
    page sub-pool, which ``runtime/kv_pool.py``'s host-local placement
    keeps slot chains inside — and KV -> "model".  SSM state/conv
    leaves shard the batch dim only (their out-projections have no
    gather seam)."""
    dp = _dp_axes(mesh)

    def spec_for(path, leaf):
        pstr = _path_str(path)
        key = pstr.rsplit("/", 1)[-1]
        shape = leaf.shape
        if key in ("k", "v", "k_scale", "v_scale"):
            # quantization scales share the pool layout with hd == 1
            # (P, page_size, KV, 1): same rule places them with their
            # pages so a page and its scale never live on different hosts
            lead = len(shape) - 4  # (B|P, S|page_size, KV, hd|1)
            base = [None] * lead
            cands = []
            if dp:
                cands.append(tuple(base) + (dp, None, "model", None))
                cands.append(tuple(base) + (dp, None, None, None))
            cands.append(tuple(base) + (None, None, "model", None))
            cands.append((None,) * len(shape))
            return _choose(mesh, shape, *cands)
        if key in ("state", "conv") and dp:
            lead = len(shape) - (4 if key == "state" else 3)
            spec = [None] * len(shape)
            spec[lead] = dp
            return _choose(mesh, shape, tuple(spec),
                           (None,) * len(shape))
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, spec_for(p, l)), cache_specs)


class ServeShardFn:
    """Activation-constraint hook for the gather-form serving layout,
    passed via ``RuntimeKnobs.shard_fn``.

    Sharding seams ("attn_q"/"attn_kv", "moe_expert_in") pin the
    parallel phases to the "model" axis; gather seams ("attn_out",
    "mlp_up", "moe_expert_out", "hidden") force the activation back
    to model-replicated immediately before a contraction over the
    sharded dim, so the contraction runs in single-device reduction
    order on every shard — the constraint that makes sharded decode
    bitwise-identical to the unsharded engine.

    Hashable on the mesh so ``RuntimeKnobs`` equality (and with it the
    ``runtime/steps.py`` compiled-step LRU) dedupes engines sharing one
    mesh."""

    def __init__(self, mesh: Mesh):
        self.mesh = mesh
        self._dp = _dp_axes(mesh)

    def __eq__(self, other):
        return isinstance(other, ServeShardFn) and self.mesh == other.mesh

    def __hash__(self):
        return hash((type(self).__name__, self.mesh))

    def __call__(self, name: str, x):
        mesh, dp = self.mesh, self._dp
        shape = x.shape
        if name in ("attn_q", "attn_kv") and len(shape) == 4:
            spec = _choose(mesh, shape, (dp, None, "model", None),
                           (dp, None, None, None), (None,) * 4)
        elif name == "attn_out" and len(shape) == 4:  # gather heads
            spec = _choose(mesh, shape, (dp, None, None, None),
                           (None,) * 4)
        elif name == "mlp_up" and len(shape) == 3:  # gather ff pre-activation
            spec = _choose(mesh, shape, (dp, None, None), (None,) * 3)
        elif name == "hidden" and len(shape) == 3:
            spec = _choose(mesh, shape, (dp, None, None), (None,) * 3)
        elif name == "moe_expert_in" and len(shape) == 5:  # shard experts
            spec = _choose(mesh, shape, (dp, None, "model", None, None),
                           (None, None, "model", None, None), (None,) * 5)
        elif name == "moe_expert_out" and len(shape) == 5:  # gather experts
            spec = _choose(mesh, shape, (dp, None, None, None, None),
                           (None,) * 5)
        else:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*spec)))


def serve_batch_sharding(mesh: Mesh, batch: int) -> Optional[NamedSharding]:
    """Sharding for the engine's per-slot host arrays (tokens, pos,
    sampling params): slot dim over "data" when divisible, else None
    (replicate — jit's default placement)."""
    dp = _dp_axes(mesh)
    if dp is None or batch % _axis_size(mesh, dp) != 0:
        return None
    return NamedSharding(mesh, P(dp))


# ------------------------------------------------------- batch/cache rules
def batch_shardings(mesh: Mesh, specs, layout: str = "tp"):
    """Inputs: shard the batch dim over (pod, data) when divisible; under
    layout="dp" the batch uses EVERY mesh axis."""
    dp = _all_axes(mesh) if layout == "dp" else _dp_axes(mesh)

    def spec_for(leaf):
        shape = leaf.shape
        if len(shape) == 0:
            return P()
        cands = [(dp,) + (None,) * (len(shape) - 1)] if dp else []
        cands.append((None,) * len(shape))
        return _choose(mesh, shape, *cands)

    return jax.tree.map(lambda l: NamedSharding(mesh, spec_for(l)), specs)


def cache_shardings(mesh: Mesh, cache_specs):
    """KV / SSM caches.

    Layout after stacking: attn k/v (L..., B, S, KV, hd); ssm state
    (L..., B, nh, hp, ds); conv (L..., B, w, ch).  Batch goes to (pod,data)
    when divisible; otherwise the *sequence* dim of attn caches is sharded
    over "data" (sequence-parallel KV for batch-1 long-context decode).
    Head-like dims go to "model" when divisible.
    """
    dp = _dp_axes(mesh)

    def spec_for(path, leaf):
        pstr = _path_str(path)
        key = pstr.rsplit("/", 1)[-1]
        shape = leaf.shape
        if key in ("k", "v"):
            lead = len(shape) - 4  # (B, S, KV, hd)
            base = [None] * lead
            b, s, kv, hd = shape[lead:]
            cands = []
            if dp:
                # sequence-sharded KV over "model" (flash-decode style):
                # kv-head counts of 1..8 can't use the 16-way model axis,
                # but the 32k sequence always can — decode then reads only
                # S/16 per chip and psums the softmax stats (tiny).
                cands.append(tuple(base) + (dp, "model", None, None))
                cands.append(tuple(base) + (dp, None, "model", None))
                cands.append(tuple(base) + (dp, None, None, "model"))
                cands.append(tuple(base) + (dp, None, None, None))
            cands.append(tuple(base) + (None, ("data", "model"), None, None))
            cands.append(tuple(base) + (None, "data", "model", None))
            cands.append(tuple(base) + (None, "data", None, None))
            cands.append(tuple(base) + (None, None, "model", None))
            cands.append((None,) * len(shape))
            return _choose(mesh, shape, *cands)
        if key == "state":  # (L..., B, nh, hp, ds)
            lead = len(shape) - 4
            base = [None] * lead
            cands = []
            if dp:
                cands.append(tuple(base) + (dp, "model", None, None))
                cands.append(tuple(base) + (dp, None, None, None))
            cands.append(tuple(base) + (None, "model", None, None))
            cands.append((None,) * len(shape))
            return _choose(mesh, shape, *cands)
        if key == "conv":  # (L..., B, w, ch)
            lead = len(shape) - 3
            base = [None] * lead
            cands = []
            if dp:
                cands.append(tuple(base) + (dp, None, "model"))
                cands.append(tuple(base) + (dp, None, None))
            cands.append(tuple(base) + (None, None, "model"))
            cands.append((None,) * len(shape))
            return _choose(mesh, shape, *cands)
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, spec_for(p, l)), cache_specs)


# ------------------------------------------------------- activation hooks
def make_shard_fn(mesh: Mesh, cfg, *, sp: bool = False,
                  layout: str = "tp"):
    """Activation sharding-constraint hook passed via RuntimeKnobs.

    sp=True: Megatron-style sequence parallelism — the residual stream
    (and hence every remat-saved layer boundary) is sharded over "model"
    along the sequence dim, cutting saved-activation HBM |model|-fold and
    letting grad-accum shrink (fewer FSDP regathers; §Perf H1).
    """
    dp = _all_axes(mesh) if layout == "dp" else _dp_axes(mesh)
    # under the pure-DP layout "model" already belongs to the batch axes
    tp = None if layout == "dp" else "model"

    def shard_fn(name: str, x):
        shape = x.shape
        if name == "hidden" and len(shape) == 3:  # (B, S, dm)
            if sp and tp:
                spec = _choose(mesh, shape, (dp, tp, None),
                               (dp, None, None), (None,) * 3)
            else:
                spec = _choose(mesh, shape, (dp, None, None), (None,) * 3)
        elif name == "microbatch":  # (accum, B/accum, ...)
            spec = _choose(mesh, shape,
                           (None, dp) + (None,) * (len(shape) - 2),
                           (None,) * len(shape))
        elif name in ("moe_expert_in", "moe_expert_out") and len(shape) == 5:
            # (B, n, E, C, d)
            tokens = shape[0] * shape[1] * shape[3]
            if tokens <= 4096 and tp:
                # serving regime (few tokens): weight-stationary — keep the
                # dm dim sharded over "data" on both sides of the expert
                # matmuls so the (tiny) token tensors move/reduce instead
                # of re-gathering 57 GB of FSDP-sharded expert weights
                # every decode step (§Perf H4)
                spec = _choose(mesh, shape, (None, None, tp, None, "data"),
                               (None, None, tp, None, None), (None,) * 5)
            else:
                spec = _choose(mesh, shape, (dp, None, tp, None, None),
                               (None, None, tp, None, None),
                               (dp, None, None, None, None), (None,) * 5)
        elif name == "attn_q" and len(shape) == 4:  # (B, S, H, hd)
            spec = _choose(mesh, shape, (dp, None, tp, None),
                           (None,) * 4)
        elif name == "attn_kv" and len(shape) == 4:
            spec = _choose(mesh, shape, (dp, None, tp, None),
                           (dp, None, None, None), (None,) * 4)
        else:
            return x
        if all(s is None for s in spec):
            return x  # never force replication — let XLA propagate
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return shard_fn
