from .pipeline import make_pipelined_forward, pipeline_apply
from .rules import (ServeShardFn, batch_shardings, cache_shardings,
                    grad_shardings, make_shard_fn, opt_state_shardings,
                    param_shardings, serve_batch_sharding,
                    serve_cache_shardings, serve_param_shardings)

__all__ = ["param_shardings", "batch_shardings", "cache_shardings",
           "opt_state_shardings", "grad_shardings", "make_shard_fn",
           "serve_param_shardings", "serve_cache_shardings",
           "serve_batch_sharding", "ServeShardFn",
           "pipeline_apply", "make_pipelined_forward"]
