"""Deterministic synthetic data pipeline.

Counter-based (Philox) generation keyed on (seed, step): any host can
materialize any step's batch without coordination or state — exactly what a
restarted/elastically-rescaled job needs (the checkpoint only stores the
step counter).  ``host_shard`` slices the global batch for a host, matching
the ``(pod, data)``-sharded in_shardings of the train step.

``MarkovSynthetic`` adds learnable sequential structure (noisy affine
next-token map) so convergence tests and the quickstart example show real
loss movement rather than ln(V) noise floor.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class SyntheticDataset:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.Generator(
            np.random.Philox(key=[self.seed & 0xFFFFFFFFFFFFFFFF,
                                  (step << 16) ^ 0xDA7A]))

    def batch(self, step: int) -> dict:
        rng = self._rng(step)
        tokens = rng.integers(0, self.vocab_size,
                              size=(self.global_batch, self.seq_len),
                              dtype=np.int32)
        return {"tokens": tokens}


@dataclass(frozen=True)
class MarkovSynthetic(SyntheticDataset):
    """next = (a * prev + b) % V with prob (1-noise); uniform otherwise."""

    a: int = 5
    b: int = 17
    noise: float = 0.1

    def batch(self, step: int) -> dict:
        rng = self._rng(step)
        b, s, v = self.global_batch, self.seq_len, self.vocab_size
        tokens = np.empty((b, s), dtype=np.int32)
        tokens[:, 0] = rng.integers(0, v, size=b)
        flip = rng.random((b, s)) < self.noise
        rand = rng.integers(0, v, size=(b, s), dtype=np.int32)
        for t in range(1, s):
            nxt = (self.a * tokens[:, t - 1] + self.b) % v
            tokens[:, t] = np.where(flip[:, t], rand[:, t], nxt)
        return {"tokens": tokens}


def host_shard(batch: dict, host_index: int, n_hosts: int) -> dict:
    """Slice a global batch into this host's contiguous shard."""
    def slice_one(x):
        bsz = x.shape[0]
        assert bsz % n_hosts == 0, (bsz, n_hosts)
        per = bsz // n_hosts
        return x[host_index * per:(host_index + 1) * per]

    return {k: slice_one(v) for k, v in batch.items()}
