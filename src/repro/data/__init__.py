from .pipeline import MarkovSynthetic, SyntheticDataset, host_shard

__all__ = ["SyntheticDataset", "MarkovSynthetic", "host_shard"]
