"""Compatibility shims for jax API drift.

The sharding tests target the post-0.5 explicit-sharding API
(``jax.sharding.AxisType``, ``make_mesh(..., axis_types=...)``,
``AbstractMesh(shape, names, axis_types=...)``).  Older jax (e.g. 0.4.x)
lacks ``AxisType`` and uses a ``tuple[(name, size)]`` AbstractMesh
constructor; axis types there are simply the default (auto) behavior.
These helpers accept the new-style arguments and degrade gracefully.
"""
from __future__ import annotations

import enum

import jax
import jax.sharding

try:  # jax >= 0.5-ish
    from jax.sharding import AxisType

    HAS_AXIS_TYPE = True
except ImportError:  # stub: callers only pass these through to the helpers
    class AxisType(enum.Enum):
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    HAS_AXIS_TYPE = False

from jax.sharding import AbstractMesh  # present in both lineages


def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
    """``jax.make_mesh`` accepting ``axis_types`` on any jax version.

    On jax without ``jax.sharding.AxisType`` the axis types are dropped:
    Auto matches the old default, and Explicit/Manual callers rely only on
    behavior (shard_map, with_sharding_constraint) that predates the enum.
    """
    kw = {} if devices is None else {"devices": devices}
    if HAS_AXIS_TYPE and axis_types is not None:
        try:
            return jax.make_mesh(axis_shapes, axis_names,
                                 axis_types=axis_types, **kw)
        except TypeError:
            pass
    return jax.make_mesh(axis_shapes, axis_names, **kw)


def abstract_mesh(axis_shapes, axis_names, *, axis_types=None):
    """``AbstractMesh`` for either constructor signature."""
    try:  # new: AbstractMesh(shape, names, axis_types=...)
        if HAS_AXIS_TYPE and axis_types is not None:
            return AbstractMesh(tuple(axis_shapes), tuple(axis_names),
                                axis_types=axis_types)
        return AbstractMesh(tuple(axis_shapes), tuple(axis_names))
    except TypeError:  # old: AbstractMesh(tuple[(name, size), ...])
        return AbstractMesh(tuple(zip(axis_names, axis_shapes)))
