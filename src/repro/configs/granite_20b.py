"""granite-20b — dense, MQA (kv=1), code model.

[arXiv:2405.04324; hf]  52L d_model=6144 48H (GQA kv=1) d_ff=24576
vocab=49152.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-20b",
    family="dense",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,  # MQA
    d_ff=24576,
    vocab_size=49152,
    gated_mlp=False,  # GPT-BigCode style 2-matrix MLP (matches ~20B count)
    supports_long_context=False,
    notes="llama-arch, MQA, code",
)
