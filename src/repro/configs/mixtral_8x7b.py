"""mixtral-8x7b — MoE 8 experts top-2, sliding-window attention.

[arXiv:2401.04088; hf]  32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, MoE 8e top-2, SWA window 4096.
"""
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=0,  # every FFN is MoE
    vocab_size=32000,
    window=4096,  # SWA
    moe=MoEConfig(num_experts=8, experts_per_token=2, d_ff=14336),
    tie_embeddings=False,
    supports_long_context=True,  # SWA bounds the KV window
    notes="8 experts top-2, SWA 4096",
)
