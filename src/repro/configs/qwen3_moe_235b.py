"""qwen3-moe-235b-a22b — MoE 128 experts top-8.

[hf:Qwen/Qwen3-30B-A3B; hf]  94L d_model=4096 64H (GQA kv=4) d_ff=1536
(per expert) vocab=151936, MoE 128e top-8.  head_dim=128 (public value).
"""
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=0,  # every FFN is MoE
    vocab_size=151936,
    rope_theta=1_000_000.0,
    moe=MoEConfig(num_experts=128, experts_per_token=8, d_ff=1536),
    tie_embeddings=False,
    supports_long_context=False,
    notes="128 experts top-8; expert-parallel over the model axis",
)
