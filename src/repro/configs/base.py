"""Architecture / shape configuration schema.

Every assigned architecture is expressed as an ``ArchConfig``.  The four
assigned input shapes are global (same for every arch) and are expressed as
``ShapeSpec``.  ``input_specs`` builds jax.ShapeDtypeStruct stand-ins for the
dry-run (no device allocation).

Pure-python module: importing it must never touch jax device state.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional


@dataclass(frozen=True)
class ShapeSpec:
    """One assigned input shape."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


# The assigned shape set (identical across the 10 LM-family archs).
SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    experts_per_token: int
    d_ff: int  # per-expert hidden dim
    capacity_factor: float = 1.25
    # inference (prefill/decode) capacity: higher to keep drops negligible
    eval_capacity_factor: float = 2.0
    # dispatch is chunked along the sequence to keep the one-hot dispatch
    # einsum linear in seq_len (see DESIGN.md / models/moe.py).
    dispatch_chunk: int = 512
    router_jitter: float = 0.0


@dataclass(frozen=True)
class SSMConfig:
    d_state: int
    head_dim: int = 64
    expand: int = 2
    n_groups: int = 1
    conv_width: int = 4
    chunk_size: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ArchConfig:
    """A single assigned architecture."""

    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int  # query heads (0 for attention-free archs)
    num_kv_heads: int
    d_ff: int  # dense FFN hidden dim (0 when every FFN is MoE / SSM)
    vocab_size: int
    head_dim: int = 0  # 0 -> derived d_model // num_heads
    # --- attention pattern ---
    window: int = 0  # global sliding-window (mixtral SWA); 0 = full causal
    local_window: int = 0  # window of the *local* layers (gemma3)
    local_global_period: int = 0  # gemma3: every Nth layer is global
    qkv_bias: bool = False
    gated_mlp: bool = True  # SwiGLU; False -> 2-matrix GELU MLP (granite)
    rope_theta: float = 10_000.0
    # --- mixture of experts ---
    moe: Optional[MoEConfig] = None
    # --- state-space (mamba2 / hybrid) ---
    ssm: Optional[SSMConfig] = None
    # zamba2-style shared attention block applied every N ssm layers
    shared_attn_period: int = 0
    # --- input modality ---
    input_mode: str = "tokens"  # "tokens" | "embeddings" (vlm stub frontend)
    tie_embeddings: bool = True
    # long_500k applicability (sub-quadratic attention available?)
    supports_long_context: bool = False
    notes: str = ""

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.num_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def is_attention_free(self) -> bool:
        return self.num_heads == 0

    def layer_kinds(self) -> list[str]:
        """Per-layer block kind: 'attn' | 'ssm' | 'moe' | 'local' | 'global'.

        The transformer assembles blocks from this list; identical kinds are
        stacked and scanned.
        """
        kinds = []
        for i in range(self.num_layers):
            if self.family in ("ssm",):
                kinds.append("ssm")
            elif self.family == "hybrid":
                kinds.append("ssm")  # shared attention handled separately
            elif self.moe is not None:
                kinds.append("moe")
            elif self.local_global_period:
                # layer i is global iff (i+1) % period == 0 (gemma3 5:1)
                kinds.append(
                    "global" if (i + 1) % self.local_global_period == 0 else "local"
                )
            else:
                kinds.append("attn")
        return kinds

    # --- parameter counting (for roofline MODEL_FLOPS = 6*N*D) -----------
    def param_count(self, active_only: bool = False) -> int:
        dm, L = self.d_model, self.num_layers
        n = 0
        # embeddings (+ untied head)
        n += self.vocab_size * dm * (1 if self.tie_embeddings else 2)
        for kind in self.layer_kinds():
            if kind == "ssm":
                assert self.ssm is not None
                di = self.ssm.d_inner(dm)
                nh = self.ssm.n_heads(dm)
                g, s = self.ssm.n_groups, self.ssm.d_state
                # in_proj: x,z branches + B,C,dt ; out_proj
                n += dm * (2 * di + 2 * g * s + nh)
                n += di * dm
                n += self.ssm.conv_width * (di + 2 * g * s)  # conv1d
                n += 2 * nh  # A_log, D
            else:  # attention sublayer
                hd = self.head_dim
                n += dm * self.num_heads * hd  # wq
                n += 2 * dm * self.num_kv_heads * hd  # wk, wv
                n += self.num_heads * hd * dm  # wo
            # ffn sublayer
            if kind == "moe":
                assert self.moe is not None
                e = self.experts_counted(active_only)
                n += dm * self.moe.num_experts  # router (always full)
                n += e * 3 * dm * self.moe.d_ff
            elif kind != "ssm":
                n += (3 if self.gated_mlp else 2) * dm * self.d_ff
            n += 2 * dm  # two rmsnorm scales
        if self.shared_attn_period:
            # one shared transformer block (zamba-style), weights reused
            hd = self.head_dim
            n += dm * self.num_heads * hd + 2 * dm * self.num_kv_heads * hd
            n += self.num_heads * hd * dm + 3 * dm * self.d_ff + 2 * dm
        n += dm  # final norm
        return n

    def experts_counted(self, active_only: bool) -> int:
        assert self.moe is not None
        return self.moe.experts_per_token if active_only else self.moe.num_experts

    def model_flops_per_token(self) -> float:
        """6 * N (active) — the standard training-FLOPs estimate per token."""
        return 6.0 * self.param_count(active_only=True)


def smoke(cfg: ArchConfig) -> ArchConfig:
    """Reduced config of the same family for CPU smoke tests."""
    changes: dict = dict(
        num_layers=min(cfg.num_layers, 4),
        d_model=64,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        head_dim=16 if cfg.num_heads else 0,
        num_heads=4 if cfg.num_heads else 0,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_heads else 0,
        window=64 if cfg.window else 0,
        local_window=32 if cfg.local_window else 0,
        local_global_period=2 if cfg.local_global_period else 0,
        shared_attn_period=2 if cfg.shared_attn_period else 0,
    )
    if cfg.moe is not None:
        changes["moe"] = replace(
            cfg.moe, num_experts=4, experts_per_token=2, d_ff=32, dispatch_chunk=16
        )
    if cfg.ssm is not None:
        changes["ssm"] = replace(cfg.ssm, d_state=8, head_dim=16, chunk_size=8)
    if cfg.family == "hybrid":
        changes["num_layers"] = 4
    return replace(cfg, **changes)


def input_specs(cfg: ArchConfig, shape: ShapeSpec):
    """ShapeDtypeStruct stand-ins for every model input of this (arch, shape).

    Decode shapes additionally need the KV/SSM cache specs, which depend on
    model internals — those come from ``repro.models.model.cache_specs``; the
    launcher composes both.
    """
    import jax
    import jax.numpy as jnp

    b, s = shape.global_batch, shape.seq_len
    specs: dict = {}
    if shape.kind in ("train", "prefill"):
        if cfg.input_mode == "embeddings":
            specs["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
            specs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)  # labels
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    else:  # decode: one new token against a cache of seq_len
        specs["tokens"] = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        specs["pos"] = jax.ShapeDtypeStruct((), jnp.int32)
    return specs
