"""zamba2-2.7b — hybrid Mamba2 backbone + shared attention blocks.

[arXiv:2411.15242; hf]  54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64.  The attention block is *shared* (Zamba-style: one
set of transformer-block weights applied periodically along the depth).
"""
from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,  # MHA in the shared block
    d_ff=10240,
    vocab_size=32000,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, n_groups=1),
    shared_attn_period=6,  # shared transformer block every 6 mamba layers
    supports_long_context=True,  # SSM state is O(1); shared attn windowed at decode
    notes="Mamba2 + shared attn blocks [arXiv:2411.15242]",
)
