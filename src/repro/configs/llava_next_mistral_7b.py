"""llava-next-mistral-7b — VLM; mistral-7b backbone, anyres tiling frontend.

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]  32L d_model=4096 32H
(GQA kv=8) d_ff=14336 vocab=32000.  The vision frontend (CLIP + anyres tiling
+ projector) is a STUB: ``input_specs()`` provides precomputed patch
embeddings of shape (batch, seq, d_model).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    input_mode="embeddings",
    tie_embeddings=False,
    supports_long_context=False,  # full attention -> skip long_500k
    notes="anyres tiling frontend stubbed; backbone only",
)
