"""musicgen-large — audio; decoder-only transformer over EnCodec tokens.

[arXiv:2306.05284; hf]  48L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=2048.  The EnCodec tokenizer frontend is a STUB — inputs are
precomputed audio-token ids (single interleaved stream).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,  # MHA
    d_ff=8192,
    vocab_size=2048,
    tie_embeddings=False,
    supports_long_context=False,
    notes="decoder-only over EnCodec tokens; frontend stubbed",
)
