"""Config registry: one module per assigned architecture.

Usage::

    from repro.configs import get_config, list_archs, SHAPES
    cfg = get_config("mixtral-8x7b")
    small = get_config("mixtral-8x7b", smoke=True)
"""
from __future__ import annotations

from .base import ArchConfig, MoEConfig, SSMConfig, ShapeSpec, SHAPES, input_specs, smoke

from . import (  # noqa: E402
    zamba2_2p7b,
    llava_next_mistral_7b,
    gemma3_27b,
    qwen2p5_32b,
    granite_20b,
    internlm2_1p8b,
    mixtral_8x7b,
    qwen3_moe_235b,
    mamba2_1p3b,
    musicgen_large,
)

_MODULES = [
    zamba2_2p7b,
    llava_next_mistral_7b,
    gemma3_27b,
    qwen2p5_32b,
    granite_20b,
    internlm2_1p8b,
    mixtral_8x7b,
    qwen3_moe_235b,
    mamba2_1p3b,
    musicgen_large,
]

REGISTRY: dict[str, ArchConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}


def list_archs() -> list[str]:
    return sorted(REGISTRY)


def get_config(name: str, smoke: bool = False) -> ArchConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {list_archs()}")
    cfg = REGISTRY[name]
    if smoke:
        from .base import smoke as _smoke

        cfg = _smoke(cfg)
    return cfg


def cells(include_skipped: bool = True):
    """All 40 (arch, shape) cells; skipped cells flagged with reason."""
    out = []
    for name, cfg in sorted(REGISTRY.items()):
        for sname, sh in SHAPES.items():
            skip = ""
            if sname == "long_500k" and not cfg.supports_long_context:
                skip = "pure full-attention arch (see DESIGN.md §Arch-applicability)"
            if include_skipped or not skip:
                out.append((name, sname, skip))
    return out


__all__ = [
    "ArchConfig",
    "MoEConfig",
    "SSMConfig",
    "ShapeSpec",
    "SHAPES",
    "REGISTRY",
    "get_config",
    "list_archs",
    "input_specs",
    "smoke",
    "cells",
]
