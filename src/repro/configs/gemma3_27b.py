"""gemma3-27b — dense, 5:1 local:global attention, 128k context.

[hf:google/gemma-3-1b-pt; unverified]  62L d_model=5376 32H (GQA kv=16)
d_ff=21504 vocab=262144.  Every 6th layer is global attention; the other five
use a 1024-token sliding window.  head_dim=128 (public value).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-27b",
    family="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    local_window=1024,
    local_global_period=6,  # 5 local : 1 global
    rope_theta=1_000_000.0,
    supports_long_context=True,  # 52/62 layers windowed; decode attn is O(seq)
    notes="5:1 local:global; local layers window=1024",
)
