"""mamba2-1.3b — attention-free SSM (state-space duality / SSD).

[arXiv:2405.21060; unverified]  48L d_model=2048 (attn-free) d_ff=0
vocab=50280, ssm_state=128.
"""
from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,  # attention-free
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, n_groups=1),
    supports_long_context=True,  # O(1) state: the long_500k showcase
    notes="SSD (state-space duality)",
)
