"""Serving launcher: load (or init) params for an arch and run the batched
decode engine over a synthetic request stream.

    PYTHONPATH=src python -m repro.launch.serve --arch musicgen-large \
        --smoke --requests 8

``--mode continuous`` (default) uses per-slot admission with chunked
prefill; ``--mode wave`` runs the legacy lockstep baseline.

``--tp N`` shards each engine replica over N devices (tensor parallel —
mesh ``(1, N)``); ``--mesh-shape D,M`` (or ``P,D,M``) gives the full
device mesh, with the leading data axes sharding the decode slots and
splitting the paged KV pool into per-host sub-pools.  Sharded output is
bitwise-identical to the single-device engine — see docs/serving.md.

``--policy fcfs|priority|sjf|drf-fair`` picks the admission policy;
``--tenants N`` spreads the synthetic requests round-robin over N tenants
(tenant-0..tenant-N-1) so ``drf-fair`` has shares to balance.
``--temperature/--top-k/--top-p/--seed`` set the per-request sampling
params (temperature 0 = greedy).

``--cache paged`` swaps the dense per-slot KV stripes for the paged pool
(``--page-size``, ``--num-pages``, ``--page-policy pack|spread``,
``--no-prefix-cache``); admission then reserves only the pages a request
can touch and queues with backpressure when the pool is exhausted.

``--preempt`` enables Mesos-style slot revocation (checkpoint/restore;
``--victim-policy youngest-first|lowest-weight-share-first``), and
``--tenant-weights "tenant-0=3,tenant-1=1"`` maps SLO tiers onto
weighted-DRF shares.

``--speculate`` enables speculative multi-token decode (``--draft-k N``
tokens per slot per tick, ``--drafter`` from ``runtime.draft.DRAFTERS``);
the run reports the draft acceptance rate alongside throughput.

``--trace-out PATH`` records the full run as Chrome trace-event JSON
(open it at https://ui.perfetto.dev); ``--metrics-out PATH`` writes the
final metrics snapshot (``.prom`` = Prometheus text, else JSON);
``--flight-recorder N`` arms a bounded flight recorder whose last N
trace events + metrics are dumped to ``artifacts/`` automatically on a
replica fence.  See docs/observability.md.

``--replicas N`` (N > 1, or any ``--fault-schedule``) fronts N engine
replicas with a ``runtime.cluster.ClusterRouter``: requests are placed
via ``--router-policy pack|spread`` offers, lost replicas are detected by
heartbeat (``--miss-threshold``) and their in-flight requests recovered
by deterministic replay on the survivors (``--retry-budget`` replays per
request).  ``--fault-schedule`` injects reproducible chaos — either
explicit ``TICK:ACTION:REPLICA[:ARG[:TICKS]]`` entries (e.g.
``"8:kill:1,30:rejoin:1"``) or ``"seed=SEED"`` for a generated schedule;
the run asserts zero lost requests.

``--roles "prefill=N,decode=M[,unified=K]"`` splits the replica pool by
role (counts must sum to ``--replicas``): a ``runtime.disagg``
``DisaggRouter`` places fresh requests on prefill workers and hands
finished prefills' KV chains off to decode slots.  ``--autoscale-policy
queue-depth|slo-backlog`` attaches an elastic ``runtime.autoscale``
``Autoscaler`` (``--min-replicas``/``--max-replicas`` per-role bounds,
``--scale-cooldown`` anti-flap freeze); ``--max-replicas`` above a
role's initial count provisions cold DOWN spares for scale-up to
rejoin.  See docs/disagg_autoscale.md.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs
from repro.models import LM, RuntimeKnobs
from repro.runtime.autoscale import AUTOSCALE_POLICIES, Autoscaler
from repro.runtime.cluster import ROUTER_POLICIES, ClusterRouter
from repro.runtime.disagg import ROLES, DisaggRouter
from repro.runtime.draft import DRAFTERS
from repro.runtime.fault import ReplicaFaultInjector
from repro.runtime.scheduler import ADMISSION_POLICIES, VICTIM_POLICIES
from repro.runtime.serve import (Request, SamplingParams, ServeConfig,
                                 ServeEngine)
from repro.runtime.telemetry import Telemetry


def parse_tenant_weights(spec: str) -> dict:
    """``"gold=3,free=1"`` -> ``{"gold": 3.0, "free": 1.0}``.  Raises
    ``ValueError`` (an argparse usage error) on malformed entries or
    non-positive weights, so bad configs fail at the CLI instead of as
    an assertion deep inside scheduling."""
    out = {}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        name, eq, w = part.partition("=")
        name = name.strip()
        if not eq or not name:
            raise ValueError(f"expected TENANT=WEIGHT, got {part!r}")
        weight = float(w)  # ValueError on junk -> argparse usage error
        if weight <= 0:
            raise ValueError(f"weight for {name!r} must be > 0, "
                             f"got {weight}")
        out[name] = weight
    return out


def parse_roles(spec: str) -> dict:
    """``"prefill=2,decode=1"`` -> ``{"prefill": 2, "decode": 1}``.
    Raises ``ValueError`` (an argparse usage error) on unknown roles,
    duplicates, or non-positive counts."""
    out = {}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        role, eq, n = part.partition("=")
        role = role.strip()
        if not eq or role not in ROLES:
            raise ValueError(f"expected ROLE=COUNT with ROLE in "
                             f"{'/'.join(ROLES)}, got {part!r}")
        if role in out:
            raise ValueError(f"role {role!r} listed twice")
        count = int(n)  # ValueError on junk -> argparse usage error
        if count <= 0:
            raise ValueError(f"count for {role!r} must be > 0, "
                             f"got {count}")
        out[role] = count
    if not out:
        raise ValueError("empty --roles spec")
    return out


def parse_mesh_shape(spec: str) -> tuple:
    """``"2,4"`` -> ``(2, 4)``: a (data, model) or (pod, data, model)
    device-mesh shape.  Raises ``ValueError`` (an argparse usage error)
    on junk so bad shapes fail at the CLI, not at engine construction."""
    try:
        shape = tuple(int(p) for p in spec.split(","))
    except ValueError:
        raise ValueError(f"expected comma-separated ints, got {spec!r}")
    if len(shape) not in (2, 3) or any(s < 1 for s in shape):
        raise ValueError(f"mesh shape must be D,M or P,D,M of positive "
                         f"ints, got {spec!r}")
    return shape


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--mode", choices=("continuous", "wave"),
                    default="continuous")
    ap.add_argument("--tp", type=int, default=1,
                    help="shard each replica over N devices (tensor "
                         "parallel; shorthand for --mesh-shape 1,N)")
    ap.add_argument("--mesh-shape", type=parse_mesh_shape, default=None,
                    metavar="D,M",
                    help="per-replica device mesh 'data,model' (or "
                         "'pod,data,model'); data axes shard the decode "
                         "slots + KV page pool across hosts")
    ap.add_argument("--prefill-chunk", type=int, default=32)
    ap.add_argument("--policy", choices=sorted(ADMISSION_POLICIES),
                    default="fcfs", help="admission policy")
    ap.add_argument("--tenants", type=int, default=1,
                    help="spread requests over N tenants (round-robin)")
    ap.add_argument("--tenant-weights", type=parse_tenant_weights,
                    default=None, metavar="T=W,...",
                    help="weighted-DRF SLO tiers, e.g. 'tenant-0=3,"
                         "tenant-1=1' (unlisted tenants weigh 1)")
    ap.add_argument("--preempt", action="store_true",
                    help="enable slot preemption (checkpoint/restore)")
    ap.add_argument("--victim-policy", choices=sorted(VICTIM_POLICIES),
                    default="youngest-first")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy)")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=None,
                    help="per-request sampling seed (default: request id)")
    ap.add_argument("--speculate", action="store_true",
                    help="speculative multi-token decode (see --draft-k)")
    ap.add_argument("--draft-k", type=int, default=3,
                    help="draft tokens per slot per tick (with --speculate)")
    ap.add_argument("--drafter", choices=sorted(DRAFTERS), default="ngram")
    ap.add_argument("--cache", choices=("dense", "paged"), default="dense")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=None,
                    help="pool size (default: dense-equivalent capacity)")
    ap.add_argument("--page-policy", choices=("pack", "spread"),
                    default="pack")
    ap.add_argument("--kv-dtype", choices=("", "int8", "fp8"), default="",
                    help="quantize the paged KV pool (per-token scales, "
                         "dequantized in-kernel); needs --cache paged")
    ap.add_argument("--no-prefix-cache", action="store_true")
    ap.add_argument("--replicas", type=int, default=1,
                    help="front N engine replicas with a ClusterRouter")
    ap.add_argument("--router-policy", choices=sorted(ROUTER_POLICIES),
                    default="spread",
                    help="replica placement policy (with --replicas > 1)")
    ap.add_argument("--roles", type=parse_roles, default=None,
                    metavar="ROLE=N,...",
                    help="disaggregate the pool: 'prefill=N,decode=M"
                         "[,unified=K]' (counts must sum to --replicas)")
    ap.add_argument("--autoscale-policy",
                    choices=sorted(AUTOSCALE_POLICIES), default=None,
                    help="attach an elastic autoscaler (needs --roles)")
    ap.add_argument("--min-replicas", type=int, default=None,
                    help="per-role floor for scale-down (default 1)")
    ap.add_argument("--max-replicas", type=int, default=None,
                    help="per-role ceiling; above a role's initial count "
                         "this provisions cold spares for scale-up")
    ap.add_argument("--scale-cooldown", type=int, default=None,
                    help="ticks a role is frozen after a scale event "
                         "(default 10)")
    ap.add_argument("--fault-schedule", default=None,
                    metavar="T:ACT:R[,...]|seed=N",
                    help="inject chaos: 'TICK:ACTION:REPLICA[:ARG[:TICKS]]"
                         ",...' or 'seed=SEED' (forces the router path)")
    ap.add_argument("--miss-threshold", type=int, default=3,
                    help="heartbeat misses before a replica is LOST")
    ap.add_argument("--retry-budget", type=int, default=3,
                    help="recovery replays per request before it fails")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the run's Chrome trace-event JSON here "
                         "(Perfetto-viewable)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the final metrics snapshot here "
                         "(.prom = Prometheus text, else JSON)")
    ap.add_argument("--flight-recorder", type=int, default=0, metavar="N",
                    help="arm the flight recorder: dump the last N trace "
                         "events + metrics to artifacts/ on replica fence")
    args = ap.parse_args()
    if args.tp < 1:
        ap.error(f"--tp must be >= 1 (got {args.tp})")
    if args.tp > 1 and args.mesh_shape is not None:
        ap.error("--tp is shorthand for --mesh-shape 1,N — pass one "
                 "or the other")
    mesh_shape = (args.mesh_shape if args.mesh_shape is not None
                  else ((1, args.tp) if args.tp > 1 else None))
    if mesh_shape is not None and args.mode != "continuous":
        ap.error(f"--mesh-shape/--tp need --mode continuous "
                 f"(got {args.mode!r})")
    if args.speculate and args.draft_k <= 0:
        ap.error(f"--speculate needs --draft-k >= 1 (got {args.draft_k})")
    if args.kv_dtype and args.cache != "paged":
        ap.error(f"--kv-dtype {args.kv_dtype} needs --cache paged")
    if args.replicas < 1:
        ap.error(f"--replicas must be >= 1 (got {args.replicas})")
    if args.roles is not None:
        total = sum(args.roles.values())
        if total != args.replicas:
            ap.error(f"--roles counts sum to {total} but --replicas is "
                     f"{args.replicas} — pass --replicas {total}")
        have = set(args.roles)
        if not have & {"prefill", "unified"}:
            ap.error("--roles needs a prefill-capable role "
                     "(prefill or unified)")
        if not have & {"decode", "unified"}:
            ap.error("--roles needs a decode-capable role "
                     "(decode or unified)")
        if args.mode != "continuous":
            ap.error(f"--roles needs --mode continuous "
                     f"(got {args.mode!r})")
    elif args.autoscale_policy is not None:
        ap.error("--autoscale-policy needs --roles")
    if args.autoscale_policy is None:
        for flag, val in (("--min-replicas", args.min_replicas),
                          ("--max-replicas", args.max_replicas),
                          ("--scale-cooldown", args.scale_cooldown)):
            if val is not None:
                ap.error(f"{flag} needs --autoscale-policy")
    else:
        min_r = 1 if args.min_replicas is None else args.min_replicas
        if min_r < 1:
            ap.error(f"--min-replicas must be >= 1 (got {min_r})")
        if min_r > min(args.roles.values()):
            ap.error(f"--min-replicas {min_r} exceeds the smallest "
                     f"initial role count {min(args.roles.values())}")
        if (args.max_replicas is not None
                and args.max_replicas < max(args.roles.values())):
            ap.error(f"--max-replicas {args.max_replicas} is below the "
                     f"largest initial role count "
                     f"{max(args.roles.values())}")
        if args.scale_cooldown is not None and args.scale_cooldown < 0:
            ap.error(f"--scale-cooldown must be >= 0 "
                     f"(got {args.scale_cooldown})")

    cfg = get_config(args.arch, smoke=args.smoke)
    model = LM(cfg, RuntimeKnobs(cache_dtype=jnp.float32))
    params = model.init(jax.random.PRNGKey(0))
    serve_cfg = ServeConfig(
        batch_slots=args.slots, max_len=args.max_len, mode=args.mode,
        prefill_chunk=args.prefill_chunk, cache=args.cache,
        page_size=args.page_size, num_pages=args.num_pages,
        page_policy=args.page_policy, kv_dtype=args.kv_dtype,
        prefix_cache=not args.no_prefix_cache, policy=args.policy,
        tenant_weights=args.tenant_weights, preempt=args.preempt,
        victim_policy=args.victim_policy,
        draft_k=args.draft_k if args.speculate else 0,
        drafter=args.drafter, mesh_shape=mesh_shape)

    tm = Telemetry(trace=bool(args.trace_out) or args.flight_recorder > 0,
                   flight=args.flight_recorder, flight_dir="artifacts")

    # replicas share model/params; compiled steps dedupe via runtime.steps
    def make_engine(rid):
        return ServeEngine(model, params, serve_cfg)

    router = None
    if args.roles is not None:
        # role list rid-by-rid; indices past a role's initial count are
        # cold DOWN spares the autoscaler can rejoin under load
        cap = (args.max_replicas if args.autoscale_policy
               and args.max_replicas is not None else None)
        role_list, start_down = [], []
        for role, count in args.roles.items():
            for i in range(max(count, cap or 0)):
                if i >= count:
                    start_down.append(len(role_list))
                role_list.append(role)

        def make_role_engine(rid):
            return ServeEngine(model, params, dataclasses.replace(
                serve_cfg, role=role_list[rid]))

        injector = (ReplicaFaultInjector.parse(args.fault_schedule)
                    if args.fault_schedule else None)
        router = DisaggRouter(make_role_engine, len(role_list),
                              roles=role_list, start_down=start_down,
                              policy=args.router_policy,
                              miss_threshold=args.miss_threshold,
                              retry_budget=args.retry_budget,
                              tenant_weights=args.tenant_weights or {},
                              injector=injector, telemetry=tm)
        if args.autoscale_policy:
            router.autoscaler = Autoscaler(
                router, args.autoscale_policy,
                min_replicas=(1 if args.min_replicas is None
                              else args.min_replicas),
                max_replicas=cap,
                cooldown=(10 if args.scale_cooldown is None
                          else args.scale_cooldown),
                telemetry=tm)
    elif args.replicas > 1 or args.fault_schedule:
        injector = (ReplicaFaultInjector.parse(args.fault_schedule)
                    if args.fault_schedule else None)
        router = ClusterRouter(make_engine, args.replicas,
                               policy=args.router_policy,
                               miss_threshold=args.miss_threshold,
                               retry_budget=args.retry_budget,
                               tenant_weights=args.tenant_weights or {},
                               injector=injector, telemetry=tm)
    else:
        engine = make_engine(0)
        engine.bind_telemetry(tm, replica=0)
    sampling = SamplingParams(temperature=args.temperature,
                              top_k=args.top_k, top_p=args.top_p,
                              seed=args.seed)
    rng = np.random.default_rng(0)
    handles = []
    front = router if router is not None else engine
    for i in range(args.requests):
        plen = int(rng.integers(1, 6))
        handles.append(front.submit(Request(
            i, rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32),
            max_new_tokens=args.max_new, sampling=sampling,
            tenant=f"tenant-{i % max(args.tenants, 1)}",
            priority=i % 3)))
    t0 = time.time()
    done = front.run()
    dt = time.time() - t0
    toks = sum(len(r.output) for r in done)
    ttft = [h.metrics().get("ttft_s") for h in handles]
    ttft = [t for t in ttft if t is not None]
    mesh_note = (f" mesh={'x'.join(map(str, mesh_shape))}"
                 if mesh_shape else "")
    print(f"arch={args.arch} mode={args.mode} cache={args.cache} "
          f"policy={args.policy}{mesh_note} served {len(done)} requests, "
          f"{toks} tokens in {dt:.1f}s ({toks / max(dt, 1e-9):.1f} tok/s)")
    if router is not None:
        st = router.stats()
        print(f"cluster: replicas={args.replicas} "
              f"router-policy={args.router_policy} ticks={st['ticks']} "
              f"lost={st['replicas_lost']} recoveries={st['recoveries']} "
              f"brownout-ticks={st['brownout_ticks']}")
        lost = [r.req_id for r in done if r.finish_reason == "failed"]
        assert not lost, f"requests lost despite recovery: {lost}"
        if args.roles is not None:
            print(f"disagg: roles={{{','.join(f'{r}={n}' for r, n in args.roles.items())}}} "
                  f"handoffs={st['handoffs_done']} "
                  f"backpressure={st['handoff_backpressure']} "
                  f"in-transit={st['handoffs_in_transit']}")
        if getattr(router, "autoscaler", None) is not None:
            asst = router.autoscaler.stats()
            print(f"autoscale: policy={asst['policy']} "
                  f"ups={asst['scale_ups']} downs={asst['scale_downs']} "
                  f"retiring={asst['retiring']}")
    if args.preempt and router is None:
        print(f"preemptions: {engine.scheduler.preempted_total} "
              f"(requests preempted >=1x: "
              f"{sum(1 for r in done if r.preempt_count)})")
    if args.speculate and router is None:
        st = engine.spec_stats()
        print(f"speculative: draft_k={st['draft_k']} "
              f"acceptance {st['acceptance_rate']:.2f} "
              f"({st['accepted']}/{st['proposed']}), "
              f"{st['tokens_per_tick']:.2f} tok/tick")
    if ttft:
        print(f"ttft p50 {np.percentile(ttft, 50) * 1e3:.0f}ms / "
              f"p99 {np.percentile(ttft, 99) * 1e3:.0f}ms "
              f"(finish reasons: "
              f"{sorted({r.finish_reason for r in done})})")
    if args.cache == "paged" and router is None:
        print(f"kv stats: {engine.kv_stats()}")
    if args.trace_out:
        path = tm.write_trace(args.trace_out)
        tr = tm.trace
        print(f"trace: {tr.total} events ({tr.dropped} dropped) -> {path} "
              f"(open at https://ui.perfetto.dev)")
    if args.metrics_out:
        print(f"metrics: {len(tm.registry.names())} series -> "
              f"{tm.write_metrics(args.metrics_out)}")
    if tm.flight_dumps:
        print(f"flight-recorder dumps: {tm.flight_dumps}")


if __name__ == "__main__":
    main()
