"""Serving launcher: load (or init) params for an arch and run the batched
decode engine over a synthetic request stream.

    PYTHONPATH=src python -m repro.launch.serve --arch musicgen-large \
        --smoke --requests 8

``--mode continuous`` (default) uses per-slot admission with chunked
prefill; ``--mode wave`` runs the legacy lockstep baseline.

``--cache paged`` swaps the dense per-slot KV stripes for the paged pool
(``--page-size``, ``--num-pages``, ``--page-policy pack|spread``,
``--no-prefix-cache``); admission then reserves only the pages a request
can touch and queues with backpressure when the pool is exhausted.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs
from repro.models import LM, RuntimeKnobs
from repro.runtime.serve import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--mode", choices=("continuous", "wave"),
                    default="continuous")
    ap.add_argument("--prefill-chunk", type=int, default=32)
    ap.add_argument("--cache", choices=("dense", "paged"), default="dense")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=None,
                    help="pool size (default: dense-equivalent capacity)")
    ap.add_argument("--page-policy", choices=("pack", "spread"),
                    default="pack")
    ap.add_argument("--no-prefix-cache", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    model = LM(cfg, RuntimeKnobs(cache_dtype=jnp.float32))
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, batch_slots=args.slots,
                         max_len=args.max_len, mode=args.mode,
                         prefill_chunk=args.prefill_chunk, cache=args.cache,
                         page_size=args.page_size, num_pages=args.num_pages,
                         page_policy=args.page_policy,
                         prefix_cache=not args.no_prefix_cache)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        plen = int(rng.integers(1, 6))
        engine.submit(Request(i, rng.integers(
            0, cfg.vocab_size, size=plen).astype(np.int32),
            max_new_tokens=args.max_new))
    t0 = time.time()
    done = engine.run()
    dt = time.time() - t0
    toks = sum(len(r.output) for r in done)
    print(f"arch={args.arch} mode={args.mode} cache={args.cache} served "
          f"{len(done)} requests, {toks} tokens in {dt:.1f}s "
          f"({toks / max(dt, 1e-9):.1f} tok/s)")
    if args.cache == "paged":
        print(f"kv stats: {engine.kv_stats()}")


if __name__ == "__main__":
    main()
