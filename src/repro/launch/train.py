"""Training launcher — the per-job driver Scylla's Task-0 analogue runs.

On real hardware every host runs this same script; jax.distributed wires the
gang together and the mesh spans the placement chosen by the scheduler.  On
this CPU container it runs reduced configs on a 1-device mesh (use
``launch/dryrun.py`` for the full-scale compile-only path).

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --smoke --steps 50
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.data import MarkovSynthetic
from repro.models import LM, RuntimeKnobs
from repro.optim import AdamWConfig
from repro.runtime.train import TrainConfig, Trainer
from repro.sharding import make_shard_fn
from repro.launch.mesh import make_job_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--bf16", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    n_dev = len(jax.devices())
    mesh = make_job_mesh(n_dev) if n_dev > 1 else None
    knobs = RuntimeKnobs(
        param_dtype=jnp.bfloat16 if args.bf16 else jnp.float32,
        compute_dtype=jnp.bfloat16 if args.bf16 else jnp.float32,
        cache_dtype=jnp.float32,
        q_chunk=min(128, args.seq),
        ce_chunk=min(256, args.seq),
        shard_fn=make_shard_fn(mesh, cfg) if mesh else (lambda n, x: x),
    )
    model = LM(cfg, knobs)
    print(f"arch={args.arch} smoke={args.smoke} "
          f"params={cfg.param_count() / 1e6:.1f}M devices={n_dev}")
    data = MarkovSynthetic(vocab_size=cfg.vocab_size, seq_len=args.seq,
                           global_batch=args.batch, seed=0, noise=0.1)
    tcfg = TrainConfig(
        steps=args.steps, grad_accum=args.grad_accum,
        checkpoint_every=args.ckpt_every,
        checkpoint_dir=args.ckpt_dir or None, log_every=10,
        opt=AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                        total_steps=args.steps))
    trainer = Trainer(model, data, tcfg, mesh=mesh)
    out = trainer.run()
    for h in out["history"]:
        print(f"step {h['step']:5d}  loss {h['loss']:.4f}  "
              f"grad_norm {h['grad_norm']:.2f}")


if __name__ == "__main__":
    main()
