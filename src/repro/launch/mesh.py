"""Production meshes + scheduler-driven submeshes.

``make_production_mesh`` builds the assigned target meshes: 16x16
("data","model") for one v5e pod (256 chips), and 2x16x16
("pod","data","model") for the 2-pod / 512-chip multi-pod dry-run.

``submesh_for_placement`` turns a Scylla placement (agent->chips) into a
Mesh over the corresponding devices — Spread puts the "pod" axis across
pods (DP over DCN), MinHost yields a single-pod mesh.  Functions, not
module constants: importing this module never touches jax device state.
"""
from __future__ import annotations

import math

import jax
import numpy as np

from repro.compat import AxisType, HAS_AXIS_TYPE
from repro.compat import make_mesh as compat_make_mesh


def _mesh(device_arr, axes):
    """``Mesh`` over an explicit device array, Auto axis types where the
    jax lineage has them (0.4.x predates the enum — plain Mesh there)."""
    from jax.sharding import Mesh
    if HAS_AXIS_TYPE:
        try:
            return Mesh(device_arr, axes,
                        axis_types=(AxisType.Auto,) * len(axes))
        except TypeError:
            pass
    return Mesh(device_arr, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes,
                            axis_types=(AxisType.Auto,) * len(axes))


def make_serve_mesh(shape):
    """Mesh for one sharded ``ServeEngine`` replica.

    ``shape`` is ``(data, model)`` or ``(pod, data, model)`` — the same
    axis names the serving shardings (``sharding/rules.py``'s
    ``serve_param_shardings`` / ``ServeShardFn``) key on: "model" carries
    tensor parallelism over heads/ff, the leading axes carry the decode
    slots and KV page pool ("data" hosts in the Scylla sense).  Raises if
    the product exceeds the visible device count, so a misconfigured
    ``--mesh-shape`` fails at engine construction, not first dispatch.
    """
    shape = tuple(int(s) for s in shape)
    if len(shape) not in (2, 3) or any(s < 1 for s in shape):
        raise ValueError(f"mesh shape must be (data, model) or "
                         f"(pod, data, model) of positive ints: {shape}")
    n = math.prod(shape)
    if n > len(jax.devices()):
        raise ValueError(f"mesh shape {shape} needs {n} devices, "
                         f"{len(jax.devices())} visible")
    axes = ("pod", "data", "model") if len(shape) == 3 else ("data", "model")
    arr = np.array(jax.devices()[:n]).reshape(shape)
    return _mesh(arr, axes)


def make_job_mesh(n_chips: int, *, n_pods: int = 1, max_model: int = 16):
    """Mesh for a gang of ``n_chips`` (scheduler jobs, examples, tests).

    model axis = largest power-of-2 divisor up to ``max_model``; remaining
    chips become data (and pod, when the placement spans pods).
    """
    assert n_chips % n_pods == 0
    per_pod = n_chips // n_pods
    model = 1
    while model * 2 <= max_model and per_pod % (model * 2) == 0:
        model *= 2
    data = per_pod // model
    if n_pods > 1:
        return compat_make_mesh((n_pods, data, model),
                                ("pod", "data", "model"),
                                axis_types=(AxisType.Auto,) * 3)
    return compat_make_mesh((data, model), ("data", "model"),
                            axis_types=(AxisType.Auto,) * 2)


def submesh_for_placement(placement, cluster, devices=None, *,
                          chips_per_host: int = 4, max_model: int = 16):
    """Build a Mesh from a Scylla placement on an actual device list."""
    devices = list(devices if devices is not None else jax.devices())
    pods = sorted({cluster.hosts[a].agent.pod_id
                   for a in placement.assignment})
    n_chips = sum(placement.assignment.values())
    n_pods = len(pods)
    if n_chips % n_pods != 0:
        n_pods = 1  # ragged across pods: treat as flat
    assert len(devices) >= n_chips, "not enough devices for the gang"
    per_pod = n_chips // n_pods
    model = 1
    while model * 2 <= max_model and per_pod % (model * 2) == 0:
        model *= 2
    data = per_pod // model
    arr = np.array(devices[:n_chips])
    if n_pods > 1:
        arr = arr.reshape(n_pods, data, model)
        return _mesh(arr, ("pod", "data", "model"))
    return _mesh(arr.reshape(data, model), ("data", "model"))
