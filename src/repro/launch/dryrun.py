import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract the roofline terms.

The two lines above MUST stay the first statements in this file: jax locks
the device count at first backend init, and the dry-run needs 512 host
placeholder devices to build the 2x16x16 production mesh.  Nothing here
allocates device memory — inputs are ShapeDtypeStructs and compilation is
ahead-of-time.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch internlm2-1.8b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
        --out artifacts/roofline.json
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config, input_specs, list_archs
from repro.core import hw
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analyze_hlo, model_flops, roofline
from repro.models import LM, RuntimeKnobs
from repro.optim import AdamWConfig
from repro.runtime import (make_prefill_step, make_serve_step,
                           make_train_step)
from repro.runtime.steps import train_state_specs
from repro.sharding import (batch_shardings, cache_shardings, grad_shardings,
                            make_shard_fn, opt_state_shardings,
                            param_shardings)

# <25B: ZeRO-1 (params replicated over data, opt sharded) — avoids the
# per-microbatch FSDP all-gather tax.  >=25B: FSDP/ZeRO-3 — the scan-VJP
# gradient buffer lives at the *param* sharding, so only weight sharding
# keeps fp32 grads under 16 GB/chip (measured; see EXPERIMENTS.md §Dry-run).
FSDP_THRESHOLD = 25e9

# Per-arch knob overrides for the baseline dry-run, memory-driven (see
# EXPERIMENTS.md §Dry-run).  qwen2.5's 40 heads don't divide the 16-way
# model axis, so its attention activations are per-device fat — smaller
# microbatches + tighter attention/CE chunks keep it under 16 GB.
ARCH_OVERRIDES = {
    "qwen2.5-32b": {"grad_accum": 16, "q_chunk": 256, "ce_chunk": 512},
}


def _cast_specs(specs, dtype):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype)
        if s.dtype == jnp.float32 else s, specs)


def _dp_size(mesh):
    out = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            out *= mesh.shape[a]
    return out


def build_knobs(cfg, mesh, args) -> RuntimeKnobs:
    return RuntimeKnobs(
        param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
        cache_dtype=jnp.bfloat16, q_chunk=args.q_chunk,
        ce_chunk=args.ce_chunk, remat=not args.no_remat,
        causal_skip=getattr(args, "causal_skip", False),
        shard_fn=make_shard_fn(mesh, cfg, sp=getattr(args, "sp", False),
                               layout=getattr(args, "layout", "tp")))


def lower_cell(arch: str, shape_name: str, mesh, args):
    """Returns (lowered, meta) for one (arch, shape, mesh) cell."""
    import argparse as _ap

    cfg = get_config(arch)
    sh = SHAPES[shape_name]
    ov = ARCH_OVERRIDES.get(arch, {})
    if ov and getattr(args, "tag", "baseline") == "baseline":
        d = vars(args).copy()
        d.update(ov)
        args = _ap.Namespace(**d)
    layout = getattr(args, "layout", "tp")
    knobs = build_knobs(cfg, mesh, args)
    model = LM(cfg, knobs)
    fsdp = cfg.param_count() > FSDP_THRESHOLD
    pspecs = model.param_specs()
    bspecs = input_specs(cfg, sh)
    b_sh = batch_shardings(mesh, bspecs, layout=layout)
    meta = {"fsdp": fsdp, "grad_accum": 1}

    huge = cfg.param_count() > 100e9
    if sh.kind == "train":
        grad_accum = args.grad_accum
        if grad_accum <= 0:
            grad_accum = (32 if huge else 8) if sh.global_batch >= 64 else 1
        grad_accum = min(grad_accum, sh.global_batch // _dp_size(mesh)) or 1
        meta["grad_accum"] = grad_accum
        # >100B params: bf16 Adam moments + bf16 grad accumulators
        # (optimizer/grad HBM halves; update math stays fp32 — DESIGN.md §5)
        moments_dtype = jnp.bfloat16 if huge else jnp.float32
        accum_dtype = (jnp.bfloat16 if (huge or getattr(args, "accum_bf16",
                                                        False))
                       else jnp.float32)
        meta["moments_dtype"] = str(jnp.dtype(moments_dtype))
        state_specs = train_state_specs(model, moments_dtype)
        p_sh = param_shardings(mesh, cfg, state_specs["params"], fsdp=fsdp,
                               layout=layout)
        o_leaf = opt_state_shardings(mesh, cfg, state_specs["params"],
                                     fsdp=fsdp, layout=layout)
        state_sh = {"params": p_sh,
                    "opt": {"master": o_leaf, "mu": o_leaf, "nu": o_leaf,
                            "step": NamedSharding(mesh, P())}}
        g_sh = grad_shardings(mesh, cfg, state_specs["params"])
        step = make_train_step(model, AdamWConfig(), grad_accum,
                               accum_dtype=accum_dtype,
                               grad_shardings=g_sh)  # ZeRO-2 over data only
        jitted = jax.jit(step, in_shardings=(state_sh, b_sh),
                         out_shardings=(state_sh, None), donate_argnums=(0,))
        with mesh:
            lowered = jitted.lower(state_specs, bspecs)
        return lowered, meta

    p_specs_bf16 = _cast_specs(pspecs, jnp.bfloat16)
    p_sh = param_shardings(mesh, cfg, p_specs_bf16, fsdp=fsdp, layout=layout)
    if sh.kind == "prefill":
        step = make_prefill_step(model)
        jitted = jax.jit(step, in_shardings=(p_sh, b_sh))
        with mesh:
            lowered = jitted.lower(p_specs_bf16, bspecs)
        return lowered, meta

    # decode: one token against a seq_len cache
    c_specs = model.cache_specs(sh.global_batch, sh.seq_len)
    c_sh = cache_shardings(mesh, c_specs)
    step = make_serve_step(model)
    jitted = jax.jit(step, in_shardings=(p_sh, c_sh, b_sh["tokens"],
                                         b_sh["pos"]),
                     out_shardings=(None, c_sh), donate_argnums=(1,))
    with mesh:
        lowered = jitted.lower(p_specs_bf16, c_specs, bspecs["tokens"],
                               bspecs["pos"])
    return lowered, meta


def run_cell(arch: str, shape_name: str, mesh_kind: str, args) -> dict:
    cfg = get_config(arch)
    sh = SHAPES[shape_name]
    row = {"arch": arch, "shape": shape_name, "mesh": mesh_kind}
    if shape_name == "long_500k" and not cfg.supports_long_context:
        row["skipped"] = "pure full-attention arch (DESIGN.md §Arch-applicability)"
        return row
    multi = mesh_kind == "multipod"
    mesh = make_production_mesh(multi_pod=multi)
    n_dev = mesh.size
    t0 = time.time()
    lowered, meta = lower_cell(arch, shape_name, mesh, args)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    ca = compiled.cost_analysis()
    ma = compiled.memory_analysis()
    hlo = analyze_hlo(compiled.as_text(),
                      pod_size=(n_dev // mesh.shape.get("pod", 1))
                      if multi else 0)
    t3 = time.time()
    # analyzer numbers are trip-count aware (XLA cost_analysis visits while
    # bodies once — see roofline.py); raw XLA numbers kept for reference
    flops_dev = hlo["flops"]
    bytes_dev = hlo["hbm_bytes"]
    terms = roofline(flops_dev, bytes_dev, hlo, n_devices=n_dev,
                     n_pods=mesh.shape.get("pod", 1))
    mf = model_flops(cfg, sh)
    # donated inputs alias outputs -> count max(args, out), not the sum
    hbm_per_dev = (max(ma.argument_size_in_bytes, ma.output_size_in_bytes)
                   + ma.temp_size_in_bytes)
    row.update(
        n_devices=n_dev, lower_s=round(t1 - t0, 1),
        compile_s=round(t2 - t1, 1), analyze_s=round(t3 - t2, 1),
        grad_accum=meta["grad_accum"], fsdp=meta["fsdp"],
        hlo_flops_per_dev=flops_dev, hlo_bytes_per_dev=bytes_dev,
        hlo_flops=flops_dev * n_dev, hlo_bytes=bytes_dev * n_dev,
        xla_cost_flops_per_dev=float(ca.get("flops", 0.0)),
        xla_cost_bytes_per_dev=float(ca.get("bytes accessed", 0.0)),
        collective_bytes=hlo["collective_bytes"] * n_dev,
        collective_bytes_per_dev=hlo["collective_bytes"],
        ici_bytes_per_dev=hlo["ici_bytes"],
        dcn_bytes_per_dev=hlo["dcn_bytes"],
        n_collectives=hlo["n_collectives"],
        per_kind={k: v for k, v in hlo["per_kind"].items() if v},
        model_flops=mf,
        useful_flops_ratio=round(mf / max(flops_dev * n_dev, 1.0), 4),
        mem_args_bytes=ma.argument_size_in_bytes,
        mem_temp_bytes=ma.temp_size_in_bytes,
        mem_out_bytes=ma.output_size_in_bytes,
        hbm_per_dev_gb=round(hbm_per_dev / 1e9, 3),
        fits_hbm=bool(hbm_per_dev <= hw.HBM_PER_CHIP),
        **{k: (round(v, 6) if isinstance(v, float) else v)
           for k, v in terms.items()},
    )
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/roofline.json")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--q-chunk", type=int, default=512)
    ap.add_argument("--ce-chunk", type=int, default=1024)
    ap.add_argument("--grad-accum", type=int, default=-1)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--sp", action="store_true",
                    help="sequence-parallel residual stream (Perf H1)")
    ap.add_argument("--layout", default="tp", choices=["tp", "dp"],
                    help="dp = replicate weights, all axes to batch (H3)")
    ap.add_argument("--causal-skip", action="store_true",
                    help="recursive causal block-skip attention (H2)")
    ap.add_argument("--accum-bf16", action="store_true",
                    help="bf16 gradient accumulators (H3 iter 2)")
    ap.add_argument("--tag", default="baseline")
    args = ap.parse_args()

    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multipod"] if args.mesh == "both" else [args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    rows = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            rows = json.load(f)
    selected = {(a, s, m, args.tag) for a in archs for s in shapes
                for m in meshes}
    if args.force:  # re-run ONLY the selected cells; keep everything else
        rows = [r for r in rows
                if (r["arch"], r["shape"], r["mesh"],
                    r.get("tag", "baseline")) not in selected]
    done = {(r["arch"], r["shape"], r["mesh"], r.get("tag", "baseline"))
            for r in rows}

    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                key = (arch, shape, mesh_kind, args.tag)
                if key in done:
                    continue
                try:
                    row = run_cell(arch, shape, mesh_kind, args)
                except Exception as e:  # record the failure, keep going
                    row = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                           "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                row["tag"] = args.tag
                rows.append(row)
                with open(args.out, "w") as f:
                    json.dump(rows, f, indent=1, default=str)
                status = ("SKIP" if row.get("skipped") else
                          ("FAIL" if row.get("error") else "ok"))
                extra = ""
                if status == "ok":
                    extra = (f"flops/dev={row['hlo_flops_per_dev']:.3e} "
                             f"bneck={row['bottleneck']} "
                             f"hbm={row['hbm_per_dev_gb']}GB "
                             f"compile={row['compile_s']}s")
                elif status == "FAIL":
                    extra = row["error"][:160]
                print(f"[{status}] {arch} x {shape} x {mesh_kind} {extra}",
                      flush=True)


if __name__ == "__main__":
    main()
