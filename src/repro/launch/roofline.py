"""Roofline analysis from the compiled dry-run artifact.

XLA's ``cost_analysis()`` visits every ``while`` body ONCE, so for
scan-over-layers programs it under-counts FLOPs/bytes by ~L x grad_accum
(verified empirically).  We therefore analyze the partitioned HLO text
ourselves, trip-count aware:

* Call-graph multipliers: ``while`` ops carry
  ``backend_config={"known_trip_count":{"n":...}}`` — exact scan lengths;
  fusions/calls propagate their caller's multiplier.
* FLOPs: 2 * out_elems * contracted_elems for every ``dot``; convolutions
  approximated (they are <0.1% here — mamba depthwise conv).
* HBM bytes: per top-level op, unique operand bytes + output bytes — i.e.
  traffic across *fusion boundaries*, XLA's own model of HBM touches.
* Collective bytes: output-shape bytes per all-reduce / all-gather /
  reduce-scatter / all-to-all / collective-permute, classified ICI vs DCN
  by whether replica groups cross a pod boundary.

Everything is **per device** (the module is the SPMD-partitioned one).

Terms (seconds), per DESIGN.md hardware constants:
    compute    = flops_per_dev / 197e12
    memory     = hbm_bytes_per_dev / 819e9
    collective = ici_bytes_per_dev / 50e9 + dcn_bytes_per_host / 12.5e9
"""
from __future__ import annotations

import re
from dataclasses import dataclass

import numpy as np

from repro.core import hw

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_ELEM_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# tuple shapes may contain /*index=N*/ comments, hence [^()] not [^=]
_OP_RE = re.compile(
    r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\([^()]*\)|\S+)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")


def _shape_bytes(shape_str: str) -> float:
    total = 0.0
    for m in _SHAPE_ELEM_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_str: str):
    """dims of the first array shape in the string (non-tuple)."""
    m = _SHAPE_ELEM_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


def _crosses_pod(line: str, pod_size: int) -> bool:
    if pod_size <= 0:
        return False
    m = re.search(r"replica_groups=\{(.*?)\}\}", line)
    if m:
        for grp in re.findall(r"\{([\d,\s]+)\}", m.group(1) + "}"):
            ids = [int(x) for x in grp.replace(" ", "").split(",") if x]
            if len({i // pod_size for i in ids}) > 1:
                return True
        return False
    m = re.search(
        r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?",
        line)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        arr = np.arange(int(np.prod(dims)))
        if len(dims) > 1:
            arr = arr.reshape(dims)
            if m.group(4):
                arr = arr.transpose([int(x) for x in m.group(4).split(",")])
        arr = arr.reshape(g, s)
        for row in arr:
            if len({int(i) // pod_size for i in row}) > 1:
                return True
    return False


# ops that don't move HBM data themselves
_NO_TRAFFIC = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "while", "conditional", "call", "after-all", "add-dependency",
    "partition-id", "replica-id", "iota", "custom-call", "rng-bit-generator",
}


def analyze_hlo(hlo_text: str, *, pod_size: int = 0) -> dict:
    # ---- split into computations ----------------------------------------
    comps: dict[str, list[str]] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        if line and not line[0].isspace() and "{" in line:
            m = _COMP_RE.match(line)
            if m:
                cur = m.group(1)
                comps[cur] = []
                if line.startswith("ENTRY"):
                    entry = cur
        elif cur is not None:
            s = line.strip()
            if s == "}":
                cur = None
            elif s.startswith(("%", "ROOT")):
                comps[cur].append(s)

    # ---- parse ops per computation ---------------------------------------
    @dataclass
    class Op:
        name: str
        shape: str
        op: str
        rest: str

    comp_ops: dict[str, list[Op]] = {}
    name_shape: dict[str, dict[str, str]] = {}
    for cname, lines in comps.items():
        ops = []
        shapes = {}
        for line in lines:
            m = _OP_RE.match(line)
            if not m:
                continue
            o = Op(m.group(1), m.group(2), m.group(3), m.group(4))
            ops.append(o)
            shapes[o.name] = o.shape
        comp_ops[cname] = ops
        name_shape[cname] = shapes

    # ---- call-graph multipliers -------------------------------------------
    calls: dict[str, list[tuple[str, float]]] = {}
    for cname, ops in comp_ops.items():
        for o in ops:
            line = o.rest
            if o.op == "while":
                b = re.search(r"body=%?([\w\.\-]+)", line)
                c = re.search(r"condition=%?([\w\.\-]+)", line)
                trip = 1.0
                t = re.search(r'"known_trip_count":\{"n":"(\d+)"', line)
                if t:
                    trip = float(t.group(1))
                elif c and c.group(1) in comps:
                    consts = [int(x) for x in re.findall(
                        r"constant\((\d+)\)", "\n".join(comps[c.group(1)]))]
                    if consts:
                        trip = float(max(consts))
                if b:
                    calls.setdefault(b.group(1), []).append((cname, trip))
                if c:
                    calls.setdefault(c.group(1), []).append((cname, trip))
            else:
                for callee in re.findall(
                        r"(?:calls=|to_apply=|body=|condition=)%?([\w\.\-]+)",
                        line):
                    calls.setdefault(callee, []).append((cname, 1.0))
                bm = re.search(r"branch_computations=\{([^}]*)\}", line)
                if bm:
                    for callee in re.findall(r"%?([\w\.\-]+)", bm.group(1)):
                        calls.setdefault(callee, []).append((cname, 1.0))

    mult: dict[str, float] = {c: 0.0 for c in comps}
    if entry:
        mult[entry] = 1.0
    for _ in range(64):
        changed = False
        for callee, sites in calls.items():
            m = sum(mult.get(caller, 0.0) * t for caller, t in sites)
            if callee in mult and m > 0 and abs(m - mult[callee]) > 1e-9:
                mult[callee] = m
                changed = True
        if not changed:
            break

    # fusions' internal computations must not be double counted for traffic;
    # we only count traffic/flops of *top-level* ops per computation, but
    # dots live inside "wrapped" fusion computations on CPU dumps — so count
    # dot FLOPs wherever they appear, with their computation's multiplier.
    fusion_callees = set()
    for cname, ops in comp_ops.items():
        for o in ops:
            if o.op == "fusion":
                for callee in re.findall(r"calls=%?([\w\.\-]+)", o.rest):
                    fusion_callees.add(callee)

    flops = 0.0
    hbm = 0.0
    per_kind = {k: 0.0 for k in _COLL_KINDS}
    coll_total = ici = dcn = 0.0
    n_coll = 0

    for cname, ops in comp_ops.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        shapes = name_shape[cname]
        for o in ops:
            base = o.op[:-6] if o.op.endswith("-start") else o.op
            # ---------------- FLOPs: dots & convs -------------------------
            if o.op in ("dot", "dot-general"):
                out_elems = float(np.prod(_shape_dims(o.shape) or [1]))
                # older XLA dumps type each operand ("dot(f32[..] %a, ..."),
                # newer ones don't — search for the first operand name
                lhs_m = re.search(r"%([\w\.\-]+)", o.rest)
                contract = 1.0
                cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", o.rest)
                if lhs_m and cm and lhs_m.group(1) in shapes:
                    ldims = _shape_dims(shapes[lhs_m.group(1)])
                    for ci in cm.group(1).split(","):
                        if ci != "" and int(ci) < len(ldims):
                            contract *= ldims[int(ci)]
                flops += m * 2.0 * out_elems * contract
            elif o.op == "convolution":
                out_elems = float(np.prod(_shape_dims(o.shape) or [1]))
                operands = re.findall(r"%([\w\.\-]+)", o.rest)
                k_elems = 1.0
                if len(operands) >= 2 and operands[1] in shapes:
                    kd = _shape_dims(shapes[operands[1]])
                    k_elems = float(np.prod(kd)) / max(kd[-1] if kd else 1, 1)
                flops += m * 2.0 * out_elems * k_elems
            # ---------------- collectives ---------------------------------
            if base in _COLL_KINDS and not o.op.endswith("-done"):
                b = _shape_bytes(o.shape) * m
                per_kind[base] += b
                coll_total += b
                n_coll += 1
                if _crosses_pod(o.rest, pod_size):
                    dcn += b
                else:
                    ici += b
            # ---------------- HBM traffic ---------------------------------
            # TPU fuses elementwise chains; the CPU dump does not.  Model:
            # inside loop bodies (mult > 1) count only the ops whose
            # operands/outputs genuinely stream HBM on TPU — matmuls,
            # big slices/updates (KV cache), copies, collectives, reduces.
            # At top level (mult == 1) count every op boundary: that is the
            # once-per-step optimizer-state and gradient traffic.
            if cname in fusion_callees:
                continue  # inside a fusion: no HBM traffic
            if o.op in _NO_TRAFFIC or o.op.endswith("-done"):
                continue

            def _operands_bytes(limit=None):
                total, seen = 0.0, set()
                for opnd in re.findall(r"%([\w\.\-]+)", o.rest):
                    if opnd in shapes and opnd not in seen:
                        seen.add(opnd)
                        total += _shape_bytes(shapes[opnd])
                        if limit and len(seen) >= limit:
                            break
                return total

            out_b = _shape_bytes(o.shape)
            if o.op in ("dot", "convolution"):
                traffic = out_b + _operands_bytes()
            elif o.op == "dynamic-update-slice":
                # in-place on TPU: read+write of the update slice only
                opnds = re.findall(r"%([\w\.\-]+)", o.rest)
                upd = (_shape_bytes(shapes[opnds[1]])
                       if len(opnds) > 1 and opnds[1] in shapes else out_b)
                traffic = 2.0 * upd
            elif o.op in ("dynamic-slice", "gather", "slice"):
                traffic = 2.0 * out_b
            elif o.op in ("copy", "transpose", "reshape", "reduce",
                          "reduce-window", "scatter", "concatenate", "sort",
                          "select-and-scatter") or base in _COLL_KINDS:
                traffic = out_b + _operands_bytes()
            elif m <= 1.0:
                traffic = out_b + _operands_bytes()
            else:
                continue
            hbm += m * traffic

    return {"flops": flops, "hbm_bytes": hbm,
            "collective_bytes": coll_total, "ici_bytes": ici,
            "dcn_bytes": dcn, "per_kind": per_kind, "n_collectives": n_coll}


def roofline(flops_per_dev: float, bytes_per_dev: float, coll: dict,
             *, n_devices: int, n_pods: int = 1) -> dict:
    """The three roofline terms in seconds (per step, per device)."""
    compute_s = flops_per_dev / hw.PEAK_FLOPS_BF16
    memory_s = bytes_per_dev / hw.HBM_BW
    ici_s = coll["ici_bytes"] / hw.ICI_BW
    hosts = max(n_devices // hw.CHIPS_PER_HOST, 1)
    dcn_s = (coll["dcn_bytes"] * n_devices / hosts / hw.DCN_BW_PER_HOST
             if coll["dcn_bytes"] else 0.0)
    collective_s = ici_s + dcn_s
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s, "ici_s": ici_s, "dcn_s": dcn_s}
    terms["bottleneck"] = max(
        (("compute", compute_s), ("memory", memory_s),
         ("collective", collective_s)), key=lambda kv: kv[1])[0]
    terms["step_s"] = max(compute_s, memory_s) + collective_s
    return terms


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D (inference)."""
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch  # decode: one token/seq
