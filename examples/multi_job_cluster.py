"""Scylla in action: a multi-tenant 2-pod cluster serving a mixed job queue.

Reproduces the paper's core demo — DRF offer negotiation, policy-driven
placement (Spread / MinHost / cost-model Auto), co-scheduling, a host
failure with checkpoint-rollback restart, and a straggler migration —
over the assigned (arch x shape) workloads, using the dry-run roofline
profiles when artifacts/roofline.json exists.

    PYTHONPATH=src python examples/multi_job_cluster.py
"""
from repro.core import ClusterSpec, JobSpec, Simulator
from repro.core.costmodel import load_dryrun_profiles


def main():
    profiles = load_dryrun_profiles("artifacts/roofline.json")
    if profiles:
        print(f"loaded {len(profiles)} exact dry-run profiles")
    sim = Simulator(ClusterSpec(n_pods=2, hosts_per_pod=8),
                    co_schedule=True, dryrun_profiles=profiles,
                    compile_cache=True, migrate_stragglers=True)

    workload = [
        (0.0, JobSpec("train-moe", "mixtral-8x7b", "train_4k", chips=32,
                      policy="auto", steps=400, framework="research")),
        (0.0, JobSpec("serve-27b", "gemma3-27b", "decode_32k", chips=16,
                      policy="minhost", steps=5000, framework="serving")),
        (10.0, JobSpec("train-small", "internlm2-1.8b", "train_4k",
                       chips=8, policy="spread", steps=800,
                       framework="research")),
        (20.0, JobSpec("long-ctx", "mamba2-1.3b", "long_500k", chips=4,
                       policy="minhost", steps=2000, framework="serving")),
        (30.0, JobSpec("train-vlm", "llava-next-mistral-7b", "train_4k",
                       chips=16, policy="auto", steps=300,
                       framework="research")),
    ]
    for t, spec in workload:
        sim.submit_at(t, spec)
    sim.fail_host_at(500.0, "pod0/host002")
    sim.straggle_at(800.0, "pod1/host001", 5.0)

    results = sim.run()
    print(f"\n{'job':12s} {'policy':14s} {'hosts':>5s} {'wait_s':>8s} "
          f"{'run_s':>9s} {'restarts':>8s}")
    for jid, j in sorted(results["jobs"].items()):
        print(f"{jid:12s} {j.spec.policy:14s} {j.n_hosts:5d} "
              f"{max(0, j.start_time - j.submit_time):8.1f} "
              f"{j.finish_time - j.start_time:9.1f} {j.restarts:8d}")
    print(f"\nmakespan          {results['makespan']:.0f}s")
    print(f"avg utilization   {results['avg_utilization'] * 100:.0f}%")
    print(f"total restarts    {results['restarts']}")
    print("\nevent log (first 20):")
    for t, kind, jid in sim.events_log[:20]:
        print(f"  t={t:8.1f}  {kind:8s} {jid}")


if __name__ == "__main__":
    main()
