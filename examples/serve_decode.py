"""Batched serving example: train briefly so outputs are non-trivial, then
serve a queue of requests through the continuously-batched ServeEngine (the
decode path the decode_32k / long_500k dry-run cells lower).  Freed slots
admit the next request immediately at their own position — no wave barrier
— and the legacy wave engine is run on the same trace for comparison.
The final section demos the request API: per-tenant ``drf-fair``
admission, sampled decode (``SamplingParams``), and a streaming
``RequestHandle``.

    PYTHONPATH=src python examples/serve_decode.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import MarkovSynthetic
from repro.models import LM, RuntimeKnobs
from repro.optim import AdamWConfig
from repro.runtime.serve import (Request, SamplingParams, ServeConfig,
                                 ServeEngine)
from repro.runtime.train import TrainConfig, Trainer


def main():
    cfg = dataclasses.replace(get_config("zamba2-2.7b", smoke=True),
                              vocab_size=64)  # hybrid SSM: O(1) decode state
    model = LM(cfg, RuntimeKnobs(cache_dtype=jnp.float32))
    data = MarkovSynthetic(vocab_size=64, seq_len=64, global_batch=8,
                           seed=0, noise=0.05)
    tr = Trainer(model, data, TrainConfig(
        steps=40, log_every=20, checkpoint_every=0,
        opt=AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=40)))
    out = tr.run()
    print(f"trained 40 steps, loss -> {out['history'][-1]['loss']:.3f}")

    def trace(seed=0, n_req=8):
        rng = np.random.default_rng(seed)
        return [Request(i, rng.integers(0, 64, size=rng.integers(1, 5))
                        .astype(np.int32), max_new_tokens=12)
                for i in range(n_req)]

    stats = {}
    for mode in ("wave", "continuous"):
        engine = ServeEngine(model, tr.state["params"],
                             ServeConfig(batch_slots=4, max_len=64,
                                         mode=mode))
        for r in trace():
            engine.submit(r)
        t0 = time.time()
        done = engine.run()
        dt = time.time() - t0
        toks = sum(len(r.output) for r in done)
        stats[mode] = (done, toks)
        print(f"{mode:10s}: served {len(done)} requests / {toks} tokens "
              f"in {dt:.1f}s ({toks / dt:.1f} tok/s on CPU)")
    done, toks = stats["continuous"]
    for r in sorted(done, key=lambda r: r.req_id)[:4]:
        print(f"  req {r.req_id}: {r.prompt.tolist()} -> {r.output}")
    # the Markov structure (next = 5*prev+17 mod 64) should dominate outputs
    follows = sum(1 for r in done for a, b in zip(
        [r.prompt[-1]] + r.output[:-1], r.output) if b == (5 * a + 17) % 64)
    print(f"markov-consistent transitions: {follows}/{toks}")

    # paged KV + prefix caching (attention-only archs): requests sharing a
    # system prompt reuse its cached pages and skip that prefill work
    acfg = dataclasses.replace(get_config("internlm2-1.8b", smoke=True),
                               num_layers=2, vocab_size=64)
    amodel = LM(acfg, RuntimeKnobs(cache_dtype=jnp.float32))
    aparams = amodel.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    system = rng.integers(0, 64, size=16).astype(np.int32)
    engine = ServeEngine(amodel, aparams,
                         ServeConfig(batch_slots=4, max_len=64,
                                     cache="paged", page_size=8))
    for i in range(8):
        tail = rng.integers(0, 64, size=rng.integers(1, 5)).astype(np.int32)
        engine.submit(Request(i, np.concatenate([system, tail]),
                              max_new_tokens=8))
    done = engine.run()
    print(f"paged    : served {len(done)} requests sharing a 16-token "
          f"system prompt; kv stats: {engine.kv_stats()}")

    # request API: per-tenant DRF admission + sampled decode + streaming.
    # Tenant "bulk" floods the queue, yet "chat"'s sampled request streams
    # its tokens almost immediately — DRF keeps bulk's dominant share of
    # the slot pool bounded, the serving analogue of the paper's
    # Mesos-level fairness across frameworks.
    engine = ServeEngine(amodel, aparams,
                         ServeConfig(batch_slots=4, max_len=64,
                                     policy="drf-fair"))
    for i in range(8):
        engine.submit(Request(i, rng.integers(0, 64, size=4)
                              .astype(np.int32), max_new_tokens=10,
                              tenant="bulk"))
    handle = engine.submit(Request(
        99, rng.integers(0, 64, size=4).astype(np.int32),
        max_new_tokens=10, tenant="chat",
        sampling=SamplingParams(temperature=0.8, top_k=8, seed=1234)))
    streamed = list(handle.tokens())  # drives the engine tick by tick
    engine.run()
    print(f"drf-fair : chat tenant streamed {streamed} "
          f"(state={handle.state.value}, reason={handle.finish_reason}, "
          f"ttft={handle.metrics()['ttft_s'] * 1e3:.0f}ms) while bulk "
          f"flooded the queue")


if __name__ == "__main__":
    main()
