"""End-to-end training driver: a ~100M-param dense LM for a few hundred
steps on synthetic Markov data, with grad accumulation, checkpointing, a
simulated mid-run host failure, and restart-from-checkpoint — the full
fault-tolerant flow Scylla relies on (DESIGN.md §2).

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--dim 640]

Default --dim 640 builds a genuine ~115M-parameter model; on this 1-core
CPU container each step takes minutes (it is meant for a TPU host —
the same driver runs unchanged there).  For a quick CPU pass use
``--dim 128 --steps 30`` (~2 min, loss visibly falls).
"""
import argparse
import dataclasses
import shutil
import time

import jax.numpy as jnp

from repro.configs import get_config
from repro.models import LM, RuntimeKnobs
from repro.optim import AdamWConfig
from repro.data import MarkovSynthetic
from repro.runtime.fault import FailureInjector, run_with_failures
from repro.runtime.train import TrainConfig, Trainer


def build_model(dim: int) -> LM:
    base = get_config("internlm2-1.8b")  # same family, reduced dims
    cfg = dataclasses.replace(
        base, num_layers=12, d_model=dim, num_heads=8, num_kv_heads=4,
        head_dim=dim // 8, d_ff=4 * dim, vocab_size=32768)
    print(f"model: {cfg.param_count() / 1e6:.1f}M params")
    return LM(cfg, RuntimeKnobs(cache_dtype=jnp.float32, q_chunk=128,
                                ce_chunk=256))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--dim", type=int, default=640)
    ap.add_argument("--fail-at", type=int, default=0,
                    help="inject a host failure at this step (0=off)")
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm_ckpt")
    args = ap.parse_args()
    shutil.rmtree(args.ckpt, ignore_errors=True)

    model = build_model(args.dim)
    data = MarkovSynthetic(vocab_size=model.cfg.vocab_size, seq_len=256,
                           global_batch=8, seed=0, noise=0.1)
    tcfg = TrainConfig(
        steps=args.steps, grad_accum=2, checkpoint_every=50,
        checkpoint_dir=args.ckpt, log_every=10,
        opt=AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps))

    injector = FailureInjector(
        fail_at_steps=(args.fail_at,) if args.fail_at else ())

    t0 = time.time()

    def make_trainer(attempt):
        if attempt:
            print(f"--- restart #{attempt}: restoring from {args.ckpt}")
        tr = Trainer(model, data, tcfg)

        def log(step, metrics):
            injector(step, metrics)
            if step % 10 == 0:
                print(f"step {step:4d} loss {float(metrics['loss']):.3f} "
                      f"({(time.time() - t0):.0f}s)", flush=True)

        tr._on_step = log
        return tr

    attempt = 0
    while True:
        tr = make_trainer(attempt)
        try:
            out = tr.run(on_step=tr._on_step)
            break
        except Exception as e:  # SimulatedHostFailure
            print(f"!!! {e}")
            attempt += 1
    hist = out["history"]
    print(f"done: step {out['step']}, loss {hist[0]['loss']:.3f} -> "
          f"{hist[-1]['loss']:.3f}, restarts={attempt}, "
          f"{time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
