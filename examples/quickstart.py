"""Quickstart: train a tiny LM for 50 steps on synthetic Markov data, then
greedy-decode from it — the whole public API in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import MarkovSynthetic
from repro.models import LM, RuntimeKnobs
from repro.optim import AdamWConfig
from repro.runtime.serve import Request, ServeConfig, ServeEngine
from repro.runtime.train import TrainConfig, Trainer


def main():
    cfg = dataclasses.replace(get_config("internlm2-1.8b", smoke=True),
                              num_layers=2, vocab_size=64)
    model = LM(cfg, RuntimeKnobs(cache_dtype=jnp.float32))
    data = MarkovSynthetic(vocab_size=cfg.vocab_size, seq_len=64,
                           global_batch=8, seed=0, noise=0.05)
    trainer = Trainer(model, data, TrainConfig(
        steps=50, log_every=10, checkpoint_every=0,
        opt=AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=50)))
    out = trainer.run()
    for h in out["history"]:
        print(f"step {h['step']:3d}  loss {h['loss']:.3f}  "
              f"grad_norm {h['grad_norm']:.2f}  lr {h['lr']:.2e}")
    first, last = out["history"][0]["loss"], out["history"][-1]["loss"]
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({(1 - last / first) * 100:.0f}% down)")

    engine = ServeEngine(model, trainer.state["params"],
                         ServeConfig(batch_slots=2, max_len=64))
    engine.submit(Request(0, np.array([3, 5], np.int32), max_new_tokens=8))
    engine.submit(Request(1, np.array([10], np.int32), max_new_tokens=8))
    for req in engine.run():
        print(f"request {req.req_id}: prompt {req.prompt.tolist()} "
              f"-> {req.output}")


if __name__ == "__main__":
    main()
