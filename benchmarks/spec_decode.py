"""Speculative multi-token decode: acceptance rate + tokens/s vs the
baseline one-token-per-tick engine, gated on bitwise-identical output.

Part 1 drives the baseline (``draft_k=0``) and speculative engines over
the same **repetition-friendly trace** — prompts built from short
repeated patterns, the traffic shape prompt-lookup drafting exists for
(templated chat, code, and the self-repetition greedy decode converges
to) — and reports tokens/s, the draft **acceptance rate**, and verified
tokens per tick for dense and paged caches.

Part 2 is the replay gate: every speculative request's token stream must
be **bitwise-identical** to the non-speculative engine's — the same
property ``tests/test_spec_decode.py`` holds at the function and engine
level, re-checked here on the benchmark trace so a perf number can never
ship without its correctness twin (the container-overhead papers'
methodology: prove the fast path indistinguishable, then time it).

The run asserts the headline claims: acceptance rate clears a structural
floor and speculative tokens/s is >= 1.3x baseline on this trace.

    PYTHONPATH=src python benchmarks/spec_decode.py [--dry]

Emits BENCH_spec_decode[_dry].json via ``common.emit_json``;
``scripts/check_bench.py`` gates the dry numbers against
``benchmarks/baselines/``.
"""
import argparse
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

try:  # python -m benchmarks.spec_decode
    from .common import emit_json
except ImportError:  # python benchmarks/spec_decode.py
    sys.path.insert(0, os.path.dirname(__file__))
    from common import emit_json
from repro.configs import get_config
from repro.models import LM, RuntimeKnobs
from repro.runtime.serve import Request, ServeConfig, ServeEngine


def repetition_trace(*, n, pattern_len, repeats, max_new, vocab, seed=0):
    """Prompts that restate themselves: a random ``pattern_len``-token
    motif tiled ``repeats`` times (+ a couple of unique lead-in tokens so
    prompts differ).  The n-gram drafter should find the continuation of
    almost every decode-time tail in the prompt itself."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        pattern = rng.integers(0, vocab, size=pattern_len).astype(np.int32)
        lead = rng.integers(0, vocab, size=2).astype(np.int32)
        prompt = np.concatenate([lead] + [pattern] * repeats)
        reqs.append(Request(i, prompt, max_new_tokens=max_new))
    return reqs


def run_engine(model, params, reqs, *, slots, max_len, draft_k,
               cache="dense", reps=4):
    """Serve the trace ``reps`` times on one warmed engine; report the
    best repetition (the gate needs the engine's speed, not the host's
    momentary load) plus the speculative telemetry and outputs."""
    # prefix cache off: the drain check below wants in_use == 0, and
    # paged_serve.py already owns the prefix-cache measurements
    eng = ServeEngine(model, params, ServeConfig(
        batch_slots=slots, max_len=max_len, cache=cache, page_size=16,
        prefix_cache=False, draft_k=draft_k))
    eng.submit(Request(-1, np.asarray(reqs[0].prompt), max_new_tokens=2))
    eng.run()
    best = None
    outputs = None
    for _ in range(reps):
        for r in reqs:
            eng.submit(dataclasses.replace(
                r, output=[], done=False, t_submit=None, t_first=None,
                t_finish=None))
        t0 = time.perf_counter()
        done = eng.run()
        wall = time.perf_counter() - t0
        done = [r for r in done if r.req_id >= 0]
        toks = sum(len(r.output) for r in done)
        out = {"requests": len(done), "tokens": int(toks), "wall_s": wall,
               "tok_per_s": toks / max(wall, 1e-9)}
        if best is None or out["tok_per_s"] > best["tok_per_s"]:
            best = out
            outputs = {r.req_id: list(r.output) for r in done}
    if draft_k:
        st = eng.spec_stats()
        best.update(acceptance_rate=st["acceptance_rate"],
                    tokens_per_tick=st["tokens_per_tick"],
                    proposed=st["proposed"], accepted=st["accepted"])
    if eng.kv is not None:
        best["pool_drained"] = bool(eng.kv.pool.in_use == 0)
    return best, outputs


def run(dry: bool = True, slots: int = 4, max_len: int = 128,
        draft_k: int = 4):
    cfg = dataclasses.replace(get_config("internlm2-1.8b", smoke=True),
                              num_layers=2, vocab_size=64)
    model = LM(cfg, RuntimeKnobs(cache_dtype=jnp.float32))
    params = model.init(jax.random.PRNGKey(0))

    if dry:
        # long enough that the wall-clock rate (and the 1.3x speedup
        # floor) is a stable measurement on a noisy shared runner, small
        # enough for a CI smoke
        trace_kw = dict(n=8, pattern_len=4, repeats=4, max_new=48)
    else:
        trace_kw = dict(n=16, pattern_len=5, repeats=6, max_new=96)
    reqs = repetition_trace(vocab=cfg.vocab_size, **trace_kw)
    results = {"trace": trace_kw, "slots": slots, "max_len": max_len,
               "draft_k": draft_k}

    base, base_out = run_engine(model, params, reqs, slots=slots,
                                max_len=max_len, draft_k=0)
    results["baseline"] = base
    print(f"baseline  : {base['tokens']} tok in {base['wall_s']:.2f}s "
          f"-> {base['tok_per_s']:.1f} tok/s")

    spec, spec_out = run_engine(model, params, reqs, slots=slots,
                                max_len=max_len, draft_k=draft_k)
    results["spec"] = spec
    print(f"spec dense: {spec['tokens']} tok in {spec['wall_s']:.2f}s "
          f"-> {spec['tok_per_s']:.1f} tok/s, acceptance "
          f"{spec['acceptance_rate']:.2f}, "
          f"{spec['tokens_per_tick']:.2f} tok/tick")

    paged, paged_out = run_engine(model, params, reqs, slots=slots,
                                  max_len=max_len, draft_k=draft_k,
                                  cache="paged")
    results["spec_paged"] = paged
    print(f"spec paged: {paged['tok_per_s']:.1f} tok/s, acceptance "
          f"{paged['acceptance_rate']:.2f}, pool drained "
          f"{paged['pool_drained']}")

    speedup = spec["tok_per_s"] / max(base["tok_per_s"], 1e-9)
    results["spec_speedup"] = speedup
    # the replay gate: fast path indistinguishable from the baseline
    results["replay_bitwise_identical"] = bool(
        spec_out == base_out and paged_out == base_out)
    print(f"spec/baseline speedup: {speedup:.2f}x, replay bitwise "
          f"identical: {results['replay_bitwise_identical']}")

    emit_json("spec_decode_dry" if dry else "spec_decode", results)
    # headline claims, asserted in-process (machine-independent):
    assert results["replay_bitwise_identical"], \
        "speculative output diverged from the baseline decode"
    assert spec["acceptance_rate"] >= 0.3, \
        f"acceptance rate {spec['acceptance_rate']:.2f} too low — the " \
        f"trace no longer exercises the drafter"
    assert spec["tokens_per_tick"] >= 1.5, \
        f"{spec['tokens_per_tick']:.2f} verified tokens/tick — " \
        f"speculation is not amortizing ticks"
    assert speedup >= 1.3, \
        f"speculative decode only {speedup:.2f}x baseline tokens/s"
    assert paged["pool_drained"], "paged spec run leaked pages"
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry", action="store_true",
                    help="fast CI mode: tiny trace")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--draft-k", type=int, default=4)
    args = ap.parse_args()
    run(dry=args.dry, slots=args.slots, max_len=args.max_len,
        draft_k=args.draft_k)


if __name__ == "__main__":
    main()
