"""Paged KV cache + prefix caching vs the dense continuous engine.

Drives both engines over a shared-prefix trace — every other request
repeats a long "system prompt" (the serving analogue of the paper's
recurring job templates) with a short unique tail, mixed with a few
long-context requests.  The paged engine runs a pool sized well under the
dense reservation (requests only ever touch ``prompt + max_new`` tokens,
never ``max_len``) with prefix caching on, so repeated system prompts
skip their chunked-prefill work entirely.

Reported per engine: tokens/s, wall seconds, per-request p50/p99
time-to-first-token and time-per-output-token, KV HBM bytes *reserved*
(the allocation the engine holds for its whole life — the paper's pooled
vs static-partition comparison), and for the paged engine the prefix-hit
counters.  The gate: the paged engine must reserve measurably less KV
HBM while matching or beating dense tokens/s.

    PYTHONPATH=src python benchmarks/paged_serve.py [--dry]

Emits BENCH_paged_serve.json via ``common.emit_json``.
"""
import argparse
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

try:  # python -m benchmarks.run / -m benchmarks.paged_serve
    from .common import emit_json, request_latency_stats
except ImportError:  # python benchmarks/paged_serve.py
    sys.path.insert(0, os.path.dirname(__file__))
    from common import emit_json, request_latency_stats
from repro.configs import get_config
from repro.models import LM, RuntimeKnobs
from repro.runtime.serve import Request, ServeConfig, ServeEngine


def shared_prefix_trace(*, n_req, prefix_len, tail_max, n_long, long_prompt,
                        max_new, vocab, seed=0):
    """Chat-style requests repeating one system prompt + a unique tail,
    with a few long-context (unshared) requests interleaved."""
    rng = np.random.default_rng(seed)
    system = rng.integers(0, vocab, size=prefix_len).astype(np.int32)
    reqs = []
    long_every = max(1, n_req // max(n_long, 1))
    for i in range(n_req):
        if n_long and i and i % long_every == 0:
            prompt = rng.integers(0, vocab, size=long_prompt) \
                .astype(np.int32)
            n_long -= 1
        else:
            tail = rng.integers(0, vocab,
                                size=int(rng.integers(1, tail_max + 1))) \
                .astype(np.int32)
            prompt = np.concatenate([system, tail])
        reqs.append(Request(i, prompt, max_new_tokens=max_new))
    return reqs


def run_engine(model, params, reqs, *, warm_prompt, reps=3, **engine_kw):
    eng = ServeEngine(model, params, ServeConfig(**engine_kw))
    # warmup: compile every step shape this engine will hit — the repeat
    # of a page-aligned prompt drives the prefix-hit admission path
    # (full-hit CoW remap + offset prefill) on the paged engine
    eng.submit(Request(-1, np.asarray(warm_prompt), max_new_tokens=2))
    eng.submit(Request(-2, np.asarray(warm_prompt), max_new_tokens=2))
    eng.run()
    if eng.kv is not None and eng.kv.prefix is not None:
        eng.kv.prefix.evict(eng.kv.pool.capacity)  # forget warmup pages
        eng.kv.prefix.hits = eng.kv.prefix.misses = 0
    # best-of-reps: the per-run walls are tens of ms, so take the min to
    # shed scheduler noise (same trace each rep; prefix cache cleared so
    # every rep does identical work)
    wall = float("inf")
    for _ in range(reps):
        if eng.kv is not None and eng.kv.prefix is not None:
            eng.kv.prefix.evict(eng.kv.pool.capacity)
            eng.kv.prefix.hits = eng.kv.prefix.misses = 0
        for r in reqs:
            eng.submit(Request(r.req_id, r.prompt.copy(),
                               max_new_tokens=r.max_new_tokens))
        t0 = time.perf_counter()
        done = eng.run()
        wall = min(wall, time.perf_counter() - t0)
    done = [r for r in done if r.req_id >= 0]
    toks = sum(len(r.output) for r in done)
    out = {
        "requests": len(done),
        "tokens": int(toks),
        "wall_s": wall,
        "tok_per_s": toks / max(wall, 1e-9),
    }
    # per-request TTFT/TPOT percentiles from the last rep's lifecycle
    # stamps (wall_s stays best-of-reps)
    out.update(request_latency_stats(done))
    out.update(eng.kv_stats())
    return out, {r.req_id: r.output for r in done}


def run(dry: bool = True, slots: int = 4, max_len: int = 128,
        page_size: int = 16):
    cfg = dataclasses.replace(get_config("internlm2-1.8b", smoke=True),
                              num_layers=2, vocab_size=64)
    model = LM(cfg, RuntimeKnobs(cache_dtype=jnp.float32))
    params = model.init(jax.random.PRNGKey(0))

    if dry:
        trace_kw = dict(n_req=8, prefix_len=64, tail_max=4, n_long=2,
                        long_prompt=96, max_new=4)
    else:
        trace_kw = dict(n_req=24, prefix_len=64, tail_max=8, n_long=4,
                        long_prompt=112, max_new=8)
    # the paged pool: enough pages for the live mix (short requests touch
    # ~prefix+tail+max_new tokens, and share the system prompt's pages),
    # far below the dense slots * max_len reservation
    num_pages = (slots * max_len // page_size) // 2 + 1
    # chunk at page granularity for both engines: admission can then
    # resume prefill right at the matched prefix, not a coarser grid
    chunk = page_size
    results = {"trace": trace_kw, "slots": slots, "max_len": max_len,
               "page_size": page_size, "num_pages": num_pages}
    outs = {}
    for name, kw in (
            ("dense", dict(cache="dense")),
            ("paged", dict(cache="paged", page_size=page_size,
                           num_pages=num_pages))):
        reqs = shared_prefix_trace(vocab=cfg.vocab_size, **trace_kw)
        warm = (np.arange(2 * page_size) % cfg.vocab_size).astype(np.int32)
        r, outs[name] = run_engine(
            model, params, reqs, warm_prompt=warm,
            batch_slots=slots, max_len=max_len, prefill_chunk=chunk, **kw)
        results[name] = r
        print(f"{name:6s}: {r['tokens']} tok in {r['wall_s']:.2f}s -> "
              f"{r['tok_per_s']:.1f} tok/s, ttft p50/p99 "
              f"{r['p50_ttft_s'] * 1e3:.0f}/{r['p99_ttft_s'] * 1e3:.0f}ms, "
              f"KV reserved {r['kv_reserved_bytes'] / 1024:.0f} KiB"
              + (f", prefix hits {r['prefix_hits']}" if name == "paged"
                 else ""))
    assert outs["dense"] == outs["paged"], \
        "paged engine diverged from dense outputs"
    saving = (1 - results["paged"]["kv_reserved_bytes"]
              / results["dense"]["kv_reserved_bytes"])
    speed = (results["paged"]["tok_per_s"]
             / max(results["dense"]["tok_per_s"], 1e-9))
    results["kv_reserved_saving"] = saving
    results["paged_speedup"] = speed
    print(f"paged reserves {saving * 100:.0f}% less KV HBM at "
          f"{speed:.2f}x dense throughput "
          f"({results['paged']['prefix_hits']} prefix-page hits)")
    # dry (CI smoke) runs must not clobber the tracked full-trace snapshot
    emit_json("paged_serve_dry" if dry else "paged_serve", results)
    # the qualitative claims this benchmark gates (acceptance criteria):
    # less HBM reserved, no throughput regression, prefix cache active
    assert saving > 0.2, f"KV reservation saving only {saving:.2f}"
    # dry traces are one tiny wall-clock sample: allow scheduler noise
    # there and keep the strict no-regression bar on the full trace (the
    # baseline-relative rate gate lives in scripts/check_bench.py)
    min_speed = 0.7 if dry else 1.0
    assert speed >= min_speed, \
        f"paged engine slower than dense: {speed:.2f}x (floor {min_speed})"
    assert results["paged"]["prefix_hits"] > 0, "prefix cache never hit"
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry", action="store_true",
                    help="fast CI mode: tiny trace")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=16)
    args = ap.parse_args()
    run(dry=args.dry, slots=args.slots, max_len=args.max_len,
        page_size=args.page_size)


if __name__ == "__main__":
    main()
