"""Paper Figs 12-13 — Spread vs MinHost per workload class.

Fig 12: memory/CPU-intensive (MiniFE) — Spread wins (paper: 29% better),
because packing shares hosts with other tenants (input-pipeline + NIC
contention on TPU hosts; DESIGN.md §2).
Fig 13: communication-intensive (HP2P) — MinHost wins (paper: 21% better
average latency), because packing keeps collectives on ICI instead of DCN.

Same scenario engine as the tests; we additionally report the beyond-paper
AutoPolicy, which picks per-job placement from the roofline cost model and
matches the better policy in both scenarios.
"""
from __future__ import annotations

import dataclasses

from repro.core import ClusterSpec, JobSpec, RooflineProfile, Simulator

from .common import emit, save_artifact

SPEC = ClusterSpec(n_pods=2, hosts_per_pod=8)


def _run_one(job: JobSpec, background: bool) -> float:
    sim = Simulator(SPEC)
    if background:
        # fragment the cluster: 12 of 16 hosts hold a 3-chip tenant, so
        # packing the main gang is forced to share hosts (paper's
        # "resource contention" — here: host input pipeline + NIC)
        for i in range(12):
            sim.submit_at(0.0, JobSpec(f"bg{i}", "internlm2-1.8b",
                                       "train_4k", chips=3,
                                       policy="minhost", steps=100_000))
    sim.submit_at(1.0, job)
    r = sim.run(until=5e6)
    j = r["jobs"].get(job.job_id)
    assert j is not None, "main job must finish"
    return j.finish_time - j.start_time


def run():
    results = {}
    # ---- Fig 12: host-resource-intensive (MiniFE analogue), contended ----
    # TPU chips have dedicated HBM; the host-level contended resources are
    # the input pipeline (host CPU/DRAM) and the NIC (DESIGN.md §2), so the
    # MiniFE analogue is an input-heavy training job.
    mem_prof = RooflineProfile(flops=1e15, hbm_bytes=1e12, ici_bytes=1e10)
    mem_job = JobSpec("minife", "llava-next-mistral-7b", "train_4k",
                      chips=22, steps=100, profile=mem_prof)
    for pol in ("spread", "minhost", "auto"):
        results[f"fig12_{pol}"] = _run_one(
            dataclasses.replace(mem_job, policy=pol), background=True)
    gain12 = (results["fig12_minhost"] - results["fig12_spread"]) \
        / results["fig12_minhost"]
    emit("fig12_spread", results["fig12_spread"] * 1e6, "memory-intensive")
    emit("fig12_minhost", results["fig12_minhost"] * 1e6, "memory-intensive")
    emit("fig12_gain", gain12 * 1e6,
         f"Spread better by {gain12 * 100:.0f}% (paper: 29%)")
    assert gain12 > 0.10, "Spread must win for memory-bound (paper Fig 12)"

    # ---- Fig 13: communication-intensive (HP2P analogue) ------------------
    comm_prof = RooflineProfile(flops=1e13, hbm_bytes=1e12, ici_bytes=8e12)
    comm_job = JobSpec("hp2p", "qwen3-moe-235b-a22b", "train_4k", chips=32,
                       steps=100, profile=comm_prof)
    for pol in ("spread", "minhost", "auto"):
        results[f"fig13_{pol}"] = _run_one(
            dataclasses.replace(comm_job, policy=pol), background=False)
    gain13 = (results["fig13_spread"] - results["fig13_minhost"]) \
        / results["fig13_spread"]
    emit("fig13_spread", results["fig13_spread"] * 1e6, "comm-intensive")
    emit("fig13_minhost", results["fig13_minhost"] * 1e6, "comm-intensive")
    emit("fig13_gain", gain13 * 1e6,
         f"MinHost better by {gain13 * 100:.0f}% (paper: 21%)")
    assert gain13 > 0.05, "MinHost must win for comm-bound (paper Fig 13)"

    # ---- beyond paper: AutoPolicy matches the winner in both --------------
    assert results["fig12_auto"] <= results["fig12_spread"] * 1.001
    assert results["fig13_auto"] <= results["fig13_minhost"] * 1.001
    emit("auto_policy", 0.0, "matches best policy in both scenarios")
    save_artifact("bench_fig12_13.json",
                  {**results, "gain12": gain12, "gain13": gain13,
                   "paper": {"fig12": 0.29, "fig13": 0.21}})


if __name__ == "__main__":
    run()
