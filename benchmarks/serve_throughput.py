"""Serving throughput + admission fairness: wave vs continuous batching,
fcfs vs drf-fair.

Part 1 drives ``ServeEngine`` over a mixed-length request trace (short
chat requests interleaved with long-context ones — the serving analogue of
the paper's heterogeneous MPI job mix) and measures tokens/s, p50/p99
per-token latency, and per-request p50/p99 time-to-first-token (TTFT,
includes queue wait) and time-per-output-token (TPOT) for both admission
modes.  Wave batching is the exclusive (non-co-scheduled) baseline: slots
drain in lockstep and freed slots idle until the whole wave finishes.

Part 2 is the two-tenant flood: tenant "heavy" floods the queue before
tenant "light" submits a trickle.  Under ``fcfs`` the light tenant
provably starves (heavy holds every slot until its backlog drains); under
``drf-fair`` the DRF allocator keeps the heavy tenant's dominant share of
the slot pool bounded while the light tenant has work queued — the
serving analogue of Scylla's Mesos-level DRF across frameworks.  The gate
compares the two on the light tenant's tail TTFT.

Part 3 is the SLO-tier flood with preemption: tenant "gold" (weight 3)
floods every slot, then tenant "free" (weight 1) trickles in mid-run.
The fcfs-no-preemption baseline starves free until gold's backlog
drains; with ``preempt=True`` + ``tenant_weights={"gold": 3, "free": 1}``
the scheduler revokes gold slots Mesos-style until the weighted shares
equalize — gold converges to exactly its 3/(3+1) = 0.75 entitlement
while free waits, and free's tail TTFT collapses.  The gate additionally
replays one preempted request on a fresh engine and asserts the
checkpoint/resume token stream is bitwise-identical to the
uninterrupted run.

Part 4 is the telemetry-overhead gate: the mixed trace is served twice
— once against the null telemetry sink, once with full span tracing +
flight recorder armed — pairwise per attempt, and the tokens/s tax of
tracing is gated at <= 2% (``telemetry.overhead_frac`` in the payload).

    PYTHONPATH=src python benchmarks/serve_throughput.py [--dry]

Emits BENCH_serve_throughput.json via ``common.emit_json``.
"""
import argparse
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

try:  # python -m benchmarks.run / -m benchmarks.serve_throughput
    from .common import emit_json, request_latency_stats
except ImportError:  # python benchmarks/serve_throughput.py
    sys.path.insert(0, os.path.dirname(__file__))
    from common import emit_json, request_latency_stats
from repro.configs import get_config
from repro.models import LM, RuntimeKnobs
from repro.runtime.serve import Request, ServeConfig, ServeEngine


def mixed_trace(*, n_short, n_long, short_prompt, long_prompt, max_new,
                vocab, seed=0):
    """Short chat requests interleaved with long-context ones."""
    rng = np.random.default_rng(seed)
    reqs = []
    long_every = max(1, (n_short + n_long) // max(n_long, 1))
    for i in range(n_short + n_long):
        if n_long and i % long_every == 0:
            plen = long_prompt
            n_long -= 1
        else:
            plen = int(rng.integers(1, short_prompt + 1))
        reqs.append(Request(i, rng.integers(0, vocab, size=plen)
                            .astype(np.int32), max_new_tokens=max_new))
    return reqs


def flood_trace(*, n_heavy, n_light, prompt_len, max_new, vocab, seed=0):
    """Tenant "heavy" floods the queue, then tenant "light" trickles in —
    the adversarial arrival order FCFS handles worst."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_heavy + n_light):
        plen = int(rng.integers(1, prompt_len + 1))
        reqs.append(Request(
            i, rng.integers(0, vocab, size=plen).astype(np.int32),
            max_new_tokens=max_new,
            tenant="heavy" if i < n_heavy else "light"))
    return reqs


def run_mode(model, params, reqs, *, mode, slots, max_len, policy="fcfs",
             reps=3, telemetry=None):
    """Serve the trace ``reps`` times on one warmed engine and report
    the best repetition — wall-clock on shared machines is dominated by
    scheduler noise, and the regression gate (scripts/check_bench.py)
    needs the engine's speed, not the host's momentary load."""
    eng = ServeEngine(model, params, ServeConfig(
        batch_slots=slots, max_len=max_len, mode=mode, policy=policy),
        telemetry=telemetry)
    # warmup: compile every step shape this engine will hit
    eng.submit(Request(-1, np.asarray(reqs[0].prompt), max_new_tokens=2))
    eng.run()
    best = None
    for _ in range(reps):
        for r in reqs:
            eng.submit(dataclasses.replace(
                r, output=[], done=False, t_submit=None, t_first=None,
                t_finish=None))
        lat = []  # per-token latency: tick duration over its tokens
        t0 = time.perf_counter()
        while eng.queue or any(r is not None for r in eng.active):
            t1 = time.perf_counter()
            emitted = eng.step()
            dt = time.perf_counter() - t1
            lat.extend([dt / max(emitted, 1)] * emitted)
        wall = time.perf_counter() - t0
        done = [r for r in eng.run(max_ticks=0, on_stall="warn")
                if r.req_id >= 0]
        toks = sum(len(r.output) for r in done)
        # chunked prefill can emit first tokens inside step()'s
        # admission — emitted counts them, so lat covers every token
        lat = np.asarray(lat) if lat else np.asarray([wall])
        out = {
            "requests": len(done),
            "tokens": int(toks),
            "wall_s": wall,
            "tok_per_s": toks / max(wall, 1e-9),
            "p50_token_latency_s": float(np.percentile(lat, 50)),
            "p99_token_latency_s": float(np.percentile(lat, 99)),
        }
        out.update(request_latency_stats(done))
        if best is None or out["tok_per_s"] > best["tok_per_s"]:
            best = out
    return best


def run_fairness(model, params, reqs, *, policy, slots, max_len):
    """Two-tenant flood under one admission policy.  Reports the heavy
    tenant's maximum slot share *while the light tenant has work queued*
    (the DRF bound) and each tenant's TTFT percentiles."""
    eng = ServeEngine(model, params, ServeConfig(
        batch_slots=slots, max_len=max_len, policy=policy))
    eng.submit(Request(-1, np.asarray(reqs[0].prompt), max_new_tokens=2))
    eng.run()
    for r in reqs:
        eng.submit(r)
    max_heavy_share = 0.0
    while eng.queue or any(r is not None for r in eng.active):
        eng.step()
        light_waiting = (any(r.tenant == "light" for r in eng.queue)
                         or any(r is not None and r.tenant == "light"
                                for r in eng.active))
        if light_waiting:
            heavy = sum(1 for r in eng.active
                        if r is not None and r.tenant == "heavy")
            max_heavy_share = max(max_heavy_share, heavy / slots)
    done = [r for r in eng._finished if r.req_id >= 0]
    out = {"max_heavy_slot_share": max_heavy_share}
    for tenant in ("heavy", "light"):
        sub = [r for r in done if r.tenant == tenant]
        out.update({f"{tenant}_{k}": v
                    for k, v in request_latency_stats(sub).items()})
    # position of the light tenant's first completion (0 = first overall)
    out["light_first_finish_index"] = next(
        (i for i, r in enumerate(done) if r.tenant == "light"), -1)
    return out


def slo_trace(*, n_gold, n_free, prompt_len, gold_new, free_new, vocab,
              seed=0):
    """Gold (weight 3) floods; free (weight 1) trickles in mid-run."""
    rng = np.random.default_rng(seed)

    def req(i, tenant, max_new):
        plen = int(rng.integers(1, prompt_len + 1))
        return Request(i, rng.integers(0, vocab, size=plen)
                       .astype(np.int32), max_new_tokens=max_new,
                       tenant=tenant)

    gold = [req(i, "gold", gold_new) for i in range(n_gold)]
    free = [req(n_gold + i, "free", free_new) for i in range(n_free)]
    return gold, free


def run_slo_flood(model, params, gold, free, *, slots, max_len,
                  weights=None, preempt=False):
    """Drive the gold flood, inject the free trickle after 2 ticks, and
    report per-tenant TTFT plus the gold slot share while free waits
    (the weighted-DRF convergence bound)."""
    policy = "drf-fair" if preempt else "fcfs"
    eng = ServeEngine(model, params, ServeConfig(
        batch_slots=slots, max_len=max_len, policy=policy,
        tenant_weights=weights, preempt=preempt,
        victim_policy="lowest-weight-share-first"))
    eng.submit(Request(-1, np.asarray(gold[0].prompt), max_new_tokens=2))
    eng.run()
    if preempt and eng.kv is None:
        # warm the dense checkpoint/restore pair: its one-time compile
        # must not land inside the timed run's first preemption
        eng._ensure_ckpt_fns()
        snap = jax.device_get(eng._copy_out(eng.caches, jnp.int32(0)))
        eng.caches = eng._copy_in(eng.caches, jax.device_put(snap),
                                  jnp.int32(0))
    for r in gold:
        eng.submit(r)
    eng.step()
    eng.step()
    for r in free:
        eng.submit(r)
    max_gold_share = 0.0
    while eng.queue or any(r is not None for r in eng.active):
        eng.step()
        if any(r.tenant == "free" for r in eng.queue):
            g = sum(1 for r in eng.active
                    if r is not None and r.tenant == "gold")
            max_gold_share = max(max_gold_share, g / slots)
    done = [r for r in eng._finished if r.req_id >= 0]
    out = {
        "max_gold_share_while_free_waits": max_gold_share,
        "preemptions": eng.scheduler.preempted_total,
        "requests_preempted": sum(1 for r in done if r.preempt_count),
        "weighted_shares_drained": all(
            v == 0.0 for v in eng.scheduler.shares().values()),
    }
    for tenant in ("gold", "free"):
        sub = [r for r in done if r.tenant == tenant]
        out.update({f"{tenant}_{k}": v
                    for k, v in request_latency_stats(sub).items()})
    return out, done


def replay_matches(model, params, done, *, max_len) -> bool:
    """Bitwise gate: a preempted request's final token stream equals an
    uninterrupted greedy run of the same prompt on a fresh engine."""
    victims = [r for r in done if r.preempt_count > 0]
    assert victims, "SLO flood produced no preemption to verify"
    eng = ServeEngine(model, params, ServeConfig(batch_slots=1,
                                                 max_len=max_len))
    for v in victims:
        ref = eng.submit(Request(v.req_id, np.asarray(v.prompt),
                                 max_new_tokens=v.max_new_tokens)).result()
        if ref.output != v.output:
            return False
    return True


def run(dry: bool = True, slots: int = 4, max_len: int = 128):
    cfg = dataclasses.replace(get_config("internlm2-1.8b", smoke=True),
                              num_layers=2, vocab_size=64)
    model = LM(cfg, RuntimeKnobs(cache_dtype=jnp.float32))
    params = model.init(jax.random.PRNGKey(0))

    if dry:
        # big enough that the wall-clock rate is a stable measurement
        # (the bench gate compares it against a tracked baseline), small
        # enough for a CI smoke
        trace_kw = dict(n_short=12, n_long=3, short_prompt=6, long_prompt=48,
                        max_new=6)
        flood_kw = dict(n_heavy=8, n_light=3, prompt_len=4, max_new=4)
        slo_kw = dict(n_gold=10, n_free=3, prompt_len=4, gold_new=10,
                      free_new=3)
    else:
        trace_kw = dict(n_short=24, n_long=6, short_prompt=8, long_prompt=96,
                        max_new=8)
        flood_kw = dict(n_heavy=20, n_light=5, prompt_len=6, max_new=6)
        slo_kw = dict(n_gold=16, n_free=4, prompt_len=6, gold_new=12,
                      free_new=4)
    results = {"trace": trace_kw, "slots": slots, "max_len": max_len}
    for mode in ("wave", "continuous"):
        reqs = mixed_trace(vocab=cfg.vocab_size, **trace_kw)
        r = run_mode(model, params, reqs, mode=mode, slots=slots,
                     max_len=max_len)
        results[mode] = r
        print(f"{mode:10s}: {r['tokens']} tok in {r['wall_s']:.2f}s "
              f"-> {r['tok_per_s']:.1f} tok/s, p50 "
              f"{r['p50_token_latency_s'] * 1e3:.1f}ms, p99 "
              f"{r['p99_token_latency_s'] * 1e3:.1f}ms, ttft p50/p99 "
              f"{r['p50_ttft_s'] * 1e3:.0f}/{r['p99_ttft_s'] * 1e3:.0f}ms")
    speedup = (results["continuous"]["tok_per_s"]
               / max(results["wave"]["tok_per_s"], 1e-9))
    results["continuous_speedup"] = speedup
    print(f"continuous/wave speedup: {speedup:.2f}x")

    # two-tenant flood: fcfs starves the light tenant, drf-fair bounds the
    # heavy tenant's slot share while light work is queued
    results["flood"] = {"trace": flood_kw}
    for policy in ("fcfs", "drf-fair"):
        reqs = flood_trace(vocab=cfg.vocab_size, **flood_kw)
        f = run_fairness(model, params, reqs, policy=policy, slots=slots,
                         max_len=max_len)
        results["flood"][policy] = f
        print(f"flood/{policy:9s}: max heavy share "
              f"{f['max_heavy_slot_share']:.2f}, light ttft p99 "
              f"{f['light_p99_ttft_s'] * 1e3:.0f}ms, light first finish "
              f"#{f['light_first_finish_index']}")
    fcfs, drf = results["flood"]["fcfs"], results["flood"]["drf-fair"]

    # SLO-tier flood: gold (weight 3) floods, free (weight 1) trickles;
    # preemption + weighted DRF vs the fcfs-no-preemption baseline
    weights = {"gold": 3, "free": 1}
    results["slo_flood"] = {"trace": slo_kw, "tenant_weights": weights}
    for label, preempt in (("fcfs", False), ("weighted-preempt", True)):
        gold, freer = slo_trace(vocab=cfg.vocab_size, **slo_kw)
        f, done = run_slo_flood(model, params, gold, freer, slots=slots,
                                max_len=max_len,
                                weights=weights if preempt else None,
                                preempt=preempt)
        if preempt:
            f["replay_bitwise_identical"] = replay_matches(
                model, params, done, max_len=max_len)
        results["slo_flood"][label] = f
        print(f"slo/{label:16s}: gold share {f['max_gold_share_while_free_waits']:.2f}, "
              f"free ttft p99 {f['free_p99_ttft_s'] * 1e3:.0f}ms, "
              f"preemptions {f['preemptions']}")
    base = results["slo_flood"]["fcfs"]
    slo = results["slo_flood"]["weighted-preempt"]

    # Part 4 — telemetry overhead: full span tracing on vs the null sink,
    # same trace, same engine config, pairwise per attempt so host noise
    # hits both sides.  The gate (scripts/check_bench.py BOUNDS) holds the
    # observability tax at <= 2% tokens/s; the min over attempts is the
    # fair estimate of the *intrinsic* overhead (anything above the min is
    # scheduler noise, which the pairing can't fully cancel).
    from repro.runtime.telemetry import Telemetry
    overhead, tele = None, None
    for _ in range(3):
        reqs = mixed_trace(vocab=cfg.vocab_size, **trace_kw)
        off = run_mode(model, params, reqs, mode="continuous", slots=slots,
                       max_len=max_len, reps=2)
        tm = Telemetry(trace=True, flight=256)
        on = run_mode(model, params, reqs, mode="continuous", slots=slots,
                      max_len=max_len, reps=2, telemetry=tm)
        frac = max(0.0, 1.0 - on["tok_per_s"] / max(off["tok_per_s"], 1e-9))
        if overhead is None or frac < overhead:
            overhead = frac
            tele = {
                "untraced_tok_per_s": off["tok_per_s"],
                "traced_tok_per_s": on["tok_per_s"],
                "overhead_frac": frac,
                "trace_events": tm.trace.total,
                "spans_balanced": not tm.trace.open_spans(),
            }
        if overhead <= 0.02:
            break
    results["telemetry"] = tele
    print(f"telemetry: {tele['trace_events']} events traced, overhead "
          f"{tele['overhead_frac'] * 100:.1f}% "
          f"({tele['traced_tok_per_s']:.1f} vs "
          f"{tele['untraced_tok_per_s']:.1f} tok/s), spans balanced: "
          f"{tele['spans_balanced']}")

    # dry (CI smoke) runs must not clobber the tracked full-trace snapshot
    emit_json("serve_throughput_dry" if dry else "serve_throughput", results)
    # the qualitative claims this benchmark gates: continuous batching
    # beats wave batching on a mixed-length trace, and DRF admission
    # bounds the flooding tenant's share where FCFS lets it starve others
    assert speedup >= 1.5, f"continuous batching only {speedup:.2f}x wave"
    assert fcfs["max_heavy_slot_share"] >= 0.99, \
        "flood trace too mild: fcfs never saturated the slots"
    assert drf["max_heavy_slot_share"] <= 0.75, \
        f"drf-fair heavy share {drf['max_heavy_slot_share']:.2f} unbounded"
    # completion order is deterministic (TTFT seconds are reported but
    # wall-clock noisy at dry scale): under drf the light tenant finishes
    # work while fcfs still drains the flood
    assert (drf["light_first_finish_index"]
            < fcfs["light_first_finish_index"]), \
        "drf-fair did not admit the light tenant ahead of the flood"
    # SLO-tier gates: weighted DRF converges gold to its 3/(3+1) = 0.75
    # entitlement (the ±0.1 band absorbs slot granularity at other slot
    # counts), preemption actually fired and restored bitwise-identically,
    # and the free tier's tail TTFT beats the no-preemption baseline
    assert abs(slo["max_gold_share_while_free_waits"] - 0.75) <= 0.1, \
        f"gold share {slo['max_gold_share_while_free_waits']:.2f} " \
        f"missed its 0.75 weighted entitlement"
    assert slo["preemptions"] >= 1, "no preemption under the SLO flood"
    assert slo["replay_bitwise_identical"], \
        "preempted request's resumed stream diverged from its solo run"
    assert slo["free_p99_ttft_s"] < base["free_p99_ttft_s"], \
        f"preemption did not improve free-tier tail TTFT " \
        f"({slo['free_p99_ttft_s']:.3f}s vs {base['free_p99_ttft_s']:.3f}s)"
    assert slo["weighted_shares_drained"], "DRF accounting leaked"
    # full tracing must stay within the observability budget, and every
    # span opened during the traced run must have closed
    assert tele["overhead_frac"] <= 0.02, \
        f"telemetry overhead {tele['overhead_frac'] * 100:.1f}% > 2%"
    assert tele["spans_balanced"], "traced run left spans open"
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry", action="store_true",
                    help="fast CI mode: tiny trace")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()
    run(dry=args.dry, slots=args.slots, max_len=args.max_len)


if __name__ == "__main__":
    main()
