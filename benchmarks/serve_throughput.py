"""Serving throughput + admission fairness: wave vs continuous batching,
fcfs vs drf-fair.

Part 1 drives ``ServeEngine`` over a mixed-length request trace (short
chat requests interleaved with long-context ones — the serving analogue of
the paper's heterogeneous MPI job mix) and measures tokens/s, p50/p99
per-token latency, and per-request p50/p99 time-to-first-token (TTFT,
includes queue wait) and time-per-output-token (TPOT) for both admission
modes.  Wave batching is the exclusive (non-co-scheduled) baseline: slots
drain in lockstep and freed slots idle until the whole wave finishes.

Part 2 is the two-tenant flood: tenant "heavy" floods the queue before
tenant "light" submits a trickle.  Under ``fcfs`` the light tenant
provably starves (heavy holds every slot until its backlog drains); under
``drf-fair`` the DRF allocator keeps the heavy tenant's dominant share of
the slot pool bounded while the light tenant has work queued — the
serving analogue of Scylla's Mesos-level DRF across frameworks.  The gate
compares the two on the light tenant's tail TTFT.

    PYTHONPATH=src python benchmarks/serve_throughput.py [--dry]

Emits BENCH_serve_throughput.json via ``common.emit_json``.
"""
import argparse
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

try:  # python -m benchmarks.run / -m benchmarks.serve_throughput
    from .common import emit_json, request_latency_stats
except ImportError:  # python benchmarks/serve_throughput.py
    sys.path.insert(0, os.path.dirname(__file__))
    from common import emit_json, request_latency_stats
from repro.configs import get_config
from repro.models import LM, RuntimeKnobs
from repro.runtime.serve import Request, ServeConfig, ServeEngine


def mixed_trace(*, n_short, n_long, short_prompt, long_prompt, max_new,
                vocab, seed=0):
    """Short chat requests interleaved with long-context ones."""
    rng = np.random.default_rng(seed)
    reqs = []
    long_every = max(1, (n_short + n_long) // max(n_long, 1))
    for i in range(n_short + n_long):
        if n_long and i % long_every == 0:
            plen = long_prompt
            n_long -= 1
        else:
            plen = int(rng.integers(1, short_prompt + 1))
        reqs.append(Request(i, rng.integers(0, vocab, size=plen)
                            .astype(np.int32), max_new_tokens=max_new))
    return reqs


def flood_trace(*, n_heavy, n_light, prompt_len, max_new, vocab, seed=0):
    """Tenant "heavy" floods the queue, then tenant "light" trickles in —
    the adversarial arrival order FCFS handles worst."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_heavy + n_light):
        plen = int(rng.integers(1, prompt_len + 1))
        reqs.append(Request(
            i, rng.integers(0, vocab, size=plen).astype(np.int32),
            max_new_tokens=max_new,
            tenant="heavy" if i < n_heavy else "light"))
    return reqs


def run_mode(model, params, reqs, *, mode, slots, max_len, policy="fcfs"):
    eng = ServeEngine(model, params, ServeConfig(
        batch_slots=slots, max_len=max_len, mode=mode, policy=policy))
    # warmup: compile every step shape this engine will hit
    eng.submit(Request(-1, np.asarray(reqs[0].prompt), max_new_tokens=2))
    eng.run()
    for r in reqs:
        eng.submit(r)
    lat = []  # per-token latency: tick duration attributed to its tokens
    t0 = time.perf_counter()
    while eng.queue or any(r is not None for r in eng.active):
        t1 = time.perf_counter()
        emitted = eng.step()
        dt = time.perf_counter() - t1
        lat.extend([dt / max(emitted, 1)] * emitted)
    wall = time.perf_counter() - t0
    done = [r for r in eng._finished if r.req_id >= 0]
    toks = sum(len(r.output) for r in done)
    # chunked prefill can emit first tokens inside step()'s admission —
    # they are counted by emitted, so lat covers every output token
    lat = np.asarray(lat) if lat else np.asarray([wall])
    out = {
        "requests": len(done),
        "tokens": int(toks),
        "wall_s": wall,
        "tok_per_s": toks / max(wall, 1e-9),
        "p50_token_latency_s": float(np.percentile(lat, 50)),
        "p99_token_latency_s": float(np.percentile(lat, 99)),
    }
    out.update(request_latency_stats(done))
    return out


def run_fairness(model, params, reqs, *, policy, slots, max_len):
    """Two-tenant flood under one admission policy.  Reports the heavy
    tenant's maximum slot share *while the light tenant has work queued*
    (the DRF bound) and each tenant's TTFT percentiles."""
    eng = ServeEngine(model, params, ServeConfig(
        batch_slots=slots, max_len=max_len, policy=policy))
    eng.submit(Request(-1, np.asarray(reqs[0].prompt), max_new_tokens=2))
    eng.run()
    for r in reqs:
        eng.submit(r)
    max_heavy_share = 0.0
    while eng.queue or any(r is not None for r in eng.active):
        eng.step()
        light_waiting = (any(r.tenant == "light" for r in eng.queue)
                         or any(r is not None and r.tenant == "light"
                                for r in eng.active))
        if light_waiting:
            heavy = sum(1 for r in eng.active
                        if r is not None and r.tenant == "heavy")
            max_heavy_share = max(max_heavy_share, heavy / slots)
    done = [r for r in eng._finished if r.req_id >= 0]
    out = {"max_heavy_slot_share": max_heavy_share}
    for tenant in ("heavy", "light"):
        sub = [r for r in done if r.tenant == tenant]
        out.update({f"{tenant}_{k}": v
                    for k, v in request_latency_stats(sub).items()})
    # position of the light tenant's first completion (0 = first overall)
    out["light_first_finish_index"] = next(
        (i for i, r in enumerate(done) if r.tenant == "light"), -1)
    return out


def run(dry: bool = True, slots: int = 4, max_len: int = 128):
    cfg = dataclasses.replace(get_config("internlm2-1.8b", smoke=True),
                              num_layers=2, vocab_size=64)
    model = LM(cfg, RuntimeKnobs(cache_dtype=jnp.float32))
    params = model.init(jax.random.PRNGKey(0))

    if dry:
        trace_kw = dict(n_short=6, n_long=2, short_prompt=6, long_prompt=48,
                        max_new=4)
        flood_kw = dict(n_heavy=8, n_light=3, prompt_len=4, max_new=4)
    else:
        trace_kw = dict(n_short=24, n_long=6, short_prompt=8, long_prompt=96,
                        max_new=8)
        flood_kw = dict(n_heavy=20, n_light=5, prompt_len=6, max_new=6)
    results = {"trace": trace_kw, "slots": slots, "max_len": max_len}
    for mode in ("wave", "continuous"):
        reqs = mixed_trace(vocab=cfg.vocab_size, **trace_kw)
        r = run_mode(model, params, reqs, mode=mode, slots=slots,
                     max_len=max_len)
        results[mode] = r
        print(f"{mode:10s}: {r['tokens']} tok in {r['wall_s']:.2f}s "
              f"-> {r['tok_per_s']:.1f} tok/s, p50 "
              f"{r['p50_token_latency_s'] * 1e3:.1f}ms, p99 "
              f"{r['p99_token_latency_s'] * 1e3:.1f}ms, ttft p50/p99 "
              f"{r['p50_ttft_s'] * 1e3:.0f}/{r['p99_ttft_s'] * 1e3:.0f}ms")
    speedup = (results["continuous"]["tok_per_s"]
               / max(results["wave"]["tok_per_s"], 1e-9))
    results["continuous_speedup"] = speedup
    print(f"continuous/wave speedup: {speedup:.2f}x")

    # two-tenant flood: fcfs starves the light tenant, drf-fair bounds the
    # heavy tenant's slot share while light work is queued
    results["flood"] = {"trace": flood_kw}
    for policy in ("fcfs", "drf-fair"):
        reqs = flood_trace(vocab=cfg.vocab_size, **flood_kw)
        f = run_fairness(model, params, reqs, policy=policy, slots=slots,
                         max_len=max_len)
        results["flood"][policy] = f
        print(f"flood/{policy:9s}: max heavy share "
              f"{f['max_heavy_slot_share']:.2f}, light ttft p99 "
              f"{f['light_p99_ttft_s'] * 1e3:.0f}ms, light first finish "
              f"#{f['light_first_finish_index']}")
    fcfs, drf = results["flood"]["fcfs"], results["flood"]["drf-fair"]
    # dry (CI smoke) runs must not clobber the tracked full-trace snapshot
    emit_json("serve_throughput_dry" if dry else "serve_throughput", results)
    # the qualitative claims this benchmark gates: continuous batching
    # beats wave batching on a mixed-length trace, and DRF admission
    # bounds the flooding tenant's share where FCFS lets it starve others
    assert speedup >= 1.5, f"continuous batching only {speedup:.2f}x wave"
    assert fcfs["max_heavy_slot_share"] >= 0.99, \
        "flood trace too mild: fcfs never saturated the slots"
    assert drf["max_heavy_slot_share"] <= 0.75, \
        f"drf-fair heavy share {drf['max_heavy_slot_share']:.2f} unbounded"
    # completion order is deterministic (TTFT seconds are reported but
    # wall-clock noisy at dry scale): under drf the light tenant finishes
    # work while fcfs still drains the flood
    assert (drf["light_first_finish_index"]
            < fcfs["light_first_finish_index"]), \
        "drf-fair did not admit the light tenant ahead of the flood"
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry", action="store_true",
                    help="fast CI mode: tiny trace")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()
    run(dry=args.dry, slots=args.slots, max_len=args.max_len)


if __name__ == "__main__":
    main()
